"""The 2-D placement layer (plan.Placement, DESIGN.md §11).

In-process tests cover the pure rules (gs_specs, pad_lanes, placement
strings, ``as_placement`` normalization) plus the ExecutorCache
concurrency/eviction satellites on the one device conftest pins.  The
real 2-D acceptance — ``(4, 2)`` runs of suites/demo.json and
suites/widelane.json bit-identical to the single-device planner on all
four backends, warm repeats compiling nothing — runs in a subprocess
with 8 forced host devices, like the other sharded acceptance tests.
"""
import os
import subprocess
import sys
import textwrap
import threading

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import (ExecutorCache, Placement, ShardedExecutor, SuitePlan,
                        as_placement, execute_bucket, make_pattern,
                        pad_lanes, run_suite)
from repro.core.plan import ExecKey
from repro.runtime.sharding import gs_specs

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
SUITES = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                      "suites"))


def _key(i: int = 0, batch: int = 4, placement: str = "") -> ExecKey:
    return ExecKey(backend="xla", kind="gather", idx_len=64 * (i + 1),
                   footprint=64, dtype="float32", row_width=1, mode="",
                   batch=batch, placement=placement)


# ---------------------------------------------------------------------------
# pure rules: pad_lanes, gs_specs, placement strings
# ---------------------------------------------------------------------------

def test_pad_lanes():
    # identity on pow2 lane dims with pow2 shard counts (the 1-D cases)
    assert pad_lanes(256) == 256
    assert pad_lanes(256, 8) == 256
    assert pad_lanes(100) == 128
    # non-pow2 lane shards: smallest shard multiple >= the pow-2 bracket
    assert pad_lanes(256, 3) == 258
    assert pad_lanes(100, 3) == 129
    with pytest.raises(ValueError):
        pad_lanes(0)
    with pytest.raises(ValueError):
        pad_lanes(8, 0)


def test_gs_specs_2d():
    # batched, both axes live: batch on dim 0 everywhere, lane on the lane
    # dim of idx/vals/keep/gather-out; tables replicated over the lane axis
    in_sp, out_sp = gs_specs("gather", batched=True, batch_axis="b",
                             lane_axis="l")
    assert in_sp == (P("b"), P("b", "l")) and out_sp == P("b", "l")
    in_sp, out_sp = gs_specs("scatter", batched=True, batch_axis="b",
                             lane_axis="l")
    assert in_sp == (P("b"), P("b", "l"), P("b", "l"), P("b", "l"))
    assert out_sp == P("b")                 # any lane shard, any row
    # degenerate lane: exactly the PR 2 batch-only specs
    in_sp, out_sp = gs_specs("gather", batched=True, batch_axis="data")
    assert in_sp == (P("data"), P("data")) and out_sp == P("data")
    # degenerate batch: the lane-only (GSEngine.sharded) specs
    in_sp, out_sp = gs_specs("gather", batched=False, lane_axis="data")
    assert in_sp == (P(), P("data")) and out_sp == P("data")
    in_sp, out_sp = gs_specs("scatter", batched=False, lane_axis="data")
    assert in_sp == (P(), P("data"), P("data"), P("data"))
    assert out_sp == P()
    # a lane-only BATCHED launch: dim 0 unsharded, lane dim split
    in_sp, out_sp = gs_specs("gather", batched=True, lane_axis="l")
    assert in_sp == (P(), P(None, "l")) and out_sp == P(None, "l")
    with pytest.raises(ValueError):
        gs_specs("neither", batched=True, batch_axis="b")
    with pytest.raises(ValueError):        # no batch dim to shard unbatched
        gs_specs("gather", batched=False, batch_axis="b")


def test_placement_validation_and_strings():
    mesh = jax.make_mesh((1,), ("data",))
    p = Placement(mesh, batch_axis="data", lane_axis=None)
    assert p.grid == (1, 1)
    assert p.placement == "data=1/1dev"     # PR 2 canonical string
    lane = Placement(mesh, batch_axis=None, lane_axis="data")
    assert lane.grid == (1, 1)
    assert lane.placement == "lane:data=1/1dev"   # never collides w/ batch
    mesh2 = jax.make_mesh((1, 1), ("data", "lane"))
    both = Placement(mesh2, batch_axis="data", lane_axis="lane")
    assert both.grid == (1, 1)
    assert both.placement == "data=1xlane=1/1dev"
    with pytest.raises(ValueError):
        Placement(mesh, batch_axis=None, lane_axis=None)
    with pytest.raises(ValueError):
        Placement(mesh, batch_axis="data", lane_axis="data")
    with pytest.raises(ValueError):
        Placement(mesh, batch_axis="model")
    # legacy shim is the same layer
    assert isinstance(ShardedExecutor(mesh, "data"), Placement)
    with pytest.raises(ValueError):
        ShardedExecutor(mesh, axis="model")


def test_placement_create_normalizes_degenerate_axes():
    # (n, 1) and n give the SAME canonical placement (shared executables);
    # (1, n) is lane-only
    assert Placement.create(1).placement == "data=1/1dev"
    assert Placement.create((1, 1)).placement == "data=1/1dev"
    with pytest.raises(ValueError):
        Placement.create((0, 1))
    with pytest.raises(ValueError):
        Placement.create((1, 2, 3))
    with pytest.raises(ValueError, match="devices"):
        Placement.create((4096, 4096))


def test_as_placement_normalization():
    assert as_placement(None) is None
    assert as_placement(0) is None
    assert as_placement(()) is None
    p = as_placement(1)
    assert isinstance(p, Placement) and p.placement == "data=1/1dev"
    assert as_placement(p) is p
    mesh = jax.make_mesh((1,), ("x",))
    pm = as_placement(mesh, "x")
    assert pm.batch_axis == "x" and pm.lane_axis is None
    pt = as_placement((1, 1))
    assert pt.placement == "data=1/1dev"
    with pytest.raises(ValueError, match="devices"):
        as_placement((64, 64))


def test_run_suite_accepts_mesh_forms():
    pats = [make_pattern("UNIFORM:4:1", kind="gather", delta=4, count=16,
                         name="g"),
            make_pattern("UNIFORM:4:1", kind="scatter", delta=4, count=16,
                         name="s")]
    cache = ExecutorCache()
    s0 = run_suite(pats, backend="xla", runs=1, cache=cache, digest=True)
    d0 = [r.out_digest for r in s0.results]
    for mesh in (1, (1, 1), Placement.create(1),
                 jax.make_mesh((1,), ("data",))):
        s = run_suite(pats, backend="xla", runs=1, cache=cache, mesh=mesh,
                      digest=True)
        assert [r.out_digest for r in s.results] == d0
    # int/tuple normalization reuses ONE placement string -> one ExecKey
    # family per (shape), so the four runs above compiled at most twice
    # (unsharded + the shared data=1/1dev placement)
    assert {k.placement for k in cache._entries} == {"", "data=1/1dev"}


def test_engine_sharded_rejects_batch_placements():
    from repro.core import GSEngine
    p = make_pattern("UNIFORM:4:1", kind="gather", delta=4, count=16)
    eng = GSEngine(p)
    batchy = Placement(jax.make_mesh((1,), ("data",)), batch_axis="data")
    with pytest.raises(ValueError, match="lane-only"):
        eng.sharded(batchy)
    # a lane-only Placement is accepted and matches the unsharded run
    lane_only = Placement(jax.make_mesh((1,), ("l",)), batch_axis=None,
                          lane_axis="l")
    fn, args = eng.sharded(lane_only)
    ref_fn, ref_args = eng.build()
    np.testing.assert_array_equal(np.asarray(fn(*args)),
                                  np.asarray(ref_fn(*ref_args)))


# ---------------------------------------------------------------------------
# ExecutorCache: concurrent builds (satellite), best_batch index + eviction
# ---------------------------------------------------------------------------

def test_distinct_keys_build_in_parallel():
    # two threads miss on DIFFERENT keys: both builders must be in flight
    # at once (the old cache held the global lock across builder(), which
    # serialized every compile in the process and would deadlock this
    # barrier)
    cache = ExecutorCache()
    barrier = threading.Barrier(2, timeout=15)

    def builder():
        barrier.wait()
        return lambda: None

    errs = []

    def get(i):
        try:
            cache.get(_key(i), builder)
        except Exception as e:           # pragma: no cover - failure path
            errs.append(e)

    threads = [threading.Thread(target=get, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    assert not errs
    assert cache.stats().misses == 2 and len(cache) == 2


def test_same_key_race_builds_once():
    # N threads race on ONE key: exactly one builds (misses == 1), the
    # rest wait on the in-flight future and count as hits
    cache = ExecutorCache()
    started = threading.Event()
    release = threading.Event()
    builds = []

    def builder():
        started.set()
        assert release.wait(timeout=15)
        builds.append(1)
        return "the-exec"

    results = []

    def get():
        results.append(cache.get(_key(), builder))

    threads = [threading.Thread(target=get) for _ in range(4)]
    threads[0].start()
    assert started.wait(timeout=15)      # owner is inside builder()
    for t in threads[1:]:
        t.start()
    release.set()
    for t in threads:
        t.join(timeout=30)
    assert len(builds) == 1              # built at most once
    assert results == ["the-exec"] * 4
    st = cache.stats()
    assert st.misses == 1 and st.hits == 3


def test_clear_during_build_does_not_resurrect_entry():
    # clear() while a build is in flight outside the lock: the orphaned
    # build must NOT re-insert into the freshly reset cache (size > 0
    # with misses == 0 would break the exact-telemetry invariant), but
    # its waiters still receive the built fn
    cache = ExecutorCache()
    started = threading.Event()
    release = threading.Event()

    def builder():
        started.set()
        assert release.wait(timeout=15)
        return "built"

    out = []
    t = threading.Thread(target=lambda: out.append(cache.get(_key(),
                                                             builder)))
    t.start()
    assert started.wait(timeout=15)
    cache.clear()
    release.set()
    t.join(timeout=30)
    assert out == ["built"]              # the builder's caller got its fn
    st = cache.stats()
    assert st.size == 0 and st.misses == 0 and not cache._pending
    # the key compiles fresh afterwards
    assert cache.get(_key(), lambda: "fresh") == "fresh"
    assert cache.stats().misses == 1


def test_failed_build_propagates_and_is_not_cached():
    cache = ExecutorCache()

    def boom():
        raise RuntimeError("compile failed")

    with pytest.raises(RuntimeError, match="compile failed"):
        cache.get(_key(), boom)
    assert len(cache) == 0 and not cache._pending
    # a failed build counts NO miss: misses is the count of compiles
    # that produced an executable, so under fault injection the sum of
    # successful requests' per-request misses still equals the lifetime
    # delta exactly (DESIGN.md §14)
    assert cache.stats().misses == 0
    # the key stays buildable (a later good builder compiles it)
    assert cache.get(_key(), lambda: "ok") == "ok"
    assert cache.stats().misses == 1


def test_batch_hits_counter():
    # batch_hits counts launches actually SERVED by a larger warm
    # executable (serve_poly), not mere best_batch lookups
    cache = ExecutorCache()
    cache.get(_key(batch=8), lambda: "b8")
    assert cache.stats().batch_hits == 0
    assert cache.best_batch(_key(batch=4)).batch == 8     # pure lookup
    assert cache.stats().batch_hits == 0
    fn, served = cache.serve_poly(_key(batch=4), lambda: "b4")
    assert fn == "b8" and served.batch == 8               # cross-batch
    assert cache.stats().batch_hits == 1
    fn, served = cache.serve_poly(_key(batch=8), lambda: "b8x")
    assert fn == "b8" and served.batch == 8               # exact: no event
    assert cache.stats().batch_hits == 1
    fn, served = cache.serve_poly(_key(batch=16), lambda: "b16")
    assert fn == "b16" and served.batch == 16             # growth compiles
    st = cache.stats()
    assert st.batch_hits == 1 and st.misses == 2
    from repro.core import CacheStats
    assert st.delta(CacheStats(0, 0, 0, 0)).batch_hits == 1
    assert st.to_json()["batch_hits"] == 1


def test_family_index_survives_eviction():
    # best_batch consults an index keyed by batch-stripped key; eviction
    # must remove the evicted batch from its family or the lookup would
    # hand out keys whose executable is gone
    cache = ExecutorCache(maxsize=2)
    cache.get(_key(0, batch=8), lambda: "a8")
    cache.get(_key(1, batch=4), lambda: "b4")
    cache.get(_key(2, batch=4), lambda: "c4")      # evicts a8 (LRU)
    assert cache.best_batch(_key(0, batch=4)) is None
    assert cache.best_batch(_key(1, batch=2)).batch == 4   # still indexed
    cache.clear()
    assert cache.best_batch(_key(1, batch=2)) is None


def test_eviction_then_best_batch_recompiles_exactly_once():
    # satellite: evict the larger-batch executable mid-suite; the next
    # launch must recompile EXACTLY once (no best_batch ghost, no double
    # compile) and stay bit-identical to a fresh exact-size launch
    # strides 2..5 (delta 8, count 32) share one bucket: footprints
    # 263..284 all pad to 512, idx_len 256
    pats = [make_pattern(f"UNIFORM:8:{s}", kind="gather", delta=8, count=32,
                         name=f"g{s}") for s in (2, 3, 4, 5)]
    plan4 = SuitePlan.build(pats)
    plan2 = SuitePlan.build(pats[:2])
    assert plan4.n_buckets == plan2.n_buckets == 1
    cache = ExecutorCache(maxsize=1)
    execute_bucket(plan4, plan4.buckets[0], backend="xla", cache=cache)
    # an unrelated executable evicts the warm batch-4 gather (maxsize=1)
    spl = SuitePlan.build([make_pattern("UNIFORM:4:1", kind="scatter",
                                        delta=4, count=16, name="s")])
    execute_bucket(spl, spl.buckets[0], backend="xla", cache=cache)
    m = cache.stats().misses
    outs = execute_bucket(plan2, plan2.buckets[0], backend="xla",
                          cache=cache)
    assert cache.stats().misses == m + 1           # exactly one recompile
    refs = execute_bucket(plan2, plan2.buckets[0], backend="xla",
                          cache=ExecutorCache())
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o, r)
    # and the recompiled executable is warm for the repeat
    execute_bucket(plan2, plan2.buckets[0], backend="xla", cache=cache)
    assert cache.stats().misses == m + 1


# ---------------------------------------------------------------------------
# acceptance: 2-D placements, 8 fake devices, subprocess (own XLA_FLAGS)
# ---------------------------------------------------------------------------

ACCEPTANCE_2D = textwrap.dedent("""\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, sys
    sys.path.insert(0, %(src)r)
    import jax, numpy as np
    assert len(jax.devices()) == 8, jax.devices()
    from repro.core import (ExecutorCache, GSEngine, Placement, SuitePlan,
                            load_suite, make_pattern, run_suite)

    # count caps per backend: bit-identity is count-independent, and the
    # suites' full counts are an xla regime here (CI smokes them via the
    # CLI) — onehot materializes an (N, F) one-hot per pattern, scalar is
    # a per-lane loop, and pallas runs every grid step through the
    # interpreter off-TPU, so those three run the same suite FILES at
    # small counts.  A lane-sharded pallas launch routes through the §16
    # manual shard_map (each device runs the kernel on its local shard —
    # the old GSPMD-replicated caveat is retired), so pallas exercises
    # lane axes here too.
    CAPS = {"xla": 4096, "pallas": 128, "scalar": 256, "onehot": 256}
    SHAPES = {"xla": ((4, 2), (2, 4)), "pallas": ((4, 2), (1, 8)),
              "scalar": ((4, 2),), "onehot": ((4, 2),)}

    def capped(path, cap):
        return [dataclasses.replace(p, count=min(p.count, cap))
                for p in load_suite(path)]

    for name in ("demo", "widelane"):
        path = %(suites)r + "/" + name + ".json"
        for backend, cap in CAPS.items():
            pats = capped(path, cap)
            ref = run_suite(pats, backend=backend, runs=1,
                            cache=ExecutorCache(), digest=True)
            d_ref = [r.out_digest for r in ref.results]
            cache = ExecutorCache()
            for shape in SHAPES[backend]:
                got = run_suite(pats, backend=backend, runs=1, cache=cache,
                                mesh=shape, digest=True)
                assert [r.out_digest for r in got.results] == d_ref, (
                    name, backend, shape)
            # warm repeat on the 2-D placement: zero compiles
            m = cache.stats().misses
            again = run_suite(pats, backend=backend, runs=1, cache=cache,
                              mesh=(4, 2), digest=True)
            assert cache.stats().misses == m, (name, backend)
            assert [r.out_digest for r in again.results] == d_ref
        print(name, "OK")

    # §16 launch census: the lane-sharded pallas executable carries its
    # single pallas_call INSIDE a shard_map over the lane mesh — the
    # kernel really runs on every device, nothing falls back to a
    # GSPMD-partitioned (or replicated) top-level launch
    import jax.numpy as jnp
    from repro.core.plan import SuitePlan, enumerate_executables
    from repro.core.tracing import (count_primitives, shard_map_meshes,
                                    shard_map_pallas_calls)
    pats = capped(%(suites)r + "/demo.json", 128)
    plan = SuitePlan.build(pats)
    for shape in ((1, 8), (4, 2)):
        pl = Placement.create(shape)
        for key, builder, avals in enumerate_executables(
                plan, backend="pallas", dtype=jnp.float32, mode="store",
                placement=pl):
            jx = jax.make_jaxpr(builder())(*avals)
            # exactly one launch, and it lives INSIDE the shard_map body
            # (count_primitives walks the whole jaxpr, so total == inside
            # means no top-level GSPMD-routed launch remains)
            assert count_primitives(jx).get("pallas_call", 0) == 1, shape
            assert shard_map_pallas_calls(jx) == 1, (shape, key)
            meshes = shard_map_meshes(jx)
            assert any(m.get("lane") == shape[1] for m in meshes), (
                shape, meshes)
    print("census OK")

    # §16 auto placement: per-bucket "auto" equals its hand-placed twins
    # — same ExecKeys (the twin run compiles nothing on the same cache),
    # same digests
    from repro.core.plan import auto_placements
    for backend in ("xla", "pallas"):
        pats = capped(%(suites)r + "/demo.json", CAPS[backend])
        plan = SuitePlan.build(pats)
        ref = run_suite(pats, backend=backend, runs=1,
                        cache=ExecutorCache(), digest=True)
        d_ref = [r.out_digest for r in ref.results]
        cache = ExecutorCache()
        got = run_suite(pats, backend=backend, runs=1, cache=cache,
                        mesh="auto", digest=True)
        assert [r.out_digest for r in got.results] == d_ref, backend
        twins = auto_placements(plan, "auto", backend=backend)
        assert len(twins) == plan.n_buckets
        m = cache.stats().misses
        again = run_suite(pats, backend=backend, runs=1, cache=cache,
                          mesh=twins, digest=True)
        assert cache.stats().misses == m, backend     # identical ExecKeys
        assert [r.out_digest for r in again.results] == d_ref, backend
    print("auto twin OK")

    # non-pow2 lane axis: pad_lanes pads the launched lane dim to a shard
    # multiple; results still bit-identical
    pats = capped(%(suites)r + "/widelane.json", 512)
    ref = run_suite(pats, backend="xla", runs=1, cache=ExecutorCache(),
                    digest=True)
    got = run_suite(pats, backend="xla", runs=1, cache=ExecutorCache(),
                    mesh=(2, 3), digest=True)
    assert ([r.out_digest for r in got.results]
            == [r.out_digest for r in ref.results])

    # every cached executable still holds exactly one trace (exact-compile
    # -count invariant across 2-D shapes)
    cache = ExecutorCache()
    for shape in ((4, 2), (2, 4), (1, 8), (8, 1)):
        run_suite(pats, backend="xla", runs=1, cache=cache, mesh=shape)
    for fn in cache._entries.values():
        assert fn._cache_size() == 1

    # GSEngine.sharded through a lane-only placement matches its build()
    p = make_pattern("UNIFORM:8:1", kind="gather", delta=8, count=64,
                     name="lane")
    eng = GSEngine(p)
    fn, args = eng.sharded(Placement.create((1, 8)))
    ref_fn, ref_args = eng.build()
    np.testing.assert_array_equal(np.asarray(fn(*args)),
                                  np.asarray(ref_fn(*ref_args)))
    print("OK")
    """)


def test_acceptance_2d_placement_8dev_subprocess():
    code = ACCEPTANCE_2D % {"src": SRC, "suites": SUITES}
    r = subprocess.run([sys.executable, "-c", code],
                       capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
    assert "OK" in r.stdout
