"""Pure-jnp oracle for the scatter-add kernel."""
import jax
import jax.numpy as jnp


def scatter_add_rows_ref(idx: jax.Array, vals: jax.Array, v: int) -> jax.Array:
    """out = zeros(V, D); out[idx[i]] += vals[i]"""
    out = jnp.zeros((v, vals.shape[1]), dtype=vals.dtype)
    return out.at[idx].add(vals, mode="drop")


def scatter_store_rows_ref(dst: jax.Array, idx: jax.Array,
                           vals: jax.Array) -> jax.Array:
    """out = dst; out[idx[i]] = vals[i] — caller pre-deduped idx (at most
    one in-range occurrence per row), out-of-range lanes dropped."""
    return dst.at[idx].set(vals, mode="drop")
