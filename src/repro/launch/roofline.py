"""Roofline analysis from compiled HLO (DESIGN.md §8).

XLA's HloCostAnalysis counts a while-loop body ONCE, but every model here
scans over layer groups — so naive ``compiled.cost_analysis()`` undercounts
FLOPs by ~n_layers.  This module parses the optimized HLO text, walks the
computation graph (while bodies multiplied by parsed trip counts, fusion
and call bodies recursed), and accumulates:

  * dot FLOPs            (matmul-only, the standard MFU numerator)
  * op bytes             (operands + outputs of non-trivial ops — the
                          HloCostAnalysis "bytes accessed" convention)
  * collective traffic   (ring-model per-chip bytes by op kind/group size)

Hardware constants are TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

# shape/byte parsing is shared with core.tracing and analysis.cost
# (DESIGN.md §15); the old private names stay as aliases for callers
from repro.core.hlo import (DTYPE_BYTES as _DTYPE_BYTES,        # noqa: F401
                            SHAPE_RE as _SHAPE_RE,
                            shape_bytes as _shape_bytes,
                            shape_dims as _shape_dims)

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_SKIP_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "copy", "copy-start", "copy-done", "after-all", "partition-id",
    "replica-id", "iota", "broadcast", "reshape", "transpose",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# op line:  %name = <type> opcode(operands...), attrs...
# <type> may be a tuple type with layouts and /*index=N*/ comments; the
# opcode is the last lowercase identifier before the first argument paren.
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s*"
    r"([a-z][\w\-]*)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


@dataclasses.dataclass
class HloOp:
    name: str
    type_str: str
    opcode: str
    operands: list[str]
    attrs: str
    raw_operands: str = ""


@dataclasses.dataclass
class Collective:
    kind: str
    out_bytes: int
    group_size: int
    count: float          # multiplier (loop trip products)

    def ring_bytes(self) -> float:
        """Per-chip link traffic under a ring schedule."""
        g = max(2, self.group_size)
        b = self.out_bytes
        if self.kind == "all-reduce":
            return 2 * b * (g - 1) / g * self.count
        if self.kind == "all-gather":
            return b * (g - 1) / g * self.count
        if self.kind == "reduce-scatter":
            return b * (g - 1) * self.count     # out is shard-sized
        if self.kind == "all-to-all":
            return b * (g - 1) / g * self.count
        return b * self.count                    # collective-permute


def parse_computations(hlo: str) -> dict[str, list[HloOp]]:
    comps: dict[str, list[HloOp]] = {}
    current = None
    for line in hlo.splitlines():
        if current is None:
            # computation headers sit at column 0 and open a brace:
            #   %name (params...) -> type {      /  ENTRY %main (...) -> ... {
            s = line.rstrip()
            if s.endswith("{") and not s.startswith(("HloModule", "//")):
                m = _COMP_RE.match(s)
                if m:
                    current = m.group(1)
                    comps[current] = []
            continue
        if line.strip() == "}":
            current = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        # split operands from attrs at the matching close paren
        depth, cut = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    cut = i
                    break
        operand_str, attrs = rest[:cut], rest[cut + 1:]
        operands = re.findall(r"%([\w.\-]+)", operand_str)
        comps[current].append(HloOp(name, type_str.strip(), opcode,
                                    operands, attrs, operand_str))
    return comps


def _attr_comp(attrs: str, key: str) -> str | None:
    m = re.search(key + r"=%?([\w.\-]+)", attrs)
    return m.group(1) if m else None


def _trip_count(comps: dict, cond_name: str) -> int:
    """Max integer constant in the loop condition — the LT/LE bound.

    XLA canonicalizes counted loops to (i = 0; i < N; ++i); the bound N is
    the largest integer constant in the condition computation.
    """
    best = 1
    for op in comps.get(cond_name, []):
        if op.opcode == "constant":
            m = re.match(r"\s*(\d+)\s*$", op.raw_operands)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops(op: HloOp, shapes: dict[str, str]) -> float:
    out_elems = 1
    for d in _shape_dims(op.type_str):
        out_elems *= d
    lhs = shapes.get(op.operands[0]) if op.operands else None
    cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    k = 1
    if lhs and cdims and cdims.group(1):
        dims = _shape_dims(lhs)
        for ci in cdims.group(1).split(","):
            ci = int(ci)
            if ci < len(dims):
                k *= dims[ci]
    return 2.0 * out_elems * k


@dataclasses.dataclass
class ModuleCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collectives: list = dataclasses.field(default_factory=list)
    bytes_by_op: dict = dataclasses.field(default_factory=dict)
    flops_by_op: dict = dataclasses.field(default_factory=dict)

    @property
    def collective_bytes(self) -> float:
        return sum(c.ring_bytes() for c in self.collectives)

    def top_bytes(self, n: int = 12) -> list[tuple[str, float]]:
        return sorted(self.bytes_by_op.items(), key=lambda kv: -kv[1])[:n]


def _group_size(attrs: str, world: int) -> int:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:                      # iota form [groups, group_size]
        return int(m.group(2))
    return world


def analyze_module(hlo: str, world: int = 1,
                   entry: str | None = None) -> ModuleCost:
    comps = parse_computations(hlo)
    if not comps:
        return ModuleCost()
    if entry is None:
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
        entry = m.group(1) if m else next(iter(comps))

    cost = ModuleCost()
    visiting: set[str] = set()

    def walk(comp: str, mult: float):
        if comp not in comps or comp in visiting:
            return
        visiting.add(comp)
        shapes = {op.name: op.type_str for op in comps[comp]}
        for op in comps[comp]:
            oc = op.opcode
            if oc == "while":
                body = _attr_comp(op.attrs, "body")
                cond = _attr_comp(op.attrs, "condition")
                m = _TRIP_RE.search(op.attrs)   # XLA's own loop analysis
                if m:
                    trips = int(m.group(1))
                else:
                    trips = _trip_count(comps, cond) if cond else 1
                if body:
                    walk(body, mult * max(1, trips))
                continue
            if oc in ("call", "async-start"):
                tgt = _attr_comp(op.attrs, "to_apply") or \
                    _attr_comp(op.attrs, "calls")
                if tgt:
                    walk(tgt, mult)
            if oc == "conditional":
                for b in re.findall(r"%([\w.\-]+)", op.attrs):
                    if b in comps:
                        walk(b, mult)
                continue
            if oc == "fusion":
                tgt = _attr_comp(op.attrs, "calls")
                if tgt:
                    # only count dots inside fusions (fusion IO counted below)
                    inner_shapes = {o.name: o.type_str
                                    for o in comps.get(tgt, [])}
                    for o in comps.get(tgt, []):
                        if o.opcode == "dot":
                            f = mult * _dot_flops(o, inner_shapes)
                            cost.flops += f
                            cost.flops_by_op["fused-dot"] = \
                                cost.flops_by_op.get("fused-dot", 0.0) + f
            if oc == "dot":
                f = mult * _dot_flops(op, shapes)
                cost.flops += f
                cost.flops_by_op["dot"] = \
                    cost.flops_by_op.get("dot", 0.0) + f
            for ckind in _COLLECTIVES:
                if oc == ckind or oc == ckind + "-start":
                    cost.collectives.append(Collective(
                        kind=ckind,
                        out_bytes=_shape_bytes(op.type_str),
                        group_size=_group_size(op.attrs, world),
                        count=mult))
                    break
            if oc in _SKIP_OPS:
                continue
            b = _shape_bytes(op.type_str)
            for o in op.operands:
                if o in shapes:
                    b += _shape_bytes(shapes[o])
            cost.bytes_accessed += mult * b
            # attribute bytes to the op's jax-level name for hillclimbing
            m2 = re.search(r'op_name="jit\([\w.\-]+\)/([^"]*)"', op.attrs)
            tag = m2.group(1).split(" ")[0] if m2 else oc
            # strip trace prefixes to the semantic tail
            tag = tag.split("/")[-1][:60]
            cost.bytes_by_op[tag] = cost.bytes_by_op.get(tag, 0.0) + mult * b
        visiting.discard(comp)

    walk(entry, 1.0)
    return cost


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    hlo_flops: float              # per-chip
    hlo_bytes: float              # per-chip
    coll_bytes: float             # per-chip ring-model link traffic
    model_flops: float            # 6·N·D global
    per_device_hbm: float         # memory_analysis args+temps

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Perfect-overlap model: the dominant term IS the step time."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (per-chip HLO flops × chips)."""
        total_hlo = self.hlo_flops * self.n_chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of peak at the modeled step time (MFU
        upper bound given this lowering)."""
        t = self.step_time
        if t <= 0:
            return 0.0
        return (self.model_flops / self.n_chips) / (t * PEAK_FLOPS)

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.n_chips,
            "hlo_gflops_per_chip": self.hlo_flops / 1e9,
            "hlo_gbytes_per_chip": self.hlo_bytes / 1e9,
            "coll_gbytes_per_chip": self.coll_bytes / 1e9,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "model_gflops": self.model_flops / 1e9,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "per_device_hbm_gb": self.per_device_hbm / 1e9,
        }
