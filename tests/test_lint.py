"""spatterlint (repro/analysis, DESIGN.md §12).

Three layers of coverage:

* seeded-violation fixtures — one per rule — proving each rule actually
  FIRES on the defect it encodes (a lint that can't fail is decoration);
* clean-path audits: the shipped suites, the live cache, and the current
  serving layer all lint clean;
* schema/infrastructure: the jax-free report import (mirroring
  test_serve's client drift guard), placement-string parsing, and the
  exit codes of the CLI front-ends (8-dev matrix in a subprocess, like
  test_sharded_plan).
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
ROOT = os.path.dirname(SRC)

import jax                                    # noqa: E402
import jax.numpy as jnp                       # noqa: E402
import numpy as np                            # noqa: E402

from repro.analysis.ast_lint import lint_source       # noqa: E402
from repro.analysis.lint import (lint_cache, lint_plan, lint_serve,
                                 lint_suite_file, run_rules,
                                 unit_for)            # noqa: E402
from repro.analysis.report import LintReport, Violation   # noqa: E402
from repro.analysis.rules import (RULES, PAD_WASTE_BUDGET,
                                  PlanUnit)           # noqa: E402
from repro.core import ExecutorCache, SuitePlan, make_pattern  # noqa: E402
from repro.core.plan import (ExecKey, _raw_batched_fn,
                             enumerate_executables, placement_grid,
                             run_plan)                # noqa: E402

X = jnp.arange(8.0)


def _fired(violations, rule):
    hits = [v for v in violations if v.rule == rule]
    assert hits, f"rule {rule} did not fire: {violations}"
    return hits


# ---------------------------------------------------------------------------
# seeded-violation fixtures: every rule must fire on its defect
# ---------------------------------------------------------------------------

def test_rule_fires_no_sort_in_hot_path():
    unit = unit_for(jax.jit(jnp.sort), (X,), backend="xla", kind="gather")
    hits = _fired(run_rules(unit, ["no-sort-in-hot-path"]),
                  "no-sort-in-hot-path")
    assert "sort" in hits[0].location        # the offending equation


def test_rule_fires_single_pallas_call_per_bucket():
    # a pallas-keyed executable with ZERO kernel launches (and implicitly
    # the >1 case: want != got)
    unit = unit_for(jax.jit(lambda x: x + 1), (X,), backend="pallas",
                    kind="gather")
    hits = _fired(run_rules(unit, ["single-pallas-call-per-bucket"]),
                  "single-pallas-call-per-bucket")
    assert "expected 1" in hits[0].message


def test_rule_fires_lane_pallas_launch_outside_shard_map():
    # exactly one launch, but at TOP level of a lane-sharded key: the
    # count census passes and the §16 shard_map census must catch the
    # GSPMD-routed fallback (no real devices needed — the rule reads
    # the jaxpr, not the mesh)
    fn = jax.jit(_raw_batched_fn("pallas", "gather", ""))
    table = jnp.zeros((2, 9, 1))
    idx = jnp.zeros((2, 8), jnp.int32)
    unit = unit_for(fn, (table, idx), backend="pallas", kind="gather",
                    placement="lane:lane=2/2dev")
    hits = _fired(run_rules(unit, ["single-pallas-call-per-bucket"]),
                  "single-pallas-call-per-bucket")
    assert "GSPMD-routed" in hits[0].message
    # the same executable under an honest single-device key: clean
    ok = unit_for(fn, (table, idx), backend="pallas", kind="gather")
    assert run_rules(ok, ["single-pallas-call-per-bucket"]) == []


def test_rule_fires_no_host_callback():
    def cb(x):
        return np.asarray(x)

    fn = jax.jit(lambda x: jax.pure_callback(
        cb, jax.ShapeDtypeStruct(x.shape, x.dtype), x))
    unit = unit_for(fn, (X,), backend="xla", kind="gather")
    _fired(run_rules(
        unit, ["no-host-callback-or-device-put-in-timed-region"]),
        "no-host-callback-or-device-put-in-timed-region")


def test_rule_fires_on_device_put_in_timed_region():
    fn = jax.jit(lambda x: jax.device_put(x) * 2)
    unit = unit_for(fn, (X,), backend="xla", kind="gather")
    _fired(run_rules(
        unit, ["no-host-callback-or-device-put-in-timed-region"]),
        "no-host-callback-or-device-put-in-timed-region")


def test_rule_fires_donation_honored():
    # the PR 4 crash class, statically: a CACHED executable that donates
    # its dst would raise 'buffer deleted or donated' on the second call
    fn = jax.jit(_raw_batched_fn("xla", "scatter", "store"),
                 donate_argnums=(0,))
    dst = jnp.zeros((2, 9, 1))
    idx = jnp.zeros((2, 8), jnp.int32)
    vals = jnp.ones((2, 8, 1))
    keep = jnp.ones((2, 8), bool)
    args = (dst, idx, vals, keep)
    unit = unit_for(fn, args, backend="xla", kind="scatter", mode="store")
    _fired(run_rules(unit, ["donation-honored"]), "donation-honored")
    # the same executable is FINE outside the cache (engine semantics)
    free = unit_for(fn, args, backend="xla", kind="scatter", mode="store",
                    cached=False)
    assert run_rules(free, ["donation-honored"]) == []


def test_rule_fires_no_f64_promotion_drift():
    from jax.experimental import enable_x64
    with enable_x64():
        jaxpr = jax.make_jaxpr(lambda x: x * 2.0)(
            jnp.arange(8, dtype=jnp.float64))
    unit = unit_for(None, (jax.ShapeDtypeStruct((8,), np.float64),),
                    backend="xla", kind="gather", dtype="float32",
                    jaxpr=jaxpr)
    hits = _fired(run_rules(unit, ["no-f64-promotion-drift"]),
                  "no-f64-promotion-drift")
    assert "float64" in hits[0].message
    # declared f64 is allowed — the rule checks drift, not the dtype
    ok = unit_for(None, (jax.ShapeDtypeStruct((8,), np.float64),),
                  backend="xla", kind="gather", dtype="float64",
                  jaxpr=jaxpr)
    assert run_rules(ok, ["no-f64-promotion-drift"]) == []


def test_rule_fires_pad_waste_threshold():
    # one 33-lane pattern, batch-padded 8 wide: 33 real lanes of
    # 64 * 8 launched = ~94% waste, over budget
    skinny = make_pattern("UNIFORM:33:1", kind="gather", delta=1, count=1)
    plan = SuitePlan.build([skinny])
    assert plan.pad_waste(8, 1) > PAD_WASTE_BUDGET
    unit = PlanUnit(plan=plan, grid=(8, 1), label="fixture @ 8x1")
    hits = _fired(RULES["pad-waste-threshold"].check(unit),
                  "pad-waste-threshold")
    assert "budget" in hits[0].message
    # and within budget at its natural single-device placement
    assert RULES["pad-waste-threshold"].check(
        PlanUnit(plan=plan, grid=(1, 1), label="fixture @ 1x1")) == []


def test_rule_fires_sharding_spec_consistency():
    # key promises a 4x2 placement over 8 devices; the executable was
    # built unplaced — the lowered module has no partitions at all
    fn = jax.jit(_raw_batched_fn("xla", "gather", ""))
    table = jnp.zeros((4, 9, 1))
    idx = jnp.zeros((4, 8), jnp.int32)
    unit = unit_for(fn, (table, idx), backend="xla", kind="gather",
                    placement="data=4xlane=2/8dev")
    hits = _fired(run_rules(unit, ["sharding-spec-consistency"]),
                  "sharding-spec-consistency")
    assert "num_partitions" in hits[0].message
    # honest single-device key on the same executable: clean
    ok = unit_for(fn, (table, idx), backend="xla", kind="gather")
    assert run_rules(ok, ["sharding-spec-consistency"]) == []


def test_rule_fires_cache_key_purity():
    base = ExecKey(backend="xla", kind="gather", idx_len=8, footprint=8,
                   dtype="float32", row_width=1, mode="", batch=1,
                   placement="")
    plan = SuitePlan.build(
        [make_pattern("UNIFORM:8:1", kind="gather", delta=8, count=1)])

    def impure_enumerate():
        # an object identity leaking into the key: different every call
        key = dataclasses.replace(base,
                                  placement=f"mesh@{hex(id(object()))}")
        return [(key, None, ())]

    unit = PlanUnit(plan=plan, grid=(1, 1), label="fixture",
                    enumerate=impure_enumerate)
    hits = _fired(RULES["cache-key-purity"].check(unit),
                  "cache-key-purity")
    assert len(hits) >= 1


BAD_SERVE_SRC = textwrap.dedent("""\
    import threading
    import time


    class BadDaemon:
        def __init__(self):
            self._run_lock = threading.Lock()
            self.stats = {}
            self.n_requests = 0

        def record(self, key):
            with self._run_lock:
                self.stats[key] = 1
                self.n_requests += 1

        def evict(self, key):
            self.stats.pop(key, None)

        def bump(self):
            self.n_requests += 1

        def slow(self):
            with self._run_lock:
                time.sleep(5)
    """)


def test_rule_fires_serve_lock_discipline():
    violations = lint_source(BAD_SERVE_SRC, "bad_daemon.py")
    lock = _fired(violations, "serve-lock-discipline")
    # both unlocked mutations of guarded state are caught, with lines
    assert len(lock) == 2
    assert {v.location.split(":")[-1] for v in lock} == {"17", "20"}


def test_rule_fires_serve_blocking_under_lock():
    violations = lint_source(BAD_SERVE_SRC, "bad_daemon.py")
    hits = _fired(violations, "serve-blocking-under-lock")
    assert "sleep" in hits[0].message


BAD_SCHEDULER_SRC = textwrap.dedent("""\
    import threading
    import time


    class BadScheduler:
        def __init__(self):
            self._cv = threading.Condition()
            self.queue = []
            self.busy = 0

        def submit(self, item):
            with self._cv:
                self.queue.append(item)
                self._cv.notify_all()

        def steal(self, item):
            self.queue.remove(item)

        def tick(self):
            self.busy += 1

        def _drain_locked(self):
            self.busy -= 1
            time.sleep(1)

        def park(self, ev):
            with self._cv:
                self._cv.wait()
                self._cv.wait_for(lambda: self.queue)
                ev.wait()
    """)


def test_ast_lint_condition_variable_counts_as_lock():
    # the scheduler's idiom: with self._cv: acquires the Condition's
    # lock, so a mutation inside seeds guarded-attr inference and the
    # unguarded mutations elsewhere fire — extending the PR 6 lint to
    # cover serve/scheduler.py without annotations
    violations = lint_source(BAD_SCHEDULER_SRC, "bad_scheduler.py")
    lock = _fired(violations, "serve-lock-discipline")
    assert {v.location.split(":")[-1] for v in lock} == {"17", "20"}
    assert all("queue" in v.message or "busy" in v.message for v in lock)


def test_ast_lint_locked_suffix_method_convention():
    # *_locked methods assert caller-held locks: their mutations count
    # as guarded (busy -= 1 on line 23 must NOT fire) while blocking
    # calls inside them DO fire, same as a lexical with-block
    violations = lint_source(BAD_SCHEDULER_SRC, "bad_scheduler.py")
    lines = {v.location.split(":")[-1]
             for v in violations if v.rule == "serve-lock-discipline"}
    assert "23" not in lines
    blocking = _fired(violations, "serve-blocking-under-lock")
    assert any("sleep" in v.message and v.location.endswith(":24")
               for v in blocking)


def test_ast_lint_sanctions_wait_on_held_cv_only():
    # Condition.wait / wait_for on the HELD cv atomically release the
    # lock — the one blocking call cv code cannot exist without — but
    # ev.wait() under the cv is a genuine deadlock shape and stays
    # flagged
    violations = lint_source(BAD_SCHEDULER_SRC, "bad_scheduler.py")
    blocking = [v for v in violations
                if v.rule == "serve-blocking-under-lock"]
    flagged_lines = {v.location.split(":")[-1] for v in blocking}
    assert "28" not in flagged_lines          # self._cv.wait()
    assert "29" not in flagged_lines          # self._cv.wait_for(...)
    assert "30" in flagged_lines              # ev.wait() under the cv


def test_ast_lint_lock_token_matching_is_word_based():
    # "_recv" must not read as a cv; "state_cond" must — token-wise
    # matching, not substring soup
    from repro.analysis.ast_lint import _is_lock_expr
    import ast as _ast

    def expr(s):
        return _ast.parse(s, mode="eval").body

    assert not _is_lock_expr(expr("self._recv"))
    assert _is_lock_expr(expr("self._cv"))
    assert _is_lock_expr(expr("self.state_cond"))
    assert _is_lock_expr(expr("self._memo_lock"))
    assert not _is_lock_expr(expr("self.blocked"))


def test_rule_fires_canonical_exec_key():
    # a key a coalescing bug could mint: un-padded combined batch,
    # non-pow2 geometry, dtype alias, unparseable placement spelling
    from repro.core.plan import ExecKey
    from repro.analysis.rules import ExecUnit
    bad = ExecKey(backend="xla", kind="gather", idx_len=24, footprint=48,
                  dtype="f32", row_width=1, mode="", batch=6,
                  placement="mesh(4,2)")
    unit = ExecUnit(key=bad, builder=None, avals=())
    hits = _fired(run_rules(unit, ["canonical-exec-key"]),
                  "canonical-exec-key")
    msgs = " | ".join(v.message for v in hits)
    assert "bracket-stable" in msgs           # batch=6 not padded
    assert "pow-2" in msgs                    # idx_len=24 / footprint=48
    assert "canonical dtype" in msgs          # "f32" alias
    assert "placement" in msgs                # placement_grid can't parse


def test_canonical_exec_key_accepts_planner_keys_and_adhoc_units():
    from repro.core.plan import BucketSpec, bucket_key
    from repro.analysis.rules import ExecUnit
    # exactly what the hot path and a coalesced launch both mint
    good = bucket_key("xla", BucketSpec("scatter", 8, 16), jnp.float32,
                      1, "store", 6, None)     # batch 6 -> bracket 8
    assert good.batch == 8
    unit = ExecUnit(key=good, builder=None, avals=())
    assert run_rules(unit, ["canonical-exec-key"]) == []
    # unit_for's zeroed ad-hoc keys are out of scope, not violations
    adhoc = unit_for(jax.jit(lambda x: x + 1), (X,), backend="xla",
                     kind="gather")
    assert run_rules(adhoc, ["canonical-exec-key"]) == []


def test_ast_lint_allows_unguarded_by_design_state():
    # attributes never mutated under ANY lock are handler-local by
    # design (the daemon's server-thread handle): no false positive
    src = textwrap.dedent("""\
        class Daemon:
            def __init__(self):
                import threading
                self._memo_lock = threading.Lock()
                self.memo = {}
                self._thread = None

            def put(self, k, v):
                with self._memo_lock:
                    bounded_put(self.memo, k, v)

            def start(self):
                self._thread = object()

            def stop(self):
                self._thread = None
        """)
    assert lint_source(src, "good.py") == []


# ---------------------------------------------------------------------------
# clean paths: shipped code must lint clean
# ---------------------------------------------------------------------------

def test_current_serve_layer_passes_ast_lint():
    report = lint_serve()
    assert report.n_units >= 3                # daemon, client, schema, ...
    assert report.ok and report.violations == [], report.summary()


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_demo_suite_lints_clean(backend):
    report = lint_suite_file(os.path.join(ROOT, "suites", "demo.json"),
                             backends=(backend,))
    assert report.ok and report.n_violations == 0, report.summary()
    assert report.n_units > 1                 # buckets + the plan unit


def test_live_cache_lints_clean_and_readonly():
    cache = ExecutorCache()
    pats = [make_pattern("UNIFORM:8:1", kind="gather", delta=8, count=16),
            make_pattern("UNIFORM:8:2", kind="scatter", delta=2, count=16,
                         name="s")]
    run_plan(SuitePlan.build(pats), backend="xla", runs=1, cache=cache)
    before = cache.stats()
    report = lint_cache(cache)
    assert report.ok and report.n_units == before.size > 0
    # the audit must not perturb serving telemetry
    assert cache.stats() == before


def test_live_cache_lint_catches_poisoned_entry():
    # seed the cache with a donating executable under a planner-shaped
    # key: GET /lint's audit path must catch the PR 4 crash class
    cache = ExecutorCache()
    key = ExecKey(backend="xla", kind="scatter", idx_len=8, footprint=8,
                  dtype="float32", row_width=1, mode="store", batch=1,
                  placement="")
    bad = jax.jit(_raw_batched_fn("xla", "scatter", "store"),
                  donate_argnums=(0,))
    cache.get(key, lambda: bad)
    report = lint_cache(cache)
    assert not report.ok
    assert [v.rule for v in report.violations] == ["donation-honored"]


def test_enumeration_matches_live_cache_keys():
    # the static enumeration IS what the hot path compiles: same keys
    pats = [make_pattern("UNIFORM:8:1", kind="gather", delta=8, count=16),
            make_pattern("UNIFORM:8:2", kind="scatter", delta=2, count=16,
                         name="s")]
    plan = SuitePlan.build(pats)
    cache = ExecutorCache()
    run_plan(plan, backend="xla", runs=1, cache=cache)
    static = {k for k, _, _ in enumerate_executables(plan, backend="xla")}
    live = {k for k, _ in cache.entries()}
    assert static == live


def test_lint_plan_counts_units():
    pats = [make_pattern("UNIFORM:8:1", kind="gather", delta=8, count=16)]
    report = lint_plan(pats, backend="xla", label="inline")
    assert report.ok and report.n_units == 2          # 1 bucket + plan
    assert "no-sort-in-hot-path" in report.rules
    assert "pad-waste-threshold" in report.rules


# ---------------------------------------------------------------------------
# report schema: shared, jax-free, round-trippable
# ---------------------------------------------------------------------------

def test_report_schema_roundtrip_and_merge():
    v = Violation(rule="r", message="m", exec_key="k", location="l")
    r1 = LintReport(violations=[v], n_units=3, rules=("r",),
                    meta={"cells": [{"cell": "a"}]})
    r2 = LintReport(n_units=2, rules=("r", "s"),
                    meta={"cells": [{"cell": "b"}]})
    merged = r1.merge(r2)
    assert (merged.n_units, merged.n_violations) == (5, 1)
    assert merged.rules == ("r", "s")
    assert [c["cell"] for c in merged.meta["cells"]] == ["a", "b"]
    doc = json.loads(json.dumps(merged.to_json()))
    back = LintReport.from_json(doc)
    assert back.to_json() == merged.to_json()
    assert not back.ok and back.violations[0] == v
    # warnings don't fail the audit; unknown fields are rejected
    assert LintReport(violations=[Violation(
        rule="r", message="m", severity="warning")]).ok
    with pytest.raises(ValueError, match="unknown"):
        Violation.from_json({"rule": "r", "message": "m", "oops": 1})
    with pytest.raises(ValueError, match="severity"):
        Violation(rule="r", message="m", severity="fatal")


def test_report_and_ast_lint_import_jax_free():
    # the report schema is the wire format CI and dashboards parse; like
    # the serve client, parsing a lint report must not pay the jax import
    code = ("import sys; sys.path.insert(0, %r); "
            "import repro.analysis.report, repro.analysis.ast_lint; "
            "assert 'jax' not in sys.modules, 'analysis.report pulls jax'; "
            "r = repro.analysis.report.LintReport.from_json("
            "{'violations': [], 'n_units': 0}); "
            "assert r.ok; print('OK')" % SRC)
    r = subprocess.run([sys.executable, "-c", code],
                       capture_output=True, text=True, timeout=120)
    assert r.returncode == 0 and "OK" in r.stdout, r.stderr[-2000:]


def test_placement_grid_parses_canonical_strings():
    assert placement_grid("") == (1, 1, 1)
    assert placement_grid("data=8/8dev") == (8, 1, 8)
    assert placement_grid("lane:lane=8/8dev") == (1, 8, 8)
    assert placement_grid("data=4xlane=2/8dev") == (4, 2, 8)
    with pytest.raises(ValueError, match="placement"):
        placement_grid("not-a-placement")
    # round-trip against the writer on the one mesh tier-1 can build
    from repro.core.plan import Placement
    p = Placement.create(1)
    assert placement_grid(p.placement) == (*p.grid, 1)


# ---------------------------------------------------------------------------
# front-end exit codes (single-device paths)
# ---------------------------------------------------------------------------

def test_matrix_runner_unbuildable_cell_is_exit_2():
    from repro.analysis.__main__ import main
    rc = main(["--suite", os.path.join(ROOT, "suites", "demo.json"),
               "--mesh", "4096x1", "--backend", "xla"])
    assert rc == 2


def test_matrix_runner_clean_run_is_exit_0(tmp_path):
    from repro.analysis.__main__ import main
    out = str(tmp_path / "LINT_report.json")
    rc = main(["--suite", os.path.join(ROOT, "suites", "demo.json"),
               "--backend", "xla", "--out", out])
    assert rc == 0
    doc = json.load(open(out))
    assert doc["ok"] is True and doc["n_units"] > 0
    # serve lint rides along by default
    assert "serve-lock-discipline" in doc["rules"]


# ---------------------------------------------------------------------------
# acceptance: 8-device matrix + seeded pad-waste violation through the
# real front-ends, in a subprocess (tier-1 sees one device)
# ---------------------------------------------------------------------------

MATRIX_8DEV = textwrap.dedent("""\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, %(src)r)
    import json, tempfile
    import jax
    assert len(jax.devices()) == 8, jax.devices()

    from repro.core.plan import Placement, placement_grid

    # placement_grid round-trips every canonical placement form
    for shape in (8, (8, 1), (4, 2), (1, 8)):
        p = Placement.create(shape)
        b, l, nd = placement_grid(p.placement)
        assert (b, l) == p.grid and nd == len(p.mesh.devices.flat), \\
            (shape, p.placement)

    # real placed executables lint clean (positive half of
    # sharding-spec-consistency: the lowered modules DO carry the tile)
    from repro.analysis.lint import lint_suite_file
    for mesh in ((8, 1), (4, 2), (1, 8)):
        r = lint_suite_file(%(demo)r, mesh=mesh)
        assert r.ok and r.n_violations == 0, r.summary()

    # seeded §16 violations: the rule halves that walk INTO shard_map
    # bodies, which need a real lane mesh to trace
    import jax.numpy as jnp
    from repro.analysis.lint import run_rules, unit_for
    from repro.core import make_pattern
    from repro.core.plan import SuitePlan, enumerate_executables

    plan = SuitePlan.build([make_pattern("UNIFORM:8:1", kind="gather",
                                         delta=8, count=64, name="g")])
    key, builder, avals = next(iter(enumerate_executables(
        plan, backend="pallas", dtype=jnp.float32, mode="store",
        placement=Placement.create((1, 8)))))
    lane_fn = builder()

    # (1) double launch: two shard_map'd kernels per bucket — the count
    # census sees both because the walk descends into shard_map bodies
    double = jax.jit(lambda *a: lane_fn(*a) + lane_fn(*a))
    unit = unit_for(double, avals, backend="pallas", kind="gather",
                    placement=key.placement)
    viol = run_rules(unit, ["single-pallas-call-per-bucket"])
    assert any("2 pallas_call" in v.message for v in viol), viol

    # (2) mesh drift: the executable shard_maps over {lane: 8} but the
    # key placement promises a 4x2 split — same device count, so only
    # the shard_map-mesh census can tell them apart
    lying = unit_for(lane_fn, avals, backend="pallas", kind="gather",
                     placement="data=4xlane=2/8dev")
    viol = run_rules(lying, ["sharding-spec-consistency"])
    assert any("shard_map splits axes" in v.message for v in viol), viol
    # honest key on the same executable: the shard_map census is clean
    honest = unit_for(lane_fn, avals, backend="pallas", kind="gather",
                      placement=key.placement)
    assert not any("shard_map" in v.message
                   for v in run_rules(honest,
                                      ["sharding-spec-consistency"]))

    # seeded pad-waste violation through both CLI front-ends: exit 1
    bad = [{"name": "skinny", "kernel": "Gather",
            "pattern": "UNIFORM:33:1", "delta": 1, "count": 1}]
    with tempfile.TemporaryDirectory() as td:
        suite = os.path.join(td, "bad.json")
        out = os.path.join(td, "report.json")
        json.dump(bad, open(suite, "w"))

        from repro.analysis.__main__ import main
        rc = main(["--suite", suite, "--mesh", "8x1",
                   "--backend", "xla", "--out", out])
        assert rc == 1, rc
        doc = json.load(open(out))
        assert doc["ok"] is False
        assert any(v["rule"] == "pad-waste-threshold"
                   for v in doc["violations"]), doc

        sys.path.insert(0, %(examples)r)
        import spatter_cli
        sys.argv = ["spatter_cli.py", "--lint", suite, "--mesh", "8x1",
                    "--backend", "xla"]
        try:
            spatter_cli.main()
            raise AssertionError("expected SystemExit(1)")
        except SystemExit as e:
            assert e.code == 1, e.code
    print("OK")
    """)


def test_acceptance_lint_matrix_8dev_subprocess():
    code = MATRIX_8DEV % {
        "src": SRC,
        "demo": os.path.join(ROOT, "suites", "demo.json"),
        "examples": os.path.join(ROOT, "examples"),
    }
    r = subprocess.run([sys.executable, "-c", code],
                       capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
    assert "OK" in r.stdout
