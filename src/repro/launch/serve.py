"""Batched serving driver: prefill + decode with the paged-KV gather path.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --batch 4 --prompt-len 32 --gen 16

Demonstrates the serving loop the decode_32k / long_500k dry-run cells
lower: batched prefill (cache build), then token-by-token decode where each
step is one Spatter gather pass over the KV cache.  --paged routes
attention through the Pallas paged_decode kernel (interpret mode on CPU).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.zoo import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    max_len = args.prompt_len + args.gen

    b = args.batch
    prompts = jnp.asarray(
        rng.integers(2, cfg.vocab, (b, args.prompt_len)), jnp.int32)

    # -- prefill ---------------------------------------------------------------
    t0 = time.perf_counter()
    if cfg.family == "audio":
        frames = jnp.asarray(
            0.01 * rng.standard_normal(
                (b, args.prompt_len // cfg.frame_ratio, cfg.d_model)),
            jnp.dtype(cfg.dtype))
        # audio prefill encodes frames only — it produces no logits, so the
        # decoder must start from BOS (token 1, the data-pipeline convention)
        # rather than argmax over a zero placeholder (which always emitted 0)
        logits, cache = model.prefill(params, {"frames": frames,
                                               "max_len": max_len})
        start_pos = 0
    else:
        cache = model.init_cache(b, max_len)
        logits, caches_pre = model.prefill(params, {"tokens": prompts})
        # prefill returns seq-length caches; decode needs max_len slots:
        # write the prefill K/V into the preallocated cache
        def splice(full, pre):
            if full.shape == pre.shape:
                return pre
            pad = [(0, f - p) for f, p in zip(full.shape, pre.shape)]
            return jnp.pad(pre, pad).astype(full.dtype)
        cache = jax.tree.map(splice, cache, caches_pre)
        start_pos = args.prompt_len
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"[serve] prefill: {b}x{args.prompt_len} in {t_prefill*1e3:.1f} ms")

    # -- decode ----------------------------------------------------------------
    step = jax.jit(lambda p, c, t, q: model.decode_step(p, c, t, q))
    tok = jnp.argmax(logits, -1).astype(jnp.int32).reshape(b, 1) \
        if logits is not None else jnp.ones((b, 1), jnp.int32)   # BOS
    generated = []
    t0 = time.perf_counter()
    for i in range(args.gen):
        logits, cache = step(params, cache, tok, jnp.int32(start_pos + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32).reshape(b, 1)
        generated.append(np.asarray(tok[:, 0]))
    jax.block_until_ready(logits)
    t_dec = time.perf_counter() - t0
    toks_s = b * args.gen / t_dec
    print(f"[serve] decode: {args.gen} steps x batch {b} in "
          f"{t_dec*1e3:.1f} ms  ({toks_s:.1f} tok/s)")
    print("[serve] sample:", np.stack(generated, 1)[0][:12])


if __name__ == "__main__":
    main()
