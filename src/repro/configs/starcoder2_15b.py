"""starcoder2-15b [dense] — 40L d6144 48H GQA kv=4 d_ff=24576 vocab=49152.

GQA, RoPE, plain-GELU FFN (non-gated). [arXiv:2402.19173; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4,
    d_ff=24576, vocab=49152, head_dim=128,
    attn_kind="full", rope="full", mlp_kind="gelu",
)

SMOKE = ModelConfig(
    arch_id="starcoder2-15b-smoke", family="dense",
    n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab=256, head_dim=12,
    attn_kind="full", rope="full", mlp_kind="gelu", attn_chunk=16,
)
