"""spatterlint report schema — the ONE document shape every front-end
shares (DESIGN.md §12).

The daemon's ``GET /lint``, the ``spatter --lint`` CLI, and the CI matrix
runner (``python -m repro.analysis``) all emit this document, so a
violation looks the same wherever it was found.  Like
``serve/schema.py``, this module is deliberately **jax-free**: a CI step
or dashboard that only wants to parse a lint report must not pay the
multi-second jax import (tests/test_lint.py pins this with the same
subprocess drift guard as the serve client's).

Wire form::

    {"ok": false,
     "n_units": 12,                      # executables/plans/files audited
     "n_violations": 1,
     "rules": ["no-sort-in-hot-path", ...],
     "meta": {"cells": [...]},           # matrix provenance (optional)
     "violations": [
        {"rule": "no-sort-in-hot-path",
         "severity": "error",
         "exec_key": "xla/scatter idx=64 fp=32 f32 r1 store b4 @single",
         "location": "a:f32[8] = sort[...] b",     # offending eqn / file:line
         "message": "1 sort primitive(s) in a timed executable: ..."}]}
"""
from __future__ import annotations

import dataclasses
import json

SEVERITIES = ("error", "warning")


@dataclasses.dataclass(frozen=True)
class Violation:
    """One rule firing once: what broke, where, and the evidence."""
    rule: str
    message: str
    exec_key: str = ""        # ExecKey string / plan label / source file
    location: str = ""        # offending equation, HLO marker, or file:line
    severity: str = "error"

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity must be one of {SEVERITIES}, "
                             f"got {self.severity!r}")

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(doc: dict) -> "Violation":
        fields = {f.name for f in dataclasses.fields(Violation)}
        unknown = set(doc) - fields
        if unknown:
            raise ValueError(f"unknown violation fields: {sorted(unknown)}")
        if "rule" not in doc or "message" not in doc:
            raise ValueError("violation needs at least rule + message")
        return Violation(**doc)

    def render(self) -> str:
        where = f" [{self.exec_key}]" if self.exec_key else ""
        loc = f"\n    at: {self.location}" if self.location else ""
        return f"{self.severity}: {self.rule}{where}: {self.message}{loc}"


@dataclasses.dataclass
class LintReport:
    """The audit result: violations plus how much was actually checked.

    ``n_units`` exists so "zero violations" is distinguishable from
    "checked nothing" — an empty matrix cell must not read as a clean
    bill of health.
    """
    violations: list[Violation] = dataclasses.field(default_factory=list)
    n_units: int = 0
    rules: tuple[str, ...] = ()
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        """True when no *error*-severity violation fired."""
        return not any(v.severity == "error" for v in self.violations)

    @property
    def n_violations(self) -> int:
        return len(self.violations)

    def merge(self, other: "LintReport") -> "LintReport":
        """Combine two audits (e.g. matrix cells) into one document."""
        meta: dict = {}
        cells = list(self.meta.get("cells", [])) \
            + list(other.meta.get("cells", []))
        for src in (self.meta, other.meta):
            for k, v in src.items():
                if k != "cells":
                    meta[k] = v
        if cells:
            meta["cells"] = cells
        return LintReport(
            violations=self.violations + other.violations,
            n_units=self.n_units + other.n_units,
            rules=self.rules + tuple(r for r in other.rules
                                     if r not in self.rules),
            meta=meta)

    def to_json(self) -> dict:
        return {
            "ok": self.ok,
            "n_units": self.n_units,
            "n_violations": self.n_violations,
            "rules": list(self.rules),
            "meta": self.meta,
            "violations": [v.to_json() for v in self.violations],
        }

    @staticmethod
    def from_json(doc: dict) -> "LintReport":
        return LintReport(
            violations=[Violation.from_json(v)
                        for v in doc.get("violations", [])],
            n_units=int(doc.get("n_units", 0)),
            rules=tuple(doc.get("rules", ())),
            meta=dict(doc.get("meta", {})))

    def summary(self) -> str:
        head = (f"spatterlint: {self.n_units} unit(s) audited, "
                f"{len(self.rules)} rule(s), "
                f"{self.n_violations} violation(s)")
        if not self.violations:
            return head + " — clean"
        return "\n".join([head] + [v.render() for v in self.violations])

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
            f.write("\n")
