"""spattercost (repro/analysis/cost.py, DESIGN.md §15).

Same three-layer discipline as test_lint.py:

* seeded-violation fixtures — each new rule (traffic-conservation,
  auto-placement-sane, cost-regression) proved to FIRE on the defect it
  encodes;
* clean paths: the shipped suites cost clean, the traffic model
  reconciles byte-for-byte against real lowered StableHLO, and
  ``mesh="auto"`` resolves to shapes whose ExecKeys match explicit-mesh
  runs (warm repeats compile 0, digests bit-identical);
* schema/infrastructure: jax-free module import, CostReport JSON
  roundtrip, baseline write/load, and the ``python -m repro.analysis
  --cost`` front-end's exit codes.
"""
import dataclasses
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
ROOT = os.path.dirname(SRC)
DEMO = os.path.join(ROOT, "suites", "demo.json")

import jax.numpy as jnp                                    # noqa: E402

from repro.analysis import cost as C                       # noqa: E402
from repro.analysis.lint import run_rules                  # noqa: E402
from repro.analysis.rules import RULES, ExecUnit, PlanUnit  # noqa: E402
from repro.core import ExecutorCache, load_suite, make_pattern, \
    run_suite                                              # noqa: E402
from repro.core.plan import (ExecKey, SuitePlan,
                             enumerate_executables)        # noqa: E402


def _fired(violations, rule):
    hits = [v for v in violations if v.rule == rule]
    assert hits, f"rule {rule} did not fire: {violations}"
    return hits


def _small_plan():
    pats = [make_pattern("UNIFORM:8:1", kind="gather", delta=8, count=512,
                         name="g"),
            make_pattern("UNIFORM:8:1", kind="scatter", delta=8, count=512,
                         name="s")]
    return SuitePlan.build(pats)


def _first_unit(plan, backend="xla", placement=None):
    key, builder, avals = next(iter(enumerate_executables(
        plan, backend=backend, dtype=jnp.float32, row_width=1,
        mode="store", placement=placement)))
    return ExecUnit(key=key, builder=builder, avals=avals)


# ---------------------------------------------------------------------------
# the traffic model itself (pure geometry, no devices)
# ---------------------------------------------------------------------------

def test_key_cost_gather_arithmetic():
    key = ExecKey(backend="xla", kind="gather", idx_len=32, footprint=16,
                  dtype="float32", row_width=1, mode="", batch=2,
                  placement="")
    uc = C.key_cost(key)
    assert uc.lanes == 32
    assert uc.index_bytes == 2 * 32 * 4
    assert uc.table_bytes == 2 * (16 + 1) * 4
    assert uc.keep_bytes == 0
    # table + idx -> lane data
    assert uc.io_bytes == uc.table_bytes + uc.index_bytes + 2 * 32 * 4
    assert uc.replicated_bytes == 0
    assert uc.device_bytes == uc.io_bytes


def test_key_cost_scatter_reads_and_writes_the_table():
    key = ExecKey(backend="xla", kind="scatter", idx_len=32, footprint=16,
                  dtype="float32", row_width=1, mode="store", batch=2,
                  placement="")
    uc = C.key_cost(key)
    assert uc.keep_bytes == 2 * 32          # one bool per lane element
    # dst + idx + vals + keep -> fresh dst-shaped result
    assert uc.io_bytes == 2 * uc.table_bytes + uc.index_bytes \
        + 2 * 32 * 4 + uc.keep_bytes


def test_lane_shards_replicate_the_table():
    plan = _small_plan()
    single = C.shape_cost(plan, (1, 1))
    split = C.shape_cost(plan, (1, 8))
    assert single["replicated_bytes"] == 0
    assert split["replicated_bytes"] > 0
    # useful bytes are placement-invariant; only overheads move
    assert split["useful_bytes"] == single["useful_bytes"]
    assert split["device_bytes"] > single["device_bytes"]


def test_lane_sharded_pallas_charges_no_replication():
    # §16: the pallas lane path is a manual shard_map — the table is
    # device-local by construction, so the GSPMD all-gather term the
    # other backends pay never materializes
    for kind, mode in (("gather", ""), ("scatter", "store")):
        key = ExecKey(backend="pallas", kind=kind, idx_len=64, footprint=16,
                      dtype="float32", row_width=1, mode=mode, batch=2,
                      placement="lane:lane=8/8dev")
        twin = dataclasses.replace(key, backend="xla")
        uc, uc_x = C.key_cost(key), C.key_cost(twin)
        assert uc.replicated_bytes == 0
        assert uc_x.replicated_bytes > 0
        # everything except the replication term stays backend-invariant
        assert uc.io_bytes == uc_x.io_bytes
        assert uc.device_bytes == uc.io_bytes
    # and the selection model therefore ranks lane splits differently
    # per backend: shape_cost must be told whose launch it is pricing
    plan = _small_plan()
    split_x = C.shape_cost(plan, (1, 8), backend="xla")
    split_p = C.shape_cost(plan, (1, 8), backend="pallas")
    assert split_x["replicated_bytes"] > 0
    assert split_p["replicated_bytes"] == 0
    assert split_p["device_bytes"] < split_x["device_bytes"]


def test_shape_cost_matches_key_cost_sum():
    plan = _small_plan()
    agg = C.shape_cost(plan, (1, 1))
    total = 0
    for key, _, _ in enumerate_executables(plan, backend="xla",
                                           dtype=jnp.float32, row_width=1,
                                           mode="store", placement=None):
        total += C.key_cost(key).io_bytes
    assert total == agg["io_bytes"]


# ---------------------------------------------------------------------------
# placement auto-selection
# ---------------------------------------------------------------------------

def test_select_shape_shipped_suites_prefer_single():
    # on the shipped suites every multi-device split inflates pad or
    # replicates tables: the model must pick (1, 1), matching the
    # recorded mesh sweep where "single" wins both axes
    for name in ("demo", "apps", "widelane"):
        plan = SuitePlan.build(load_suite(
            os.path.join(ROOT, "suites", name + ".json")))
        assert C.select_shape(plan, n_devices=8) == (1, 1), name


def test_select_shape_tie_breaks_toward_batch_shards():
    # 8 identical patterns -> one bucket of batch 8: splitting the batch
    # 8 ways moves zero extra bytes (a pure tie), and the tie-break must
    # take the free wall-time division, never a lane split
    pats = [make_pattern("UNIFORM:8:1", kind="gather", delta=8, count=512,
                         name=f"g{i}") for i in range(8)]
    plan = SuitePlan.build(pats)
    assert C.select_shape(plan, n_devices=8) == (8, 1)
    assert C.auto_placement(plan, n_devices=8) == (8, 1)


def test_auto_placement_single_is_none():
    plan = _small_plan()
    assert C.auto_placement(plan, n_devices=1) is None
    assert C.auto_placement(plan, n_devices=8) is None


def test_candidate_shapes():
    assert C.candidate_shapes(1) == [(1, 1)]
    assert set(C.candidate_shapes(8)) == {(1, 1), (1, 8), (2, 4), (4, 2),
                                          (8, 1)}


# ---------------------------------------------------------------------------
# seeded-violation fixtures: every new rule must fire on its defect
# ---------------------------------------------------------------------------

def test_rule_fires_traffic_conservation_overstated_key():
    plan = _small_plan()
    unit = _first_unit(plan)
    assert run_rules(unit, ["traffic-conservation"]) == []
    # a key that claims 8x the index length it lowered with is lying
    # about its geometry: predicted >> lowered
    lying = dataclasses.replace(unit.key, idx_len=unit.key.idx_len * 8)
    bad = ExecUnit(key=lying, builder=unit.builder, avals=unit.avals)
    hits = _fired(run_rules(bad, ["traffic-conservation"]),
                  "traffic-conservation")
    assert "overstates" in hits[0].message


def test_rule_fires_traffic_conservation_unaccounted_traffic():
    plan = _small_plan()
    unit = _first_unit(plan)
    # a key that understates its geometry leaves lowered bytes
    # unaccounted: lowered >> predicted
    lying = dataclasses.replace(unit.key, idx_len=unit.key.idx_len // 4,
                                footprint=unit.key.footprint // 4)
    bad = ExecUnit(key=lying, builder=unit.builder, avals=unit.avals)
    hits = _fired(run_rules(bad, ["traffic-conservation"]),
                  "traffic-conservation")
    assert "unaccounted" in hits[0].message


def test_rule_fires_cost_regression(tmp_path, monkeypatch):
    plan = _small_plan()
    unit = _first_unit(plan)
    io = C.key_cost(unit.key).io_bytes
    base = tmp_path / "COST_baseline.json"
    C.write_baseline({C.key_id(unit.key): io - 1}, str(base))
    monkeypatch.setenv(C.BASELINE_ENV, str(base))
    hits = _fired(run_rules(unit, ["cost-regression"]), "cost-regression")
    assert "baseline" in hits[0].message
    # exact match (or headroom) is clean
    C.write_baseline({C.key_id(unit.key): io}, str(base))
    assert run_rules(unit, ["cost-regression"]) == []


def test_rule_cost_regression_clean_when_nothing_committed(tmp_path,
                                                           monkeypatch):
    # pointing at a missing file gates nothing
    monkeypatch.setenv(C.BASELINE_ENV, str(tmp_path / "absent.json"))
    plan = _small_plan()
    assert run_rules(_first_unit(plan), ["cost-regression"]) == []


def _bench_doc(single, split):
    return {"backends": {"xla": {"hmean_measured_gbs": 1.0}},
            "mesh_sweep": {"n_dev": 8, "suites": {"demo": {
                "single": single, "shapes": {"8x1": split}}}}}


def test_rule_fires_auto_placement_sane(tmp_path, monkeypatch):
    plan = SuitePlan.build(load_suite(DEMO))
    unit = PlanUnit(plan=plan, grid=(1, 1),
                    label="suites/demo.json @ single backend=xla")
    # a sweep where the recorded 8x1 cell beats auto's "single" choice
    # on BOTH pad waste and GB/s: the model is measurably wrong
    bench = tmp_path / "BENCH_suite.json"
    bench.write_text(json.dumps(_bench_doc(
        {"pad_waste": 0.5, "hmean_gbs": 1.0},
        {"pad_waste": 0.1, "hmean_gbs": 2.0})))
    monkeypatch.setenv(C.BENCH_ENV, str(bench))
    hits = _fired(RULES["auto-placement-sane"].check(unit),
                  "auto-placement-sane")
    assert "dominated" in hits[0].message
    # ...and the real-world shape (single wins an axis) is clean
    bench.write_text(json.dumps(_bench_doc(
        {"pad_waste": 0.1, "hmean_gbs": 1.0},
        {"pad_waste": 0.5, "hmean_gbs": 2.0})))
    assert RULES["auto-placement-sane"].check(unit) == []


def test_rule_auto_placement_sane_clean_without_sweep(tmp_path,
                                                      monkeypatch):
    monkeypatch.setenv(C.BENCH_ENV, str(tmp_path / "absent.json"))
    plan = _small_plan()
    unit = PlanUnit(plan=plan, grid=(1, 1), label="fixture @ single")
    assert RULES["auto-placement-sane"].check(unit) == []


# ---------------------------------------------------------------------------
# clean paths: real lowered reconciliation + shipped artifacts
# ---------------------------------------------------------------------------

def test_cost_plan_demo_reconciles_and_is_clean():
    report = C.cost_plan(load_suite(DEMO), backend="xla",
                         label="suites/demo.json")
    assert report.ok, report.summary()
    assert report.n_units > 0
    for u in report.units:
        assert u.useful_bytes > 0
        assert u.pad_bytes >= 0
        copies = 2 if u.kind == "scatter" else 1   # dst read + result
        assert u.useful_bytes + u.pad_bytes + u.index_bytes \
            + copies * u.table_bytes + u.keep_bytes == u.io_bytes
        # the lowered StableHLO agrees with the predicted bytes within
        # the documented tolerance (keep-mask deficit allowed)
        tol = max(C.TRAFFIC_TOL * u.io_bytes, C.TRAFFIC_TOL_FLOOR)
        assert u.io_bytes - u.keep_bytes - tol <= u.lowered_bytes \
            <= u.io_bytes + tol


def test_cost_plan_calibrated_predictions():
    cal = C.Calibration(source="test", bw_gbs={"xla": 10.0}, n_dev=1)
    report = C.cost_plan(load_suite(DEMO), backend="xla",
                         calibration=cal, label="suites/demo.json")
    for u in report.units:
        # predicted = ceiling x useful/device fraction, so it can never
        # beat the calibrated roofline
        assert 0 < u.predicted_gbs < 10.0
        assert u.predicted_gbs == pytest.approx(
            10.0 * u.useful_bytes / u.device_bytes)


def test_cost_suite_file_auto_records_choice():
    report = C.cost_suite_file(DEMO, mesh="auto", backends=("xla",))
    assert report.ok, report.summary()
    # per-bucket auto on one device: every bucket resolves to "single"
    choices = report.meta["auto"][DEMO]["xla"]
    assert isinstance(choices, list) and choices
    assert all(c == "single" for c in choices)
    # auto resolved to single-device: unplaced ExecKeys
    assert all(u.placement == "" for u in report.units)


def test_cost_suite_file_auto_suite_records_choice():
    report = C.cost_suite_file(DEMO, mesh="auto-suite", backends=("xla",))
    assert report.ok, report.summary()
    # one suite-wide choice (the pre-PR-10 auto): a single string
    assert report.meta["auto"][DEMO]["xla"] == "single"
    assert all(u.placement == "" for u in report.units)


# ---------------------------------------------------------------------------
# mesh="auto" end-to-end: ExecKeys (and digests) match explicit runs
# ---------------------------------------------------------------------------

def test_run_suite_auto_mesh_matches_explicit():
    pats = [make_pattern("UNIFORM:8:1", kind="gather", delta=8, count=256,
                         name="g")]
    cache = ExecutorCache()
    explicit = run_suite(pats, runs=1, cache=cache, digest=True, mesh=None)
    warm = cache.stats().misses
    auto = run_suite(pats, runs=1, cache=cache, digest=True, mesh="auto")
    # the auto run resolved to the same placement: same ExecKeys, so a
    # warm cache compiles NOTHING for it...
    assert cache.stats().misses == warm
    # ...and the results are bit-identical
    assert [r.out_digest for r in auto.results] \
        == [r.out_digest for r in explicit.results]


# ---------------------------------------------------------------------------
# schema / report infrastructure
# ---------------------------------------------------------------------------

def test_cost_module_is_jax_free():
    code = ("import sys; import repro.analysis.cost; "
            "assert 'jax' not in sys.modules, 'cost imported jax'")
    env = dict(os.environ, PYTHONPATH=SRC)
    subprocess.run([sys.executable, "-c", code], check=True, env=env)


def test_cost_report_json_roundtrip():
    report = C.cost_plan(load_suite(DEMO), backend="xla",
                         label="suites/demo.json")
    doc = json.loads(json.dumps(report.to_json()))
    back = C.CostReport.from_json(doc)
    assert back.n_units == report.n_units
    assert back.ok == report.ok
    assert [u.exec_key for u in back.units] \
        == [u.exec_key for u in report.units]
    with pytest.raises(ValueError):
        C.CostReport.from_json({"unitz": []})
    with pytest.raises(ValueError):
        C.UnitCost.from_json({"exec_key": "k", "bogus": 1})


def test_baseline_roundtrip(tmp_path):
    path = tmp_path / "COST_baseline.json"
    C.write_baseline({"k1": 100, "k2": 200}, str(path),
                     meta={"suites": ["x"]})
    assert C.load_baseline(str(path)) == {"k1": 100, "k2": 200}
    doc = json.loads(path.read_text())
    assert doc["meta"]["suites"] == ["x"]


def test_committed_baseline_covers_the_matrix():
    # the repo ships COST_baseline.json; the demo suite's single-device
    # keys must all be present (the CI gate audits against it)
    base = C.load_baseline(os.path.join(ROOT, "COST_baseline.json"))
    assert base, "COST_baseline.json missing or empty"
    plan = SuitePlan.build(load_suite(DEMO))
    for key, _, _ in enumerate_executables(plan, backend="xla",
                                           dtype=jnp.float32, row_width=1,
                                           mode="store", placement=None):
        assert C.key_id(key) in base
        assert base[C.key_id(key)] == C.key_cost(key).io_bytes


def test_calibration_from_committed_bench():
    cal = C.Calibration.from_bench(os.path.join(ROOT, "BENCH_suite.json"))
    assert cal.bw_gbs.get("xla", 0) > 0
    assert cal.n_dev >= 1


def test_suite_stem():
    assert C.suite_stem("suites/demo.json @ single backend=xla") == "demo"
    assert C.suite_stem("no suite here") == ""


def test_module_cli_cost_matrix(tmp_path):
    # the CI front-end: one small cell, report written, exit 0
    from repro.analysis.__main__ import main
    out = tmp_path / "COST_report.json"
    base = tmp_path / "baseline.json"
    rc = main(["--cost", "--suite", DEMO, "--backend", "xla",
               "--out", str(out), "--write-baseline", str(base)])
    assert rc == 0
    doc = json.loads(out.read_text())
    assert doc["ok"] and doc["n_units"] > 0
    assert C.load_baseline(str(base))
