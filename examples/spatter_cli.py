"""The paper's CLI, reproduced (§3.4):

    ./spatter -k Gather -p UNIFORM:8:1 -d 8 -l $((2**24))
becomes
    PYTHONPATH=src python examples/spatter_cli.py -k Gather -p UNIFORM:8:1 \
        -d 8 -l 65536 [-b xla|onehot|scalar|pallas] [--json suites/x.json]

Prints the paper's outputs (min-time bandwidth) plus the TPU-model columns
(modeled v5e GB/s, tile efficiency, reuse factor).

Multi-device suites (--json mode): ``--mesh N`` splits every bucket
launch's pattern-batch dim over a 1-D mesh of N devices (the paper §3.4
thread-scaling story, scaled to devices; see the DESIGN NOTE in
core/plan.py).  On a CPU-only host, force fake devices first:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python examples/spatter_cli.py --json suite.json --mesh 8
"""
import argparse

import jax.numpy as jnp

from repro.core import GSEngine, load_suite, make_pattern, run_suite


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("-k", "--kernel", default="Gather",
                    choices=["Gather", "Scatter", "gather", "scatter"])
    ap.add_argument("-p", "--pattern", default="UNIFORM:8:1",
                    help="UNIFORM:N:S | MS1:N:B:G | LAPLACIAN:D:L:S | "
                         "BROADCAST:N:R | i0,i1,...")
    ap.add_argument("-d", "--delta", type=int, default=8)
    ap.add_argument("-l", "--count", type=int, default=1 << 16)
    ap.add_argument("-b", "--backend", default="xla",
                    choices=["xla", "onehot", "scalar", "pallas"])
    ap.add_argument("-r", "--runs", type=int, default=10,
                    help="min-of-K timing (paper §3.5, default 10)")
    ap.add_argument("--row-width", type=int, default=1,
                    help="TPU row granularity (1 = paper's scalar element)")
    ap.add_argument("--json", default=None,
                    help="run a JSON suite file instead (paper §3.3)")
    ap.add_argument("--no-batch", action="store_true",
                    help="suite mode: one compile per pattern instead of "
                         "the bucketed planner (plan.py)")
    ap.add_argument("--mesh", type=int, default=0, metavar="N",
                    help="suite mode: shard bucket launches' pattern-batch "
                         "dim over a 1-D mesh of N devices (0 = off)")
    args = ap.parse_args()

    mesh = None
    if args.mesh:
        if not args.json:
            ap.error("--mesh only applies to --json suite mode")
        if args.no_batch:
            ap.error("--mesh requires the bucketed planner (drop --no-batch)")
        import jax
        n_dev = len(jax.devices())
        if args.mesh > n_dev:
            ap.error(f"--mesh {args.mesh} > {n_dev} visible devices "
                     f"(set XLA_FLAGS=--xla_force_host_platform_device_"
                     f"count={args.mesh} on CPU)")
        mesh = jax.make_mesh((args.mesh,), ("data",))

    if args.json:
        stats = run_suite(load_suite(args.json), backend=args.backend,
                          runs=args.runs, row_width=args.row_width,
                          batch=not args.no_batch, mesh=mesh)
        print(f"{'name':24s} {'type':16s} {'cpu GB/s':>9s} {'v5e GB/s':>9s} "
              f"{'tile_eff':>8s}")
        for r in stats.results:
            print(f"{r.pattern.name:24s} {r.pattern.classify():16s} "
                  f"{r.measured_gbs:9.2f} {r.modeled_gbs:9.1f} "
                  f"{r.tile_efficiency:8.3f}")
        print(f"\nsuite: min {stats.min_gbs:.2f}  max {stats.max_gbs:.2f}  "
              f"harmonic-mean {stats.hmean_gbs:.2f} GB/s   (paper §3.5)")
        if stats.plan is not None:
            print(f"plan : {len(stats.results)} patterns -> "
                  f"{stats.plan.n_buckets} shape buckets "
                  f"(pad waste {stats.plan.pad_waste(args.mesh or 1):.1%})")
        if mesh is not None:
            print(f"mesh : pattern-batch dim sharded over {args.mesh} "
                  f"devices (aggregate GB/s above; per-device = /"
                  f"{args.mesh})")
        return

    p = make_pattern(args.pattern, kind=args.kernel.lower(),
                     delta=args.delta, count=args.count)
    print(f"pattern  : {list(p.index)}")
    print(f"type     : {p.classify()}   delta={p.delta}  count={p.count}")
    print(f"footprint: {p.footprint()} elems   reuse={p.reuse_factor():.2f}x")
    r = GSEngine(p, backend=args.backend,
                 row_width=args.row_width).run(runs=args.runs)
    print(f"time     : {r.time_s*1e6:.1f} us (min of {args.runs})")
    print(f"bandwidth: {r.measured_gbs:.2f} GB/s measured(cpu)   "
          f"{r.modeled_gbs:.1f} GB/s modeled(v5e)   "
          f"tile_eff={r.tile_efficiency:.3f}")


if __name__ == "__main__":
    main()
