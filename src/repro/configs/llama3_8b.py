"""llama3-8b [dense] — 32L d4096 32H GQA kv=8 d_ff=14336 vocab=128256.

GQA, 128k vocab, RoPE theta 500000. [arXiv:2407.21783]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256, head_dim=128,
    attn_kind="full", rope="full", rope_theta=500000.0, mlp_kind="swiglu",
)

SMOKE = ModelConfig(
    arch_id="llama3-8b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=160, vocab=512, head_dim=16,
    attn_kind="full", rope="full", rope_theta=500000.0, mlp_kind="swiglu",
    attn_chunk=16,
)
