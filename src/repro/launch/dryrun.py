"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The FIRST two lines below must run before ANY other import (jax locks the
device count on first init): they give this CPU-only container 512
placeholder devices so jax.make_mesh can build the production meshes.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all        # every live cell, subprocess-isolated

Each cell writes experiments/dryrun/<arch>__<shape>__<mesh>.json with
memory_analysis, cost_analysis, parsed roofline terms, and the collective
schedule — EXPERIMENTS.md §Dry-run and §Roofline are generated from these.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_skips
from repro.launch.mesh import make_production_mesh
from repro.launch import roofline as rl
from repro.models.zoo import Model, model_flops
from repro.optim import AdamWConfig
from repro.runtime.sharding import use_mesh, logical_to_spec
from repro.runtime.train import (assemble_train, batch_specs,
                                 shardings_from_axes, _AXES_LEAF)
from jax.sharding import NamedSharding, PartitionSpec as P

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _local_bytes(tree, shardings) -> float:
    """Exact per-device bytes of sharded abstract args (params/opt/cache)."""
    total = 0.0
    for av, sh in zip(jax.tree.leaves(tree), jax.tree.leaves(
            shardings, is_leaf=lambda x: isinstance(x, NamedSharding))):
        shape = av.shape
        spec = sh.spec if isinstance(sh, NamedSharding) else ()
        n = 1
        for i, d in enumerate(shape):
            s = spec[i] if i < len(spec) else None
            div = 1
            if s is not None:
                for ax in (s if isinstance(s, tuple) else (s,)):
                    div *= sh.mesh.shape[ax]
            n *= -(-d // div)
        total += n * av.dtype.itemsize
    return total


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: dict | None = None):
    """Lower + compile one cell. Returns (lowered, compiled, mesh, model)."""
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    skip = shape_skips(cfg, shape)
    if skip:
        raise SystemExit(f"SKIP: {skip}")
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = Model(cfg)
    specs = model.input_specs(shape)

    analytic = {}
    if shape.kind == "train":
        fn, (aparams, aopt), (p_sh, o_sh) = assemble_train(
            model, mesh, AdamWConfig(), abstract_batch=specs)
        analytic = {
            "params_gb": _local_bytes(aparams, p_sh) / 1e9,
            "opt_state_gb": (_local_bytes(aopt["m"], o_sh["m"])
                             + _local_bytes(aopt["v"], o_sh["v"])) / 1e9,
        }
        lowered = fn.lower(aparams, aopt, specs)
    elif shape.kind == "prefill":
        aparams = model.abstract_params()
        p_sh = shardings_from_axes(model.param_axes(), aparams, mesh)
        b_sh = batch_specs({k: v for k, v in specs.items()
                            if hasattr(v, "shape")}, mesh)

        def prefill(params, batch):
            with use_mesh(mesh):
                return model.prefill(params, dict(batch, **(
                    {"max_len": shape.seq_len} if model.cfg.family == "audio"
                    else {})))

        analytic = {"params_gb": _local_bytes(aparams, p_sh) / 1e9}
        lowered = jax.jit(prefill, in_shardings=(p_sh, b_sh)).lower(
            aparams, {k: v for k, v in specs.items() if hasattr(v, "shape")})
    else:  # decode
        aparams = model.abstract_params()
        p_sh = shardings_from_axes(model.param_axes(), aparams, mesh)
        acache = specs["cache"]
        c_axes = model.cache_axes()
        c_sh = jax.tree.map(
            lambda ax, av: NamedSharding(
                mesh, logical_to_spec(ax, av.shape, mesh, None)),
            c_axes, acache, is_leaf=_AXES_LEAF)
        tok_sh = batch_specs({"t": specs["tokens"]}, mesh)["t"]

        def serve_step(params, cache, tokens, pos):
            with use_mesh(mesh):
                return model.decode_step(params, cache, tokens, pos)

        analytic = {
            "params_gb": _local_bytes(aparams, p_sh) / 1e9,
            "kv_cache_gb": _local_bytes(acache, c_sh) / 1e9,
        }
        lowered = jax.jit(
            serve_step,
            in_shardings=(p_sh, c_sh, tok_sh, NamedSharding(mesh, P())),
            donate_argnums=(1,),
        ).lower(aparams, acache, specs["tokens"], specs["pos"])

    compiled = lowered.compile()
    return lowered, compiled, mesh, model, analytic


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str, overrides: dict | None = None,
             tag: str = "") -> dict:
    t0 = time.time()
    lowered, compiled, mesh, model, analytic = lower_cell(
        arch, shape_name, multi_pod, overrides)
    n_chips = mesh.devices.size
    mesh_name = "2x16x16" if multi_pod else "16x16"

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    mod_cost = rl.analyze_module(hlo, world=n_chips)
    shape = SHAPES[shape_name]
    mflops = model_flops(model.cfg, shape)

    per_device_hbm = float(
        getattr(mem, "argument_size_in_bytes", 0)
        + getattr(mem, "temp_size_in_bytes", 0))
    roof = rl.Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, n_chips=n_chips,
        hlo_flops=mod_cost.flops, hlo_bytes=mod_cost.bytes_accessed,
        coll_bytes=mod_cost.collective_bytes, model_flops=mflops,
        per_device_hbm=per_device_hbm)

    coll_summary = {}
    for c in mod_cost.collectives:
        key = f"{c.kind}(g={c.group_size})"
        coll_summary.setdefault(key, {"count": 0.0, "gbytes": 0.0})
        coll_summary[key]["count"] += c.count
        coll_summary[key]["gbytes"] += c.ring_bytes() / 1e9

    result = {
        "cell": {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "chips": n_chips},
        "compile_s": time.time() - t0,
        "memory_analysis": {
            "argument_size_gb": getattr(mem, "argument_size_in_bytes", 0) / 1e9,
            "temp_size_gb": getattr(mem, "temp_size_in_bytes", 0) / 1e9,
            "output_size_gb": getattr(mem, "output_size_in_bytes", 0) / 1e9,
            # CPU-backend caveat: XLA:CPU promotes bf16 dot operands to f32
            # and may materialize whole stacked-weight converts; TPU executes
            # bf16 natively, so temp_size over-reports vs the TPU target.
            "analytic_per_device": analytic,
        },
        "xla_cost_analysis": {
            "flops_per_chip_while_body_once": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "roofline": roof.row(),
        "collectives": coll_summary,
    }
    if overrides:
        result["overrides"] = {k: str(v) for k, v in overrides.items()}
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    fn = os.path.join(out_dir,
                      f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
    with open(fn, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[dryrun] {arch} {shape_name} {mesh_name}: compile "
          f"{result['compile_s']:.1f}s  dominant={roof.dominant}  "
          f"hbm/dev={per_device_hbm/1e9:.2f} GB")
    print(f"  memory_analysis: {mem}")
    return result


def live_cells():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape_name, shape in SHAPES.items():
            if shape_skips(cfg, shape):
                continue
            yield arch, shape_name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every live cell (subprocess isolated)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(OUT_DIR))
    ap.add_argument("--moe-impl", default=None,
                    choices=["gspmd_sort", "ep_shardmap"],
                    help="override cfg.moe_impl (perf variants)")
    ap.add_argument("--tag", default="", help="suffix for the output JSON")
    args = ap.parse_args()

    if args.all:
        failures = []
        for arch, shape_name in live_cells():
            meshes = [False, True] if args.both_meshes else [args.multi_pod]
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                out = os.path.join(
                    args.out, f"{arch}__{shape_name}__{mesh_name}.json")
                if os.path.exists(out):
                    print(f"[dryrun] cached: {out}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape_name,
                       "--out", args.out] + (["--multi-pod"] if mp else [])
                r = subprocess.run(cmd, env=dict(
                    os.environ, PYTHONPATH=os.environ.get("PYTHONPATH", "src")))
                if r.returncode != 0:
                    failures.append((arch, shape_name, mesh_name))
        if failures:
            print("FAILED CELLS:", failures)
            sys.exit(1)
        print("all cells compiled OK")
        return

    assert args.arch and args.shape, "--arch and --shape (or --all)"
    overrides = {"moe_impl": args.moe_impl} if args.moe_impl else None
    run_cell(args.arch, args.shape, args.multi_pod, args.out,
             overrides=overrides, tag=args.tag)


if __name__ == "__main__":
    main()
