"""Client for spatterd (stdlib http.client; see daemon.py / DESIGN.md §10).

Library::

    from repro.serve import SpatterClient
    c = SpatterClient("http://127.0.0.1:8089")
    r1 = c.run_suite(json.load(open("suites/demo.json")), runs=3)
    r2 = c.run_suite(json.load(open("suites/demo.json")), runs=3)
    assert r2["cache"]["misses"] == 0            # warm: zero compiles
    assert [t["digest"] for t in r1["stats"]["table"]] == \
           [t["digest"] for t in r2["stats"]["table"]]   # bit-identical

CLI::

    PYTHONPATH=src python -m repro.serve.client \
        --url http://127.0.0.1:8089 --json suites/demo.json [--mesh 8|4x2]
    PYTHONPATH=src python -m repro.serve.client \
        --url http://127.0.0.1:8089 --stats

Transport: ONE keep-alive ``http.client.HTTPConnection`` per
(client, thread) — spatterd speaks HTTP/1.1 with explicit framing
exactly so a benchmark loop or a polling monitor never pays per-request
TCP setup.  Connections live in ``threading.local`` storage because the
same ``SpatterClient`` is routinely shared across submitter threads
(bench_serve's closed-loop clients, the concurrent tests) and an
``http.client`` connection is not thread-safe.  Idempotent GETs
(health/cache/stats/lint) get a small bounded retry on connection
errors: a daemon restart or an idle-timeout reset shows up as a dead
cached socket, and remounting it is strictly better than failing a
read-only probe.  POSTs never retry on *network* errors — a /run may
have executed before the connection died, and replaying it would
silently double work.  A 503, though, is the daemon's own pre-execution
backpressure verdict (the run never touched a queue slot), so with
``retries_503 > 0`` the client retries it with jittered exponential
backoff floored by the server's ``Retry-After`` hint — the fleet-client
behavior (DESIGN.md §14); the default stays fail-fast.
"""
from __future__ import annotations

import argparse
import http.client
import json
import random
import threading
import time
from urllib.parse import urlsplit

from .schema import SuiteRequest, parse_mesh

# connection-error retries for idempotent GETs (total attempts = 1 + this)
GET_RETRIES = 2


def _retry_after_s(header: str | None) -> float | None:
    # delta-seconds form only; spatterd never emits the HTTP-date form
    if header is None:
        return None
    try:
        return max(0.0, float(header))
    except ValueError:
        return None


class ServerError(RuntimeError):
    """A failed spatterd exchange; ``.status`` is the HTTP code (0 when
    the daemon could not be reached at all), ``.doc`` the parsed error
    body when there was one, ``.retry_after`` the server's Retry-After
    hint in seconds (None when absent)."""

    def __init__(self, status: int, message: str, *,
                 doc: dict | None = None,
                 retry_after: float | None = None):
        prefix = f"spatterd returned {status}" if status \
            else "cannot reach spatterd"
        super().__init__(f"{prefix}: {message}")
        self.status = status
        self.doc = doc
        self.retry_after = retry_after


class SpatterClient:
    def __init__(self, url: str, timeout: float = 600.0, *,
                 retries_503: int = 0, backoff_base_s: float = 0.5,
                 backoff_cap_s: float = 30.0,
                 backoff_seed: int | None = None):
        self.url = url.rstrip("/")
        self.timeout = timeout
        self.retries_503 = retries_503
        self.backoff_base_s = backoff_base_s
        self.backoff_cap_s = backoff_cap_s
        self._rng = random.Random(backoff_seed)
        parts = urlsplit(self.url if "//" in self.url
                         else "//" + self.url)
        if parts.scheme not in ("", "http"):
            raise ValueError(f"unsupported URL scheme {parts.scheme!r}; "
                             f"spatterd speaks plain http")
        if not parts.hostname:
            raise ValueError(f"URL {url!r} has no host")
        self._host = parts.hostname
        self._port = parts.port if parts.port is not None else 80
        self._prefix = parts.path.rstrip("/")
        self._local = threading.local()

    # -- connection management ----------------------------------------------
    def _conn(self) -> http.client.HTTPConnection:
        conn = getattr(self._local, "conn", None)
        if conn is None:
            conn = http.client.HTTPConnection(self._host, self._port,
                                              timeout=self.timeout)
            self._local.conn = conn
        return conn

    def _drop(self) -> None:
        conn = getattr(self._local, "conn", None)
        if conn is not None:
            self._local.conn = None
            try:
                conn.close()
            except OSError:
                pass

    def close(self) -> None:
        """Close THIS thread's cached connection (each thread owns its
        own; a shared client's other threads are unaffected)."""
        self._drop()

    # -- transport -----------------------------------------------------------
    def _request(self, path: str, body: dict | None = None) -> dict:
        payload = None if body is None else json.dumps(body).encode()
        method = "GET" if payload is None else "POST"
        # GETs are idempotent by construction (the daemon's read-only
        # endpoints): retry across dead keep-alive sockets.  POST /run is
        # not: one attempt, the caller decides about replays.
        attempts = 1 + (GET_RETRIES if method == "GET" else 0)
        err: Exception | None = None
        conn_tries = 0
        tries_503 = 0
        while conn_tries < attempts:
            conn = self._conn()
            try:
                conn.request(method, self._prefix + path, body=payload,
                             headers={"Content-Type": "application/json"})
                resp = conn.getresponse()
                data = resp.read()
            except (http.client.HTTPException, OSError) as e:
                # covers ConnectionError/reset/refused, timeouts, and
                # half-closed keep-alive sockets (BadStatusLine /
                # RemoteDisconnected); drop the socket and maybe retry
                self._drop()
                err = e
                conn_tries += 1
                continue
            if resp.will_close:
                self._drop()
            retry_after = _retry_after_s(resp.getheader("Retry-After"))
            if (resp.status == 503 and method == "POST"
                    and tries_503 < self.retries_503):
                # 503 is the daemon's PRE-execution verdict (queue full /
                # draining): the run never started, so this is the one
                # POST replay that cannot double work
                time.sleep(self._backoff_s(tries_503, retry_after))
                tries_503 += 1
                continue
            if resp.status >= 400:
                doc = None
                try:
                    doc = json.loads(data)
                    msg = doc.get("error", "")
                except (ValueError, AttributeError):
                    msg = ""
                raise ServerError(resp.status,
                                  msg or f"{resp.status} {resp.reason}",
                                  doc=doc if isinstance(doc, dict) else None,
                                  retry_after=retry_after)
            return json.loads(data)
        raise ServerError(0, f"{self.url}: {err}")

    @staticmethod
    def _shape_suite(patterns, options) -> dict:
        if isinstance(patterns, str):
            patterns = json.loads(patterns)
        if isinstance(patterns, dict):          # envelope document
            return {**patterns, **options}
        return {"patterns": list(patterns), **options}

    def _backoff_s(self, attempt: int, retry_after: float | None) -> float:
        """Jittered exponential delay for 503 retry number ``attempt``,
        floored by the server's Retry-After hint, capped last so the
        client's patience bounds even a pathological server hint."""
        base = self.backoff_base_s * (2 ** attempt) * \
            (0.5 + self._rng.random())
        if retry_after is not None:
            base = max(base, retry_after)
        return min(base, self.backoff_cap_s)

    # -- endpoints -----------------------------------------------------------
    def health(self) -> dict:
        return self._request("/healthz")

    def readyz(self) -> dict:
        """Readiness document (GET /readyz).  Unlike the other verbs a
        not-ready 503 is a normal answer here, not a failure: the doc is
        returned either way and the caller reads ``doc["ready"]``."""
        try:
            return self._request("/readyz")
        except ServerError as e:
            if e.status == 503 and e.doc is not None:
                return e.doc
            raise

    def cache(self) -> dict:
        return self._request("/cache")

    def stats(self) -> dict:
        """Live serving stats (GET /stats): lifetime cache counters plus
        the scheduler snapshot — queue depth, worker occupancy, total and
        coalesced launch counts (null on a workers=0 daemon)."""
        return self._request("/stats")

    def lint(self) -> dict:
        """spatterlint audit of the daemon's live cache (GET /lint);
        the ``report`` field is an ``analysis.report.LintReport``
        document — parse it jax-free with ``LintReport.from_json``."""
        return self._request("/lint")

    def cost(self) -> dict:
        """spattercost traffic accounting of the daemon's live cache
        (GET /cost); the ``report`` field is an
        ``analysis.cost.CostReport`` document — parse it jax-free with
        ``CostReport.from_json``."""
        return self._request("/cost")

    def run_suite(self, patterns, **options) -> dict:
        """POST a suite; ``patterns`` is a list of suite-JSON dicts, a
        full ``{"patterns": [...], ...}`` envelope, or a JSON string of
        either, and ``options`` are the SuiteRequest fields (backend=,
        runs=, mode=, metric=, mesh=, stream_r=, ...) — keyword options
        override same-named envelope fields.

        The request is validated client-side first, so a typo'd option
        fails fast with the same message the server would give.
        """
        doc = self._shape_suite(patterns, options)
        return self._request("/run", SuiteRequest.from_json(doc).to_json())

    def warm(self, patterns, **options) -> dict:
        """POST a suite to /warm: compile (or disk-restore) and prime
        every executable the suite needs WITHOUT running a measured
        suite — the restart-recovery verb (DESIGN.md §14).  Same
        patterns/options shapes as :meth:`run_suite`."""
        doc = self._shape_suite(patterns, options)
        return self._request("/warm", SuiteRequest.from_json(doc).to_json())


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="POST a JSON suite to a running spatterd, or query "
                    "its serving stats")
    ap.add_argument("--url", default="http://127.0.0.1:8089")
    ap.add_argument("--json", default=None, help="suite file (paper §3.3)")
    ap.add_argument("--stats", action="store_true",
                    help="print the daemon's /stats document (cache "
                         "counters + scheduler queue/worker snapshot) "
                         "instead of posting a suite")
    ap.add_argument("--warm", action="store_true",
                    help="POST the suite to /warm (compile + prime every "
                         "executable, no measured runs) instead of /run")
    ap.add_argument("--deadline-ms", type=int, default=None,
                    help="per-request queue deadline; an expiry before "
                         "launch returns 504 without running anything")
    ap.add_argument("--retries-503", type=int, default=0,
                    help="retry a backpressure 503 this many times with "
                         "jittered exponential backoff (Retry-After "
                         "honored); default fail-fast")
    # option defaults are None = "not given": an envelope suite file's own
    # fields must not be silently overridden by CLI defaults
    ap.add_argument("-b", "--backend", default=None)
    ap.add_argument("-r", "--runs", type=int, default=None)
    ap.add_argument("--mode", default=None, help="scatter mode store|add")
    ap.add_argument("--mesh", type=parse_mesh, default=None,
                    metavar="N|BxL|auto",
                    help="shard over N devices (batch-only), a BxL "
                         "(batch x lane) 2-D placement (e.g. 4x2), "
                         "'auto' (per-bucket cost-model placement — the "
                         "default for unpinned requests), or "
                         "'auto-suite' (one cost-model shape suite-wide)")
    ap.add_argument("--row-width", type=int, default=None)
    ap.add_argument("--metric", default=None,
                    help="gbs column: measured|modeled")
    ap.add_argument("--seed", type=int, default=None,
                    help="host-buffer RNG seed")
    ap.add_argument("--stream-r", action="store_true",
                    help="include paper Eq. 1 Pearson R vs STREAM")
    ap.add_argument("--stream-n", type=int, default=None,
                    help="STREAM reference size (elements)")
    ap.add_argument("--no-digest", action="store_true",
                    help="skip the per-pattern output digests")
    args = ap.parse_args(argv)
    c = SpatterClient(args.url, retries_503=args.retries_503)
    if args.stats:
        if args.json is not None:
            ap.error("--stats is a read-only verb; drop --json")
        try:
            print(json.dumps(c.stats(), indent=2, sort_keys=True))
        except ServerError as e:
            raise SystemExit(f"error: {e}")
        return
    if args.json is None:
        ap.error("--json SUITE required (or use --stats)")
    opts = {name: v for name, v in
            [("backend", args.backend), ("runs", args.runs),
             ("mode", args.mode), ("mesh", args.mesh),
             ("row_width", args.row_width), ("metric", args.metric),
             ("seed", args.seed), ("stream_n", args.stream_n),
             ("deadline_ms", args.deadline_ms)]
            if v is not None}
    if args.stream_r:
        opts["stream_r"] = True
    if args.no_digest:
        opts["digest"] = False
    # ValueError covers client-side schema rejections AND a malformed
    # --json file (JSONDecodeError): both get the same clean one-liner
    # a server-rejected request would
    try:
        with open(args.json) as f:
            pats = json.load(f)
        if args.warm:
            print(json.dumps(c.warm(pats, **opts), indent=2,
                             sort_keys=True))
            return
        resp = c.run_suite(pats, **opts)
    except (ServerError, ValueError) as e:
        raise SystemExit(f"error: {e}")
    print_response(resp)


def print_response(resp: dict) -> None:
    stats, cache = resp["stats"], resp["cache"]

    def _n(x):
        # to_json serializes non-finite floats as null (strict JSON);
        # render them as nan rather than crashing the formatter
        return float("nan") if x is None else x

    print(f"{'name':24s} {'type':16s} {'cpu GB/s':>9s} {'v5e GB/s':>9s} "
          f"{'digest':>12s}")
    for row in stats["table"]:
        print(f"{row['name']:24s} {row['type']:16s} "
              f"{_n(row['measured_cpu_gbs']):9.2f} "
              f"{_n(row['modeled_v5e_gbs']):9.1f} "
              f"{(row['digest'] or '')[:12]:>12s}")
    extra = ""
    if stats.get("stream_gbs") is not None:
        # gate on stream_gbs: R itself may be null (NaN on a degenerate
        # suite) while the reference run still happened and is worth
        # showing — same gate as the local CLI path
        extra = (f"   stream {_n(stats['stream_gbs']):.2f} GB/s "
                 f"R={_n(stats['stream_r']):.3f}")
    print(f"\nsuite: min {_n(stats['min_gbs']):.2f}  "
          f"max {_n(stats['max_gbs']):.2f}  "
          f"harmonic-mean {_n(stats['hmean_gbs']):.2f} GB/s{extra}")
    sched = ""
    if resp.get("serve"):
        sv = resp["serve"]
        sched = (f"  queued {sv['queued_ms']:.0f}ms  "
                 f"launches {sv['launches']} "
                 f"({sv['coalesced_launches']} coalesced)")
    print(f"serve: {resp['plan']['n_buckets']} buckets  "
          f"pad waste {resp['plan']['pad_waste']:.1%}  "
          f"cache hits {cache['hits']} misses {cache['misses']} "
          f"(exact compiles this request)  {resp['elapsed_s']:.2f}s"
          f"{sched}")


if __name__ == "__main__":
    main()
