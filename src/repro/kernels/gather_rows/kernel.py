"""Scalar-prefetch DMA row gather — the TPU-native Spatter gather kernel.

Two regimes, mirroring the paper's cache-resident vs memory-resident split
(DESIGN.md §2):

  * ``dma``  — the table stays in HBM; the index buffer is scalar-prefetched
    into SMEM and drives the input ``BlockSpec.index_map``, so the *DMA
    engine itself* performs the gather, one (1, block_d) row-slice per grid
    step.  Pallas double-buffers these DMAs (the TPU analogue of the HW
    prefetcher studied in paper Fig 4).
  * ``vmem`` — small tables are staged whole into VMEM and gathered with an
    in-register ``take`` over ``block_n`` rows per step (the "cache-resident"
    regime: once the table is in VMEM, arbitrary reuse is free).

The CUDA backend's trick of staging the index buffer in shared memory (paper
§3.2) maps exactly onto scalar prefetch: indices live in SMEM for the whole
kernel invocation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _copy_row_kernel(idx_ref, table_blk, out_blk):
    # The gather already happened in the DMA (index_map read idx_ref);
    # the kernel body is a pure VMEM->VMEM tile copy.
    del idx_ref
    out_blk[...] = table_blk[...]


def gather_rows_dma(table: jax.Array, idx: jax.Array, *,
                    block_d: int, interpret: bool) -> jax.Array:
    """HBM-resident gather: grid (N, D/block_d), one table row-slice per step."""
    n = idx.shape[0]
    v, d = table.shape
    assert d % block_d == 0, (d, block_d)
    grid = (n, d // block_d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_d), lambda i, j, idx_ref: (idx_ref[i], j)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda i, j, idx_ref: (i, j)),
    )
    return pl.pallas_call(
        _copy_row_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), table.dtype),
        interpret=interpret,
    )(idx, table)


def _vmem_take_kernel(block_n: int, idx_ref, table_ref, out_ref):
    i = pl.program_id(0)
    rows = idx_ref[pl.ds(i * block_n, block_n)]
    out_ref[...] = jnp.take(table_ref[...], rows, axis=0)


def gather_rows_vmem(table: jax.Array, idx: jax.Array, *,
                     block_n: int, interpret: bool) -> jax.Array:
    """VMEM-resident gather: whole table in VMEM, block_n rows per step.

    Caller guarantees n % block_n == 0 (ops.py pads).
    """
    n = idx.shape[0]
    v, d = table.shape
    assert n % block_n == 0, (n, block_n)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n // block_n,),
        in_specs=[pl.BlockSpec((v, d), lambda i, idx_ref: (0, 0))],
        out_specs=pl.BlockSpec((block_n, d), lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_vmem_take_kernel, block_n),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), table.dtype),
        interpret=interpret,
    )(idx, table)
