"""falcon-mamba-7b [ssm] — 64L d4096 attention-free, vocab=65024, state=16.

Mamba-1 blocks: d_inner = 2*d_model, d_conv=4, selective scan.
[arXiv:2410.05355]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=65024,
    attn_kind="none", rope="none", mlp_kind="swiglu",
    ssm_state=16, ssm_conv=4, ssm_expand=2,
)

SMOKE = ModelConfig(
    arch_id="falcon-mamba-7b-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=256,
    attn_kind="none", rope="none", mlp_kind="swiglu",
    ssm_state=8, ssm_conv=4, ssm_expand=2,
)
