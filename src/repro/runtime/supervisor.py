"""Fault-tolerance supervisor: restart-from-checkpoint, stragglers, SIGTERM.

What runs on a real cluster and what is simulated here is explicit:

  * crash restart      — REAL: the supervisor catches any exception from the
    step function, restores the latest checkpoint, rebuilds the step, and
    resumes.  Tests kill a training subprocess and verify loss-continuity.
  * preemption         — REAL: SIGTERM triggers a final synchronous
    checkpoint before exit (the TPU-preemption contract).
  * straggler detection— REAL detection / SIMULATED remediation: per-step
    wall-time EWMA; a step exceeding ``straggler_factor``x the EWMA is
    logged and counted.  On a real multi-host cluster remediation would
    re-dispatch that host's data shard (the pipeline is deterministic
    exactly so this is possible); single-process we record the event.
  * elastic restart    — REAL: restore() re-derives shardings from logical
    rules against whatever mesh exists now (checkpoint/checkpointer.py),
    so a 512-chip checkpoint restarts on 256 chips.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable

from repro.checkpoint import CheckpointManager


@dataclasses.dataclass
class SupervisorConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep_n: int = 3
    max_restarts: int = 3
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2


@dataclasses.dataclass
class StepStats:
    step: int
    wall_s: float
    is_straggler: bool
    loss: float


class TrainSupervisor:
    """Drives (state, batch) -> state' step functions with FT semantics.

    ``build``: () -> (state, step_fn, pipeline_pos) — called at start and
    after every crash restore; it must consult the checkpoint manager.
    """

    def __init__(self, cfg: SupervisorConfig):
        self.cfg = cfg
        self.ckpt = CheckpointManager(cfg.ckpt_dir, keep_n=cfg.keep_n)
        self._ewma: float | None = None
        self.stats: list[StepStats] = []
        self.straggler_events: list[int] = []
        self._stop = False
        self._orig_handler = None

    # -- signals ----------------------------------------------------------------
    def install_sigterm(self, get_state: Callable[[], tuple]):
        def handler(signum, frame):
            self._stop = True
        self._orig_handler = signal.signal(signal.SIGTERM, handler)
        self._get_state = get_state

    # -- main loop ---------------------------------------------------------------
    def run(self, build: Callable, n_steps: int, log_every: int = 10):
        restarts = 0
        while True:
            try:
                state, step_fn, start_step = build(self.ckpt)
                self.install_sigterm(lambda: state)
                for i in range(start_step, n_steps):
                    t0 = time.perf_counter()
                    state, metrics = step_fn(state, i)
                    wall = time.perf_counter() - t0
                    straggler = False
                    if self._ewma is not None and \
                            wall > self.cfg.straggler_factor * self._ewma:
                        straggler = True
                        self.straggler_events.append(i)
                    self._ewma = (wall if self._ewma is None else
                                  (1 - self.cfg.ewma_alpha) * self._ewma
                                  + self.cfg.ewma_alpha * wall)
                    loss = float(metrics.get("loss", float("nan")))
                    self.stats.append(StepStats(i, wall, straggler, loss))
                    if i % log_every == 0:
                        print(f"[train] step {i:5d} loss {loss:8.4f} "
                              f"wall {wall*1e3:7.1f} ms"
                              + ("  STRAGGLER" if straggler else ""))
                    if (i + 1) % self.cfg.ckpt_every == 0:
                        self.ckpt.save_async(i + 1, state)
                    if self._stop:
                        print("[train] SIGTERM: final checkpoint at", i + 1)
                        self.ckpt.ckpt.save(i + 1, state)   # synchronous
                        return state
                self.ckpt.wait()
                return state
            except KeyboardInterrupt:
                raise
            except Exception as e:
                restarts += 1
                if restarts > self.cfg.max_restarts:
                    raise
                print(f"[train] CRASH ({type(e).__name__}: {e}); restart "
                      f"{restarts}/{self.cfg.max_restarts} from latest ckpt")
                continue
