"""Quickstart: the Spatter pattern language and engine in 40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import GSEngine, appdb, make_pattern, run_suite

# 1. The paper's own CLI example (§3.4), scaled to this host:
#    ./spatter -k Gather -p UNIFORM:8:1 -d 8 -l $((2**24))
p = make_pattern("UNIFORM:8:1", kind="gather", delta=8, count=2 ** 16)
r = GSEngine(p, backend="xla").run(runs=5)
print(f"STREAM-like gather: {r.measured_gbs:6.2f} GB/s measured(cpu)   "
      f"{r.modeled_gbs:7.1f} GB/s modeled(v5e)  tile_eff={r.tile_efficiency:.3f}")

# 2. A strided pattern: bandwidth halves per stride doubling (paper Fig 3)
for stride in (1, 2, 4, 8):
    p = make_pattern(f"UNIFORM:8:{stride}", delta=8 * stride, count=2 ** 14)
    r = GSEngine(p).run(runs=3)
    print(f"stride-{stride}: modeled(v5e) {r.modeled_gbs:7.1f} GB/s")

# 3. Application-derived patterns (paper Table 5) through the same engine
pats = appdb.scale_counts([appdb.get("PENNANT-G4"), appdb.get("AMG-G0"),
                           appdb.get("LULESH-G2")], 1 / 1024)
stats = run_suite(pats, runs=3)
for res in stats.results:
    print(f"{res.pattern.name:12s} [{res.pattern.classify():15s}] "
          f"{res.measured_gbs:6.2f} GB/s cpu  {res.modeled_gbs:7.1f} GB/s v5e")
print(f"suite harmonic mean: {stats.hmean_gbs:.2f} GB/s")

# 4. Custom pattern, scatter kernel, different backend
p = make_pattern("CUSTOM:0,4,8,12", kind="scatter", delta=1, count=4096)
r = GSEngine(p, backend="onehot", row_width=8).run(runs=3)
print(f"custom scatter (onehot backend, row=8): {r.measured_gbs:.2f} GB/s")
