"""Gather / scatter backends — the TPU adaptation of Spatter's backend set.

Paper backends -> this repo (DESIGN.md §2):

    OpenMP (compiler-vectorized)  ->  "xla"     jnp.take / .at[] — XLA's native
                                               gather/scatter lowering, i.e. what
                                               "the compiler" does with the access.
    CUDA (shared-mem index buf)   ->  "pallas"  explicit scalar-prefetch DMA kernel
                                               (index buffer in SMEM drives the DMA).
    Scalar (#pragma novec)        ->  "scalar"  lax.fori_loop of dynamic_slice,
                                               one row per step — the no-vector
                                               baseline.
    (no analogue on CPU/GPU)      ->  "onehot"  gather as one-hot MXU matmul — the
                                               TPU-only trick of turning data
                                               movement into dense compute.

All backends share one contract:

    gather(src, idx)            src: (F, R) table, idx: (N,) int32 -> (N, R)
    scatter(dst, idx, vals)     vals: (N, R) -> dst' (F, R); mode "store"|"add"

The *row* (R) is the TPU element unit (DESIGN.md §2): Spatter's 8-byte double
becomes a lane-aligned row here.  R=1 recovers the paper's scalar semantics.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

BACKENDS = ("xla", "onehot", "scalar", "pallas")

# Guard for the one-hot backend: a (N, F) one-hot with F beyond this is a
# mistake, not a benchmark (it would build a >2^31-element intermediate).
_ONEHOT_MAX_FOOTPRINT = 1 << 22


# ---------------------------------------------------------------------------
# Gather
# ---------------------------------------------------------------------------

def gather_xla(src: jax.Array, idx: jax.Array) -> jax.Array:
    """XLA-native gather — what the compiler emits for indexed loads."""
    return jnp.take(src, idx, axis=0)


def gather_onehot(src: jax.Array, idx: jax.Array) -> jax.Array:
    """Gather as one-hot matmul: out = onehot(idx) @ src.  MXU-resident on TPU."""
    f = src.shape[0]
    if f > _ONEHOT_MAX_FOOTPRINT:
        raise ValueError(f"onehot backend: footprint {f} too large")
    oh = jax.nn.one_hot(idx, f, dtype=src.dtype)
    return oh @ src


def gather_scalar(src: jax.Array, idx: jax.Array) -> jax.Array:
    """One row per loop step — the paper's non-vectorized Scalar backend."""
    n = idx.shape[0]
    r = src.shape[1]
    out = jnp.zeros((n, r), dtype=src.dtype)

    def body(i, out):
        row = lax.dynamic_slice(src, (idx[i], 0), (1, r))
        return lax.dynamic_update_slice(out, row, (i, 0))

    return lax.fori_loop(0, n, body, out)


def gather_pallas(src: jax.Array, idx: jax.Array) -> jax.Array:
    """Scalar-prefetch DMA gather (Pallas TPU kernel, interpret=True on CPU)."""
    from repro.kernels.gather_rows import ops as gather_ops
    return gather_ops.gather_rows(src, idx)


# ---------------------------------------------------------------------------
# Scatter
# ---------------------------------------------------------------------------

def _dedup_keep_last(idx: jax.Array, vals: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Mask out all but the last occurrence of each duplicate index.

    Gives deterministic last-write-wins store semantics on every backend
    (the paper's parallel scatter leaves duplicate order unspecified; we pin
    it down so backends are cross-checkable).
    """
    n = idx.shape[0]
    positions = jnp.arange(n, dtype=jnp.int32)
    # last position at which each index value occurs
    last_pos = jnp.full((n,), -1, dtype=jnp.int32)
    # segment_max over idx as segment ids is unbounded; instead compare pairwise
    # via sort: sort by (idx, pos); the last element of each run wins.
    order = jnp.lexsort((positions, idx))
    sidx = idx[order]
    is_last = jnp.concatenate([sidx[1:] != sidx[:-1], jnp.ones((1,), bool)])
    keep = jnp.zeros((n,), bool).at[order].set(is_last)
    del last_pos
    return keep, order


def scatter_xla(dst: jax.Array, idx: jax.Array, vals: jax.Array,
                mode: str = "store") -> jax.Array:
    if mode == "add":
        return dst.at[idx].add(vals)
    keep, _ = _dedup_keep_last(idx, vals)
    # route dropped writes to a scratch row one past the end
    f = dst.shape[0]
    padded = jnp.concatenate([dst, jnp.zeros((1, dst.shape[1]), dst.dtype)])
    safe_idx = jnp.where(keep, idx, f)
    return padded.at[safe_idx].set(vals)[:f]


def scatter_onehot(dst: jax.Array, idx: jax.Array, vals: jax.Array,
                   mode: str = "store") -> jax.Array:
    f = dst.shape[0]
    if f > _ONEHOT_MAX_FOOTPRINT:
        raise ValueError(f"onehot backend: footprint {f} too large")
    if mode == "add":
        oh = jax.nn.one_hot(idx, f, dtype=vals.dtype)      # (N, F)
        return dst + oh.T @ vals
    keep, _ = _dedup_keep_last(idx, vals)
    oh = jax.nn.one_hot(idx, f, dtype=vals.dtype) * keep[:, None].astype(vals.dtype)
    covered = jnp.clip(oh.sum(axis=0), 0, 1)[:, None]      # (F, 1) in {0,1}
    return dst * (1 - covered) + oh.T @ vals


def scatter_scalar(dst: jax.Array, idx: jax.Array, vals: jax.Array,
                   mode: str = "store") -> jax.Array:
    n = idx.shape[0]
    r = dst.shape[1]

    def body(i, dst):
        row = lax.dynamic_slice(vals, (i, 0), (1, r))
        if mode == "add":
            cur = lax.dynamic_slice(dst, (idx[i], 0), (1, r))
            row = row + cur
        return lax.dynamic_update_slice(dst, row, (idx[i], 0))

    return lax.fori_loop(0, n, body, dst)


def scatter_pallas(dst: jax.Array, idx: jax.Array, vals: jax.Array,
                   mode: str = "store") -> jax.Array:
    from repro.kernels.scatter_rows import ops as scatter_ops
    if mode == "add":
        return dst + scatter_ops.scatter_add_rows(idx, vals, dst.shape[0])
    # store semantics: dedup then delegate to the add kernel on a zero base,
    # masking covered rows.
    keep, _ = _dedup_keep_last(idx, vals)
    zeros = jnp.zeros_like(vals)
    masked_vals = jnp.where(keep[:, None], vals, zeros)
    written = scatter_ops.scatter_add_rows(idx, masked_vals, dst.shape[0])
    ones = jnp.where(keep[:, None], jnp.ones_like(vals[:, :1]), zeros[:, :1])
    covered = jnp.clip(
        scatter_ops.scatter_add_rows(idx, ones, dst.shape[0]), 0, 1)
    return dst * (1 - covered) + written


# ---------------------------------------------------------------------------
# Dispatch tables
# ---------------------------------------------------------------------------

GATHER_FNS: dict[str, Callable] = {
    "xla": gather_xla,
    "onehot": gather_onehot,
    "scalar": gather_scalar,
    "pallas": gather_pallas,
}

SCATTER_FNS: dict[str, Callable] = {
    "xla": scatter_xla,
    "onehot": scatter_onehot,
    "scalar": scatter_scalar,
    "pallas": scatter_pallas,
}


def gather(src: jax.Array, idx: jax.Array, *, backend: str = "xla") -> jax.Array:
    return GATHER_FNS[backend](src, idx)


def scatter(dst: jax.Array, idx: jax.Array, vals: jax.Array, *,
            mode: str = "store", backend: str = "xla") -> jax.Array:
    return SCATTER_FNS[backend](dst, idx, vals, mode)


# ---------------------------------------------------------------------------
# Batched dispatch (suite planner, core/plan.py): one vmapped launch runs a
# whole shape bucket of patterns.  Leading dim is the pattern-batch dim.
# ---------------------------------------------------------------------------

def gather_batched(src: jax.Array, idx: jax.Array, *,
                   backend: str = "xla") -> jax.Array:
    """src: (B, F, R), idx: (B, N) -> (B, N, R); one launch for B patterns."""
    return jax.vmap(lambda s, i: gather(s, i, backend=backend))(src, idx)


def scatter_batched(dst: jax.Array, idx: jax.Array, vals: jax.Array, *,
                    mode: str = "store", backend: str = "xla") -> jax.Array:
    """dst: (B, F, R), idx: (B, N), vals: (B, N, R) -> (B, F, R)."""
    return jax.vmap(
        lambda d, i, v: scatter(d, i, v, mode=mode, backend=backend)
    )(dst, idx, vals)
