"""Mamba-1 selective-state-space block (falcon-mamba-7b).

Attention-free: the paper's G/S technique applies only to the embedding/
logit layers of this family (DESIGN.md §6 arch-applicability).  The
selective scan is a sequential lax.scan carrying (B, d_inner, N) state —
the TPU-friendly constant-memory form (no (B, L, D, N) blow-up).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.runtime.sharding import constrain
from .common import ParamDef


def _d_inner(cfg) -> int:
    return cfg.ssm_expand * cfg.d_model


def _dt_rank(cfg) -> int:
    return cfg.ssm_dt_rank or (cfg.d_model + 15) // 16


def mamba_defs(cfg) -> dict:
    d, di, n, r, dc = (cfg.d_model, _d_inner(cfg), cfg.ssm_state,
                       _dt_rank(cfg), cfg.ssm_conv)
    return {
        "in_proj": ParamDef((d, 2 * di), ("embed", "rnn_width")),
        "conv_w": ParamDef((dc, di), ("conv", "rnn_width")),
        "conv_b": ParamDef((di,), ("rnn_width",), init="zeros"),
        "x_proj": ParamDef((di, r + 2 * n), ("rnn_width", None)),
        "dt_proj": ParamDef((r, di), (None, "rnn_width")),
        "dt_bias": ParamDef((di,), ("rnn_width",), init="zeros"),
        "a_log": ParamDef((di, n), ("rnn_width", "state"), init="zeros"),
        "d_skip": ParamDef((di,), ("rnn_width",), init="ones"),
        "out_proj": ParamDef((di, d), ("rnn_width", "embed")),
    }


def _ssm_inputs(cfg, p, u):
    """u (B,S,di) -> (dt, B_mat, C_mat) for the selective scan."""
    n, r = cfg.ssm_state, _dt_rank(cfg)
    xdbc = u @ p["x_proj"]                                  # (B,S,r+2n)
    dt_r, b_mat, c_mat = jnp.split(xdbc, [r, r + n], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["dt_proj"] + p["dt_bias"])  # (B,S,di)
    return dt, b_mat, c_mat


def _conv_causal(cfg, p, x, conv_state=None):
    """Depthwise causal conv1d. x (B,S,di). Returns (y, new_state)."""
    dc = cfg.ssm_conv
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], dc - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, x], axis=1)                  # (B,S+dc-1,di)
    y = sum(xp[:, i:i + x.shape[1]] * p["conv_w"][i] for i in range(dc))
    y = y + p["conv_b"]
    new_state = xp[:, -(dc - 1):] if dc > 1 else pad
    return y, new_state


def _scan_step(a_log, d_skip, carry, inp):
    """h' = exp(dt*A) h + dt*B*u ; y = C·h + D*u   (single timestep)."""
    h = carry                                               # (B, di, N)
    u_t, dt_t, b_t, c_t = inp   # (B,di) (B,di) (B,N) (B,N)
    a = -jnp.exp(a_log.astype(jnp.float32))                 # (di, N)
    da = jnp.exp(dt_t[..., None] * a)                       # (B,di,N)
    h = h * da + (dt_t * u_t)[..., None] * b_t[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, c_t) + d_skip * u_t
    return h, y


def mamba_apply(cfg, p: dict, x: jax.Array, *,
                use_scan_kernel: bool = False) -> jax.Array:
    """Full-sequence selective scan. x (B,S,d) -> (B,S,d).

    ``use_scan_kernel`` routes the recurrence through the fused Pallas
    kernel (kernels/selective_scan) — on TPU this removes the per-timestep
    HBM round-trips that dominate the XLA lax.scan lowering (§Perf
    iteration, falcon-mamba train_4k).  The XLA path remains the portable
    default (and what the CPU dry-run lowers).
    """
    b, s, _ = x.shape
    di, n = _d_inner(cfg), cfg.ssm_state
    xz = x @ p["in_proj"]
    u, z = jnp.split(xz, 2, axis=-1)                        # (B,S,di) each
    u = constrain(u, ("batch", "seq", "rnn_width"))
    u, _ = _conv_causal(cfg, p, u)
    u = jax.nn.silu(u)
    dt, b_mat, c_mat = _ssm_inputs(cfg, p, u)

    if use_scan_kernel:
        from repro.kernels.selective_scan import selective_scan
        a = -jnp.exp(p["a_log"].astype(jnp.float32)).T      # (N, di)
        ys, _ = selective_scan(u, dt, b_mat, c_mat, a,
                               p["d_skip"][None].astype(jnp.float32))
        y = ys.astype(x.dtype)
    else:
        h0 = jnp.zeros((b, di, n), jnp.float32)
        xs = (jnp.moveaxis(u, 1, 0).astype(jnp.float32),
              jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
              jnp.moveaxis(b_mat, 1, 0).astype(jnp.float32),
              jnp.moveaxis(c_mat, 1, 0).astype(jnp.float32))
        _, ys = jax.lax.scan(
            lambda c, i: _scan_step(p["a_log"], p["d_skip"], c, i), h0, xs)
        y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)          # (B,S,di)
    y = y * jax.nn.silu(z)
    return (y @ p["out_proj"]).astype(x.dtype)


def mamba_init_cache(cfg, batch: int, dtype):
    di = _d_inner(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
    }


def mamba_cache_axes():
    return {"conv": ("batch", "conv", "rnn_width"),
            "ssm": ("batch", "rnn_width", "state")}


def mamba_decode(cfg, p: dict, x: jax.Array, cache: dict):
    """Single-token state update: O(1) in context length — this is why the
    ssm family runs the long_500k cell."""
    xz = x @ p["in_proj"]                                   # (B,1,2di)
    u, z = jnp.split(xz, 2, axis=-1)
    u, conv_state = _conv_causal(cfg, p, u, cache["conv"])
    u = jax.nn.silu(u)
    dt, b_mat, c_mat = _ssm_inputs(cfg, p, u)
    h, y = _scan_step(p["a_log"], p["d_skip"], cache["ssm"],
                      (u[:, 0].astype(jnp.float32),
                       dt[:, 0].astype(jnp.float32),
                       b_mat[:, 0].astype(jnp.float32),
                       c_mat[:, 0].astype(jnp.float32)))
    y = y[:, None].astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    return out, {"conv": conv_state, "ssm": h}
