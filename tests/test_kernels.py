"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles.

All kernels run in interpret mode on CPU (the TPU lowering is exercised by
the same pallas_call + BlockSpec on real hardware).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gather_rows import ops as gops
from repro.kernels.gather_rows.ref import gather_rows_ref
from repro.kernels.paged_decode import ops as pops
from repro.kernels.paged_decode.ref import paged_decode_attention_ref
from repro.kernels.scatter_rows import ops as sops
from repro.kernels.scatter_rows.ref import scatter_add_rows_ref

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-5, atol=1e-6)


class TestGatherRows:
    @pytest.mark.parametrize("v,d,n", [
        (8, 8, 1), (64, 16, 37), (128, 128, 128), (1000, 256, 300),
        (33, 48, 7), (4096, 64, 513),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("mode", ["vmem", "dma"])
    def test_sweep(self, v, d, n, dtype, mode):
        table = jnp.asarray(RNG.standard_normal((v, d)), dtype)
        idx = jnp.asarray(RNG.integers(0, v, n), jnp.int32)
        out = gops.gather_rows(table, idx, mode=mode)
        np.testing.assert_allclose(
            np.asarray(out, np.float32),
            np.asarray(gather_rows_ref(table, idx), np.float32),
            **_tol(dtype))

    def test_duplicate_and_boundary_indices(self):
        table = jnp.asarray(RNG.standard_normal((16, 8)), jnp.float32)
        idx = jnp.asarray([0, 15, 15, 0, 7, 7, 7], jnp.int32)
        for mode in ("vmem", "dma"):
            out = gops.gather_rows(table, idx, mode=mode)
            np.testing.assert_allclose(out, np.asarray(table)[idx])

    def test_auto_mode_selection(self):
        small = jnp.zeros((64, 16), jnp.float32)
        big = jnp.zeros((1 << 15, 512), jnp.float32)    # > VMEM budget
        i = jnp.zeros((4,), jnp.int32)
        assert gops.gather_rows(small, i).shape == (4, 16)
        assert gops.gather_rows(big, i).shape == (4, 512)


class TestScatterAddRows:
    @pytest.mark.parametrize("v,d,n", [
        (8, 8, 8), (64, 16, 200), (130, 100, 57), (128, 128, 1000),
        (1000, 32, 64),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32])
    def test_sweep(self, v, d, n, dtype):
        idx = jnp.asarray(RNG.integers(0, v, n), jnp.int32)
        vals = jnp.asarray(RNG.standard_normal((n, d)), dtype)
        out = sops.scatter_add_rows(idx, vals, v)
        ref = scatter_add_rows_ref(idx, vals, v)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)

    def test_all_same_index(self):
        """LULESH-S3 regime: every write lands on one row (delta 0)."""
        n, v, d = 256, 16, 32
        idx = jnp.full((n,), 3, jnp.int32)
        vals = jnp.ones((n, d), jnp.float32)
        out = sops.scatter_add_rows(idx, vals, v)
        np.testing.assert_allclose(np.asarray(out)[3], np.full(d, n))
        assert np.abs(np.asarray(out)[[i for i in range(v) if i != 3]]).max() == 0

    def test_out_of_range_dropped(self):
        idx = jnp.asarray([0, 99, 1], jnp.int32)
        vals = jnp.ones((3, 4), jnp.float32)
        out = sops.scatter_add_rows(idx, vals, 8)
        assert np.asarray(out).sum() == 8.0


class TestPagedDecode:
    @pytest.mark.parametrize("b,kvh,g,dh,pages,page,pps", [
        (1, 1, 1, 16, 4, 8, 2), (2, 2, 4, 16, 12, 8, 3),
        (4, 2, 2, 64, 32, 16, 4), (2, 4, 1, 32, 8, 8, 2),
    ])
    def test_sweep(self, b, kvh, g, dh, pages, page, pps):
        q = jnp.asarray(RNG.standard_normal((b, kvh, g, dh)), jnp.float32)
        kp = jnp.asarray(RNG.standard_normal((kvh, pages, page, dh)),
                         jnp.float32)
        vp = jnp.asarray(RNG.standard_normal((kvh, pages, page, dh)),
                         jnp.float32)
        pt = jnp.asarray(RNG.integers(0, pages, (b, pps)), jnp.int32)
        ln = jnp.asarray(RNG.integers(1, page * pps + 1, (b,)), jnp.int32)
        out = pops.paged_decode_attention(q, kp, vp, pt, ln)
        ref = paged_decode_attention_ref(q, kp, vp, pt, ln,
                                         scale=1.0 / dh ** 0.5)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)

    def test_bf16(self):
        b, kvh, g, dh, pages, page, pps = 2, 2, 2, 32, 8, 8, 2
        q = jnp.asarray(RNG.standard_normal((b, kvh, g, dh)), jnp.bfloat16)
        kp = jnp.asarray(RNG.standard_normal((kvh, pages, page, dh)),
                         jnp.bfloat16)
        vp = jnp.asarray(RNG.standard_normal((kvh, pages, page, dh)),
                         jnp.bfloat16)
        pt = jnp.asarray(RNG.integers(0, pages, (b, pps)), jnp.int32)
        ln = jnp.full((b,), page * pps, jnp.int32)
        out = pops.paged_decode_attention(q, kp, vp, pt, ln)
        ref = paged_decode_attention_ref(q, kp, vp, pt, ln,
                                         scale=1.0 / dh ** 0.5)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=5e-2, atol=5e-2)


class TestFlashAttention:
    @pytest.mark.parametrize("b,kvh,g,s,t,dh,causal,window,cap", [
        (2, 2, 2, 64, 64, 16, True, 0, 0.0),
        (1, 1, 4, 128, 128, 32, True, 32, 0.0),
        (2, 1, 1, 64, 64, 16, True, 0, 50.0),     # gemma2 softcap
        (1, 2, 2, 96, 96, 16, False, 0, 0.0),     # bidirectional (whisper)
    ])
    def test_fwd_and_grad(self, b, kvh, g, s, t, dh, causal, window, cap):
        from repro.kernels.flash_attention import flash_attention
        from repro.kernels.flash_attention.ref import flash_attention_ref
        q = jnp.asarray(RNG.standard_normal((b, kvh, g, s, dh)), jnp.float32)
        k = jnp.asarray(RNG.standard_normal((b, kvh, t, dh)), jnp.float32)
        v = jnp.asarray(RNG.standard_normal((b, kvh, t, dh)), jnp.float32)
        out = flash_attention(q, k, v, causal=causal, window=window,
                              softcap=cap, block_q=32, block_k=32)
        ref = flash_attention_ref(q, k, v, scale=1 / dh ** 0.5,
                                  causal=causal, window=window, softcap=cap)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)
        gk = jax.grad(lambda q: flash_attention(
            q, k, v, causal=causal, window=window, softcap=cap,
            block_q=32, block_k=32).sum())(q)
        gr = jax.grad(lambda q: flash_attention_ref(
            q, k, v, scale=1 / dh ** 0.5, causal=causal, window=window,
            softcap=cap).sum())(q)
        np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-5)

    def test_matches_model_attention(self):
        """flash == the model's chunked_attention on a GQA case."""
        from repro.kernels.flash_attention import flash_attention
        from repro.models.common import chunked_attention
        b, s, kvh, g, dh = 2, 64, 2, 2, 16
        q = jnp.asarray(RNG.standard_normal((b, s, kvh, g, dh)), jnp.float32)
        k = jnp.asarray(RNG.standard_normal((b, s, kvh, dh)), jnp.float32)
        v = jnp.asarray(RNG.standard_normal((b, s, kvh, dh)), jnp.float32)
        ref = chunked_attention(q, k, v, chunk=16, causal=True)
        qf = jnp.moveaxis(q, 1, 3)                     # (B,KVH,G,S,dh)
        kf = jnp.moveaxis(k, 1, 2)                     # (B,KVH,T,dh)
        vf = jnp.moveaxis(v, 1, 2)
        out = flash_attention(qf, kf, vf, causal=True, block_q=16,
                              block_k=16)
        np.testing.assert_allclose(jnp.moveaxis(out, 3, 1), ref,
                                   rtol=1e-4, atol=1e-5)


class TestSelectiveScan:
    @pytest.mark.parametrize("b,l,d,n,bl", [
        (2, 32, 16, 8, 8), (1, 64, 32, 16, 16), (2, 128, 8, 4, 32),
        (1, 48, 16, 8, 16),
    ])
    def test_matches_ref(self, b, l, d, n, bl):
        from repro.kernels.selective_scan import selective_scan
        from repro.kernels.selective_scan.ref import selective_scan_ref
        u = jnp.asarray(RNG.standard_normal((b, l, d)), jnp.float32)
        dt = jnp.asarray(np.abs(RNG.standard_normal((b, l, d))) * 0.1,
                         jnp.float32)
        bi = jnp.asarray(RNG.standard_normal((b, l, n)), jnp.float32)
        ci = jnp.asarray(RNG.standard_normal((b, l, n)), jnp.float32)
        a = jnp.asarray(-np.abs(RNG.standard_normal((n, d))), jnp.float32)
        dsk = jnp.asarray(RNG.standard_normal((1, d)), jnp.float32)
        y, h = selective_scan(u, dt, bi, ci, a, dsk, block_l=bl)
        yr, hr = selective_scan_ref(u, dt, bi, ci, a, dsk)
        np.testing.assert_allclose(y, yr, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(h, hr, rtol=1e-5, atol=1e-5)

    def test_kernel_path_in_model(self):
        """mamba_apply(use_scan_kernel=True) == default XLA path."""
        import dataclasses
        from repro.configs import get_smoke_config
        from repro.models.ssm import mamba_apply, mamba_defs
        from repro.models.common import init_tree
        cfg = dataclasses.replace(get_smoke_config("falcon-mamba-7b"),
                                  dtype="float32")
        p = init_tree(jax.random.PRNGKey(0), mamba_defs(cfg), jnp.float32)
        x = jnp.asarray(RNG.standard_normal((2, 32, cfg.d_model)),
                        jnp.float32)
        y0 = mamba_apply(cfg, p, x)
        y1 = mamba_apply(cfg, p, x, use_scan_kernel=True)
        np.testing.assert_allclose(y0, y1, rtol=1e-4, atol=1e-4)
