"""Pure-jnp oracle for the scatter-add kernel."""
import jax
import jax.numpy as jnp


def scatter_add_rows_ref(idx: jax.Array, vals: jax.Array, v: int) -> jax.Array:
    """out = zeros(V, D); out[idx[i]] += vals[i]"""
    out = jnp.zeros((v, vals.shape[1]), dtype=vals.dtype)
    return out.at[idx].add(vals, mode="drop")
