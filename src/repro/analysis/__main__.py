"""spatterlint matrix runner — ``python -m repro.analysis`` (CI's lint
job; DESIGN.md §12).

Audits every (suite x placement x backend) cell statically plus the
serving-layer ast lint, writes one merged JSON report, and exits
non-zero on any violation::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m repro.analysis \\
        --suite suites/demo.json --suite suites/apps.json \\
        --suite suites/widelane.json \\
        --mesh 1x1 --mesh 8x1 --mesh 4x2 --mesh 1x8 \\
        --out LINT_report.json

Placement cells that need more devices than are visible are a hard
error (exit 2), not a skip: CI asserting "matrix clean" must never
silently audit less than the matrix.
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="spatterlint: static audit of planner executables "
                    "over a suite x placement matrix")
    ap.add_argument("--suite", action="append", default=[],
                    metavar="FILE", help="suites/*.json file (repeatable)")
    ap.add_argument("--mesh", action="append", default=[],
                    metavar="N|BxL",
                    help="placement cell, e.g. 1x1, 8x1, 4x2, 1x8 "
                         "(repeatable; default: single-device only)")
    ap.add_argument("--backend", action="append", default=[],
                    choices=["xla", "onehot", "scalar", "pallas"],
                    help="backend(s) to audit (default: xla + pallas)")
    ap.add_argument("--mode", default="store", choices=["store", "add"])
    ap.add_argument("--out", default=None, metavar="FILE",
                    help="write the merged JSON lint report here")
    ap.add_argument("--no-serve-lint", action="store_true",
                    help="skip the repro/serve ast concurrency lint")
    args = ap.parse_args(argv)
    if not args.suite and args.no_serve_lint:
        ap.error("nothing to lint: pass --suite and/or drop "
                 "--no-serve-lint")

    from repro.analysis.lint import lint_serve, lint_suite_file
    from repro.analysis.report import LintReport
    from repro.serve.schema import parse_mesh

    backends = tuple(args.backend) or ("xla", "pallas")
    meshes = [parse_mesh(m) for m in args.mesh] or [0]

    report = LintReport()
    if not args.no_serve_lint:
        report = report.merge(lint_serve())
    try:
        for suite in args.suite:
            for mesh in meshes:
                report = report.merge(lint_suite_file(
                    suite, mesh=mesh, backends=backends, mode=args.mode))
    except ValueError as e:
        # an unbuildable cell (mesh > visible devices, bad suite) must
        # fail the job loudly — a skipped cell is not a clean cell
        print(f"error: {e}", file=sys.stderr)
        return 2

    if args.out:
        report.dump(args.out)
    print(report.summary())
    if args.out:
        print(f"report: {args.out}")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
