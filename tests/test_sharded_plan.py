"""Sharded bucket launches + batch-polymorphic executor cache (plan.py).

In-process tests use a 1-device mesh (conftest pins the suite to one
device); the 8-fake-device acceptance run — sharded results bit-identical
to the single-device planner, membership changes compiling nothing —
happens in a subprocess with its own XLA_FLAGS, like the dry-run tests.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import (ExecutorCache, ShardedExecutor, SuitePlan,
                        execute_bucket, gs_shardings, make_pattern,
                        pad_batch, run_suite)
from repro.core import backends as B
from repro.core.plan import ExecKey

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _suite(n_gather=4, n_scatter=4, count=32):
    pats = []
    for i in range(n_gather):
        pats.append(make_pattern(f"UNIFORM:8:{i + 1}", kind="gather",
                                 delta=8, count=count, name=f"g{i}"))
    for i in range(n_scatter):
        pats.append(make_pattern(f"UNIFORM:8:{i + 1}", kind="scatter",
                                 delta=8, count=count, name=f"s{i}"))
    return pats


def _mesh1():
    return jax.make_mesh((1,), ("data",))


# ---------------------------------------------------------------------------
# batch padding
# ---------------------------------------------------------------------------

def test_pad_batch():
    assert [pad_batch(n) for n in (1, 2, 3, 4, 5, 7, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 8, 16]
    # shard-count multiple: even split for any mesh size, applied ON TOP
    # of the pow-2 bracket (smallest n_shards-multiple >= next_pow2(nb))
    assert pad_batch(5, 8) == 8
    assert pad_batch(9, 8) == 16
    assert pad_batch(1, 3) == 3        # non-pow2 shard counts work too
    assert pad_batch(5, 6) == 12       # bracket 8 -> first multiple of 6
    assert pad_batch(13, 6) == 18      # bracket 16 -> first multiple of 6
    # the anti-fragmentation property best_batch relies on: every member
    # count in a pow-2 bracket maps to ONE padded batch (5 and 7 share
    # bracket 8; the old code gave them 6 and 12 with n_shards=3)
    assert pad_batch(5, 3) == pad_batch(7, 3) == 9
    with pytest.raises(ValueError):
        pad_batch(0)
    with pytest.raises(ValueError):
        pad_batch(4, 0)


# ---------------------------------------------------------------------------
# batch-polymorphic cache: membership drift never compiles
# ---------------------------------------------------------------------------

def test_membership_change_zero_compiles():
    pats = _suite()
    cache = ExecutorCache()
    s1 = run_suite(pats, backend="xla", runs=1, cache=cache)
    m1 = cache.misses
    assert m1 == s1.plan.n_buckets
    # shrink within a pow2 bracket and across brackets: zero new compiles
    run_suite(pats[:3] + pats[4:7], backend="xla", runs=1, cache=cache)
    assert cache.misses == m1
    run_suite(pats[:2] + pats[4:6], backend="xla", runs=1, cache=cache)
    assert cache.misses == m1
    run_suite([pats[0], pats[4]], backend="xla", runs=1, cache=cache)
    assert cache.misses == m1
    # the exact-compile-count invariant: every cached executable holds
    # exactly one trace (it is only ever called at its padded batch)
    for fn in cache._entries.values():
        assert fn._cache_size() == 1


def test_membership_growth_within_bracket_zero_compiles():
    # strides 2..5 (delta 8, count 32) share one bucket: footprints
    # 263..284 all pad to 512, idx_len 256
    def gp(s):
        return make_pattern(f"UNIFORM:8:{s}", kind="gather", delta=8,
                            count=32, name=f"g{s}")
    cache = ExecutorCache()
    run_suite([gp(2), gp(3), gp(4)], backend="xla", runs=1, cache=cache)
    m1 = cache.misses
    # 3 -> 4 members stays in the pow2-4 bracket: same executable
    run_suite([gp(2), gp(3), gp(4), gp(5)], backend="xla", runs=1,
              cache=cache)
    assert cache.misses == m1


def test_results_correct_after_polymorphic_reuse():
    # a bucket executed through a larger warm executable (extra scratch
    # patterns) must still produce per-pattern outputs identical to a
    # freshly-compiled exact-size launch
    pats = [make_pattern(f"UNIFORM:4:{s}", kind="gather", delta=4, count=16,
                         name=f"g{s}") for s in (1, 2, 3, 5)]
    plan4 = SuitePlan.build(pats)
    plan2 = SuitePlan.build(pats[:2])
    warm = ExecutorCache()
    for bucket in plan4.buckets:
        execute_bucket(plan4, bucket, backend="xla", cache=warm)
    m = warm.misses
    for bucket in plan2.buckets:
        outs = execute_bucket(plan2, bucket, backend="xla", cache=warm)
        ref = execute_bucket(plan2, bucket, backend="xla",
                             cache=ExecutorCache())
        for o, r in zip(outs, ref):
            np.testing.assert_array_equal(o, r)
    assert warm.misses == m            # reused the batch-4 executable


def test_best_batch_lookup():
    cache = ExecutorCache()
    def key(batch):
        return ExecKey(backend="xla", kind="gather", idx_len=64,
                       footprint=64, dtype="float32", row_width=1,
                       mode="", batch=batch, placement="")
    cache.get(key(8), lambda: (lambda: 8))
    cache.get(key(2), lambda: (lambda: 2))
    assert cache.best_batch(key(4)).batch == 8      # smallest >= 4
    assert cache.best_batch(key(1)).batch == 2
    assert cache.best_batch(key(16)) is None        # growth: must compile
    # any other field mismatch disqualifies
    other = ExecKey(backend="scalar", kind="gather", idx_len=64,
                    footprint=64, dtype="float32", row_width=1,
                    mode="", batch=4, placement="")
    assert cache.best_batch(other) is None


# ---------------------------------------------------------------------------
# sharded launches (1-device mesh in-process; 8 devices in the subprocess)
# ---------------------------------------------------------------------------

def test_gs_shardings_specs():
    mesh = _mesh1()
    # batched: every operand shards dim 0 (the pattern-batch)
    in_sh, out_sh = gs_shardings(mesh, "data", "gather", batched=True)
    assert [s.spec for s in in_sh] == [P("data"), P("data")]
    assert out_sh.spec == P("data")
    # scatter executables take (dst, idx, vals, keep) — four operands
    in_sh, out_sh = gs_shardings(mesh, "data", "scatter", batched=True)
    assert [s.spec for s in in_sh] == [P("data")] * 4
    assert out_sh.spec == P("data")
    # unbatched (GSEngine.sharded): lane dim shards, gather table and
    # scatter result stay replicated
    in_sh, out_sh = gs_shardings(mesh, "data", "gather")
    assert [s.spec for s in in_sh] == [P(), P("data")]
    assert out_sh.spec == P("data")
    in_sh, out_sh = gs_shardings(mesh, "data", "scatter")
    assert [s.spec for s in in_sh] == [P(), P("data"), P("data"), P("data")]
    assert out_sh.spec == P()
    with pytest.raises(ValueError):
        gs_shardings(mesh, "data", "neither")


def test_sharded_executor_validates_axis():
    with pytest.raises(ValueError):
        ShardedExecutor(_mesh1(), axis="model")


def test_sharded_matches_unsharded_all_backends():
    pats = [make_pattern(f"UNIFORM:4:{s}", kind="gather", delta=2, count=16,
                         name=f"g{s}") for s in (1, 2, 3)]
    pats += [make_pattern(f"UNIFORM:4:{s}", kind="scatter", delta=2,
                          count=16, name=f"s{s}") for s in (1, 2, 3)]
    plan = SuitePlan.build(pats)
    mesh = _mesh1()
    for backend in B.BACKENDS:
        for mode in ("store", "add"):
            for bucket in plan.buckets:
                ref = execute_bucket(plan, bucket, backend=backend,
                                     mode=mode, cache=ExecutorCache())
                out = execute_bucket(plan, bucket, backend=backend,
                                     mode=mode, cache=ExecutorCache(),
                                     mesh=mesh)
                for o, r in zip(out, ref):
                    np.testing.assert_array_equal(
                        o, r, err_msg=f"{backend}/{mode}")


def test_sharded_and_unsharded_executables_never_collide():
    pats = _suite(n_gather=2, n_scatter=0)
    cache = ExecutorCache()
    run_suite(pats, backend="xla", runs=1, cache=cache)
    m1 = cache.misses
    run_suite(pats, backend="xla", runs=1, cache=cache, mesh=_mesh1())
    assert cache.misses > m1           # placement is part of the key
    keys = list(cache._entries)
    assert {k.placement for k in keys} == {"", "data=1/1dev"}


def test_run_suite_mesh_requires_batch():
    pats = _suite(n_gather=1, n_scatter=0)
    with pytest.raises(ValueError):
        run_suite(pats, mesh=_mesh1(), batch=False)


def test_run_suite_sharded_stats():
    pats = _suite(n_gather=2, n_scatter=2)
    stats = run_suite(pats, backend="xla", runs=2, cache=ExecutorCache(),
                      mesh=_mesh1())
    assert len(stats.results) == len(pats)
    for p, r in zip(pats, stats.results):
        assert r.pattern is p
        assert r.measured_gbs > 0 and r.time_s > 0


# ---------------------------------------------------------------------------
# acceptance: 8 fake devices, subprocess with its own XLA_FLAGS
# ---------------------------------------------------------------------------

ACCEPTANCE_8DEV = textwrap.dedent("""\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, %r)
    import jax, numpy as np
    assert len(jax.devices()) == 8, jax.devices()
    from repro.core import (ExecutorCache, SuitePlan, execute_bucket,
                            make_pattern, run_suite)
    from repro.core import backends as B

    pats = []
    for i in range(12):
        kind = "gather" if i %% 2 == 0 else "scatter"
        pats.append(make_pattern("UNIFORM:8:%%d" %% ((i %% 4) + 1),
                                 kind=kind, delta=4, count=32,
                                 name="p%%d" %% i))
    plan = SuitePlan.build(pats)
    mesh = jax.make_mesh((8,), ("data",))

    # sharded bucket launches bit-identical to the single-device planner
    for backend in B.BACKENDS:
        for mode in ("store", "add"):
            for bucket in plan.buckets:
                ref = execute_bucket(plan, bucket, backend=backend,
                                     mode=mode, cache=ExecutorCache())
                out = execute_bucket(plan, bucket, backend=backend,
                                     mode=mode, cache=ExecutorCache(),
                                     mesh=mesh)
                for o, r in zip(out, ref):
                    np.testing.assert_array_equal(
                        o, r, err_msg="%%s/%%s" %% (backend, mode))

    # membership change across streamed sharded runs: zero new compiles
    cache = ExecutorCache()
    run_suite(pats, backend="xla", runs=2, cache=cache, mesh=mesh)
    m1 = cache.misses
    run_suite(pats[:9], backend="xla", runs=2, cache=cache, mesh=mesh)
    run_suite(pats[:5], backend="xla", runs=2, cache=cache, mesh=mesh)
    assert cache.misses == m1, (cache.misses, m1)
    for fn in cache._entries.values():
        assert fn._cache_size() == 1
    print("OK")
    """) % SRC


def test_acceptance_sharded_suite_8dev_subprocess():
    r = subprocess.run([sys.executable, "-c", ACCEPTANCE_8DEV],
                       capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
    assert "OK" in r.stdout
