"""GSEngine — pattern -> executable gather/scatter, with paper-style timing.

The engine materializes a Pattern's absolute indices, builds the requested
backend's jitted callable, and times it the way the paper does: minimum
over K runs (§3.5), reporting the paper's useful-bytes bandwidth alongside
the modeled v5e number (bandwidth.py).

Sharding: the count dimension is the paper's OpenMP-thread / CUDA-block
dimension; ``sharded()`` splits it over a mesh axis with shard_map, each
shard gathering into its own output block (no false sharing by
construction — paper §3.1's per-thread dst buffers).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from . import backends as B
from . import bandwidth as bw
from .pattern import Pattern


def gs_shardings(mesh: Mesh, axis: str, kind: str, *, batched: bool = False):
    """(in_shardings, out_sharding) for a gather/scatter executable.

    Compat shim over the placement layer (``plan.Placement`` /
    ``runtime.sharding.gs_specs`` — DESIGN.md §11), which owns the axis
    rules for every sharded path.  ``axis`` plays the 1-D role the
    pre-placement code gave it: the pattern-batch axis when ``batched``
    (each device runs whole patterns), the lane axis otherwise (the
    paper's OpenMP-thread split ``GSEngine.sharded`` uses).

    Scatter executables take four operands (dst, idx, vals, keep): the
    host-precomputed last-write-wins keep mask rides with the indices.
    """
    from .plan import Placement
    if batched:
        placement = Placement(mesh, batch_axis=axis, lane_axis=None)
    else:
        placement = Placement(mesh, batch_axis=None, lane_axis=axis)
    return placement.shardings(kind, batched=batched)


def make_host_buffers(pattern: Pattern, row_width: int, seed: int = 0):
    """Host-side buffers for one pattern: (src, abs_idx, vals, keep).

    ``src`` is the (footprint, row_width) float32 table, ``abs_idx`` the
    flattened (count*index_len,) int32 absolute indices, ``vals`` the
    scatter payload (None for gathers), and ``keep`` the last-write-wins
    keep mask over ``abs_idx`` (None for gathers).  The mask is computed
    HERE, on the host, once per pattern — static-index preprocessing never
    enters a timed executable (paper §3.5).  Both GSEngine and the suite
    planner (plan.py) build their device buffers from this one function so
    batched and per-pattern execution see bit-identical inputs.
    """
    rng = np.random.default_rng(seed)
    f = pattern.footprint()
    abs_idx = pattern.absolute_indices().reshape(-1)
    src = rng.standard_normal((f, row_width), dtype=np.float32)
    if pattern.kind == "gather":
        return src, abs_idx, None, None
    vals = rng.standard_normal((abs_idx.shape[0], row_width),
                               dtype=np.float32)
    return src, abs_idx, vals, B.keep_last_mask(abs_idx)


@dataclasses.dataclass(frozen=True)
class RunResult:
    pattern: Pattern
    backend: str
    elem_bytes: int
    row_width: int
    runs: int
    time_s: float                 # min over runs (paper §3.5)
    measured_gbs: float           # paper formula over measured CPU time
    modeled_gbs: float            # paper formula over modeled v5e time
    tile_efficiency: float
    out_digest: str | None = None   # sha256 of the computed output
                                    # (run_plan(digest=True)); timing-free,
                                    # so it is the bit-identity witness for
                                    # repeated serving requests

    def row(self) -> dict:
        return {
            "name": self.pattern.name,
            "kind": self.pattern.kind,
            "type": self.pattern.classify(),
            "backend": self.backend,
            "delta": self.pattern.delta,
            "idx_len": self.pattern.index_len,
            "count": self.pattern.count,
            "time_s": self.time_s,
            "measured_cpu_gbs": self.measured_gbs,
            "modeled_v5e_gbs": self.modeled_gbs,
            "tile_eff": self.tile_efficiency,
            "digest": self.out_digest,
        }


SCATTER_MODES = ("store", "add")


class GSEngine:
    """Executable form of one Spatter pattern.

    ``mode`` selects the scatter write semantics ("store" last-write-wins,
    the paper's default, or "add" accumulation); gathers ignore it.
    """

    def __init__(self, pattern: Pattern, *, backend: str = "xla",
                 dtype=jnp.float32, row_width: int = 1, seed: int = 0,
                 mode: str = "store"):
        if backend not in B.BACKENDS:
            raise ValueError(f"unknown backend {backend!r}")
        if mode not in SCATTER_MODES:
            raise ValueError(f"unknown mode {mode!r}; "
                             f"expected one of {SCATTER_MODES}")
        self.pattern = pattern
        self.backend = backend
        self.dtype = jnp.dtype(dtype)
        self.row_width = row_width
        self.mode = mode
        self._seed = seed
        self._abs_idx = pattern.absolute_indices().reshape(-1)   # (count*L,)
        self._built = None

    # -- buffers -------------------------------------------------------------
    @property
    def elem_bytes(self) -> int:
        return self.dtype.itemsize * self.row_width

    def footprint_shape(self) -> tuple[int, int]:
        return (self.pattern.footprint(), self.row_width)

    def make_buffers(self):
        """Device operands: (src, idx, None, None) for gathers,
        (None, idx, vals, keep) for scatters.  The scatter dst is NOT
        allocated here — the executable donates it, so ``build()`` hands
        out a fresh zero dst per call; materializing one here too would
        be a dead device allocation the size of the footprint."""
        host_src, host_idx, host_vals, host_keep = make_host_buffers(
            self.pattern, self.row_width, seed=self._seed)
        idx = jnp.asarray(host_idx, jnp.int32)
        if self.pattern.kind == "gather":
            return jnp.asarray(host_src, self.dtype), idx, None, None
        vals = jnp.asarray(host_vals, self.dtype)
        return None, idx, vals, jnp.asarray(host_keep)

    # -- executables ---------------------------------------------------------
    def build(self):
        """Returns (fn, args) where fn(*args) performs the whole pattern.

        Scatter args carry the host-precomputed keep mask as a regular
        operand: the jitted hot path contains only the access itself.

        The scatter executable DONATES its dst operand (argnum 0), so a
        scatter's args are single-use: every ``build()`` call hands out a
        fresh zero dst, and only the executable plus the non-donated
        operands are cached.  Caching the dst itself made the second
        ``run()`` (or ``sharded()`` after ``run()``) die with "buffer has
        been deleted or donated" — the repeated-execution regime the
        serving layer depends on.
        """
        if self._built is None:
            backend, mode = self.backend, self.mode
            if self.pattern.kind == "gather":
                src, idx, _, _ = self.make_buffers()

                @jax.jit
                def fn(src, idx):
                    return B.gather(src, idx, backend=backend)

                self._built = (fn, (src, idx))
            else:
                _, idx, vals, keep = self.make_buffers()

                @partial(jax.jit, donate_argnums=(0,))
                def fn(dst, idx, vals, keep):
                    return B.scatter(dst, idx, vals, mode=mode,
                                     backend=backend, keep=keep)

                self._built = (fn, (idx, vals, keep))
        fn, args = self._built
        if self.pattern.kind == "scatter":
            args = (jnp.zeros(self.footprint_shape(), self.dtype),) + args
        return fn, args

    def sharded(self, mesh, axis: str = "data"):
        """Shard the count dimension over ``axis`` (paper's thread dim).

        The lane-only degenerate form of the placement layer: ``mesh``
        may be a raw ``Mesh`` (its ``axis`` becomes the lane axis) or a
        lane-only ``plan.Placement``; batch-sharded placements belong to
        the suite planner (a single pattern has no batch dim).
        """
        from .plan import Placement
        fn, args = self.build()
        if isinstance(mesh, Placement):
            placement = mesh
            if placement.batch_axis is not None:
                raise ValueError(
                    "GSEngine.sharded is per-pattern: the placement must "
                    f"be lane-only, got {placement.placement}")
        else:
            placement = Placement(mesh, batch_axis=None, lane_axis=axis)
        n_shards = placement.lane_shards
        total = self._abs_idx.shape[0]
        if total % n_shards:
            raise ValueError(f"count*index_len={total} not divisible by "
                             f"{n_shards} shards")
        in_shardings, out_shardings = placement.shardings(
            self.pattern.kind, batched=False)
        backend, mode = self.backend, self.mode
        if self.pattern.kind == "gather":
            def raw(src, idx):
                return B.gather(src, idx, backend=backend)
        else:
            # mode must match build()'s: a fixed "add" here made sharded and
            # unsharded runs disagree whenever a pattern writes an index twice
            def raw(dst, idx, vals, keep):
                return B.scatter(dst, idx, vals, mode=mode,
                                 backend=backend, keep=keep)
        sharded_fn = jax.jit(raw, in_shardings=in_shardings,
                             out_shardings=out_shardings)
        return sharded_fn, args

    # -- paper-style timing ---------------------------------------------------
    def run(self, runs: int = 10) -> RunResult:
        fn, args = self.build()
        if self.pattern.kind == "scatter":
            # donation consumes dst; rebuild per run
            dst, idx, vals, keep = args
            out = fn(dst, idx, vals, keep)
            jax.block_until_ready(out)          # compile & warm
            times = []
            for _ in range(runs):
                d = jnp.zeros_like(out)
                jax.block_until_ready(d)
                t0 = time.perf_counter()
                out = fn(d, idx, vals, keep)
                jax.block_until_ready(out)
                times.append(time.perf_counter() - t0)
        else:
            out = fn(*args)
            jax.block_until_ready(out)
            times = []
            for _ in range(runs):
                t0 = time.perf_counter()
                out = fn(*args)
                jax.block_until_ready(out)
                times.append(time.perf_counter() - t0)
        t = min(times)                           # paper §3.5: min of K
        tm = bw.tpu_tile_model(self.pattern, self.elem_bytes)
        return RunResult(
            pattern=self.pattern, backend=self.backend,
            elem_bytes=self.elem_bytes, row_width=self.row_width,
            runs=runs, time_s=t,
            measured_gbs=bw.paper_bandwidth(self.pattern, t,
                                            self.elem_bytes) / 1e9,
            modeled_gbs=tm.modeled_gbs,
            tile_efficiency=tm.tile_efficiency,
        )
