"""chatglm3-6b [dense] — 28L d4096 32H GQA kv=2 d_ff=13696 vocab=65024.

RoPE applied to half the head dim ("2d" interleaved rotary), GQA.
[arXiv:2406.12793; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab=65024, head_dim=128,
    attn_kind="full", rope="2d", mlp_kind="swiglu",
)

SMOKE = ModelConfig(
    arch_id="chatglm3-6b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, head_dim=16,
    attn_kind="full", rope="2d", mlp_kind="swiglu", attn_chunk=16,
)
