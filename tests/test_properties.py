"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dep: property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import Pattern, make_pattern
from repro.core.bandwidth import tpu_tile_model, HBM_BW, VMEM_BW


@st.composite
def patterns(draw):
    n = draw(st.integers(1, 32))
    stride = draw(st.integers(0, 64))
    delta = draw(st.integers(0, 256))
    count = draw(st.integers(1, 256))
    kind = draw(st.sampled_from(["gather", "scatter"]))
    idx = tuple(i * stride for i in range(n))
    return Pattern("prop", kind, idx, delta, count)


@settings(max_examples=60, deadline=None)
@given(patterns())
def test_pattern_geometry_invariants(p):
    assert p.footprint() >= p.span
    assert p.useful_elements() == p.index_len * p.count
    assert 1 <= p.unique_elements() <= p.useful_elements()
    assert p.reuse_factor() >= 1.0
    abs_idx = p.absolute_indices()
    assert abs_idx.shape == (p.count, p.index_len)
    assert abs_idx.max() < p.footprint()
    assert abs_idx.min() >= 0


@settings(max_examples=40, deadline=None)
@given(patterns())
def test_tile_model_invariants(p):
    tm = tpu_tile_model(p, 4, sim_ops=32)
    # modeled bandwidth never exceeds the VMEM ceiling and time is positive
    assert tm.modeled_time_s > 0
    assert tm.modeled_gbs <= VMEM_BW / 1e9 + 1e-6
    # no-reuse patterns can't beat HBM bandwidth
    if p.reuse_factor() == 1.0:
        assert tm.modeled_gbs <= HBM_BW / 1e9 + 1e-6
    # tile efficiency is bounded by reuse
    assert tm.tile_efficiency <= p.reuse_factor() + 1e-9


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 16), st.integers(1, 8), st.integers(1, 1024))
def test_uniform_tile_efficiency_decays(n, s_exp, count):
    """Fig 3 invariant: doubling the stride never increases tile traffic."""
    stride = 2 ** (s_exp - 1)
    p1 = make_pattern(f"UNIFORM:{n}:{stride}", delta=n * stride + 1,
                      count=count)
    p2 = make_pattern(f"UNIFORM:{n}:{stride * 2}", delta=n * stride * 2 + 1,
                      count=count)
    t1 = tpu_tile_model(p1, 4, sim_ops=16)
    t2 = tpu_tile_model(p2, 4, sim_ops=16)
    assert t2.fetched_bytes >= t1.fetched_bytes - 1e-6


# ---------------------------------------------------------------------------
# attention: chunked implementation vs naive oracle
# ---------------------------------------------------------------------------

import jax
import jax.numpy as jnp

from repro.models.common import chunked_attention


def _naive_attention(q, k, v, causal, window, cap):
    b, s, kvh, g, dh = q.shape
    t = k.shape[1]
    scores = jnp.einsum("bqkgd,btkd->bkgqt", q, k) / np.sqrt(dh)
    if cap > 0:
        scores = jnp.tanh(scores / cap) * cap
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= qp >= kp
    if window > 0:
        mask &= (qp - kp) < window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bkgqt,btkd->bqkgd", p, v)


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 3), st.sampled_from([8, 16, 24, 32]),
       st.sampled_from([1, 2]), st.sampled_from([1, 2, 4]),
       st.booleans(), st.sampled_from([0, 8]),
       st.sampled_from([0.0, 30.0]))
def test_chunked_attention_matches_naive(b, s, kvh, g, causal, window, cap):
    rng = np.random.default_rng(0)
    dh = 8
    q = jnp.asarray(rng.standard_normal((b, s, kvh, g, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kvh, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kvh, dh)), jnp.float32)
    out = chunked_attention(q, k, v, chunk=8, causal=causal, window=window,
                            attn_softcap=cap)
    ref = _naive_attention(q, k, v, causal, window, cap)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# pad_batch contract (plan.py): smallest n_shards-multiple >= the pow-2
# bracket.  The bracket-stability property is what ExecutorCache.best_batch
# assumes — every member count in one pow-2 bracket must map to ONE padded
# batch per shard count, or streamed suite runs fragment the ExecKey space
# and recompile on membership drift.
# ---------------------------------------------------------------------------

@settings(max_examples=200, deadline=None)
@given(st.integers(1, 4096), st.integers(1, 64))
def test_pad_batch_contract(nb, n_shards):
    from repro.core.plan import next_pow2, pad_batch
    b = pad_batch(nb, n_shards)
    bracket = next_pow2(nb)
    assert b >= nb                          # fits every member
    assert b % n_shards == 0                # even sharded split
    assert b >= bracket                     # never below the pow-2 bracket
    assert b - n_shards < bracket           # minimal such multiple
    # unsharded: exactly the pow-2 bracket
    assert pad_batch(nb) == bracket
    # pow-2 shard counts keep pow-2 batches (max of the two brackets)
    if n_shards & (n_shards - 1) == 0:
        assert b == max(bracket, n_shards)


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 2048), st.integers(1, 2048), st.integers(1, 64))
def test_pad_batch_bracket_stability(nb1, nb2, n_shards):
    from repro.core.plan import next_pow2, pad_batch
    if next_pow2(nb1) == next_pow2(nb2):
        assert pad_batch(nb1, n_shards) == pad_batch(nb2, n_shards)


# ---------------------------------------------------------------------------
# pad_lanes contract (plan.py): the lane-axis twin of pad_batch, with the
# SAME shard-multiple >= pow-2-bracket rule on the launched lane dim — the
# 2-D placement layer pads both axes with one contract (DESIGN.md §11).
# ---------------------------------------------------------------------------

@settings(max_examples=200, deadline=None)
@given(st.integers(1, 1 << 18), st.integers(1, 64))
def test_pad_lanes_contract(n, n_shards):
    from repro.core.plan import next_pow2, pad_lanes
    lanes = pad_lanes(n, n_shards)
    bracket = next_pow2(n)
    assert lanes >= n                       # fits every real lane
    assert lanes % n_shards == 0            # even lane-axis split
    assert lanes >= bracket                 # never below the pow-2 bracket
    assert lanes - n_shards < bracket       # minimal such multiple
    # unsharded: exactly the pow-2 bracket — so a bucket's already-pow2
    # idx_len is the identity case and single-device launches are
    # unchanged by the placement layer
    assert pad_lanes(n) == bracket
    assert pad_lanes(bracket) == bracket
    # pow-2 lane-shard counts keep pow-2 lane dims
    if n_shards & (n_shards - 1) == 0:
        assert lanes == max(bracket, n_shards)


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 1 << 16), st.integers(1, 1 << 16), st.integers(1, 64))
def test_pad_lanes_bracket_stability(n1, n2, n_shards):
    from repro.core.plan import next_pow2, pad_lanes
    if next_pow2(n1) == next_pow2(n2):
        assert pad_lanes(n1, n_shards) == pad_lanes(n2, n_shards)


# ---------------------------------------------------------------------------
# spattercost traffic model (analysis/cost.py, DESIGN.md §15): the byte
# accounting is pure plan geometry, so its invariants hold for EVERY
# suite x shard shape — useful bytes never move with placement, overhead
# only ever grows along a shard axis, and the pad fraction reconciles
# exactly with the planner's own pad_waste metric.
# ---------------------------------------------------------------------------

@st.composite
def small_suites(draw):
    n = draw(st.integers(1, 5))
    out = []
    for i in range(n):
        m = draw(st.integers(1, 16))
        stride = draw(st.integers(1, 8))
        count = draw(st.integers(1, 64))
        kind = draw(st.sampled_from(["gather", "scatter"]))
        idx = tuple(j * stride for j in range(m))
        out.append(Pattern(f"p{i}", kind, idx, m * stride, count))
    return out


_SHARDS = st.sampled_from([1, 2, 4, 8])


@settings(max_examples=60, deadline=None)
@given(small_suites(), _SHARDS, _SHARDS)
def test_cost_useful_bytes_placement_invariant(pats, b, l):
    from repro.analysis import cost as C
    from repro.core.plan import SuitePlan
    plan = SuitePlan.build(pats)
    single = C.shape_cost(plan, (1, 1))
    placed = C.shape_cost(plan, (b, l))
    # placement moves pad/replication, never the analytic minimum
    assert placed["useful_bytes"] == single["useful_bytes"]
    assert placed["useful_bytes"] \
        == sum(p.count * p.index_len for p in pats) * 4
    # and the overhead axes are one-directional
    assert placed["pad_bytes"] >= single["pad_bytes"]
    assert placed["replicated_bytes"] >= single["replicated_bytes"]
    assert placed["device_bytes"] >= single["device_bytes"]


@settings(max_examples=60, deadline=None)
@given(small_suites(), _SHARDS, _SHARDS)
def test_cost_pad_fraction_matches_plan_pad_waste(pats, b, l):
    from repro.analysis import cost as C
    from repro.core.plan import SuitePlan
    plan = SuitePlan.build(pats)
    sc = C.shape_cost(plan, (b, l))
    lane_data = sc["useful_bytes"] + sc["pad_bytes"]
    assert sc["pad_bytes"] / lane_data == pytest.approx(
        plan.pad_waste(b, l))


@settings(max_examples=60, deadline=None)
@given(small_suites(), _SHARDS, _SHARDS)
def test_cost_monotone_in_shards(pats, b, l):
    from repro.analysis import cost as C
    from repro.core.plan import SuitePlan
    plan = SuitePlan.build(pats)
    base = C.shape_cost(plan, (b, l))
    # doubling either shard axis can only add pad (batch axis) or pad +
    # table replication (lane axis) — predicted traffic never shrinks
    more_b = C.shape_cost(plan, (2 * b, l))
    more_l = C.shape_cost(plan, (b, 2 * l))
    assert more_b["device_bytes"] >= base["device_bytes"]
    assert more_l["device_bytes"] >= base["device_bytes"]
    assert more_l["replicated_bytes"] > base["replicated_bytes"] \
        or base["table_bytes"] == 0


@settings(max_examples=30, deadline=None)
@given(small_suites(), _SHARDS, _SHARDS)
def test_cost_reproducible_across_reenumeration(pats, b, l):
    from repro.analysis import cost as C
    from repro.core.plan import SuitePlan
    # the model is a pure function of the plan: rebuilding the plan from
    # the same patterns predicts bit-identical traffic (what makes the
    # committed COST_baseline.json a stable gate)
    c1 = C.shape_cost(SuitePlan.build(pats), (b, l))
    c2 = C.shape_cost(SuitePlan.build(list(pats)), (b, l))
    assert c1 == c2
    s1 = C.select_shape(SuitePlan.build(pats), n_devices=8)
    s2 = C.select_shape(SuitePlan.build(list(pats)), n_devices=8)
    assert s1 == s2
