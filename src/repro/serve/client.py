"""Client for spatterd (stdlib urllib; see daemon.py / DESIGN.md §10).

Library::

    from repro.serve import SpatterClient
    c = SpatterClient("http://127.0.0.1:8089")
    r1 = c.run_suite(json.load(open("suites/demo.json")), runs=3)
    r2 = c.run_suite(json.load(open("suites/demo.json")), runs=3)
    assert r2["cache"]["misses"] == 0            # warm: zero compiles
    assert [t["digest"] for t in r1["stats"]["table"]] == \
           [t["digest"] for t in r2["stats"]["table"]]   # bit-identical

CLI::

    PYTHONPATH=src python -m repro.serve.client \
        --url http://127.0.0.1:8089 --json suites/demo.json [--mesh 8|4x2]
"""
from __future__ import annotations

import argparse
import json
import urllib.error
import urllib.request

from .schema import SuiteRequest, parse_mesh


class ServerError(RuntimeError):
    """A failed spatterd exchange; ``.status`` is the HTTP code (0 when
    the daemon could not be reached at all)."""

    def __init__(self, status: int, message: str):
        prefix = f"spatterd returned {status}" if status \
            else "cannot reach spatterd"
        super().__init__(f"{prefix}: {message}")
        self.status = status


class SpatterClient:
    def __init__(self, url: str, timeout: float = 600.0):
        self.url = url.rstrip("/")
        self.timeout = timeout

    def _request(self, path: str, body: dict | None = None) -> dict:
        req = urllib.request.Request(
            self.url + path,
            data=None if body is None else json.dumps(body).encode(),
            headers={"Content-Type": "application/json"},
            method="GET" if body is None else "POST")
        try:
            with urllib.request.urlopen(req, timeout=self.timeout) as resp:
                return json.loads(resp.read())
        except urllib.error.HTTPError as e:
            try:
                msg = json.loads(e.read()).get("error", str(e))
            except Exception:
                msg = str(e)
            raise ServerError(e.code, msg) from None
        except urllib.error.URLError as e:      # refused / DNS / timeout
            raise ServerError(0, f"{self.url}: {e.reason}") from None

    def health(self) -> dict:
        return self._request("/healthz")

    def cache(self) -> dict:
        return self._request("/cache")

    def lint(self) -> dict:
        """spatterlint audit of the daemon's live cache (GET /lint);
        the ``report`` field is an ``analysis.report.LintReport``
        document — parse it jax-free with ``LintReport.from_json``."""
        return self._request("/lint")

    def run_suite(self, patterns, **options) -> dict:
        """POST a suite; ``patterns`` is a list of suite-JSON dicts, a
        full ``{"patterns": [...], ...}`` envelope, or a JSON string of
        either, and ``options`` are the SuiteRequest fields (backend=,
        runs=, mode=, metric=, mesh=, stream_r=, ...) — keyword options
        override same-named envelope fields.

        The request is validated client-side first, so a typo'd option
        fails fast with the same message the server would give.
        """
        if isinstance(patterns, str):
            patterns = json.loads(patterns)
        if isinstance(patterns, dict):          # envelope document
            doc = {**patterns, **options}
        else:
            doc = {"patterns": list(patterns), **options}
        return self._request("/run", SuiteRequest.from_json(doc).to_json())


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="POST a JSON suite to a running spatterd")
    ap.add_argument("--url", default="http://127.0.0.1:8089")
    ap.add_argument("--json", required=True, help="suite file (paper §3.3)")
    # option defaults are None = "not given": an envelope suite file's own
    # fields must not be silently overridden by CLI defaults
    ap.add_argument("-b", "--backend", default=None)
    ap.add_argument("-r", "--runs", type=int, default=None)
    ap.add_argument("--mode", default=None, help="scatter mode store|add")
    ap.add_argument("--mesh", type=parse_mesh, default=None, metavar="N|BxL",
                    help="shard over N devices (batch-only) or a BxL "
                         "(batch x lane) 2-D placement, e.g. 4x2")
    ap.add_argument("--row-width", type=int, default=None)
    ap.add_argument("--metric", default=None,
                    help="gbs column: measured|modeled")
    ap.add_argument("--seed", type=int, default=None,
                    help="host-buffer RNG seed")
    ap.add_argument("--stream-r", action="store_true",
                    help="include paper Eq. 1 Pearson R vs STREAM")
    ap.add_argument("--stream-n", type=int, default=None,
                    help="STREAM reference size (elements)")
    ap.add_argument("--no-digest", action="store_true",
                    help="skip the per-pattern output digests")
    args = ap.parse_args(argv)
    opts = {name: v for name, v in
            [("backend", args.backend), ("runs", args.runs),
             ("mode", args.mode), ("mesh", args.mesh),
             ("row_width", args.row_width), ("metric", args.metric),
             ("seed", args.seed), ("stream_n", args.stream_n)]
            if v is not None}
    if args.stream_r:
        opts["stream_r"] = True
    if args.no_digest:
        opts["digest"] = False
    c = SpatterClient(args.url)
    # ValueError covers client-side schema rejections AND a malformed
    # --json file (JSONDecodeError): both get the same clean one-liner
    # a server-rejected request would
    try:
        with open(args.json) as f:
            pats = json.load(f)
        resp = c.run_suite(pats, **opts)
    except (ServerError, ValueError) as e:
        raise SystemExit(f"error: {e}")
    print_response(resp)


def print_response(resp: dict) -> None:
    stats, cache = resp["stats"], resp["cache"]

    def _n(x):
        # to_json serializes non-finite floats as null (strict JSON);
        # render them as nan rather than crashing the formatter
        return float("nan") if x is None else x

    print(f"{'name':24s} {'type':16s} {'cpu GB/s':>9s} {'v5e GB/s':>9s} "
          f"{'digest':>12s}")
    for row in stats["table"]:
        print(f"{row['name']:24s} {row['type']:16s} "
              f"{_n(row['measured_cpu_gbs']):9.2f} "
              f"{_n(row['modeled_v5e_gbs']):9.1f} "
              f"{(row['digest'] or '')[:12]:>12s}")
    extra = ""
    if stats.get("stream_gbs") is not None:
        # gate on stream_gbs: R itself may be null (NaN on a degenerate
        # suite) while the reference run still happened and is worth
        # showing — same gate as the local CLI path
        extra = (f"   stream {_n(stats['stream_gbs']):.2f} GB/s "
                 f"R={_n(stats['stream_r']):.3f}")
    print(f"\nsuite: min {_n(stats['min_gbs']):.2f}  "
          f"max {_n(stats['max_gbs']):.2f}  "
          f"harmonic-mean {_n(stats['hmean_gbs']):.2f} GB/s{extra}")
    print(f"serve: {resp['plan']['n_buckets']} buckets  "
          f"pad waste {resp['plan']['pad_waste']:.1%}  "
          f"cache hits {cache['hits']} misses {cache['misses']} "
          f"(exact compiles this request)  {resp['elapsed_s']:.2f}s")


if __name__ == "__main__":
    main()
