"""JAX version-compatibility shims (compat policy).

The repo pins no jax version; the container ships jax 0.4.37 but the code
must keep working as the APIs it touches migrate.  Policy: every
cross-version API goes through ONE symbol defined here — call sites never
feature-test jax themselves.  Current shims:

``shard_map``
    Lived in ``jax.experimental.shard_map`` through the 0.4/0.5 series and
    was promoted to ``jax.shard_map`` in newer releases.  We prefer the
    top-level export when present and fall back to the experimental module.

``make_abstract_mesh(shape, names)``
    ``jax.sharding.AbstractMesh`` changed constructors: old releases
    (including 0.4.37) take a single ``shape_tuple`` of ``(name, size)``
    pairs; newer ones take ``(axis_sizes, axis_names)`` positionally.
    This helper accepts the uniform ``(sizes, names)`` form and builds the
    mesh whichever way the installed jax understands.

``axis_size(name)``
    ``jax.lax.axis_size`` is a late addition; on older jax the idiom is
    ``lax.psum(1, name)``, which evaluates statically to a Python int.

``pcast(x, name, to=...)``
    ``jax.lax.pcast`` belongs to the newer varying-manual-axes (VMA) type
    system.  Older shard_map tracks replication with its own checker and
    inserts the equivalent coercions itself, so the shim is the identity
    there.
"""
from __future__ import annotations

from typing import Sequence

import jax
from jax import lax

if hasattr(jax, "shard_map"):           # newer jax: top-level export
    shard_map = jax.shard_map
else:                                   # jax <= 0.5: experimental module
    from jax.experimental.shard_map import shard_map  # noqa: F401


def shard_map_unchecked(f, **kw):
    """shard_map with the replication/VMA checker off.

    For bodies that thread rank-local state (e.g. error-feedback residuals)
    through a nominally-replicated out_spec: every checker generation
    rejects that, but the per-device buffers carry the state correctly as
    long as nothing reshards them.  The disable flag was renamed
    ``check_rep`` -> ``check_vma`` across jax versions; pass whichever the
    installed shard_map accepts.
    """
    import inspect
    params = inspect.signature(shard_map).parameters
    if "check_vma" in params:
        return shard_map(f, check_vma=False, **kw)
    return shard_map(f, check_rep=False, **kw)


def axis_size(name: str) -> int:
    """Static size of a mapped mesh axis, on any supported jax version."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(name)
    return lax.psum(1, name)


def pcast(x, name: str, *, to: str = "varying"):
    """Coerce replicated<->varying under shard_map where jax supports it."""
    if hasattr(lax, "pcast"):
        return lax.pcast(x, name, to=to)
    if to == "varying" and hasattr(lax, "pvary"):
        # the VMA window before pcast existed: pvary is the varying cast,
        # and skipping it there silently drops replicated-input gradients
        return lax.pvary(x, name)
    return x


def make_abstract_mesh(shape: Sequence[int], names: Sequence[str]):
    """Build ``jax.sharding.AbstractMesh`` on any supported jax version.

    ``shape`` are the axis sizes and ``names`` the axis names, e.g.
    ``make_abstract_mesh((16, 16), ("data", "model"))``.
    """
    if len(shape) != len(names):
        raise ValueError(f"shape/names length mismatch: {shape} vs {names}")
    AbstractMesh = jax.sharding.AbstractMesh
    try:
        # newer jax: AbstractMesh(axis_sizes, axis_names)
        return AbstractMesh(tuple(int(s) for s in shape), tuple(names))
    except TypeError:
        # jax <= 0.4.x: AbstractMesh(shape_tuple of (name, size) pairs)
        return AbstractMesh(tuple((n, int(s)) for n, s in zip(names, shape)))
