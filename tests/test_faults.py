"""serve/faults + daemon recovery under injected chaos (DESIGN.md §14).

The acceptance regime per fault class: the daemon STAYS LIVE (health
200 and a clean request succeeds afterwards), /readyz and /stats
reflect the state, and the telemetry stays EXACT — the summed
per-request ``misses`` of successful requests equal the lifetime
cache-miss delta even with faults firing in between.

``CHAOS_WORKERS`` (env) dials the scheduler pool — the CI chaos job
runs this file at 2 and 4 workers.
"""
import os
import threading
import time

import pytest

from repro.core import ExecutorCache
from repro.serve import (FaultInjector, InjectedFault, ServerError,
                         SpatterClient, SpatterDaemon, WorkerKilled)
from repro.serve.faults import ENV_SPEC, _parse_rule

WORKERS = int(os.environ.get("CHAOS_WORKERS", "2"))

SUITE = [
    {"name": "g1", "kernel": "Gather", "pattern": "UNIFORM:4:1",
     "delta": 4, "count": 64},
    {"name": "g2", "kernel": "Gather", "pattern": "UNIFORM:4:2",
     "delta": 4, "count": 64},
    {"name": "s1", "kernel": "Scatter", "pattern": "UNIFORM:4:2",
     "delta": 2, "count": 64},
]
ONE = [SUITE[0]]


def _daemon(spec=None, seed=0, **kw):
    faults = FaultInjector.from_spec(spec, seed=seed) if spec else None
    kw.setdefault("workers", WORKERS)
    return SpatterDaemon(port=0, cache=ExecutorCache(), faults=faults, **kw)


def _wait(pred, timeout=60.0):
    deadline = time.time() + timeout
    while not pred():
        assert time.time() < deadline, "condition never became true"
        time.sleep(0.01)


# ---------------------------------------------------------------------------
# the injector itself
# ---------------------------------------------------------------------------

def test_spec_parsing():
    inj = FaultInjector.from_spec(
        "compile:fail:1, launch:delay:2:0.05,worker:kill:3")
    snap = inj.snapshot()
    assert [r["site"] for r in snap["rules"]] == ["compile", "launch",
                                                 "worker"]
    assert snap["rules"][1]["arg"] == 0.05
    assert snap["triggered"] == 0
    for bad in ("compile:fail", "disk:corrupt:0", "nope:fail:1",
                "compile:explode:1", "launch:delay:1:x", "launch:fail:-2"):
        with pytest.raises(ValueError):
            _parse_rule(bad)


def test_from_env_reads_spec_and_seed():
    assert FaultInjector.from_env({}) is None
    inj = FaultInjector.from_env({ENV_SPEC: "launch:fail:2",
                                  ENV_SPEC + "_SEED": "7"})
    assert inj.seed == 7
    assert inj.snapshot()["rules"][0]["times"] == 2


def test_rules_fire_exactly_times_then_exhaust():
    inj = FaultInjector.from_spec("compile:fail:2,worker:kill:1")
    for _ in range(2):
        with pytest.raises(InjectedFault):
            inj.check("compile")
    inj.check("compile")                      # exhausted: passes clean
    with pytest.raises(WorkerKilled):
        inj.check("worker")
    inj.check("worker")
    inj.check("launch")                       # no rule: always clean
    snap = inj.snapshot()
    assert snap["triggered"] == 3
    assert snap["consults"] == {"compile": 3, "worker": 2, "launch": 1}


def test_delay_jitter_is_seeded_deterministic(monkeypatch):
    import repro.serve.faults as F
    slept = []
    monkeypatch.setattr(F.time, "sleep", slept.append)
    a = FaultInjector.from_spec("launch:delay:3:0.2", seed=11)
    b = FaultInjector.from_spec("launch:delay:3:0.2", seed=11)
    for _ in range(3):
        a.check("launch")
    first = list(slept)
    slept.clear()
    for _ in range(3):
        b.check("launch")
    assert slept == first                     # replayable chaos
    assert all(0.1 <= s < 0.3 for s in first)  # arg x [0.5, 1.5)


def test_mangle_flips_one_byte_then_exhausts():
    inj = FaultInjector.from_spec("disk:corrupt:1")
    payload = bytes(range(64))
    bad = inj.mangle("disk", payload)
    assert bad != payload and len(bad) == len(payload)
    assert sum(x != y for x, y in zip(bad, payload)) == 1
    assert inj.mangle("disk", payload) == payload   # exhausted: pass-through


# ---------------------------------------------------------------------------
# daemon recovery, one fault class at a time
# ---------------------------------------------------------------------------

def test_compile_fault_fails_request_then_recovers():
    with _daemon("compile:fail:1") as d:
        c = SpatterClient(d.url)
        with pytest.raises(ServerError) as e:
            c.run_suite(ONE, runs=1)
        assert e.value.status == 500
        assert "InjectedFault" in str(e.value)
        assert c.health()["ok"]               # alive after the failure
        r = c.run_suite(ONE, runs=1)          # injector exhausted
        assert r["ok"] and r["cache"]["misses"] > 0
        s = c.stats()
        assert s["faults"]["triggered"] == 1
        # exactness through the fault: the failed build never counted
        assert r["cache"]["misses"] == s["cache"]["misses"]


def test_compile_fault_degrades_to_xla_fallback():
    # a non-xla backend gets the xla fallback builder: the injected
    # compile failure degrades the key instead of failing the request,
    # and EVERY launch served by the degraded executable is flagged
    with _daemon("compile:fail:1") as d:
        c = SpatterClient(d.url)
        r1 = c.run_suite(ONE, runs=1, backend="scalar")
        assert r1["ok"] and r1["serve"]["degraded_launches"] == 1
        assert r1["cache"]["misses"] == 1     # the fallback DID compile
        assert r1["cache"]["lifetime"]["degraded"] == 1
        r2 = c.run_suite(ONE, runs=1, backend="scalar")
        assert r2["cache"]["misses"] == 0     # warm on the degraded entry
        assert r2["serve"]["degraded_launches"] == 1   # still flagged
        assert d.scheduler.snapshot()["degraded_launches"] == 2


def test_launch_fault_fails_one_request_only():
    with _daemon("launch:fail:1") as d:
        c = SpatterClient(d.url)
        with pytest.raises(ServerError) as e:
            c.run_suite(SUITE, runs=1)
        assert e.value.status == 500
        r = c.run_suite(SUITE, runs=1)
        assert r["ok"]
        s = c.stats()
        assert s["scheduler"]["failed"] == 1
        # the injected launch failure fired BEFORE any compile: lifetime
        # misses are exactly the successful request's
        assert s["cache"]["misses"] == r["cache"]["misses"]


def test_latency_fault_slows_but_serves():
    with _daemon("launch:delay:1:0.2", seed=3) as d:
        c = SpatterClient(d.url)
        r = c.run_suite(ONE, runs=1)
        assert r["ok"] and r["elapsed_s"] >= 0.1   # jitter floor: 0.5 x arg
        assert c.stats()["faults"]["triggered"] == 1


def test_worker_kill_is_survived_and_respawned():
    with _daemon("worker:kill:1") as d:
        c = SpatterClient(d.url)
        # the kill fires at a worker's loop top; the supervisor counts
        # the death and replaces the thread
        _wait(lambda: c.stats()["scheduler"]["dead_workers"] == 1)
        _wait(lambda: c.stats()["scheduler"]["alive_workers"] == WORKERS)
        sched = c.stats()["scheduler"]
        assert sched["respawned"] == 1
        # a full request train still serves on the recovered pool
        r1 = c.run_suite(SUITE, runs=1)
        r2 = c.run_suite(SUITE, runs=1)
        assert r1["ok"] and r2["ok"]
        assert r2["cache"]["misses"] == 0


def test_quarantine_then_operator_reset():
    from repro.serve.scheduler import QUARANTINE_AFTER
    with _daemon(f"launch:fail:{QUARANTINE_AFTER}") as d:
        c = SpatterClient(d.url)
        for _ in range(QUARANTINE_AFTER):
            with pytest.raises(ServerError):
                c.run_suite(ONE, runs=1)
        assert c.stats()["scheduler"]["quarantined_families"] == 1
        # fail-FAST now: the injector is exhausted, so a launch would
        # succeed — but the family must not reach a worker at all
        launches = c.stats()["scheduler"]["total_launches"]
        with pytest.raises(ServerError, match="quarantined"):
            c.run_suite(ONE, runs=1)
        assert c.stats()["scheduler"]["total_launches"] == launches
        assert d.scheduler.clear_quarantine() == 1
        assert c.run_suite(ONE, runs=1)["ok"]


def test_load_fault_serves_cold_not_dead(tmp_path):
    with _daemon("load:fail:1", cache_dir=str(tmp_path)) as d:
        c = SpatterClient(d.url)
        _wait(lambda: c.readyz()["ready"])    # preload failure != not ready
        r = c.run_suite(ONE, runs=1)
        assert r["ok"] and r["cache"]["misses"] > 0   # cold, but serving
        assert c.stats()["faults"]["triggered"] == 1


def test_disk_corruption_quarantined_on_restart(tmp_path):
    root = str(tmp_path)
    with _daemon("disk:corrupt:1", cache_dir=root) as d:
        c = SpatterClient(d.url)
        r1 = c.run_suite(SUITE, runs=1)
        n_buckets = r1["plan"]["n_buckets"]
        digests = [t["digest"] for t in r1["stats"]["table"]]
        assert d.disk.stats()["stores"] == n_buckets
    # restart on the poisoned directory: the checksum catches exactly
    # the mangled entry — quarantined + recompiled, never loaded
    with _daemon(cache_dir=root) as d:
        c = SpatterClient(d.url)
        r2 = c.run_suite(SUITE, runs=1)
        assert [t["digest"] for t in r2["stats"]["table"]] == digests
        assert r2["cache"]["misses"] == 1     # only the corrupt one
        s = c.stats()
        assert s["disk"]["quarantined"] == 1
        assert s["disk"]["loads"] == n_buckets - 1


# ---------------------------------------------------------------------------
# deadlines + readiness through the HTTP layer
# ---------------------------------------------------------------------------

def test_deadline_ms_expired_in_queue_is_504_and_launches_nothing():
    with _daemon() as d:
        c = SpatterClient(d.url)
        c.health()                            # daemon fully up
        d.scheduler.pause()                   # no worker will ever look
        with pytest.raises(ServerError) as e:
            c.run_suite(ONE, runs=1, deadline_ms=150)
        assert e.value.status == 504
        assert e.value.doc["deadline_ms"] == 150
        # nothing launched, nothing compiled — and the expired work was
        # CANCELLED out of the queue, not left for the resumed workers
        snap = d.scheduler.snapshot()
        assert snap["total_launches"] == 0 and snap["queue_depth"] == 0
        assert d.cache.stats().misses == 0
        d.scheduler.resume()
        assert c.run_suite(ONE, runs=1, deadline_ms=60_000)["ok"]


def test_readyz_splits_from_healthz():
    with _daemon() as d:
        c = SpatterClient(d.url)
        _wait(lambda: c.readyz()["ready"])
        d.scheduler.pause()
        doc = c.readyz()                      # 503 but a normal answer
        assert not doc["ready"] and doc["paused"]
        assert c.health()["ok"]               # liveness unaffected
        d.scheduler.resume()
        assert c.readyz()["ready"]


def test_client_retries_503_with_backoff(monkeypatch):
    # retries_503 turns backpressure into a bounded jittered wait; the
    # staged queue drains on resume so the retry SUCCEEDS
    one = ONE
    with SpatterDaemon(port=0, cache=ExecutorCache(), workers=1,
                       max_queue=1) as d:
        c = SpatterClient(d.url, retries_503=4, backoff_base_s=0.05,
                          backoff_cap_s=0.2, backoff_seed=1)
        d.scheduler.pause()
        filler = threading.Thread(
            target=lambda: SpatterClient(d.url).run_suite(one, runs=1))
        filler.start()
        _wait(lambda: d.scheduler.snapshot()["queue_depth"] == 1)
        resumer = threading.Timer(0.3, d.scheduler.resume)
        resumer.start()
        try:
            r = c.run_suite(one, runs=1)      # 503s, backs off, then lands
            assert r["ok"]
        finally:
            resumer.cancel()
            d.scheduler.resume()
            filler.join(timeout=300)
    # fail-fast default unchanged: no retry without opt-in
    assert SpatterClient("http://x", timeout=1).retries_503 == 0


# ---------------------------------------------------------------------------
# the acceptance invariant, across the whole fault matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [
    "compile:fail:1",
    "launch:fail:1",
    "launch:delay:2:0.05",
    "worker:kill:1",
    "compile:fail:1,launch:fail:1,worker:kill:1",
])
def test_miss_exactness_survives_fault_matrix(spec):
    # whatever the chaos, successful responses' summed per-request
    # misses equal the daemon's lifetime compile count — faults can fail
    # requests but can never lose or double-count a compile
    with _daemon(spec, seed=5) as d:
        c = SpatterClient(d.url)
        ok = []
        for suite in (SUITE, ONE, SUITE, SUITE):
            try:
                ok.append(c.run_suite(suite, runs=1))
            except ServerError as e:
                assert e.status == 500
        assert len(ok) >= 1                   # chaos never took it down
        assert c.health()["ok"]
        lifetime = c.stats()["cache"]["misses"]
        assert sum(r["cache"]["misses"] for r in ok) == lifetime
        assert c.stats()["faults"]["triggered"] >= 1
