"""RG-LRU recurrent block (recurrentgemma / Griffin).

Real-gated linear recurrent unit:
    r_t = sigmoid(W_a x_t)        (recurrence gate)
    i_t = sigmoid(W_x x_t)        (input gate)
    a_t = a ** (c * r_t),  a = sigmoid(lambda)   [elementwise, c = 8]
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

wrapped in Griffin's recurrent block: conv1d(4) on the input branch, GeLU
gate branch, output projection.  Sequential lax.scan with (B, width) state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.runtime.sharding import constrain
from .common import ParamDef

_C = 8.0


def rglru_defs(cfg) -> dict:
    d, w = cfg.d_model, cfg.lru_width
    return {
        "in_x": ParamDef((d, w), ("embed", "rnn_width")),
        "in_gate": ParamDef((d, w), ("embed", "rnn_width")),
        "conv_w": ParamDef((4, w), ("conv", "rnn_width")),
        "conv_b": ParamDef((w,), ("rnn_width",), init="zeros"),
        "w_a": ParamDef((w, w), ("rnn_width", None)),
        "b_a": ParamDef((w,), ("rnn_width",), init="zeros"),
        "w_i": ParamDef((w, w), ("rnn_width", None)),
        "b_i": ParamDef((w,), ("rnn_width",), init="zeros"),
        "lam": ParamDef((w,), ("rnn_width",), init="ones"),
        "out_proj": ParamDef((w, d), ("rnn_width", "embed")),
    }


def _conv4(p, x, conv_state=None):
    dc = p["conv_w"].shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], dc - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * p["conv_w"][i] for i in range(dc))
    return y + p["conv_b"], xp[:, -(dc - 1):]


def _gates(p, x):
    r = jax.nn.sigmoid(x @ p["w_a"] + p["b_a"])
    i = jax.nn.sigmoid(x @ p["w_i"] + p["b_i"])
    log_a_base = -jax.nn.softplus(p["lam"]).astype(jnp.float32)  # log sigmoid
    log_a = _C * r.astype(jnp.float32) * log_a_base
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12))
    return a, beta, i


def _step(carry, inp):
    h = carry
    a_t, beta_t, gated_x = inp
    h = a_t * h + beta_t * gated_x
    return h, h


def rglru_apply(cfg, p: dict, x: jax.Array) -> jax.Array:
    """x (B,S,d) -> (B,S,d)."""
    b, s, _ = x.shape
    xb = x @ p["in_x"]
    gate = jax.nn.gelu(x @ p["in_gate"])
    xb = constrain(xb, ("batch", "seq", "rnn_width"))
    xb, _ = _conv4(p, xb)
    a, beta, i = _gates(p, xb)
    gx = (i * xb).astype(jnp.float32)
    xs = (jnp.moveaxis(a, 1, 0), jnp.moveaxis(beta, 1, 0),
          jnp.moveaxis(gx, 1, 0))
    h0 = jnp.zeros((b, cfg.lru_width), jnp.float32)
    _, hs = jax.lax.scan(_step, h0, xs)
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype) * gate
    return y @ p["out_proj"]


def rglru_init_cache(cfg, batch: int, dtype):
    return {
        "conv": jnp.zeros((batch, 3, cfg.lru_width), dtype),
        "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
    }


def rglru_cache_axes():
    return {"conv": ("batch", "conv", "rnn_width"),
            "h": ("batch", "rnn_width")}


def rglru_decode(cfg, p: dict, x: jax.Array, cache: dict):
    """Single-token update — O(1) state, runs the long_500k cell."""
    xb = x @ p["in_x"]
    gate = jax.nn.gelu(x @ p["in_gate"])
    xb, conv_state = _conv4(p, xb, cache["conv"])
    a, beta, i = _gates(p, xb)
    gx = (i * xb).astype(jnp.float32)
    h, _ = _step(cache["h"], (a[:, 0], beta[:, 0], gx[:, 0]))
    y = h[:, None].astype(x.dtype) * gate
    return y @ p["out_proj"], {"conv": conv_state, "h": h}
