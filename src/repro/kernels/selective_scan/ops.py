"""Public wrapper for the fused selective scan."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel
from .ref import selective_scan_ref


def _should_interpret(interpret: bool | None) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def selective_scan(u: jax.Array, dt: jax.Array, b_in: jax.Array,
                   c_in: jax.Array, a: jax.Array, d_skip: jax.Array, *,
                   block_l: int = 256, interpret: bool | None = None):
    """Fused Mamba scan. u,dt (B,L,D); b_in,c_in (B,L,N); a (N,D) (<0);
    d_skip (1,D). Returns (y (B,L,D), h_final (B,N,D))."""
    l = u.shape[1]
    bl = min(block_l, l)
    while l % bl:
        bl //= 2
    return kernel.selective_scan_fwd(u, dt, b_in, c_in, a, d_skip,
                                     block_l=max(1, bl),
                                     interpret=_should_interpret(interpret))
