"""Spatter-JAX: gather/scatter-centric training & serving framework for TPU.

Reproduction of "Spatter: A Tool for Evaluating Gather / Scatter
Performance" (Lavin et al.), adapted to TPU and integrated as the indexed-
access substrate of a multi-pod LLM training/serving framework.
"""
__version__ = "0.1.0"
