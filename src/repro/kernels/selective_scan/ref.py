"""Pure-jnp oracle for the selective scan (mirrors models/ssm.py math)."""
import jax
import jax.numpy as jnp


def selective_scan_ref(u, dt, b_in, c_in, a, d_skip):
    """u, dt (B,L,D); b_in, c_in (B,L,N); a (N,D) negative; d_skip (1,D).

    Returns (y (B,L,D), h_final (B,N,D)).
    """
    bsz, l, d = u.shape
    n = b_in.shape[2]

    def step(h, xs):
        u_t, dt_t, b_t, c_t = xs
        da = jnp.exp(dt_t[:, None, :] * a[None])             # (B,N,D)
        h = h * da + (dt_t * u_t)[:, None, :] * b_t[:, :, None]
        y = jnp.einsum("bnd,bn->bd", h, c_t) + d_skip[0] * u_t
        return h, y

    xs = (jnp.moveaxis(u, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(b_in, 1, 0).astype(jnp.float32),
          jnp.moveaxis(c_in, 1, 0).astype(jnp.float32))
    h0 = jnp.zeros((bsz, n, d), jnp.float32)
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(u.dtype), h
