"""whisper-base [audio] — 6L enc + 6L dec, d512 8H d_ff=2048 vocab=51865.

Encoder-decoder; conv frontend STUBBED — input_specs() provides precomputed
frame embeddings (B, frames, d). [arXiv:2212.04356]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-base", family="audio",
    n_layers=6, n_enc_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865, head_dim=64,
    attn_kind="full", rope="none", mlp_kind="gelu", frame_ratio=4,
)

SMOKE = ModelConfig(
    arch_id="whisper-base-smoke", family="audio",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, head_dim=16,
    attn_kind="full", rope="none", mlp_kind="gelu", frame_ratio=4,
    attn_chunk=16,
)
