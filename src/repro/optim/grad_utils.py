"""Gradient utilities: global-norm clipping and int8 compression.

int8 compression (with per-tensor scales and error feedback) is the
cross-pod gradient-all-reduce trick: the "pod" axis crosses data-center
interconnect, so halving/quartering gradient bytes there is the single
biggest multi-pod comm lever.  Used by the shard_map DP variant in
runtime/train.py and validated in tests/test_optim.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), tree), norm


def int8_compress(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum(g: jax.Array, axis_name: str,
                    residual: jax.Array | None = None):
    """int8 all-reduce with error feedback, for use inside shard_map.

    Returns (mean-reduced g_approx, new_residual).  The residual carries the
    quantization error into the next step (error feedback keeps convergence
    unbiased in expectation).
    """
    x = g.astype(jnp.float32)
    if residual is not None:
        x = x + residual
    q, scale = int8_compress(x)
    # scales are tiny: all-reduce them in fp32, values in int8->int32 sum
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
    scale_max = jax.lax.pmax(scale, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    approx = summed.astype(jnp.float32) * scale_max / n
    new_residual = x - int8_decompress(q, scale)
    return approx.astype(g.dtype), new_residual
