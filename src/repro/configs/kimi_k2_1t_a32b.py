"""kimi-k2-1t-a32b [moe] — 61L d7168 64H GQA kv=8 d_ff_expert=2048 vocab=163840.

Trillion-parameter MoE: 384 routed experts top-8 + 1 shared, first layer
dense (d_ff 18432). Assignment-table numbers (GQA kv=8). [arXiv:2501.kimi2]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=18432, vocab=163840, head_dim=112,
    attn_kind="full", rope="full",
    n_experts=384, n_shared_experts=1, top_k=8, d_ff_expert=2048,
    n_dense_layers=1, d_ff_dense=18432, mlp_kind="swiglu",
)

SMOKE = ModelConfig(
    arch_id="kimi-k2-1t-a32b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, head_dim=16,
    attn_kind="full", rope="full",
    n_experts=8, n_shared_experts=1, top_k=2, d_ff_expert=32,
    n_dense_layers=1, d_ff_dense=128, mlp_kind="swiglu", attn_chunk=16,
)
