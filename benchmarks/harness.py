"""Shared benchmark harness: paper-style timing + CSV emission."""
from __future__ import annotations

import sys
import time

import jax


def time_fn(fn, *args, runs: int = 10, warmup: int = 1) -> float:
    """Paper §3.5: minimum time over ``runs`` executions (seconds)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(runs):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def emit(name: str, us_per_call: float, derived: str):
    """One CSV row: name,us_per_call,derived  (benchmarks/run.py contract)."""
    print(f"{name},{us_per_call:.2f},{derived}")
    sys.stdout.flush()
