"""Pure-jnp oracle for the row gather kernel."""
import jax
import jax.numpy as jnp


def gather_rows_ref(table: jax.Array, idx: jax.Array) -> jax.Array:
    """out[i, :] = table[idx[i], :]"""
    return jnp.take(table, idx, axis=0)
