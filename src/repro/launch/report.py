"""Generate EXPERIMENTS.md §Dry-run / §Roofline tables from cell JSONs.

    PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_skips

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_cells(d: str) -> dict:
    cells = {}
    for fn in glob.glob(os.path.join(d, "*.json")):
        with open(fn) as f:
            j = json.load(f)
        c = j["cell"]
        cells[(c["arch"], c["shape"], c["mesh"])] = j
    return cells


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def roofline_table(cells: dict, mesh: str = "16x16") -> str:
    rows = ["| arch | shape | t_comp | t_mem | t_coll | dominant | "
            "MODEL/HLO flops | roofline frac | HBM/dev |",
            "|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname in SHAPE_ORDER:
            skip = shape_skips(cfg, SHAPES[sname])
            if skip:
                rows.append(f"| {arch} | {sname} | — | — | — | "
                            f"SKIP (full-attention @524k) | — | — | — |")
                continue
            j = cells.get((arch, sname, mesh))
            if not j:
                rows.append(f"| {arch} | {sname} | MISSING | | | | | | |")
                continue
            r = j["roofline"]
            an = j["memory_analysis"].get("analytic_per_device", {})
            hbm = sum(v for v in an.values()) if an else \
                r["per_device_hbm_gb"]
            rows.append(
                f"| {arch} | {sname} | {fmt_s(r['t_compute_s'])} | "
                f"{fmt_s(r['t_memory_s'])} | {fmt_s(r['t_collective_s'])} | "
                f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
                f"{r['roofline_fraction']*100:.1f}% | {hbm:.1f} GB |")
    return "\n".join(rows)


def dryrun_table(cells: dict) -> str:
    rows = ["| arch | shape | mesh | chips | compile | HLO GF/chip | "
            "coll GB/chip | top collectives | args+temp GB/dev |",
            "|---|---|---|---|---|---|---|---|---|"]
    for (arch, sname, mesh) in sorted(cells):
        j = cells[(arch, sname, mesh)]
        r = j["roofline"]
        m = j["memory_analysis"]
        colls = sorted(j["collectives"].items(),
                       key=lambda kv: -kv[1]["gbytes"])[:2]
        cstr = "; ".join(f"{k}x{int(v['count'])}:{v['gbytes']:.1f}GB"
                         for k, v in colls) or "none"
        rows.append(
            f"| {arch} | {sname} | {mesh} | {j['cell']['chips']} | "
            f"{j['compile_s']:.0f}s | {r['hlo_gflops_per_chip']:.0f} | "
            f"{r['coll_gbytes_per_chip']:.1f} | {cstr} | "
            f"{m['argument_size_gb']:.1f}+{m['temp_size_gb']:.1f} |")
    return "\n".join(rows)


def pick_hillclimb(cells: dict, mesh: str = "16x16") -> list[tuple]:
    """Worst roofline fraction, most collective-bound, most paper-central."""
    live = [(k, v) for k, v in cells.items() if k[2] == mesh]
    worst = min(live, key=lambda kv: kv[1]["roofline"]["roofline_fraction"])
    coll = max(live, key=lambda kv: (
        kv[1]["roofline"]["t_collective_s"]
        / max(1e-12, kv[1]["roofline"]["step_time"]
              if "step_time" in kv[1]["roofline"] else
              max(kv[1]["roofline"]["t_compute_s"],
                  kv[1]["roofline"]["t_memory_s"],
                  kv[1]["roofline"]["t_collective_s"]))))
    return [worst[0], coll[0]]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    cells = load_cells(args.dir)
    print(f"loaded {len(cells)} cells\n")
    print("## Roofline (single-pod 16x16, 256 chips)\n")
    print(roofline_table(cells, "16x16"))
    print("\n## Dry-run (all cells, both meshes)\n")
    print(dryrun_table(cells))
    print("\nhillclimb candidates:", pick_hillclimb(cells))


if __name__ == "__main__":
    main()
