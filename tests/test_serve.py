"""spatterd serving layer (repro/serve, DESIGN.md §10).

In-process daemon on an ephemeral port with its OWN ExecutorCache (never
the process-wide default — tests must not warm or read global state).
The acceptance regime — second identical request compiles nothing and
returns bit-identical results — is pinned here for the single-device
path in-process and for the 8-device --mesh path in a subprocess (the
tier-1 suite must see one device; same pattern as test_sharded_plan).
"""
import json
import os
import subprocess
import sys
import textwrap
import threading
import time

import pytest

from repro.core import ExecutorCache
from repro.serve import ServerError, SpatterClient, SpatterDaemon
from repro.serve.schema import SuiteRequest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

SUITE = [
    {"name": "g1", "kernel": "Gather", "pattern": "UNIFORM:4:1",
     "delta": 4, "count": 64},
    {"name": "g2", "kernel": "Gather", "pattern": "UNIFORM:4:2",
     "delta": 4, "count": 64},
    {"name": "s1", "kernel": "Scatter", "pattern": "UNIFORM:4:2",
     "delta": 2, "count": 64},
]


@pytest.fixture()
def served():
    with SpatterDaemon(port=0, cache=ExecutorCache()) as d:
        yield SpatterClient(d.url)


# ---------------------------------------------------------------------------
# request schema
# ---------------------------------------------------------------------------

def test_schema_accepts_bare_suite_list():
    req = SuiteRequest.from_json(SUITE)
    assert req.patterns == tuple(SUITE)
    assert req.backend == "xla" and req.mode == "store"
    assert len(req.build_patterns()) == 3


def test_schema_envelope_roundtrip():
    req = SuiteRequest.from_json({"patterns": SUITE, "backend": "scalar",
                                  "mode": "add", "runs": 5, "mesh": 2,
                                  "stream_r": True})
    assert (req.backend, req.mode, req.runs, req.mesh,
            req.stream_r) == ("scalar", "add", 5, 2, True)
    assert SuiteRequest.from_json(req.to_json()) == req


def test_schema_mesh_accepts_2d_shapes():
    # the 2-D placement wire form: [batch, lane] (DESIGN.md §11);
    # normalized to a tuple in the dataclass, back to a list on the wire
    req = SuiteRequest.from_json({"patterns": SUITE, "mesh": [4, 2]})
    assert req.mesh == (4, 2)
    assert req.to_json()["mesh"] == [4, 2]
    assert SuiteRequest.from_json(req.to_json()) == req
    # client-side kwargs may hand a tuple directly
    assert SuiteRequest.from_json(
        {"patterns": SUITE, "mesh": (4, 2)}).mesh == (4, 2)


def test_schema_mesh_accepts_auto():
    # mesh="auto" rides the wire as the literal string; the daemon
    # resolves it to a concrete shape via the §15 cost model
    req = SuiteRequest.from_json({"patterns": SUITE, "mesh": "auto"})
    assert req.mesh == "auto"
    assert req.to_json()["mesh"] == "auto"
    assert SuiteRequest.from_json(req.to_json()) == req
    with pytest.raises(ValueError, match="mesh"):
        SuiteRequest.from_json({"patterns": SUITE, "mesh": "turbo"})


def test_parse_mesh():
    from repro.serve.schema import parse_mesh
    assert parse_mesh("8") == 8
    assert parse_mesh("4x2") == (4, 2)
    assert parse_mesh(" 2X4 ") == (2, 4)
    assert parse_mesh("auto") == "auto"
    assert parse_mesh(" AUTO ") == "auto"
    for bad in ("4y2", "x", "4x", "4x2x1", "a"):
        with pytest.raises(ValueError, match="mesh"):
            parse_mesh(bad)


def test_schema_rejects_bad_requests():
    cases = [
        ([], "at least one pattern"),
        ({"patterns": SUITE, "backend": "cuda"}, "backend"),
        ({"patterns": SUITE, "mode": "max"}, "mode"),
        ({"patterns": SUITE, "metric": "measurd"}, "metric"),
        ({"patterns": SUITE, "runs": 0}, "runs"),
        ({"patterns": SUITE, "runs": "3"}, "runs"),
        ({"patterns": SUITE, "runs": 10 ** 9}, "runs"),
        ({"patterns": SUITE, "row_width": 10 ** 6}, "row_width"),
        ({"patterns": SUITE, "mesh": -1}, "mesh"),
        ({"patterns": SUITE, "mesh": True}, "mesh"),
        ({"patterns": SUITE, "mesh": [4]}, "mesh"),
        ({"patterns": SUITE, "mesh": [4, 2, 1]}, "mesh"),
        ({"patterns": SUITE, "mesh": [0, 2]}, "mesh"),
        ({"patterns": SUITE, "mesh": [True, 2]}, "mesh"),
        ({"patterns": SUITE, "mesh": ["4", 2]}, "mesh"),
        ({"patterns": SUITE, "mesh": [1 << 20, 2]}, "mesh"),
        ({"patterns": SUITE, "mesh": "4x2"}, "mesh"),   # wire form is a list
        ({"patterns": SUITE, "stream_r": 1}, "stream_r"),
        ({"patterns": SUITE, "stream_n": 4}, "stream_n"),
        ({"patterns": SUITE, "stream_n": 2 ** 40}, "stream_n"),
        ({"patterns": SUITE, "seed": -1}, "seed"),
        ({"patterns": SUITE, "mesh_axis": "a b"}, "mesh_axis"),
        ({"patterns": SUITE, "mod": "add"}, "unknown request fields"),
        ({"backend": "xla"}, "patterns"),
        ("42", "list or object"),
        ([{"name": "x"}, 7], r"patterns\[1\] is not an object"),
    ]
    for doc, needle in cases:
        with pytest.raises(ValueError, match=needle):
            SuiteRequest.from_json(doc)


def test_schema_bad_pattern_entry_is_value_error():
    req = SuiteRequest.from_json([{"name": "nope", "kernel": "Gather"}])
    with pytest.raises(ValueError, match="bad pattern entry"):
        req.build_patterns()
    # generator spec with too few args raises IndexError internally —
    # still a ValueError here (the daemon maps ValueError to a 400)
    for spec in ("UNIFORM", "MS1:8"):
        short = SuiteRequest.from_json(
            [{"name": "short", "kernel": "Gather", "pattern": spec,
              "delta": 1, "count": 1}])
        with pytest.raises(ValueError, match="bad pattern entry"):
            short.build_patterns()


def test_schema_bounds_pattern_geometry():
    # a few request bytes must not be able to declare a terabyte pattern:
    # geometry is bounded before any host buffer is allocated
    huge = [{"name": "huge", "kernel": "Gather", "pattern": "UNIFORM:8:1",
             "delta": 8, "count": 2 ** 40}]
    with pytest.raises(ValueError, match="too large to serve"):
        SuiteRequest.from_json(huge).build_patterns()
    # an enormous generator spec is rejected BEFORE materialization — a
    # 90-byte body must not build a 2-billion-element tuple while parsing
    gen = [{"name": "gen", "kernel": "Gather",
            "pattern": "UNIFORM:2000000000:1", "delta": 8, "count": 1}]
    with pytest.raises(ValueError, match="index buffer"):
        SuiteRequest.from_json(gen).build_patterns()
    # row_width multiplies the allocation: a lanes-ok pattern times a
    # huge row_width is rejected too
    wide = {"patterns": [{"name": "w", "kernel": "Gather",
                          "pattern": "UNIFORM:8:1", "delta": 8,
                          "count": 2 ** 20}], "row_width": 4096}
    with pytest.raises(ValueError, match="too large to serve"):
        SuiteRequest.from_json(wide).build_patterns()


def test_spec_index_len_mirror_tracks_generate_index():
    # the pre-materialization bound mirrors core's generator grammar;
    # this drift guard keeps the mirror honest: estimates must never
    # under-count a real buffer, and unknown generator heads must fail
    # CLOSED (oversized) rather than slip past the bound
    from repro.core.pattern import generate_index
    from repro.serve.schema import MAX_INDEX_LEN, _spec_index_len
    for spec in ("UNIFORM:8:1", "UNIFORM:128:4", "MS1:8:4:64",
                 "LAPLACIAN:2:2:100", "LAPLACIAN:3:1:10", "BROADCAST:8:4",
                 "STREAM:16", "CUSTOM:0,4,8,12", "0,4,8,12", [0, 3, 10]):
        est, real = _spec_index_len(spec), len(generate_index(spec))
        assert est >= real, (spec, est, real)
    assert _spec_index_len("HASH:2000000000:1") > MAX_INDEX_LEN


def test_wire_choice_sets_match_core():
    # schema duplicates core's choice sets to keep the client jax-free;
    # this is the drift guard that duplication relies on
    from repro.core import SCATTER_MODES
    from repro.core import backends as B
    from repro.core.suite import _METRIC_COLUMNS
    from repro.serve.schema import WIRE_BACKENDS, WIRE_METRICS, WIRE_MODES
    assert set(WIRE_BACKENDS) == set(B.BACKENDS)
    assert WIRE_MODES == SCATTER_MODES
    assert set(WIRE_METRICS) == set(_METRIC_COLUMNS)


def test_client_import_is_jax_free():
    # the thin HTTP client (and its schema validation) must not pay the
    # multi-second jax import — that is the whole point of --client
    code = ("import sys; sys.path.insert(0, %r); "
            "import repro.serve.client, repro.serve.schema; "
            "assert 'jax' not in sys.modules, 'client imports jax'; "
            "print('OK')" % SRC)
    r = subprocess.run([sys.executable, "-c", code],
                       capture_output=True, text=True, timeout=120)
    assert r.returncode == 0 and "OK" in r.stdout, r.stderr[-2000:]


# ---------------------------------------------------------------------------
# daemon round trips
# ---------------------------------------------------------------------------

def test_health_and_cache_endpoints(served):
    h = served.health()
    assert h["ok"] and h["service"] == "spatterd"
    assert h["n_devices"] >= 1 and "xla" in h["backends"]
    assert served.cache()["cache"] == {"hits": 0, "misses": 0, "size": 0,
                                       "batch_hits": 0, "disk_hits": 0,
                                       "degraded": 0}


def test_lint_endpoint_audits_warm_cache(served):
    # GET /lint: spatterlint over the daemon's LIVE cache.  Cold: zero
    # units (and "ok", but distinguishable from a real clean audit by
    # n_units).  Warm: every cached ExecKey is audited, zero violations.
    from repro.analysis.report import LintReport
    cold = served.lint()
    assert cold["ok"] and cold["report"]["n_units"] == 0
    served.run_suite(SUITE, backend="xla", runs=1)
    size = served.cache()["cache"]["size"]
    assert size > 0
    r = served.lint()
    report = LintReport.from_json(r["report"])     # shared schema parses
    assert r["ok"] and report.ok
    assert report.n_units == size                  # every entry audited
    assert report.n_violations == 0, report.summary()
    # the audit is read-only: serving telemetry unchanged
    assert served.cache()["cache"]["size"] == size


def test_cost_endpoint_accounts_warm_cache(served):
    # GET /cost: spattercost over the daemon's LIVE cache (DESIGN.md
    # §15).  Cold: zero units.  Warm: every cached ExecKey is
    # byte-accounted and reconciled against its lowered StableHLO.
    from repro.analysis.cost import CostReport
    cold = served.cost()
    assert cold["ok"] and cold["report"]["n_units"] == 0
    served.run_suite(SUITE, backend="xla", runs=1)
    size = served.cache()["cache"]["size"]
    r = served.cost()
    report = CostReport.from_json(r["report"])     # jax-free schema
    assert r["ok"] and report.ok
    assert report.n_units == size                  # every entry costed
    assert report.n_violations == 0, report.summary()
    for u in report.units:
        assert u.io_bytes > 0
        assert u.lowered_bytes > 0                 # live entries reconcile
    # read-only, like /lint
    assert served.cache()["cache"]["size"] == size


def test_cost_endpoint_degrades_on_restored_entries(tmp_path):
    # restored (DiskTier) executables are one opaque exported call: no
    # lowered signature to reconcile, so GET /cost degrades them to
    # key-geometry accounting (lowered_bytes = -1) plus the key-only
    # rules — mirroring /lint's downgrade — and stays clean
    from repro.analysis.cost import CostReport
    root = str(tmp_path)
    with SpatterDaemon(port=0, cache=ExecutorCache(), cache_dir=root) as d:
        c = SpatterClient(d.url)
        r1 = c.run_suite(SUITE, runs=1)
        n_buckets = r1["plan"]["n_buckets"]
    with SpatterDaemon(port=0, cache=ExecutorCache(), cache_dir=root) as d:
        c = SpatterClient(d.url)
        r2 = c.run_suite(SUITE, runs=1)
        assert r2["cache"]["misses"] == 0          # all restored from disk
        r = c.cost()
        report = CostReport.from_json(r["report"])
        assert r["ok"] and report.ok, report.summary()
        assert report.meta["restored"] == n_buckets
        assert report.n_units == n_buckets
        for u in report.units:
            assert u.lowered_bytes == -1           # opaque: not reconciled
            assert u.io_bytes > 0                  # geometry still exact


def test_mesh_auto_request_resolves_and_stays_warm(served):
    # mesh="auto" on the wire: the daemon resolves a placement per bucket
    # via the §15 cost model; on one device that is "single" everywhere,
    # so the ExecKeys — and therefore the warm cache and the digests —
    # match an unpinned request exactly
    r1 = served.run_suite(SUITE, runs=1)
    d1 = [t["digest"] for t in r1["stats"]["table"]]
    r2 = served.run_suite(SUITE, runs=1, mesh="auto")
    assert r2["ok"]
    placement = r2["plan"]["placement"]
    assert isinstance(placement, list) and set(placement) == {"single"}
    assert len(placement) == r2["plan"]["n_buckets"]
    assert r2["cache"]["misses"] == 0              # same ExecKeys as r1
    assert [t["digest"] for t in r2["stats"]["table"]] == d1


def test_mesh_auto_suite_request_picks_one_shape(served):
    # the escape hatch: mesh="auto-suite" keeps the pre-PR-10 behaviour
    # of one placement for the whole suite, reported as a plain string
    r1 = served.run_suite(SUITE, runs=1)
    d1 = [t["digest"] for t in r1["stats"]["table"]]
    r2 = served.run_suite(SUITE, runs=1, mesh="auto-suite")
    assert r2["ok"]
    assert r2["plan"]["placement"] == "single"
    assert r2["cache"]["misses"] == 0
    assert [t["digest"] for t in r2["stats"]["table"]] == d1


def test_second_request_compiles_nothing_and_is_bit_identical(served):
    r1 = served.run_suite(SUITE, backend="xla", runs=2)
    r2 = served.run_suite(SUITE, backend="xla", runs=2)
    assert r1["ok"] and r2["ok"]
    # cold: exactly one compile per bucket; warm: exactly zero
    assert r1["cache"]["misses"] == r1["plan"]["n_buckets"]
    assert r2["cache"]["misses"] == 0
    assert r2["cache"]["hits"] == r2["plan"]["n_buckets"]
    d1 = [row["digest"] for row in r1["stats"]["table"]]
    d2 = [row["digest"] for row in r2["stats"]["table"]]
    assert d1 == d2 and all(d1)
    # lifetime telemetry accumulates across requests
    assert r2["cache"]["lifetime"]["misses"] == r1["cache"]["misses"]


def test_client_accepts_envelope_documents(served):
    # the wire format's envelope form works through the client too, with
    # keyword options overriding same-named envelope fields
    env = {"patterns": SUITE, "runs": 1, "mode": "store"}
    r = served.run_suite(env)
    assert r["ok"] and r["stats"]["n_patterns"] == len(SUITE)
    r2 = served.run_suite(json.dumps(env), metric="modeled")
    assert r2["stats"]["metric"] == "modeled_v5e_gbs"
    # digest is opt-out on the wire
    r3 = served.run_suite(env, digest=False)
    assert all(row["digest"] is None for row in r3["stats"]["table"])


def test_response_stats_document(served):
    r = served.run_suite(SUITE, backend="xla", runs=1, metric="modeled")
    stats = r["stats"]
    assert stats["metric"] == "modeled_v5e_gbs"
    assert stats["n_patterns"] == len(SUITE)
    assert [row["name"] for row in stats["table"]] == ["g1", "g2", "s1"]
    for row in stats["table"]:
        assert row["gbs"] == row["modeled_v5e_gbs"] > 0
    assert 0 <= r["plan"]["pad_waste"] < 1
    assert r["elapsed_s"] > 0


def test_mode_add_reaches_the_executable(served):
    # duplicate-write suite: store and add must differ, and the two modes
    # must not share cache entries (mode is part of ExecKey)
    dup = [{"name": "dup", "kernel": "Scatter", "pattern": "BROADCAST:4:2",
            "delta": 0, "count": 8}]
    r_store = served.run_suite(dup, runs=1, mode="store")
    r_add = served.run_suite(dup, runs=1, mode="add")
    assert r_add["cache"]["misses"] > 0      # distinct executable
    assert r_store["stats"]["table"][0]["digest"] != \
        r_add["stats"]["table"][0]["digest"]
    # and each mode is itself warm-repeatable
    again = served.run_suite(dup, runs=1, mode="add")
    assert again["cache"]["misses"] == 0
    assert again["stats"]["table"][0]["digest"] == \
        r_add["stats"]["table"][0]["digest"]


def test_stream_r_surfaces_in_response(served):
    # row_width 8 + stride spread: the modeled column gets real variance,
    # so R is defined (a 1-pattern or uniform suite serializes null)
    pats = [{"name": f"g{s}", "kernel": "Gather",
             "pattern": f"UNIFORM:8:{s}", "delta": 8, "count": 64}
            for s in (1, 16, 64)]
    r = served.run_suite(pats, runs=1, row_width=8, stream_r=True,
                         stream_n=1024)
    assert r["stats"]["stream_gbs"] and r["stats"]["stream_gbs"] > 0
    assert -1.0 <= r["stats"]["stream_r"] <= 1.0
    # off by default
    r2 = served.run_suite(pats, runs=1)
    assert r2["stats"]["stream_gbs"] is None
    assert r2["stats"]["stream_r"] is None
    # the reference run is memoized per (backend, n, runs): a repeat
    # stream_r request reuses its RunResult, so the measured stream_gbs
    # is byte-for-byte the first one's (a re-run would re-time it)
    r3 = served.run_suite(pats, runs=1, row_width=8, stream_r=True,
                          stream_n=1024)
    assert r3["stats"]["stream_gbs"] == r["stats"]["stream_gbs"]
    assert r3["cache"]["misses"] == 0


def test_mesh_request_single_device(served):
    r1 = served.run_suite(SUITE, runs=1, mesh=1)
    r2 = served.run_suite(SUITE, runs=1, mesh=1)
    assert r2["cache"]["misses"] == 0
    # sharded results bit-identical to the single-device launch
    r0 = served.run_suite(SUITE, runs=1)
    assert [t["digest"] for t in r0["stats"]["table"]] == \
        [t["digest"] for t in r1["stats"]["table"]]


def test_http_error_codes(served):
    with pytest.raises(ServerError) as e:
        served._request("/run", {"patterns": SUITE, "mode": "max"})
    assert e.value.status == 400
    with pytest.raises(ServerError) as e:
        served.run_suite(SUITE, mesh=4096)      # > visible devices
    assert e.value.status == 400
    with pytest.raises(ServerError) as e:
        served._request("/nope", {})
    assert e.value.status == 404
    # client-side validation gives the same message without a round trip
    with pytest.raises(ValueError, match="mode"):
        served.run_suite(SUITE, mode="max")
    # and the daemon is still healthy afterwards
    assert served.health()["ok"]


def test_keep_alive_connection_survives_404(served):
    # the daemon speaks HTTP/1.1 (persistent connections): a wrong-path
    # POST must still drain its body, or the leftover bytes would be
    # parsed as the next request's start line on the same connection
    import http.client
    host, port = served.url[len("http://"):].rsplit(":", 1)
    conn = http.client.HTTPConnection(host, int(port), timeout=60)
    try:
        hdr = {"Content-Type": "application/json"}
        conn.request("POST", "/runs", body=json.dumps(SUITE), headers=hdr)
        r1 = conn.getresponse()
        assert r1.status == 404 and not json.loads(r1.read())["ok"]
        # same connection: a valid request right behind the 404
        conn.request("POST", "/run", headers=hdr,
                     body=json.dumps({"patterns": SUITE, "runs": 1}))
        r2 = conn.getresponse()
        doc = json.loads(r2.read())
        assert r2.status == 200 and doc["ok"]
    finally:
        conn.close()


def test_bad_framing_gets_an_error_response(served):
    # a malformed Content-Length must produce an HTTP error (and close
    # the connection), never an unhandled handler crash with no response
    import socket
    host, port = served.url[len("http://"):].rsplit(":", 1)
    with socket.create_connection((host, int(port)), timeout=60) as s:
        s.sendall(b"POST /run HTTP/1.1\r\nHost: x\r\n"
                  b"Content-Length: abc\r\n\r\n")
        head = s.recv(4096).decode()
    assert head.startswith("HTTP/1.1 400"), head
    assert served.health()["ok"]               # daemon unharmed


def test_concurrent_requests_keep_exact_telemetry(served):
    # N identical concurrent requests through the scheduler: every
    # compile is attributed to exactly ONE request (the launch that
    # claimed the _BuildFuture), so per-request misses sum EXACTLY to
    # the daemon's lifetime compile count — no lost or double-counted
    # compiles under concurrency.  Coalescing may stack requests into
    # shared launches whose combined batch lands in a larger pow-2
    # bracket, so the sum can exceed n_buckets (one compile per DISTINCT
    # bracket actually launched), but never non-deterministically drift
    # from the cache's own count.  Results stay bit-identical regardless
    # of which launch carried which request.
    before = served.stats()["cache"]["misses"]
    results = []

    def post():
        results.append(served.run_suite(SUITE, runs=1))

    threads = [threading.Thread(target=post) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(results) == 4 and all(r["ok"] for r in results)
    n_buckets = results[0]["plan"]["n_buckets"]
    compiles = served.stats()["cache"]["misses"] - before
    assert sum(r["cache"]["misses"] for r in results) == compiles
    assert compiles >= n_buckets              # each family compiled once
    digests = {tuple(t["digest"] for t in r["stats"]["table"])
               for r in results}
    assert len(digests) == 1                  # all four bit-identical
    # every request reports its scheduler telemetry
    assert all(r["serve"]["launches"] == n_buckets for r in results)


def test_stats_endpoint_reports_scheduler_snapshot(served):
    s0 = served.stats()
    assert s0["ok"] and s0["n_requests"] == 0 and s0["uptime_s"] >= 0
    assert s0["cache"]["misses"] == 0
    sched = s0["scheduler"]
    assert sched["workers"] >= 1 and sched["queue_depth"] == 0
    assert sched["submitted"] == 0 and sched["total_launches"] == 0
    served.run_suite(SUITE, runs=1)
    s1 = served.stats()
    assert s1["n_requests"] == 1
    assert s1["cache"]["misses"] > 0            # lifetime compile count
    assert s1["scheduler"]["submitted"] == 1
    assert s1["scheduler"]["completed"] == 1
    assert s1["scheduler"]["total_launches"] >= 1


def test_serial_baseline_daemon_has_no_scheduler():
    # workers=0 keeps the PR 4 run-lock path: /stats says so (null
    # scheduler) and /run still serves with exact telemetry, minus the
    # serve section
    with SpatterDaemon(port=0, cache=ExecutorCache(), workers=0) as d:
        c = SpatterClient(d.url)
        assert c.stats()["scheduler"] is None
        r = c.run_suite(SUITE, runs=1)
        assert r["ok"] and r["serve"] is None
        assert r["cache"]["misses"] == r["plan"]["n_buckets"]
        assert c.run_suite(SUITE, runs=1)["cache"]["misses"] == 0


def test_client_keep_alive_reuses_socket(served):
    # the whole point of the http.client rewrite: one persistent
    # connection per (client, thread), not a TCP handshake per probe
    served.health()
    conn = served._conn()
    sock = conn.sock
    assert sock is not None
    served.cache()
    served.stats()
    assert served._conn() is conn and conn.sock is sock
    # close() drops only this thread's connection
    served.close()
    assert getattr(served._local, "conn", None) is None


def test_client_retries_get_across_daemon_restart():
    # an idle daemon restart leaves the client holding a dead keep-alive
    # socket; the next GET must remount and succeed (bounded retry),
    # because read-only probes are idempotent
    d1 = SpatterDaemon(port=0, cache=ExecutorCache()).start()
    port = d1.port
    c = SpatterClient(d1.url)
    assert c.health()["ok"]
    assert c._conn().sock is not None           # keep-alive socket cached
    d1.stop()
    with SpatterDaemon(port=port, cache=ExecutorCache()) as d2:
        assert d2.port == port
        assert c.health()["ok"]                 # retried on the dead socket
    # with no daemon at all, the retry budget exhausts into status 0
    # (drop the cached socket first: stopped daemons no longer accept,
    # but an established keep-alive handler thread would still answer)
    c.close()
    with pytest.raises(ServerError) as e:
        c.health()
    assert e.value.status == 0


def test_backpressure_503_with_retry_after():
    # a full scheduler queue rejects BEFORE the run — the handler maps
    # QueueFull to 503 + Retry-After while staged requests are unharmed
    one = [SUITE[0]]                            # single bucket -> 1 item
    with SpatterDaemon(port=0, cache=ExecutorCache(), workers=1,
                       max_queue=2) as d:
        c = SpatterClient(d.url)
        d.scheduler.pause()
        results, threads = [], []
        for _ in range(2):                      # stage the queue full
            t = threading.Thread(
                target=lambda: results.append(c.run_suite(one, runs=1)))
            t.start()
            threads.append(t)
        deadline = time.time() + 60
        while (d.scheduler.snapshot()["queue_depth"] < 2
               and time.time() < deadline):
            time.sleep(0.01)
        assert d.scheduler.snapshot()["queue_depth"] == 2
        # raw exchange so the Retry-After header is visible
        import http.client
        conn = http.client.HTTPConnection(d.host, d.port, timeout=60)
        try:
            body = json.dumps({"patterns": one, "runs": 1})
            conn.request("POST", "/run", body=body,
                         headers={"Content-Type": "application/json"})
            resp = conn.getresponse()
            doc = json.loads(resp.read())
            assert resp.status == 503
            assert int(resp.getheader("Retry-After")) >= 1
            assert not doc["ok"] and doc["retry_after_s"] >= 1
            assert "queue full" in doc["error"]
        finally:
            conn.close()
        assert d.cache.stats().misses == 0      # rejected before any work
        d.scheduler.resume()
        for t in threads:
            t.join(timeout=300)
        assert len(results) == 2 and all(r["ok"] for r in results)
        assert c.health()["ok"]


def test_acceptance_16_clients_coalesce_to_one_compile():
    # ISSUE 7 acceptance: 16 concurrent clients posting the same
    # single-bucket suite cause exactly ONE compile and fewer launches
    # than requests, with responses bit-identical to the serial
    # run_plan path.  pause() stages all 16 in the queue so the sweep
    # is deterministic, then resume() releases one coalesced launch.
    from repro.core import SuitePlan
    from repro.core.plan import run_plan
    one = [SUITE[0]]
    with SpatterDaemon(port=0, cache=ExecutorCache()) as d:
        c = SpatterClient(d.url)
        d.scheduler.pause()
        results = []
        threads = [threading.Thread(
            target=lambda: results.append(c.run_suite(one, runs=1)))
            for _ in range(16)]
        for t in threads:
            t.start()
        deadline = time.time() + 120
        while (d.scheduler.snapshot()["queue_depth"] < 16
               and time.time() < deadline):
            time.sleep(0.01)
        assert d.scheduler.snapshot()["queue_depth"] == 16
        d.scheduler.resume()
        for t in threads:
            t.join(timeout=600)
        snap = d.scheduler.snapshot()
        compiles = d.cache.stats().misses

    assert len(results) == 16 and all(r["ok"] for r in results)
    # exactly one compile, attributed to exactly one request
    assert compiles == 1
    assert sum(r["cache"]["misses"] for r in results) == 1
    # fewer launches than requests — in the staged case, exactly one
    assert snap["total_launches"] == 1 < 16
    assert snap["coalesced_launches"] == 1
    assert all(r["serve"]["launches"] == 1 for r in results)
    assert all(r["serve"]["coalesced_launches"] == 1 for r in results)
    # bit-identical to the serial run_plan path
    pats = SuiteRequest.from_json(one).build_patterns()
    ref = run_plan(SuitePlan.build(pats), runs=1, cache=ExecutorCache(),
                   digest=True)
    refd = [r.out_digest for r in ref]
    assert all(refd)
    for r in results:
        assert [t["digest"] for t in r["stats"]["table"]] == refd


# ---------------------------------------------------------------------------
# acceptance: sharded serving, 8 fake devices, real daemon process
# ---------------------------------------------------------------------------

SHARDED_SERVE = textwrap.dedent("""\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, %r)
    import jax
    assert len(jax.devices()) == 8, jax.devices()
    from repro.core import ExecutorCache
    from repro.serve import SpatterClient, SpatterDaemon

    SUITE = %s
    with SpatterDaemon(port=0, cache=ExecutorCache()) as d:
        c = SpatterClient(d.url)
        base = c.run_suite(SUITE, runs=1)
        d0 = [t["digest"] for t in base["stats"]["table"]]
        r1 = c.run_suite(SUITE, runs=1, mesh=8)
        r2 = c.run_suite(SUITE, runs=1, mesh=8)
        assert r2["cache"]["misses"] == 0, r2["cache"]
        d1 = [t["digest"] for t in r1["stats"]["table"]]
        d2 = [t["digest"] for t in r2["stats"]["table"]]
        assert d1 == d2 == d0 and all(d1), (d0, d1, d2)
        # 2-D placement requests (mesh=[b, l]): distinct executables from
        # the 1-D path, same bit-identical digests, warm repeat compiles 0
        m1 = c.run_suite(SUITE, runs=1, mesh=[4, 2])
        assert m1["cache"]["misses"] > 0, m1["cache"]   # new placement
        m2 = c.run_suite(SUITE, runs=1, mesh=[4, 2])
        assert m2["cache"]["misses"] == 0, m2["cache"]
        e1 = [t["digest"] for t in m1["stats"]["table"]]
        e2 = [t["digest"] for t in m2["stats"]["table"]]
        assert e1 == e2 == d0 and all(e1), (d0, e1, e2)
        # GET /lint on the warm cache: single-device AND placed (8, 4x2)
        # executables all audit clean, one unit per cached ExecKey
        lr = c.lint()
        size = c.cache()["cache"]["size"]
        assert lr["ok"], lr["report"]["violations"]
        assert lr["report"]["n_units"] == size > 0, (lr["report"], size)
    print("OK")
    """)


def test_acceptance_sharded_serve_8dev_subprocess():
    code = SHARDED_SERVE % (SRC, json.dumps(SUITE))
    r = subprocess.run([sys.executable, "-c", code],
                       capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
    assert "OK" in r.stdout


# ---------------------------------------------------------------------------
# warm start: POST /warm, persistent cache across restarts, crash safety
# ---------------------------------------------------------------------------

def test_warm_endpoint_makes_run_execute_only(served):
    w = served.warm(SUITE)
    assert w["ok"] and w["n_executables"] > 0
    assert w["compiled"] == w["n_executables"]
    assert w["cache"]["misses"] == w["compiled"]
    # the warmed executables are first-called too (jit dispatch cache
    # populated), so the next /run is execute-only: zero compiles
    r = served.run_suite(SUITE, runs=1)
    assert r["ok"] and r["cache"]["misses"] == 0
    assert all(t["digest"] for t in r["stats"]["table"])
    w2 = served.warm(SUITE)                   # warming twice is idempotent
    assert w2["compiled"] == 0 and w2["cache"]["misses"] == 0


def test_warm_restart_zero_compiles_bit_identical(tmp_path):
    root = str(tmp_path)
    with SpatterDaemon(port=0, cache=ExecutorCache(), cache_dir=root) as d:
        c = SpatterClient(d.url)
        r1 = c.run_suite(SUITE, runs=1)
        n_buckets = r1["plan"]["n_buckets"]
        digests = [t["digest"] for t in r1["stats"]["table"]]
        assert d.disk.stats()["stores"] == n_buckets
    # a FRESH daemon process-equivalent (new ExecutorCache) on the
    # populated directory: the whole suite serves with zero compiles
    with SpatterDaemon(port=0, cache=ExecutorCache(), cache_dir=root) as d:
        c = SpatterClient(d.url)
        r2 = c.run_suite(SUITE, runs=1)
        assert r2["cache"]["misses"] == 0
        assert r2["cache"]["lifetime"]["misses"] == 0
        assert r2["cache"]["lifetime"]["disk_hits"] == n_buckets
        assert [t["digest"] for t in r2["stats"]["table"]] == digests
        assert c.stats()["disk"]["quarantined"] == 0


CRASH_PHASE1 = textwrap.dedent("""\
    import json, os, signal, sys
    sys.path.insert(0, %r)
    from repro.core import ExecutorCache
    from repro.serve import SpatterClient, SpatterDaemon

    SUITE = %s
    root, out = sys.argv[1], sys.argv[2]
    d = SpatterDaemon(port=0, cache=ExecutorCache(), cache_dir=root).start()
    c = SpatterClient(d.url)
    r = c.run_suite(SUITE, runs=1)
    json.dump({"digests": [t["digest"] for t in r["stats"]["table"]],
               "n_buckets": r["plan"]["n_buckets"],
               "stores": d.disk.stats()["stores"]}, open(out, "w"))
    os.kill(os.getpid(), signal.SIGKILL)   # hard crash: no atexit, no drain
    """)

CRASH_PHASE2 = textwrap.dedent("""\
    import json, sys
    sys.path.insert(0, %r)
    from repro.core import ExecutorCache
    from repro.serve import SpatterClient, SpatterDaemon

    SUITE = %s
    root, ref_path = sys.argv[1], sys.argv[2]
    ref = json.load(open(ref_path))
    with SpatterDaemon(port=0, cache=ExecutorCache(), cache_dir=root) as d:
        c = SpatterClient(d.url)
        r = c.run_suite(SUITE, runs=1)
        assert r["cache"]["misses"] == 0, r["cache"]        # pre-kill entries
        assert [t["digest"] for t in r["stats"]["table"]] == ref["digests"]
        assert d.disk.stats()["quarantined"] == 1, d.disk.stats()
    print("OK")
    """)


def test_crash_safety_sigkill_then_warm_restart(tmp_path):
    # a daemon SIGKILLed after serving must leave a cache directory a
    # fresh daemon can trust: complete entries restore (0 compiles,
    # bit-identical), and a torn half-written entry — planted here as a
    # truncated copy, what a non-atomic writer would leave — is caught
    # by the checksum and quarantined, never loaded
    import glob
    import signal as _signal
    root = str(tmp_path / "cache")
    out = str(tmp_path / "phase1.json")
    r1 = subprocess.run(
        [sys.executable, "-c", CRASH_PHASE1 % (SRC, json.dumps(SUITE)),
         root, out],
        capture_output=True, text=True, timeout=540)
    assert r1.returncode == -_signal.SIGKILL, (r1.stdout, r1.stderr[-3000:])
    ref = json.load(open(out))
    assert ref["stores"] == ref["n_buckets"]
    victim = sorted(glob.glob(os.path.join(root, "*.spx")))[0]
    with open(victim, "rb") as f:
        raw = f.read()
    with open(os.path.join(root, "f" * 40 + ".spx"), "wb") as f:
        f.write(raw[:len(raw) - 7])
    r2 = subprocess.run(
        [sys.executable, "-c", CRASH_PHASE2 % (SRC, json.dumps(SUITE)),
         root, out],
        capture_output=True, text=True, timeout=540)
    assert r2.returncode == 0, (r2.stdout[-1000:], r2.stderr[-3000:])
    assert "OK" in r2.stdout


SHARDED_RESTART = textwrap.dedent("""\
    import json, os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    sys.path.insert(0, %r)
    from repro.core import ExecutorCache
    from repro.serve import SpatterClient, SpatterDaemon

    SUITE = %s
    root, ref_path, phase = sys.argv[1], sys.argv[2], sys.argv[3]
    with SpatterDaemon(port=0, cache=ExecutorCache(), cache_dir=root) as d:
        c = SpatterClient(d.url)
        digs = {}
        for name, kw in (("flat", {}), ("mesh8", {"mesh": 8}),
                         ("mesh4x2", {"mesh": [4, 2]})):
            r = c.run_suite(SUITE, runs=1, **kw)
            digs[name] = [t["digest"] for t in r["stats"]["table"]]
            if phase == "warm":
                assert r["cache"]["misses"] == 0, (name, r["cache"])
        if phase == "warm":
            assert d.cache.stats().misses == 0      # across ALL placements
            assert json.load(open(ref_path)) == digs
        else:
            json.dump(digs, open(ref_path, "w"))
    print("OK")
    """)


def test_acceptance_sharded_warm_restart_subprocess(tmp_path):
    # the ISSUE 8 restart proof on the 2-D placement path: a fresh
    # 8-device daemon on a populated cache dir serves flat, mesh=8, AND
    # mesh=[4,2] with zero compiles and bit-identical digests
    root, ref = str(tmp_path / "cache"), str(tmp_path / "ref.json")
    code = SHARDED_RESTART % (SRC, json.dumps(SUITE))
    for phase in ("cold", "warm"):
        r = subprocess.run([sys.executable, "-c", code, root, ref, phase],
                           capture_output=True, text=True, timeout=540)
        assert r.returncode == 0, (phase, r.stdout[-1000:],
                                   r.stderr[-3000:])
        assert "OK" in r.stdout


def test_sigterm_graceful_drain_cli():
    import signal as _signal
    env = {**os.environ, "PYTHONPATH": SRC, "PYTHONUNBUFFERED": "1"}
    p = subprocess.Popen(
        [sys.executable, "-m", "repro.serve.daemon", "--port", "0"],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True, env=env)
    try:
        line = p.stdout.readline()
        assert "listening on" in line, line
        c = SpatterClient(line.split("listening on")[1].split()[0])
        assert c.run_suite([SUITE[0]], runs=1)["ok"]
        p.send_signal(_signal.SIGTERM)
        out, err = p.communicate(timeout=300)
    finally:
        p.kill()
    assert p.returncode == 0, (out, err[-3000:])
    assert "drained cleanly" in out
    # fully drained: the port no longer accepts (drop the cached
    # keep-alive socket first, as in the restart-retry test)
    c.close()
    with pytest.raises(ServerError) as e:
        c.health()
    assert e.value.status == 0
