"""deepseek-v2-236b [moe] — 60L d5120 128H d_ff_expert=1536 vocab=102400.

MLA: kv_lora_rank=512, q_lora_rank=1536, qk_nope=128, qk_rope=64, v=128.
MoE: 2 shared + 160 routed experts, top-6; first layer dense (d_ff 12288).
[arXiv:2405.04434; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=12288, vocab=102400,
    attn_kind="mla", rope="full",
    kv_lora_rank=512, q_lora_rank=1536,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    n_experts=160, n_shared_experts=2, top_k=6, d_ff_expert=1536,
    n_dense_layers=1, d_ff_dense=12288, mlp_kind="swiglu",
)

SMOKE = ModelConfig(
    arch_id="deepseek-v2-236b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256,
    attn_kind="mla", rope="full",
    kv_lora_rank=32, q_lora_rank=48,
    qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
    n_experts=8, n_shared_experts=2, top_k=2, d_ff_expert=32,
    n_dense_layers=1, d_ff_dense=128, mlp_kind="swiglu", attn_chunk=16,
)
