"""spatterd — a long-lived suite-serving daemon on the warm ExecutorCache.

The paper's value proposition is sweeping *many* configurations cheaply
(§3.3 JSON suites, §3.5 min-over-K timing); the planner PRs made a repeat
suite run compile nothing, but only inside one-shot scripts.  spatterd is
the process that makes repeated execution the product (DESIGN.md §10):
it holds the process-wide ``ExecutorCache`` open across HTTP requests, so
the FIRST identical suite request compiles ``n_buckets`` executables and
every later one — from any client — compiles zero, and each response
carries the telemetry that proves it (per-request cache hits/misses,
where ``misses`` is an exact compile count, plus per-pattern output
digests for bit-identity).

Endpoints (all JSON; stdlib ``http.server``, no dependencies):

    POST /run      run a suite (schema.SuiteRequest; bare ``suites/*.json``
                   lists work as-is).  ``mesh: N`` in the request shards
                   every bucket launch's pattern-batch dim over N devices;
                   ``mesh: [b, l]`` places launches on a 2-D (batch x
                   lane) mesh (plan.Placement, DESIGN.md §11).
    GET  /healthz  liveness + device/backend inventory + lifetime stats
    GET  /cache    lifetime ExecutorCache counters
    GET  /lint     spatterlint audit of the live cache's compiled
                   executables (repro.analysis, DESIGN.md §12) — the
                   report schema the --lint CLI shares

Quickstart::

    PYTHONPATH=src python -m repro.serve.daemon --port 8089 &
    PYTHONPATH=src python -m repro.serve.client --url http://127.0.0.1:8089 \
        --json suites/demo.json

Concurrency model: request *handling* is multi-threaded
(``ThreadingHTTPServer`` — parsing, validation, and serialization overlap
freely), but suite *execution* is serialized by one run lock.  Two
reasons: concurrent XLA executions would contend for the same device and
corrupt each other's min-over-K timings (§3.5), and bracketing each run
with ``ExecutorCache.stats()`` snapshots under the lock is what makes the
per-request hits/misses delta exact rather than approximate.  The cache
itself is additionally lock-protected (plan.ExecutorCache) so /cache and
/healthz can read counters mid-run.
"""
from __future__ import annotations

import argparse
import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.core import backends as B
from repro.core.plan import ExecutorCache, default_cache
from repro.core.suite import run_suite, stream_reference

from .schema import SuiteRequest


def _bounded_put(memo: dict, key, value, bound: int = 32) -> None:
    """FIFO-bounded insert: client-controlled memo keys must never grow a
    long-lived daemon's memory without limit."""
    while len(memo) >= bound:
        memo.pop(next(iter(memo)))
    memo[key] = value


class SpatterDaemon:
    """The serving process around one (usually process-wide) ExecutorCache.

    ``port=0`` binds an ephemeral port (read it back from ``.port``) —
    tests and benchmarks use that to avoid collisions.  ``start()`` serves
    from a background thread; ``serve_forever()`` blocks (the CLI path).
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 8089, *,
                 cache: ExecutorCache | None = None, quiet: bool = True):
        self.cache = cache if cache is not None else default_cache()
        self.quiet = quiet
        self.started_at = time.time()
        self.n_requests = 0
        self._run_lock = threading.Lock()
        self._memo_lock = threading.Lock()     # guards _placements mutation
        self._placements: dict[tuple, object] = {}   # (shape, axis) -> Placement
        self._stream_refs: dict[tuple, object] = {}   # memoized STREAM runs
        self._thread: threading.Thread | None = None
        self._httpd = ThreadingHTTPServer((host, port), _make_handler(self))
        # ThreadingHTTPServer defaults block process exit on hung handlers
        self._httpd.daemon_threads = True

    # -- lifecycle -----------------------------------------------------------
    @property
    def host(self) -> str:
        return self._httpd.server_address[0]

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "SpatterDaemon":
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="spatterd", daemon=True)
        self._thread.start()
        return self

    def serve_forever(self) -> None:
        self._httpd.serve_forever()

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None

    def __enter__(self) -> "SpatterDaemon":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- request execution ---------------------------------------------------
    def _placement(self, mesh, axis: str):
        """Placement per (shape, batch axis), memoized by shape tuple: the
        canonical placement string — not the Mesh object's identity — keys
        the ExecutorCache, but reusing the object keeps mesh/sharding
        construction out of repeat requests.  ``mesh`` is the validated
        wire value: an int N (batch-only) or a (b, l) tuple (2-D).
        Called OUTSIDE the run lock so an oversized mesh fails fast even
        while a long run is in flight; _memo_lock covers the check +
        bounded FIFO eviction + insert (concurrent handler threads)."""
        import jax
        from repro.core.plan import Placement
        shape = (mesh, 1) if isinstance(mesh, int) else tuple(mesh)
        key = (shape, axis)
        with self._memo_lock:
            if key not in self._placements:
                n_dev = len(jax.devices())
                need = shape[0] * shape[1]
                if need > n_dev:
                    raise ValueError(
                        f"mesh={mesh} needs {need} devices, {n_dev} visible "
                        f"(start the daemon under XLA_FLAGS=--xla_force_"
                        f"host_platform_device_count={need} to fake devices "
                        f"on CPU)")
                _bounded_put(self._placements, key,
                             Placement.create(shape, batch_axis=axis))
            return self._placements[key]

    def run_request(self, req: SuiteRequest) -> dict:
        """Execute one validated request; returns the response document.

        Raises ValueError for request-shaped problems (bad pattern entry,
        mesh larger than the device count) — the handler maps those to
        400s — and lets genuine execution failures propagate to a 500.
        """
        # request-shaped failures (bad patterns, oversized mesh) resolve
        # BEFORE the run lock: a 400 never queues behind an in-flight run
        patterns = req.build_patterns()
        mesh = self._placement(req.mesh, req.mesh_axis) if req.mesh else None
        with self._run_lock:
            # timed inside the lock: elapsed_s is THIS request's
            # execution, not time spent queued behind other requests
            t0 = time.perf_counter()
            stream_ref = None
            if req.stream_r:
                # the STREAM reference is its own jitted engine, outside
                # the ExecutorCache; memoize its RunResult so only the
                # FIRST stream_r request per (backend, n, runs) compiles
                # and times it — warm requests stay execute-only, keeping
                # the misses==0 warm-repeat proof honest
                skey = (req.backend, req.stream_n, req.runs)
                stream_ref = self._stream_refs.get(skey)
                if stream_ref is None:
                    stream_ref = stream_reference(
                        n=req.stream_n, runs=req.runs, backend=req.backend)
                    _bounded_put(self._stream_refs, skey, stream_ref)
            before = self.cache.stats()
            stats = run_suite(
                patterns, backend=req.backend, runs=req.runs,
                row_width=req.row_width, metric=req.metric, mode=req.mode,
                seed=req.seed, cache=self.cache, mesh=mesh,
                mesh_axis=req.mesh_axis, stream_r=req.stream_r,
                stream_n=req.stream_n, stream_ref=stream_ref,
                digest=req.digest)
            after = self.cache.stats()
            self.n_requests += 1
        delta = after.delta(before)
        return {
            "ok": True,
            "stats": stats.to_json(req.metric),
            "cache": {
                # this request's traffic; misses == exact compile count
                "hits": delta.hits,
                "misses": delta.misses,
                "size": after.size,
                "lifetime": after.to_json(),
            },
            "plan": {
                "n_buckets": stats.plan.n_buckets,
                # the plan's static padding waste at exact-fit batches — a
                # lower bound when best_batch serves a larger warm
                # executable (member bandwidth attribution already uses
                # the actual launched batch, plan.run_plan)
                "pad_waste": stats.plan.pad_waste(
                    *(mesh.grid if mesh is not None else (1, 1))),
            },
            "elapsed_s": time.perf_counter() - t0,
        }

    def lint(self) -> dict:
        """Static audit of every compiled executable in the live cache.

        Runs the executable-scope spatterlint rules against each cached
        ExecKey, reconstructing launch avals from the key alone — so the
        audit also proves the keys describe their executables honestly.
        Read-only (``ExecutorCache.entries``): it can run mid-request
        without perturbing the hits/misses telemetry, and it takes no
        lock the run path needs.
        """
        from repro.analysis.lint import lint_cache
        report = lint_cache(self.cache)
        return {"ok": report.ok, "report": report.to_json()}

    def health(self) -> dict:
        import jax
        return {
            "ok": True,
            "service": "spatterd",
            "n_devices": len(jax.devices()),
            "backends": sorted(B.BACKENDS),
            "n_requests": self.n_requests,
            "uptime_s": time.time() - self.started_at,
            "cache": self.cache.stats().to_json(),
        }

    def _log(self, fmt: str, *args) -> None:
        if not self.quiet:
            print(f"spatterd: {fmt % args}", flush=True)


MAX_BODY_BYTES = 64 << 20     # one request can't OOM a long-lived daemon


def _make_handler(daemon: SpatterDaemon):
    class Handler(BaseHTTPRequestHandler):
        server_version = "spatterd/1.0"
        protocol_version = "HTTP/1.1"
        # socket timeout: a stalled upload or an idle keep-alive
        # connection must not pin a handler thread forever (the
        # stdlib default is no timeout at all)
        timeout = 120

        def log_message(self, fmt, *args):          # route through the daemon
            daemon._log(fmt, *args)

        def _reply(self, code: int, doc: dict) -> None:
            body = json.dumps(doc).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def do_GET(self):
            if self.path in ("/healthz", "/health"):
                self._reply(200, daemon.health())
            elif self.path == "/cache":
                self._reply(200, {"ok": True,
                                  "cache": daemon.cache.stats().to_json()})
            elif self.path == "/lint":
                self._reply(200, daemon.lint())
            else:
                self._reply(404, {"ok": False,
                                  "error": f"no such path {self.path!r}"})

        def do_POST(self):
            # a body we cannot fully drain would desync HTTP/1.1
            # keep-alive (leftover bytes parse as the NEXT request's
            # start line): bad framing gets an error AND a closed
            # connection
            te = (self.headers.get("Transfer-Encoding") or "").lower()
            if "chunked" in te:
                self.close_connection = True
                self._reply(411, {"ok": False,
                                  "error": "chunked bodies unsupported; "
                                           "send Content-Length"})
                return
            try:
                length = int(self.headers.get("Content-Length", 0))
                if length < 0:
                    raise ValueError(length)
            except (TypeError, ValueError):
                self.close_connection = True
                self._reply(400, {"ok": False,
                                  "error": "bad Content-Length header"})
                return
            if length > MAX_BODY_BYTES:
                self.close_connection = True
                self._reply(413, {"ok": False,
                                  "error": f"body {length} bytes > "
                                           f"{MAX_BODY_BYTES} limit"})
                return
            # drain the body unconditionally: on HTTP/1.1 keep-alive an
            # unread body would be parsed as the NEXT request's start line
            body = self.rfile.read(length)
            if self.path != "/run":
                self._reply(404, {"ok": False,
                                  "error": f"no such path {self.path!r}; "
                                           f"POST /run"})
                return
            try:
                doc = json.loads(body)
                req = SuiteRequest.from_json(doc)
            except (ValueError, KeyError, TypeError) as e:
                self._reply(400, {"ok": False, "error": f"bad request: {e}"})
                return
            try:
                self._reply(200, daemon.run_request(req))
            except ValueError as e:
                self._reply(400, {"ok": False, "error": str(e)})
            except Exception as e:   # execution failure: report, stay alive
                self._reply(500, {"ok": False,
                                  "error": f"{type(e).__name__}: {e}"})

    return Handler


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="spatterd: long-lived Spatter suite server "
                    "(warm ExecutorCache across requests)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8089)
    ap.add_argument("--verbose", action="store_true",
                    help="log one line per handled request")
    args = ap.parse_args(argv)
    daemon = SpatterDaemon(args.host, args.port, quiet=not args.verbose)
    print(f"spatterd listening on {daemon.url}  "
          f"(POST /run, GET /healthz)", flush=True)
    try:
        daemon.serve_forever()
    except KeyboardInterrupt:
        daemon.stop()


if __name__ == "__main__":
    main()
