"""Scalar-prefetch DMA row gather — the TPU-native Spatter gather kernel.

Two regimes, mirroring the paper's cache-resident vs memory-resident split
(DESIGN.md §2):

  * ``dma``  — the table stays in HBM (``pltpu.ANY``); the index buffer is
    scalar-prefetched into SMEM and the kernel issues its own row DMAs
    against a two-slot VMEM scratch: while row ``r``'s copy drains into
    the output tile, row ``r+1``'s DMA is already in flight (explicit
    double buffering, DESIGN.md §16).  Each grid step covers ``block_i``
    rows, so the pipeline keeps one fetch ahead across the whole block —
    the TPU analogue of the HW prefetcher's outstanding-miss depth
    studied in paper Fig 4.
  * ``vmem`` — small tables are staged whole into VMEM and gathered with an
    in-register ``take`` over ``block_n`` rows per step (the "cache-resident"
    regime: once the table is in VMEM, arbitrary reuse is free).

The CUDA backend's trick of staging the index buffer in shared memory (paper
§3.2) maps exactly onto scalar prefetch: indices live in SMEM for the whole
kernel invocation.

Both kernels are batch-NATIVE (DESIGN.md §2.2): the grid leads with the
pattern-batch dim so a whole planner bucket — (B, V, D) tables, (B, N)
indices — is ONE launch with the index buffers scalar-prefetched once;
the single-pattern entry point in ops.py is just the B=1 case.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _vmem_take_kernel(block_n: int, idx_ref, table_blk, out_blk):
    b = pl.program_id(0)
    i = pl.program_id(1)
    rows = idx_ref[b, pl.ds(i * block_n, block_n)]
    out_blk[...] = jnp.take(table_blk[0], rows, axis=0)[None]


def gather_rows_vmem(table: jax.Array, idx: jax.Array, *,
                     block_n: int, interpret: bool) -> jax.Array:
    """VMEM-resident gather: (B, V, D) tables, (B, N) idx -> (B, N, D).

    One launch for the whole pattern batch; pattern b's table is staged
    whole per b-step.  Caller guarantees n % block_n == 0 (ops.py pads).
    """
    bsz, n = idx.shape
    _, v, d = table.shape
    assert n % block_n == 0, (n, block_n)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bsz, n // block_n),
        in_specs=[pl.BlockSpec((1, v, d), lambda b, i, idx_ref: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, block_n, d),
                               lambda b, i, idx_ref: (b, i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_vmem_take_kernel, block_n),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, n, d), table.dtype),
        interpret=interpret,
    )(idx, table)


def _dma_rows_kernel(block_i: int, block_d: int,
                     idx_ref, table_ref, out_blk, scratch, sems):
    # Explicit double buffer: two scratch slots, two DMA semaphores.  Row
    # r+1's copy is started before row r's is consumed, so the writeback
    # of each row overlaps the fetch of the next (prefetch depth 1 — the
    # slot count bounds it, not the block size).
    b = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)

    def dma(r, slot):
        row = idx_ref[b, i * block_i + r]
        return pltpu.make_async_copy(
            table_ref.at[b, row, pl.ds(j * block_d, block_d)],
            scratch.at[slot], sems.at[slot])

    dma(0, 0).start()                                      # warm-up fetch

    def body(r, carry):
        slot = jax.lax.rem(r, 2)

        @pl.when(r + 1 < block_i)
        def _prefetch():
            dma(r + 1, jax.lax.rem(r + 1, 2)).start()

        dma(r, slot).wait()
        out_blk[0, r, :] = scratch[slot]
        return carry

    jax.lax.fori_loop(0, block_i, body, 0)


def gather_rows_dma(table: jax.Array, idx: jax.Array, *,
                    block_d: int, block_i: int, interpret: bool) -> jax.Array:
    """HBM-resident gather: grid (B, N/block_i, D/block_d), block_i rows/step.

    The table never enters the automatic pipeline — it is bound in
    ``pltpu.ANY`` and the kernel gathers rows itself with double-buffered
    async copies.  Caller guarantees n % block_i == 0 and
    d % block_d == 0 (ops.py pads).
    """
    bsz, n = idx.shape
    _, v, d = table.shape
    assert d % block_d == 0, (d, block_d)
    assert n % block_i == 0, (n, block_i)
    grid = (bsz, n // block_i, d // block_d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((1, block_i, block_d),
                               lambda b, i, j, idx_ref: (b, i, j)),
        scratch_shapes=[
            pltpu.VMEM((2, block_d), table.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_dma_rows_kernel, block_i, block_d),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, n, d), table.dtype),
        interpret=interpret,
    )(idx, table)
