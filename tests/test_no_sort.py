"""Hot-path hygiene regression tests (ISSUE 3 tentpole).

The last-write-wins keep mask for store-mode scatter is computed once on
the host at build/plan time (backends.keep_last_mask) and threaded through
as an operand; nothing the engine or planner times may contain a ``sort``
primitive.  These tests pin that down for every backend on every execution
path (per-pattern, batched bucket, sharded bucket) so the hoist can never
silently regress.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GSEngine, SuitePlan, gs_shardings, make_pattern
from repro.core import backends as B
from repro.core.engine import make_host_buffers
from repro.core.plan import ShardedExecutor, _assemble_bucket, \
    _build_executable
from repro.core.tracing import count_primitives

# delta 2 < span 15: every pattern writes rows more than once
DUP = make_pattern("UNIFORM:8:2", kind="scatter", delta=2, count=32,
                   name="dup")


def _assert_no_sort(jaxpr, label):
    counts = count_primitives(jaxpr)
    assert counts.get("sort", 0) == 0, \
        f"{label}: sort primitive in hot path ({counts})"
    assert counts.get("sort_p", 0) == 0, label


# ---------------------------------------------------------------------------
# the host mask itself
# ---------------------------------------------------------------------------

def test_keep_last_mask_semantics():
    idx = np.asarray([3, 1, 3, 2, 1, 1], np.int32)
    keep = B.keep_last_mask(idx)
    assert keep.tolist() == [False, False, True, True, False, True]
    # no duplicates: everything keeps
    assert B.keep_last_mask(np.asarray([5, 1, 9])).all()
    # empty buffer: empty mask, no crash
    assert B.keep_last_mask(np.zeros((0,), np.int32)).shape == (0,)
    # all duplicates: only the last survives
    assert B.keep_last_mask(np.full(7, 4)).tolist() == [False] * 6 + [True]


def test_make_host_buffers_carries_keep():
    _, abs_idx, vals, keep = make_host_buffers(DUP, 2)
    assert keep is not None and keep.dtype == bool
    assert keep.shape == abs_idx.shape
    np.testing.assert_array_equal(keep, B.keep_last_mask(abs_idx))
    # gathers carry no mask
    g = make_pattern("UNIFORM:8:2", kind="gather", delta=2, count=32)
    assert make_host_buffers(g, 2)[3] is None


# ---------------------------------------------------------------------------
# per-pattern executables (GSEngine.build)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", B.BACKENDS)
def test_engine_store_executable_has_no_sort(backend):
    fn, args = GSEngine(DUP, backend=backend).build()
    _assert_no_sort(jax.make_jaxpr(fn)(*args), f"engine/{backend}")


# ---------------------------------------------------------------------------
# batched bucket executables (plan._build_executable), store mode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", B.BACKENDS)
def test_bucket_store_executable_has_no_sort(backend):
    plan = SuitePlan.build([DUP])
    bucket = plan.buckets[0]
    args, _ = _assemble_bucket(plan, bucket, jnp.float32, 1, 0)
    fn = _build_executable(backend, "scatter", "store")
    _assert_no_sort(jax.make_jaxpr(fn)(*args), f"bucket/{backend}")


@pytest.mark.parametrize("backend", B.BACKENDS)
def test_sharded_bucket_store_executable_has_no_sort(backend):
    mesh = jax.make_mesh((1,), ("data",))
    plan = SuitePlan.build([DUP])
    bucket = plan.buckets[0]
    args, _ = _assemble_bucket(plan, bucket, jnp.float32, 1, 0)
    sharder = ShardedExecutor(mesh, "data")
    fn = sharder.build(backend, "scatter", "store")
    args = sharder.place("scatter", args)
    _assert_no_sort(jax.make_jaxpr(fn)(*args), f"sharded/{backend}")


def test_sharded_engine_store_has_no_sort():
    mesh = jax.make_mesh((1,), ("data",))
    fn, args = GSEngine(DUP, backend="xla").sharded(mesh, "data")
    _assert_no_sort(jax.make_jaxpr(fn)(*args), "engine-sharded/xla")


# ---------------------------------------------------------------------------
# one-launch property: the pallas store bucket executable issues exactly
# one pallas_call per bucket (was three: masked-add + count + blend)
# ---------------------------------------------------------------------------

def test_pallas_store_bucket_is_single_launch():
    plan = SuitePlan.build([DUP])
    args, _ = _assemble_bucket(plan, plan.buckets[0], jnp.float32, 1, 0)
    fn = _build_executable("pallas", "scatter", "store")
    counts = count_primitives(jax.make_jaxpr(fn)(*args))
    assert counts.get("pallas_call", 0) == 1, counts


def test_pallas_store_engine_is_single_launch():
    fn, args = GSEngine(DUP, backend="pallas").build()
    counts = count_primitives(jax.make_jaxpr(fn)(*args))
    assert counts.get("pallas_call", 0) == 1, counts


def test_pallas_gather_bucket_is_single_launch():
    g = make_pattern("UNIFORM:8:2", kind="gather", delta=2, count=32)
    plan = SuitePlan.build([g])
    args, _ = _assemble_bucket(plan, plan.buckets[0], jnp.float32, 1, 0)
    fn = _build_executable("pallas", "gather", "")
    counts = count_primitives(jax.make_jaxpr(fn)(*args))
    assert counts.get("pallas_call", 0) == 1, counts
