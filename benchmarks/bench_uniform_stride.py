"""Paper Fig 3 / Fig 5: uniform-stride gather & scatter bandwidth sweep.

Strides 1..128 (doubling), gather and scatter, measured on CPU-XLA
(methodology reproduction) + modeled v5e via the tile model (DESIGN.md §2).
Paper-claim check: bandwidth halves per stride doubling until the
line/tile is exhausted (CPU cache line = 8 doubles; TPU tile = 1024 f32).
"""
from __future__ import annotations

from repro.core import GSEngine, make_pattern
from .harness import emit

STRIDES = [1, 2, 4, 8, 16, 32, 64, 128]
COUNT = 1 << 14
IDX_LEN = 16       # paper §4: CPU index buffer = 16 (2-4x vector length)


def run(runs: int = 5):
    rows = []
    for kind in ("gather", "scatter"):
        for s in STRIDES:
            p = make_pattern(f"UNIFORM:{IDX_LEN}:{s}", kind=kind,
                             delta=IDX_LEN * s, count=COUNT,
                             name=f"{kind}-stride-{s}")
            r = GSEngine(p, backend="xla").run(runs=runs)
            emit(f"uniform_stride/{kind}/s{s}", r.time_s * 1e6,
                 f"cpu={r.measured_gbs:.2f}GB/s v5e_model="
                 f"{r.modeled_gbs:.1f}GB/s tile_eff={r.tile_efficiency:.4f}")
            rows.append((kind, s, r))
    # paper-claim: halving per stride-doubling in the modeled v5e curve
    g = {s: r.modeled_gbs for k, s, r in rows if k == "gather"}
    for s in (1, 2, 4):
        ratio = g[s] / max(g[2 * s], 1e-9)
        emit(f"uniform_stride/claim/halving_s{s}_to_s{2*s}", 0.0,
             f"ratio={ratio:.2f} (paper predicts ~2)")
    return rows


if __name__ == "__main__":
    run()
