"""Fused causal (optionally windowed / softcapped) flash attention, TPU.

§Perf iteration 3 (llama3-8b train_4k): the XLA-lowered chunked attention
round-trips every (chunk, T) f32 score tensor through HBM — measured 40-50%
of the cell's memory term.  This kernel keeps scores in VMEM: per grid step
it loads one (block_q) x (block_k) tile, updates the online-softmax running
(m, l, acc) scratch, and writes only the (G, block_q, dh) output — HBM
traffic is exactly q, k, v, o.

Layout: q (B, KVH, G, S, dh); k/v (B, KVH, T, dh).
Grid (B, KVH, nQ, nKV), kv innermost so scratch carries across kv steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _flash_kernel(block_q: int, block_k: int, scale: float, causal: bool,
                  window: int, softcap: float,
                  q_blk, k_blk, v_blk, out_blk, m_scr, l_scr, acc_scr):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    n_k = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_blk[0, 0].astype(jnp.float32)              # (G, bq, dh)
    k = k_blk[0, 0].astype(jnp.float32)              # (bk, dh)
    v = v_blk[0, 0].astype(jnp.float32)              # (bk, dh)

    s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
    mask = jnp.ones(s.shape, jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, _NEG)

    m_prev = m_scr[...]                               # (G, bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=2, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    e = jnp.exp(s - m_new)
    l_scr[...] = l_scr[...] * corr + e.sum(axis=2, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        e, v, (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        out_blk[0, 0] = (acc_scr[...] / denom).astype(out_blk.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        scale: float, causal: bool, window: int,
                        softcap: float, block_q: int, block_k: int,
                        interpret: bool) -> jax.Array:
    b, kvh, g, s, dh = q.shape
    t = k.shape[2]
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    grid = (b, kvh, s // block_q, t // block_k)

    return pl.pallas_call(
        functools.partial(_flash_kernel, block_q, block_k, scale, causal,
                          window, softcap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, block_q, dh),
                         lambda b, h, iq, ik: (b, h, 0, iq, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b, h, iq, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, block_q, dh),
                               lambda b, h, iq, ik: (b, h, 0, iq, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, block_q, 1), jnp.float32),
            pltpu.VMEM((g, block_q, 1), jnp.float32),
            pltpu.VMEM((g, block_q, dh), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, s, dh), q.dtype),
        interpret=interpret,
    )(q, k, v)
