"""Concurrent, coalescing work-unit scheduler for spatterd (DESIGN.md §13).

The paper's thesis is that gather/scatter throughput comes from keeping
many indexed accesses in flight at once (§1; the Cell study in PAPERS.md
reaches the same conclusion) — and PR 4's daemon contradicted it at the
serving layer by serializing every request on one run lock.  This module
replaces the lock with a scheduler over the planner's work-unit pipeline
(core/plan.py: ``BucketWork`` / ``launch`` / ``demux``):

* ``submit(works)`` enqueues one item per ``BucketWork`` onto a BOUNDED
  queue (``QueueFull`` when it would overflow — the daemon maps that to
  503 + Retry-After, *before* any JAX work) and returns a ``SuiteTicket``
  the handler thread waits on.

* Worker threads drain the queue with **bucket-affinity batching**: a
  worker pops the head item (FIFO leader), then sweeps the queue for
  items sharing its coalesce key — ``(BucketWork.family, runs)``, the
  batch-stripped canonical ``ExecKey`` plus the timing contract — and
  stacks them into ONE padded launch.  The batch-polymorphic cache
  already serves any pow-2 bracket, so concatenating pattern batches
  just lands in a (possibly larger) bracket of the same family; member
  rows are assembled per-work with per-work seeds, so each member's
  buffers — and therefore its demuxed sha256 digest — are bit-identical
  to the serial ``run_plan`` path (DESIGN.md §13 correctness argument).
  Coalescing is capped by the per-suite assembly budget
  (``schema.MAX_SUITE_LANES``) and a member ceiling, so a coalesced
  launch never assembles more than a maximal single request could.

* Telemetry stays EXACT.  ``launch`` reports whether *it* claimed the
  executable's ``_BuildFuture`` (``LaunchResult.compiled``); the
  scheduler attributes that compile to the launch leader's ticket, so
  ``sum(ticket.misses)`` over any set of requests equals the cache's
  ``misses`` delta — the same "misses is an exact compile count"
  contract the serial daemon proved with stats snapshots, now valid
  under concurrency.  Non-leader participants record a hit (their
  bucket ran warm on a shared launch).  Per-ticket ``queued_ms`` (worst
  item wait) and ``coalesced_launches`` make the scheduling itself
  observable.

Scheduling policy is FIFO with *bounded bucket-affinity bypass*: the
leader is always the oldest queued item, and a swept item only ever
jumps the line to ride the leader's launch — it cannot delay anything,
because it adds member rows to a launch that was departing anyway while
freeing its own future slot.  Items that don't share the leader's key
keep strict FIFO order.

``pause()``/``resume()`` gate the workers without touching the queue —
tests use this to stage a full queue and prove coalescing
deterministically; operators get the same lever for quiescing a live
daemon.  ``stop()`` drains: queued and in-flight work completes (tickets
resolve) and only then do workers exit; ``stop(drain=False)`` fails
queued tickets with ``SchedulerStopped`` instead.

Thread-safety: ONE condition variable (``self._cv``) guards the queue,
the counters, and all ticket mutation; launches run outside it.  The
``analysis/ast_lint.py`` concurrency lint enforces both properties
structurally (guarded-attr mutations, no blocking calls under the lock
— ``Condition.wait`` on the *held* lock is the one sanctioned
exception).

Fault tolerance (DESIGN.md §14): workers are *supervised* — an
exception escaping the item loop (previously a silent permanent pool
shrink) is counted (``dead_workers``) and the thread replaced
(``respawned``); tickets may carry a **deadline** — work still queued
past it resolves with ``DeadlineExceeded`` instead of launching (the
daemon maps that to 504); a work *family* whose launches fail
``QUARANTINE_AFTER`` consecutive times is quarantined — its queued and
future items fail fast with ``FamilyQuarantined`` so one poison bucket
cannot monopolize the workers; and ``cancel(ticket)`` removes an
abandoned request's queued items (the daemon calls it when the client
is gone) so workers never launch work nobody will read.
"""
from __future__ import annotations

import threading
import time
from collections import deque

from repro.core.plan import (BucketWork, ExecutorCache, default_cache, demux,
                             launch)

from .schema import MAX_SUITE_LANES

# serving defaults, importable by daemon/CLI and pinned by tests
DEFAULT_WORKERS = 2
DEFAULT_MAX_QUEUE = 256        # queued BucketWork items, not requests
MAX_COALESCE_MEMBERS = 1024    # pattern rows one coalesced launch may carry
QUARANTINE_AFTER = 3           # consecutive launch failures -> quarantine


class QueueFull(RuntimeError):
    """submit() would overflow the bounded queue — backpressure, not
    failure.  ``.depth`` is the queue depth observed; the daemon turns
    this into 503 + Retry-After."""

    def __init__(self, depth: int, limit: int):
        super().__init__(f"scheduler queue full ({depth}/{limit} items)")
        self.depth = depth
        self.limit = limit


class SchedulerStopped(RuntimeError):
    """The scheduler is stopping/stopped and accepts no new work."""


class DeadlineExceeded(RuntimeError):
    """The ticket's deadline passed while its work was still queued —
    nothing launched for the expired items.  The daemon maps this to
    504 (the request's ``deadline_ms``)."""


class RequestCancelled(RuntimeError):
    """The ticket was cancelled (``Scheduler.cancel``) — typically the
    daemon abandoning a request whose client is gone."""


class FamilyQuarantined(RuntimeError):
    """This work family failed ``QUARANTINE_AFTER`` consecutive launches
    and is quarantined: items fail fast instead of launching (clear with
    ``Scheduler.clear_quarantine``)."""


def _work_cost(work: BucketWork) -> int:
    """A work unit's assembly budget in the schema's units: lanes (or
    footprint, whichever dominates) x row_width, summed over members —
    the same quantity ``SuiteRequest.build_patterns`` bounds per
    request, so the coalescing cap below speaks the wire schema's
    language."""
    return sum(max(p.count * p.index_len, p.footprint()) * work.row_width
               for p in work.patterns)


class _Item:
    """One queued BucketWork plus its bookkeeping (slots: the queue can
    hold hundreds of these)."""
    __slots__ = ("ticket", "work", "key", "cost", "t_enq")

    def __init__(self, ticket: "SuiteTicket", work: BucketWork):
        self.ticket = ticket
        self.work = work
        self.key = (work.family, work.runs)   # coalesce identity
        self.cost = _work_cost(work)
        self.t_enq = time.perf_counter()


class SuiteTicket:
    """A submitted request's handle: wait on it, then read results.

    ``results`` maps suite position -> RunResult (complete when ``done``
    is set without ``error``).  Counters mirror the serial daemon's
    per-request cache telemetry: ``misses`` is the exact number of
    compiles attributed to THIS request (it claimed the build),
    ``hits`` the warm serves, ``launches`` how many bucket launches its
    work rode, ``coalesced_launches`` how many of those were shared
    with other requests, ``queued_ms`` the worst queue wait among its
    items.  All mutation happens under the owning scheduler's lock.

    ``deadline`` is an absolute ``time.monotonic()`` instant (or None):
    a worker reaching a queued item past it retires the item with
    ``DeadlineExceeded`` instead of launching.  ``degraded_launches``
    counts launches this request rode that were served by a degraded
    (fallback-built) executable — threaded from
    ``LaunchResult.degraded`` so per-request telemetry shows it.
    """

    def __init__(self, n_works: int, deadline: float | None = None):
        self.results: dict[int, object] = {}
        self.hits = 0
        self.misses = 0
        self.launches = 0
        self.coalesced_launches = 0
        self.degraded_launches = 0
        self.queued_ms = 0.0
        self.deadline = deadline
        self.error: BaseException | None = None
        self.done = threading.Event()
        self._pending = n_works

    def wait(self, timeout: float | None = None) -> "SuiteTicket":
        """Block until the ticket resolves; re-raise its failure."""
        if not self.done.wait(timeout):
            raise TimeoutError("scheduler ticket not resolved in time")
        if self.error is not None:
            raise self.error
        return self

    def telemetry(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "launches": self.launches,
            "coalesced_launches": self.coalesced_launches,
            "degraded_launches": self.degraded_launches,
            "queued_ms": self.queued_ms,
        }


class Scheduler:
    """Bounded-queue, multi-worker, bucket-affinity-coalescing executor
    over ``plan.launch``/``plan.demux`` (module docstring; DESIGN.md
    §13)."""

    def __init__(self, cache: ExecutorCache | None = None, *,
                 workers: int = DEFAULT_WORKERS,
                 max_queue: int = DEFAULT_MAX_QUEUE,
                 max_coalesce_cost: int = MAX_SUITE_LANES,
                 max_coalesce_members: int = MAX_COALESCE_MEMBERS,
                 faults=None):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.cache = cache if cache is not None else default_cache()
        self.max_queue = max_queue
        self.max_coalesce_cost = max_coalesce_cost
        self.max_coalesce_members = max_coalesce_members
        self._faults = faults          # FaultInjector | None (serve/faults)
        self._cv = threading.Condition()
        self._queue: deque[_Item] = deque()
        self._paused = False
        self._stopping = False
        self._busy = 0
        self._n_workers = workers
        self._fail_streak: dict = {}   # family -> consecutive launch fails
        self._quarantined: set = set()
        self.submitted = 0
        self.completed = 0
        self.failed = 0
        self.cancelled = 0
        self.deadline_expired = 0
        self.dead_workers = 0
        self.respawned = 0
        self.total_launches = 0
        self.coalesced_launches = 0
        self.degraded_launches = 0
        self._threads = [
            threading.Thread(target=self._run_worker,
                             name=f"spatterd-worker-{i}", daemon=True)
            for i in range(workers)
        ]
        # snapshot: a worker killed at its loop top appends its OWN
        # replacement (already started) to _threads while this loop runs
        for t in list(self._threads):
            t.start()

    # -- submission ----------------------------------------------------------
    def submit(self, works: list[BucketWork], *,
               deadline_s: float | None = None) -> SuiteTicket:
        """Enqueue one request's work units; returns its ticket.

        Raises ``QueueFull`` (backpressure) or ``SchedulerStopped``
        BEFORE accepting anything — a request is queued whole or not at
        all, so a ticket's ``_pending`` accounting can never be split
        across an overflow.

        ``deadline_s`` (relative, seconds) arms a queue deadline: items
        still queued when it passes are retired with
        ``DeadlineExceeded`` — they never launch.  Work already
        in-flight at expiry finishes (a JAX execution cannot be
        cancelled midway); its result is discarded by the failed ticket.
        """
        if not works:
            raise ValueError("submit needs at least one work unit")
        ticket = SuiteTicket(len(works),
                             deadline=(time.monotonic() + deadline_s
                                       if deadline_s is not None else None))
        items = [_Item(ticket, w) for w in works]
        with self._cv:
            if self._stopping:
                raise SchedulerStopped("scheduler is stopping")
            if len(self._queue) + len(items) > self.max_queue:
                raise QueueFull(len(self._queue), self.max_queue)
            self._queue.extend(items)
            self.submitted += 1
            self._cv.notify_all()
        return ticket

    # -- worker loop ---------------------------------------------------------
    def _run_worker(self) -> None:
        """Supervised worker shell.  An exception escaping ``_worker``'s
        item loop used to kill the thread silently, shrinking the pool
        forever; now it is counted (``dead_workers``) and the thread
        replaced (``respawned``) — chaos tests kill workers through the
        fault harness and assert the pool recovers.  Item-level failures
        never get here: ``_execute`` resolves them into their tickets.
        """
        try:
            self._worker()
            return                         # clean exit: stopping
        except BaseException:
            pass
        replacement = None
        with self._cv:
            self.dead_workers += 1
            if not self._stopping:
                self.respawned += 1
                replacement = threading.Thread(
                    target=self._run_worker,
                    name=f"spatterd-worker-r{self.respawned}", daemon=True)
                self._threads.append(replacement)
        if replacement is not None:
            replacement.start()

    def _worker(self) -> None:
        while True:
            # the worker-kill fault fires BEFORE taking from the queue,
            # so a killed worker can never strand claimed items
            if self._faults is not None:
                self._faults.check("worker")
            with self._cv:
                while not self._stopping \
                        and (self._paused or not self._queue):
                    self._cv.wait()
                if not self._queue:            # stopping and drained
                    return
                batch = self._take_locked()
                if batch:
                    self._busy += 1
            if not batch:                      # head items were all dead
                continue
            try:
                self._execute(batch)
            finally:
                with self._cv:
                    self._busy -= 1
                    self._cv.notify_all()

    def _take_locked(self) -> list[_Item]:
        """Pop the FIFO leader plus every queued item sharing its
        coalesce key, within the assembly-cost and member caps.  Dead
        head items are retired on the spot before a leader is chosen:
        ticket already failed (their request got its 500 from an
        earlier launch), deadline passed (``DeadlineExceeded`` — the
        item never launches), or family quarantined
        (``FamilyQuarantined`` fail-fast)."""
        now = time.monotonic()
        while self._queue:
            head = self._queue[0]
            t = head.ticket
            if t.error is not None:
                self._finish_locked(self._queue.popleft())
            elif t.deadline is not None and now > t.deadline:
                self.deadline_expired += 1
                self._fail_locked(self._queue.popleft(), DeadlineExceeded(
                    "deadline expired while queued; work never launched"))
            elif head.key[0] in self._quarantined:
                self._fail_locked(self._queue.popleft(), FamilyQuarantined(
                    f"work family quarantined after {QUARANTINE_AFTER} "
                    f"consecutive launch failures: {head.key[0]}"))
            else:
                break
        if not self._queue:
            return []
        leader = self._queue.popleft()
        batch = [leader]
        cost = leader.cost
        members = leader.work.n_members
        for it in list(self._queue):
            if it.key != leader.key or it.ticket.error is not None:
                continue
            if it.ticket.deadline is not None and now > it.ticket.deadline:
                continue               # expired: head loop retires it
            if cost + it.cost > self.max_coalesce_cost:
                continue
            if members + it.work.n_members > self.max_coalesce_members:
                continue
            self._queue.remove(it)
            batch.append(it)
            cost += it.cost
            members += it.work.n_members
        return batch

    def _finish_locked(self, item: _Item) -> None:
        """Retire one item of a ticket; resolves the ticket when it was
        the last."""
        t = item.ticket
        t._pending -= 1
        if t._pending == 0 and not t.done.is_set():
            if t.error is None:
                self.completed += 1
            t.done.set()

    def _fail_locked(self, item: _Item, exc: BaseException) -> None:
        """Fail an item's whole ticket immediately: the handler thread
        gets its 500 now; the ticket's still-queued items are retired
        as dead when a worker reaches them."""
        t = item.ticket
        if t.error is None:
            t.error = exc
            self.failed += 1
        if not t.done.is_set():
            t.done.set()
        t._pending -= 1

    def _execute(self, batch: list[_Item]) -> None:
        """Run one (possibly coalesced) launch and demux per ticket.

        Launch failures feed the quarantine ledger: ``QUARANTINE_AFTER``
        consecutive failures of one family (reset by any success)
        quarantine it, so a poison bucket stops reaching the workers.
        """
        t_start = time.perf_counter()
        works = [it.work for it in batch]
        family = batch[0].key[0]
        try:
            # the launch fault site: injected exceptions/latency land
            # exactly where a real launch failure would
            if self._faults is not None:
                self._faults.check("launch")
            result = launch(works, self.cache)
            demuxed, offset = [], 0
            for it in batch:
                demuxed.append(demux(result, it.work, offset))
                offset += it.work.n_members
        except BaseException as exc:
            with self._cv:
                self.total_launches += 1
                streak = self._fail_streak.get(family, 0) + 1
                self._fail_streak[family] = streak
                if streak >= QUARANTINE_AFTER:
                    self._quarantined.add(family)
                for it in batch:
                    self._fail_locked(it, exc)
            return
        shared = len(batch) > 1
        with self._cv:
            self.total_launches += 1
            self._fail_streak.pop(family, None)
            if shared:
                self.coalesced_launches += 1
            if result.degraded:
                self.degraded_launches += 1
            for i, it in enumerate(batch):
                t = it.ticket
                if t.error is None:
                    for pos, r in demuxed[i]:
                        t.results[pos] = r
                t.launches += 1
                if shared:
                    t.coalesced_launches += 1
                if result.degraded:
                    t.degraded_launches += 1
                # the compile (if any) belongs to the launch leader:
                # serve_poly_info said whether THIS launch claimed the
                # _BuildFuture, so summed ticket misses == cache misses
                if i == 0 and result.compiled:
                    t.misses += 1
                else:
                    t.hits += 1
                t.queued_ms = max(t.queued_ms,
                                  (t_start - it.t_enq) * 1e3)
                self._finish_locked(it)

    # -- control plane -------------------------------------------------------
    def cancel(self, ticket: SuiteTicket,
               exc: BaseException | None = None) -> int:
        """Abandon a ticket: remove its still-queued items and resolve it.

        The abandoned-ticket fix: a handler whose ``ticket.wait``
        timed out (client gone) previously left queued items live, so
        workers later launched work nobody would read.  Returns the
        number of queued items removed.  In-flight items finish (their
        results are discarded by the failed ticket); a ticket that
        already completed cleanly is left untouched.
        """
        exc = exc if exc is not None else RequestCancelled(
            "request cancelled; queued work removed")
        removed = 0
        with self._cv:
            if ticket.done.is_set() and ticket.error is None:
                return 0
            for it in [i for i in self._queue if i.ticket is ticket]:
                self._queue.remove(it)
                self._fail_locked(it, exc)
                removed += 1
            newly = False
            if ticket.error is None:
                ticket.error = exc
                self.failed += 1
                newly = True
            if not ticket.done.is_set():
                ticket.done.set()
                newly = True
            if removed or newly:
                self.cancelled += 1
        return removed

    def clear_quarantine(self) -> int:
        """Drop every quarantine + failure streak (operator reset after
        fixing the underlying cause); returns families released."""
        with self._cv:
            n = len(self._quarantined)
            self._quarantined.clear()
            self._fail_streak.clear()
        return n

    def pause(self) -> None:
        """Stop workers from taking NEW batches (in-flight ones finish).
        Submissions still queue; tests stage a full queue under pause to
        make coalescing deterministic."""
        with self._cv:
            self._paused = True

    def resume(self) -> None:
        with self._cv:
            self._paused = False
            self._cv.notify_all()

    def stop(self, drain: bool = True, timeout: float = 60.0) -> None:
        """Shut the workers down.  With ``drain`` (default) queued and
        in-flight work completes and every ticket resolves before the
        workers exit; with ``drain=False`` queued tickets fail with
        ``SchedulerStopped`` (in-flight launches still finish — a JAX
        execution cannot be cancelled midway)."""
        with self._cv:
            self._stopping = True
            self._paused = False
            if not drain:
                while self._queue:
                    self._fail_locked(self._queue.popleft(),
                                      SchedulerStopped("scheduler stopped"))
            self._cv.notify_all()
            threads = list(self._threads)   # respawns append concurrently
        for t in threads:
            t.join(timeout=timeout)

    def snapshot(self) -> dict:
        """Queue/worker occupancy + lifetime counters (GET /stats).

        ``workers`` is the configured pool size; ``alive_workers`` the
        threads currently running (supervision keeps them equal outside
        the instant between a death and its respawn); ``dead_workers``/
        ``respawned`` the supervisor's lifetime ledger.
        """
        with self._cv:
            return {
                "workers": self._n_workers,
                "alive_workers": sum(1 for t in self._threads
                                     if t.is_alive()),
                "dead_workers": self.dead_workers,
                "respawned": self.respawned,
                "busy": self._busy,
                "queue_depth": len(self._queue),
                "max_queue": self.max_queue,
                "paused": self._paused,
                "stopping": self._stopping,
                "submitted": self.submitted,
                "completed": self.completed,
                "failed": self.failed,
                "cancelled": self.cancelled,
                "deadline_expired": self.deadline_expired,
                "quarantined_families": len(self._quarantined),
                "total_launches": self.total_launches,
                "coalesced_launches": self.coalesced_launches,
                "degraded_launches": self.degraded_launches,
            }
