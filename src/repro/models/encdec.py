"""Whisper-style encoder-decoder backbone (audio family).

The conv/mel frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, frames, d) where frames = seq_len /
frame_ratio.  Positional encoding is sinusoidal for both stacks (whisper's
encoder is sinusoidal; its decoder is learned — we use sinusoidal for both
so parameters stay shape-independent; recorded as a deviation in DESIGN.md).

Cross-attention K/V are computed once from the encoder output and reused by
every decode step — serving-time cross-KV is a pure Spatter gather target.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import backends as gs_backends
from repro.runtime.sharding import constrain
from . import attention as attn
from .common import (ParamDef, init_tree, mlp_apply, mlp_def, rms_norm,
                     rms_norm_def, stack_defs)
from .transformer import embed_defs, embed_lookup, unembed_logits


def sinusoidal(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / max(1, half - 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def enc_block_defs(cfg) -> dict:
    return {"ln1": rms_norm_def(cfg.d_model),
            "attn": attn.gqa_defs(cfg),
            "ln2": rms_norm_def(cfg.d_model),
            "mlp": mlp_def(cfg, cfg.d_model, cfg.d_ff)}


def dec_block_defs(cfg) -> dict:
    return {"ln1": rms_norm_def(cfg.d_model),
            "self_attn": attn.gqa_defs(cfg),
            "ln_x": rms_norm_def(cfg.d_model),
            "cross_attn": attn.gqa_defs(cfg),
            "ln2": rms_norm_def(cfg.d_model),
            "mlp": mlp_def(cfg, cfg.d_model, cfg.d_ff)}


def encdec_defs(cfg) -> dict:
    return {
        "embed": embed_defs(cfg),
        "enc": stack_defs(enc_block_defs(cfg), cfg.n_enc_layers),
        "dec": stack_defs(dec_block_defs(cfg), cfg.n_layers),
        "ln_enc": rms_norm_def(cfg.d_model),
        "ln_f": rms_norm_def(cfg.d_model),
    }


def encode(cfg, params: dict, frames: jax.Array) -> jax.Array:
    """frames (B, F, d) stub embeddings -> encoder states (B, F, d)."""
    f = frames.shape[1]
    x = frames + sinusoidal(jnp.arange(f), cfg.d_model)[None].astype(
        frames.dtype)
    x = constrain(x, ("batch", "frames", "embed"))
    positions = jnp.arange(f, dtype=jnp.int32)

    def body(x, p):
        h = rms_norm(p["ln1"], x, cfg.norm_eps)
        x = x + attn.gqa_apply(cfg, p["attn"], h, positions, causal=False)
        h = rms_norm(p["ln2"], x, cfg.norm_eps)
        return x + mlp_apply(cfg, p["mlp"], h), None

    x, _ = jax.lax.scan(body, x, params["enc"])
    return rms_norm(params["ln_enc"], x, cfg.norm_eps)


def decode_train(cfg, params: dict, tokens: jax.Array,
                 enc_out: jax.Array) -> jax.Array:
    """Teacher-forced decoder pass -> hidden (B, S, d)."""
    b, s = tokens.shape
    x = embed_lookup(cfg, params["embed"], tokens)
    x = x + sinusoidal(jnp.arange(s), cfg.d_model)[None].astype(x.dtype)
    positions = jnp.arange(s, dtype=jnp.int32)
    enc_pos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)

    def body(x, p):
        h = rms_norm(p["ln1"], x, cfg.norm_eps)
        x = x + attn.gqa_apply(cfg, p["self_attn"], h, positions, causal=True)
        h = rms_norm(p["ln_x"], x, cfg.norm_eps)
        kv = attn.gqa_kv(cfg, p["cross_attn"], enc_out, enc_pos)
        x = x + attn.gqa_apply(cfg, p["cross_attn"], h, positions,
                               causal=False, kv=kv)
        h = rms_norm(p["ln2"], x, cfg.norm_eps)
        return x + mlp_apply(cfg, p["mlp"], h), None

    x, _ = jax.lax.scan(body, x, params["dec"])
    return rms_norm(params["ln_f"], x, cfg.norm_eps)


def encdec_loss(cfg, params: dict, batch: dict, **kw) -> jax.Array:
    from .transformer import chunked_xent
    enc_out = encode(cfg, params, batch["frames"])
    hidden = decode_train(cfg, params, batch["tokens"], enc_out)
    return chunked_xent(cfg, params, hidden, batch["labels"])


# -- serving ----------------------------------------------------------------

def encdec_init_cache(cfg, batch: int, max_len: int, dtype,
                      n_frames: int) -> dict:
    l = cfg.n_layers
    kvh, dh = cfg.n_kv_heads, cfg.dh
    return {
        "self_k": jnp.zeros((l, batch, max_len, kvh, dh), dtype),
        "self_v": jnp.zeros((l, batch, max_len, kvh, dh), dtype),
        # cross K/V precomputed from the encoder at prefill
        "cross_k": jnp.zeros((l, batch, n_frames, kvh, dh), dtype),
        "cross_v": jnp.zeros((l, batch, n_frames, kvh, dh), dtype),
    }


def encdec_cache_axes() -> dict:
    a = ("batch", None, "kv_heads", "head_dim")
    return {"self_k": (None,) + a, "self_v": (None,) + a,
            "cross_k": (None,) + a, "cross_v": (None,) + a}


def encdec_prefill_cross(cfg, params: dict, frames: jax.Array, cache: dict):
    """Run the encoder and fill the cross-attention KV cache."""
    enc_out = encode(cfg, params, frames)
    enc_pos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)

    def per_layer(p):
        return attn.gqa_kv(cfg, p["cross_attn"], enc_out, enc_pos)

    ks, vs = jax.vmap(per_layer)(params["dec"]) if False else jax.lax.map(
        per_layer, params["dec"])
    return dict(cache, cross_k=ks.astype(cache["cross_k"].dtype),
                cross_v=vs.astype(cache["cross_v"].dtype))


def encdec_decode_step(cfg, params: dict, cache: dict, tokens: jax.Array,
                       pos: jax.Array):
    """One decoder token with self-cache update + cross-attention."""
    b = tokens.shape[0]
    x = embed_lookup(cfg, params["embed"], tokens)
    x = x + sinusoidal(jnp.full((1,), pos), cfg.d_model)[None].astype(x.dtype)

    def body(x, xs):
        p, sk, sv, ck, cv = xs
        h = rms_norm(p["ln1"], x, cfg.norm_eps)
        y, new_c = attn.gqa_decode(cfg, p["self_attn"], h, pos,
                                   {"k": sk, "v": sv})
        x = x + y
        h = rms_norm(p["ln_x"], x, cfg.norm_eps)
        # cross attention: full (non-causal) attention over cached cross KV
        q = jnp.einsum("bsd,dhe->bshe", h, p["cross_attn"]["wq"])
        kvh, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
        q = q.reshape(b, 1, kvh, g, cfg.dh)
        s = jnp.einsum("bqkgd,btkd->bkgqt", q.astype(jnp.float32),
                       ck.astype(jnp.float32)) / math.sqrt(cfg.dh)
        prob = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqt,btkd->bqkgd", prob, cv.astype(jnp.float32))
        o = o.reshape(b, 1, cfg.n_heads, cfg.dh).astype(x.dtype)
        x = x + jnp.einsum("bshe,hed->bsd", o, p["cross_attn"]["wo"])
        h = rms_norm(p["ln2"], x, cfg.norm_eps)
        x = x + mlp_apply(cfg, p["mlp"], h)
        return x, (new_c["k"], new_c["v"])

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec"], cache["self_k"], cache["self_v"],
                  cache["cross_k"], cache["cross_v"]))
    x = rms_norm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed_logits(cfg, params["embed"], x)[:, 0]
    return logits, dict(cache, self_k=nk, self_v=nv)
