"""Attention blocks: GQA (full / local / softcapped) and MLA (deepseek-v2).

Each block exposes:
    defs(cfg, ...)                      -> ParamDef tree
    apply(cfg, p, x, positions, ...)    -> y                    (train/prefill)
    decode(cfg, p, x, pos, cache)       -> (y, new_cache)       (serve_step)
    init_cache(cfg, batch, max_len)     -> cache ShapeDtypeStructs/zeros

KV caches are the framework's paged/contiguous gather targets (DESIGN.md §3):
decode attention is a Spatter broadcast-gather over the cache, and the
optimized serving path runs it through kernels/paged_decode.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.runtime.sharding import constrain
from .common import (ParamDef, apply_rope, chunked_attention, rms_norm,
                     rms_norm_def, softcap)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def gqa_defs(cfg) -> dict:
    d, h, kvh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh
    return {
        "wq": ParamDef((d, h, dh), ("embed", "heads", "head_dim")),
        "wk": ParamDef((d, kvh, dh), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((d, kvh, dh), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((h, dh, d), ("heads", "head_dim", "embed")),
    }


def _qkv(cfg, p, x, positions, rope_mode):
    kvh = cfg.n_kv_heads
    g = cfg.n_heads // kvh
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"])
    q = apply_rope(q, positions, cfg.rope_theta, rope_mode)
    k = apply_rope(k, positions, cfg.rope_theta, rope_mode)
    b, s = x.shape[:2]
    q = q.reshape(b, s, kvh, g, cfg.dh)
    q = constrain(q, ("batch", "seq", "kv_heads", None, "head_dim"))
    k = constrain(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = constrain(v, ("batch", "seq", "kv_heads", "head_dim"))
    return q, k, v


def gqa_kv(cfg, p: dict, src: jax.Array, positions: jax.Array):
    """K/V from an external source sequence (cross-attention)."""
    if positions.ndim == 1:
        positions = positions[None, :]
    k = jnp.einsum("bsd,dhe->bshe", src, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", src, p["wv"])
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rope)
    return k, v


def gqa_apply(cfg, p: dict, x: jax.Array, positions: jax.Array, *,
              window: int = 0, return_kv: bool = False, causal: bool = True,
              kv: tuple | None = None):
    """Training / prefill attention. x (B,S,d); positions (S,) or (B,S).

    ``kv`` overrides the self-attention K/V with externally computed ones
    (cross-attention: whisper decoder attending to encoder states).
    """
    rope_mode = cfg.rope
    if positions.ndim == 1:
        positions = positions[None, :]
    q, k, v = _qkv(cfg, p, x, positions, rope_mode)
    if kv is not None:
        k, v = kv
    o = chunked_attention(q, k, v, chunk=cfg.attn_chunk, causal=causal,
                          window=window, attn_softcap=cfg.attn_softcap)
    b, s = x.shape[:2]
    o = o.reshape(b, s, cfg.n_heads, cfg.dh)
    y = jnp.einsum("bshe,hed->bsd", o, p["wo"])
    y = constrain(y, ("batch", "seq", "embed"))
    if return_kv:
        return y, (k, v)
    return y


def gqa_init_cache(cfg, batch: int, max_len: int, dtype, *, window: int = 0):
    s = min(max_len, window) if window > 0 else max_len
    shape = (batch, s, cfg.n_kv_heads, cfg.dh)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def gqa_cache_axes():
    return {"k": ("batch", None, "kv_heads", "head_dim"),
            "v": ("batch", None, "kv_heads", "head_dim")}


def gqa_decode(cfg, p: dict, x: jax.Array, pos: jax.Array, cache: dict, *,
               window: int = 0):
    """Single-token decode. x (B,1,d); pos scalar int32; cache {k,v}.

    The attention over the cache is the Spatter gather workload: every step
    reads the whole (B, S, KVH, dh) cache once — pure memory traffic.
    """
    b = x.shape[0]
    kvh, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _qkv(cfg, p, x, positions, cfg.rope)
    s_max = cache["k"].shape[1]
    slot = pos % s_max if window > 0 else pos      # ring buffer for local attn
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))

    scale = 1.0 / math.sqrt(cfg.dh)
    scores = jnp.einsum("bqkgd,btkd->bkgqt", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = softcap(scores, cfg.attn_softcap)
    kv_pos = jnp.arange(s_max)
    if window > 0:
        # ring buffer of size == window: once pos >= s_max every slot holds
        # one of the last `window` positions, so all slots are valid; before
        # that only slots <= pos have been written.
        valid = (kv_pos <= pos) | (pos >= s_max)
    else:
        valid = kv_pos <= pos
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    prob = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgqt,btkd->bqkgd", prob, v.astype(jnp.float32))
    o = o.reshape(b, 1, cfg.n_heads, cfg.dh).astype(x.dtype)
    y = jnp.einsum("bshe,hed->bsd", o, p["wo"])
    return y, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLA (deepseek-v2 multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_defs(cfg) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    r_kv, r_q = cfg.kv_lora_rank, cfg.q_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "w_dq": ParamDef((d, r_q), ("embed", "qk_rank")),
        "q_norm": rms_norm_def(r_q),
        "w_uq": ParamDef((r_q, h, dn + dr), ("qk_rank", "heads", "head_dim")),
        "w_dkv": ParamDef((d, r_kv), ("embed", "kv_rank")),
        "kv_norm": rms_norm_def(r_kv),
        "w_kr": ParamDef((d, dr), ("embed", "head_dim")),
        "w_uk": ParamDef((r_kv, h, dn), ("kv_rank", "heads", "head_dim")),
        "w_uv": ParamDef((r_kv, h, dv), ("kv_rank", "heads", "head_dim")),
        "wo": ParamDef((h, dv, d), ("heads", "head_dim", "embed")),
    }


def _mla_q(cfg, p, x, positions):
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = rms_norm(p["q_norm"], x @ p["w_dq"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhe->bshe", cq, p["w_uq"])          # (B,S,H,dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta, "full")
    return q_nope, q_rope


def _mla_ckv(cfg, p, x, positions):
    c_kv = rms_norm(p["kv_norm"], x @ p["w_dkv"], cfg.norm_eps)   # (B,S,r_kv)
    k_pe = apply_rope((x @ p["w_kr"])[:, :, None, :], positions,
                      cfg.rope_theta, "full")[:, :, 0]            # (B,S,dr)
    return c_kv, k_pe


def mla_apply(cfg, p: dict, x: jax.Array, positions: jax.Array):
    """Training/prefill MLA: materialize per-head K/V from the latent."""
    if positions.ndim == 1:
        positions = positions[None, :]
    b, s, _ = x.shape
    h, dn, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    c_kv, k_pe = _mla_ckv(cfg, p, x, positions)
    k_nope = jnp.einsum("bsr,rhe->bshe", c_kv, p["w_uk"])
    v = jnp.einsum("bsr,rhe->bshe", c_kv, p["w_uv"])
    # append rope part to both q and k; treat heads as KVH groups of 1
    q = jnp.concatenate([q_nope, q_rope], axis=-1)[:, :, :, None, :]
    # (B,S,H,1,dn+dr): every MLA head has its own K, so KVH=H and G=1
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_pe[:, :, None, :], (b, s, h, cfg.qk_rope_dim))], axis=-1)
    scale = 1.0 / math.sqrt(dn + cfg.qk_rope_dim)
    o = chunked_attention(q, k, v, chunk=cfg.attn_chunk, causal=True,
                          scale=scale, pin_heads=True)
    o = o.reshape(b, s, h, dv)
    y = jnp.einsum("bshe,hed->bsd", o, p["wo"])
    return constrain(y, ("batch", "seq", "embed"))


def mla_apply_cache(cfg, p: dict, x: jax.Array, positions: jax.Array):
    """Prefill variant of mla_apply that also returns the compressed cache."""
    if positions.ndim == 1:
        pos2 = positions[None, :]
    else:
        pos2 = positions
    y = mla_apply(cfg, p, x, positions)
    c_kv, k_pe = _mla_ckv(cfg, p, x, pos2)
    return y, {"c_kv": c_kv, "k_pe": k_pe}


def mla_init_cache(cfg, batch: int, max_len: int, dtype):
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_pe": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
    }


def mla_cache_axes():
    return {"c_kv": ("batch", None, "kv_rank"),
            "k_pe": ("batch", None, None)}


def mla_decode(cfg, p: dict, x: jax.Array, pos: jax.Array, cache: dict):
    """Absorbed-matrix MLA decode (the paper-relevant optimization):

    Instead of re-materializing per-head K from the latent cache each step
    (a (S, H, dn) blow-up gather), fold W_uk into the query — scores are an
    inner product in the *compressed* space, so the gather over the cache
    touches only r_kv + dr bytes per position: ~9x less memory traffic for
    deepseek-v2 (512+64 vs 128·128).  Recorded in EXPERIMENTS.md §Perf.
    """
    b = x.shape[0]
    h, dn, dv, dr = cfg.n_heads, cfg.qk_nope_dim, cfg.v_head_dim, cfg.qk_rope_dim
    positions = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(cfg, p, x, positions)          # (B,1,H,*)
    c_new, kpe_new = _mla_ckv(cfg, p, x, positions)
    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_new, (0, pos, 0))
    k_pe = jax.lax.dynamic_update_slice(cache["k_pe"], kpe_new, (0, pos, 0))

    # absorb: q_c[b,1,h,r] = q_nope · W_uk^T
    q_c = jnp.einsum("bshe,rhe->bshr", q_nope, p["w_uk"])
    scores = (jnp.einsum("bshr,btr->bhst", q_c.astype(jnp.float32),
                         c_kv.astype(jnp.float32))
              + jnp.einsum("bshe,bte->bhst", q_rope.astype(jnp.float32),
                           k_pe.astype(jnp.float32)))
    scores *= 1.0 / math.sqrt(dn + dr)
    t = c_kv.shape[1]
    valid = jnp.arange(t) <= pos
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    prob = jax.nn.softmax(scores, axis=-1)
    # o_c[b,h,1,r] then expand through W_uv
    o_c = jnp.einsum("bhst,btr->bshr", prob, c_kv.astype(jnp.float32))
    o = jnp.einsum("bshr,rhe->bshe", o_c.astype(x.dtype), p["w_uv"])
    y = jnp.einsum("bshe,hed->bsd", o, p["wo"])
    return y, {"c_kv": c_kv, "k_pe": k_pe}
