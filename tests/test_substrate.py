"""Optimizer / checkpoint / data-pipeline / supervisor tests."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, CheckpointManager
from repro.data import TokenPipeline
from repro.optim import (AdamWConfig, adamw_update, clip_by_global_norm,
                         init_opt_state, int8_compress, int8_decompress,
                         warmup_cosine)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def _numpy_adamw(cfg, p, g, m, v, step):
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * g * g
    mh = m / (1 - cfg.b1 ** step)
    vh = v / (1 - cfg.b2 ** step)
    lr = cfg.lr if not callable(cfg.lr) else cfg.lr(jnp.int32(step))
    p2 = p - lr * (mh / (np.sqrt(vh) + cfg.eps) + cfg.weight_decay * p)
    return p2, m, v


def test_adamw_matches_reference():
    cfg = AdamWConfig(lr=1e-2, weight_decay=0.01)
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.standard_normal((5, 3)), jnp.float32)}
    g = {"w": jnp.asarray(rng.standard_normal((5, 3)), jnp.float32)}
    state = init_opt_state(p)
    p_ref = np.asarray(p["w"]).copy()
    m = np.zeros_like(p_ref)
    v = np.zeros_like(p_ref)
    for step in range(1, 4):
        p, state = adamw_update(cfg, p, g, state)
        p_ref, m, v = _numpy_adamw(cfg, p_ref, np.asarray(g["w"]), m, v, step)
        np.testing.assert_allclose(np.asarray(p["w"]), p_ref, rtol=1e-5,
                                   atol=1e-6)
    assert int(state["step"]) == 3


def test_adamw_bf16_params_fp32_moments():
    cfg = AdamWConfig(lr=1e-2)
    p = {"w": jnp.ones((4,), jnp.bfloat16)}
    g = {"w": jnp.full((4,), 0.1, jnp.bfloat16)}
    state = init_opt_state(p)
    p2, s2 = adamw_update(cfg, p, g, state)
    assert p2["w"].dtype == jnp.bfloat16
    assert s2["m"]["w"].dtype == jnp.float32


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert np.isclose(float(norm), np.sqrt(10 * 9 + 10 * 16))
    _, norm2 = clip_by_global_norm(clipped, 1.0)
    assert float(norm2) <= 1.0 + 1e-5


def test_warmup_cosine():
    lr = warmup_cosine(1e-3, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert np.isclose(float(lr(jnp.int32(10))), 1e-3, rtol=1e-2)
    assert float(lr(jnp.int32(100))) < 2e-4


def test_int8_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                    jnp.float32)
    q, s = int8_compress(x)
    back = int8_decompress(q, s)
    err = np.abs(np.asarray(back) - np.asarray(x)).max()
    assert err <= float(s) * 0.5 + 1e-6        # quantization bound


def test_compressed_psum_shardmap():
    from repro.optim.grad_utils import compressed_psum
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    def f(g):
        out, res = compressed_psum(g, "data")
        return out, res

    g = jnp.asarray(np.random.default_rng(1).standard_normal((8, 4)),
                    jnp.float32)
    out, res = shard_map(f, mesh=mesh, in_specs=P(), out_specs=P())(g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g), atol=0.05)
    # error feedback residual equals quantization error
    np.testing.assert_allclose(np.asarray(g - out), np.asarray(res),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.standard_normal((8, 4)),
                                        jnp.float32),
                       "b": jnp.asarray(rng.standard_normal(4), jnp.float32)},
            "step": jnp.int32(7)}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(7, t)
    assert ck.latest_step() == 7
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    back = ck.restore(7, like)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree())
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_checkpoint_shape_mismatch(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        ck.restore(1, {"w": jax.ShapeDtypeStruct((5,), jnp.float32)})


def test_manager_async_and_prune(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    for s in (10, 20, 30):
        mgr.save_async(s, _tree(s))
    mgr.wait()
    assert mgr.latest_step() == 30
    steps = sorted(int(d[5:]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [20, 30]
    mgr.close()


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic():
    p1 = TokenPipeline(vocab=100, seq_len=16, global_batch=4, seed=3)
    p2 = TokenPipeline(vocab=100, seq_len=16, global_batch=4, seed=3)
    b1, b2 = p1.batch(5), p2.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p1.batch(6)["tokens"], b1["tokens"])


def test_pipeline_shards_partition_batch():
    p = TokenPipeline(vocab=100, seq_len=8, global_batch=8, seed=0)
    full = p.batch(2)["tokens"]
    parts = [p.shard_batch(2, h, 4)["tokens"] for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_pipeline_labels_shift():
    p = TokenPipeline(vocab=50, seq_len=8, global_batch=2, seed=0)
    b = p.batch(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_pipeline_tokens_in_range():
    p = TokenPipeline(vocab=64, seq_len=128, global_batch=4, seed=1)
    t = p.batch(0)["tokens"]
    assert t.min() >= 0 and t.max() < 64


# ---------------------------------------------------------------------------
# supervisor (crash restart, straggler detection, sigterm)
# ---------------------------------------------------------------------------

def test_supervisor_crash_restart(tmp_path):
    from repro.runtime.supervisor import SupervisorConfig, TrainSupervisor
    crashed = {"done": False}

    def build(ckpt):
        start = ckpt.latest_step() or 0
        state = {"x": jnp.float32(start)}
        if start:
            state = ckpt.restore(start, jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state))

        def step_fn(state, i):
            if i == 7 and not crashed["done"]:
                crashed["done"] = True
                raise RuntimeError("simulated node failure")
            return {"x": state["x"] + 1}, {"loss": float(state["x"])}

        return state, step_fn, start

    sup = TrainSupervisor(SupervisorConfig(ckpt_dir=str(tmp_path),
                                           ckpt_every=5, max_restarts=2))
    state = sup.run(build, 12)
    # crash at 7 -> restart from ckpt step 5 -> steps 5..11 rerun
    assert crashed["done"]
    assert float(state["x"]) == 12.0
    sup.ckpt.close()


def test_supervisor_straggler_detection(tmp_path):
    from repro.runtime.supervisor import SupervisorConfig, TrainSupervisor

    def build(ckpt):
        def step_fn(state, i):
            if i == 8:
                time.sleep(0.25)       # straggler
            else:
                time.sleep(0.01)
            return state, {"loss": 1.0}
        return {}, step_fn, 0

    sup = TrainSupervisor(SupervisorConfig(ckpt_dir=str(tmp_path),
                                           ckpt_every=100,
                                           straggler_factor=5.0))
    sup.run(build, 10)
    assert 8 in sup.straggler_events
    sup.ckpt.close()


def test_elastic_restore_across_meshes():
    """Checkpoint written under one mesh restores onto a different mesh
    (elastic restart) — subprocess with 8 fake devices."""
    import subprocess, sys, textwrap, os
    code = textwrap.dedent("""
        import os, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, %r)
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import Checkpointer

        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        mesh_a = jax.make_mesh((8, 1), ("data", "model"))
        tree_a = jax.device_put(tree, {"w": NamedSharding(mesh_a,
                                                          P("data", None))})
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d)
            ck.save(1, tree_a)
            # restore onto a DIFFERENT topology: 2x4, sharded other way
            mesh_b = jax.make_mesh((2, 4), ("data", "model"))
            sh_b = {"w": NamedSharding(mesh_b, P(None, "model"))}
            like = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
            back = ck.restore(1, like, shardings=sh_b)
            assert np.allclose(np.asarray(back["w"]), np.asarray(tree["w"]))
            assert back["w"].sharding == sh_b["w"]
        print("OK")
    """) % os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                        "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_checkpoint_bf16_roundtrip(tmp_path):
    """bf16 leaves survive save/restore (stored as uint16 views — numpy
    cannot cast foreign ml_dtypes; regression for the train-driver resume
    crash)."""
    ck = Checkpointer(str(tmp_path))
    t = {"w": jnp.asarray([1.5, -2.25, 3.0], jnp.bfloat16),
         "s": jnp.float32(2.0)}
    ck.save(3, t)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    back = ck.restore(3, like)
    assert back["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(back["w"], np.float32),
                                  np.asarray(t["w"], np.float32))
