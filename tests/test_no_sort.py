"""Hot-path hygiene regression tests (ISSUE 3 tentpole, rewired to
spatterlint in ISSUE 6).

The last-write-wins keep mask for store-mode scatter is computed once on
the host at build/plan time (backends.keep_last_mask) and threaded
through as an operand; nothing the engine or planner times may contain a
``sort`` primitive, and the pallas backend launches exactly ONE kernel
per bucket.  These invariants are now owned by the spatterlint rules
``no-sort-in-hot-path`` and ``single-pallas-call-per-bucket``
(repro.analysis.rules, DESIGN.md §12) — this file calls THOSE rules
rather than a private jaxpr walker, so the test and the lint can never
disagree about what "no sort in the hot path" means.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.lint import run_rules, unit_for
from repro.core import GSEngine, SuitePlan, make_pattern
from repro.core import backends as B
from repro.core.engine import make_host_buffers
from repro.core.plan import ShardedExecutor, _assemble_bucket, \
    _build_executable

# delta 2 < span 15: every pattern writes rows more than once
DUP = make_pattern("UNIFORM:8:2", kind="scatter", delta=2, count=32,
                   name="dup")


def _assert_rules_clean(fn, args, backend, *, kind, mode="store",
                        placement="", cached=True, label=""):
    """Every executable-scope lint rule, via the real registry."""
    unit = unit_for(fn, args, backend=backend, kind=kind, mode=mode,
                    placement=placement, cached=cached)
    violations = run_rules(unit)
    assert not violations, \
        f"{label}: {[v.render() for v in violations]}"


# ---------------------------------------------------------------------------
# the host mask itself
# ---------------------------------------------------------------------------

def test_keep_last_mask_semantics():
    idx = np.asarray([3, 1, 3, 2, 1, 1], np.int32)
    keep = B.keep_last_mask(idx)
    assert keep.tolist() == [False, False, True, True, False, True]
    # no duplicates: everything keeps
    assert B.keep_last_mask(np.asarray([5, 1, 9])).all()
    # empty buffer: empty mask, no crash
    assert B.keep_last_mask(np.zeros((0,), np.int32)).shape == (0,)
    # all duplicates: only the last survives
    assert B.keep_last_mask(np.full(7, 4)).tolist() == [False] * 6 + [True]


def test_make_host_buffers_carries_keep():
    _, abs_idx, vals, keep = make_host_buffers(DUP, 2)
    assert keep is not None and keep.dtype == bool
    assert keep.shape == abs_idx.shape
    np.testing.assert_array_equal(keep, B.keep_last_mask(abs_idx))
    # gathers carry no mask
    g = make_pattern("UNIFORM:8:2", kind="gather", delta=2, count=32)
    assert make_host_buffers(g, 2)[3] is None


# ---------------------------------------------------------------------------
# per-pattern executables (GSEngine.build) — every lint rule must pass.
# cached=False: engine executables are rebuilt per GSEngine and may
# legitimately donate their dst (fresh buffer every call), unlike
# ExecutorCache entries (the donation-honored rule's subject).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", B.BACKENDS)
def test_engine_store_executable_passes_lint(backend):
    fn, args = GSEngine(DUP, backend=backend).build()
    _assert_rules_clean(fn, args, backend, kind="scatter", mode="store",
                        cached=False, label=f"engine/{backend}")


# ---------------------------------------------------------------------------
# batched bucket executables (plan._build_executable), store mode —
# these DO live in the ExecutorCache, so cached=True adds the
# donation-honored check on top of no-sort / single-pallas / host
# boundary / f64.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", B.BACKENDS)
def test_bucket_store_executable_passes_lint(backend):
    plan = SuitePlan.build([DUP])
    bucket = plan.buckets[0]
    args, _ = _assemble_bucket(plan, bucket, jnp.float32, 1, 0)
    fn = _build_executable(backend, "scatter", "store")
    _assert_rules_clean(fn, args, backend, kind="scatter", mode="store",
                        label=f"bucket/{backend}")


@pytest.mark.parametrize("backend", B.BACKENDS)
def test_sharded_bucket_store_executable_passes_lint(backend):
    mesh = jax.make_mesh((1,), ("data",))
    plan = SuitePlan.build([DUP])
    bucket = plan.buckets[0]
    args, _ = _assemble_bucket(plan, bucket, jnp.float32, 1, 0)
    sharder = ShardedExecutor(mesh, "data")
    fn = sharder.build(backend, "scatter", "store")
    args = sharder.place("scatter", args)
    _assert_rules_clean(fn, args, backend, kind="scatter", mode="store",
                        placement=sharder.placement,
                        label=f"sharded/{backend}")


def test_sharded_engine_store_passes_lint():
    mesh = jax.make_mesh((1,), ("data",))
    fn, args = GSEngine(DUP, backend="xla").sharded(mesh, "data")
    _assert_rules_clean(fn, args, "xla", kind="scatter", mode="store",
                        cached=False, label="engine-sharded/xla")


# ---------------------------------------------------------------------------
# one-launch property: the single-pallas-call-per-bucket rule (expects
# exactly one pallas_call for backend="pallas") passes on every pallas
# execution path — store bucket, store engine, gather bucket
# ---------------------------------------------------------------------------

def test_pallas_store_bucket_is_single_launch():
    plan = SuitePlan.build([DUP])
    args, _ = _assemble_bucket(plan, plan.buckets[0], jnp.float32, 1, 0)
    fn = _build_executable("pallas", "scatter", "store")
    unit = unit_for(fn, args, backend="pallas", kind="scatter",
                    mode="store")
    assert run_rules(unit, ["single-pallas-call-per-bucket"]) == []
    assert unit.counts.get("pallas_call", 0) == 1, unit.counts


def test_pallas_store_engine_is_single_launch():
    fn, args = GSEngine(DUP, backend="pallas").build()
    unit = unit_for(fn, args, backend="pallas", kind="scatter",
                    mode="store", cached=False)
    assert run_rules(unit, ["single-pallas-call-per-bucket"]) == []
    assert unit.counts.get("pallas_call", 0) == 1, unit.counts


def test_pallas_gather_bucket_is_single_launch():
    g = make_pattern("UNIFORM:8:2", kind="gather", delta=2, count=32)
    plan = SuitePlan.build([g])
    args, _ = _assemble_bucket(plan, plan.buckets[0], jnp.float32, 1, 0)
    fn = _build_executable("pallas", "gather", "")
    unit = unit_for(fn, args, backend="pallas", kind="gather")
    assert run_rules(unit, ["single-pallas-call-per-bucket"]) == []
    assert unit.counts.get("pallas_call", 0) == 1, unit.counts
