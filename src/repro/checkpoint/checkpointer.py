"""Fault-tolerant checkpointing: atomic, async, topology-agnostic.

Design (DESIGN.md §5):
  * device-agnostic layout: every leaf is saved as a host numpy array under
    a stable tree path — restore works on a *different* mesh shape (elastic
    restart) because shardings are re-derived from logical rules at load.
  * atomic: write to step_NNNNNN.tmp/, fsync, rename — a crash mid-save
    never corrupts the latest checkpoint.
  * async: a writer thread snapshots (device_get) synchronously (cheap on
    host RAM) and writes in the background, overlapping I/O with compute.
  * retention: keep_n newest checkpoints are kept, older ones pruned.

The format is a directory of .npy files + a JSON manifest of tree paths —
no pickle, no framework lock-in, greppable on disk.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time

import jax
import ml_dtypes
import numpy as np

# numpy can't cast to/from ml_dtypes types it didn't create; store them as
# same-width unsigned views and reconstruct via the manifest dtype.
_VIEW_SAVE = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
              "float8_e5m2": np.uint8}
_VIEW_LOAD = {"bfloat16": ml_dtypes.bfloat16,
              "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
              "float8_e5m2": ml_dtypes.float8_e5m2}


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


class Checkpointer:
    """Synchronous core: save/restore one pytree atomically."""

    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def save(self, step: int, tree) -> str:
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {}
        for key, leaf in _flatten_with_paths(tree):
            arr = np.asarray(jax.device_get(leaf))
            dtype_name = str(arr.dtype)
            if dtype_name in _VIEW_SAVE:
                arr = arr.view(_VIEW_SAVE[dtype_name])
            fname = f"{len(manifest):06d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest[key] = {"file": fname, "shape": list(arr.shape),
                             "dtype": dtype_name}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "leaves": manifest}, f, indent=2)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)          # atomic on POSIX
        return final

    def latest_step(self) -> int | None:
        steps = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    steps.append(int(d[5:]))
                except ValueError:
                    pass
        return max(steps) if steps else None

    def restore(self, step: int, like_tree, shardings=None):
        """Restore into the structure of ``like_tree``.

        ``shardings``: optional matching tree of NamedShardings — leaves are
        device_put with them (this is what makes restore elastic: the target
        mesh can differ from the mesh that saved).
        """
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)["leaves"]
        flat_like = _flatten_with_paths(like_tree)
        leaves = []
        for key, like_leaf in flat_like:
            if key not in manifest:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = np.load(os.path.join(d, manifest[key]["file"]))
            saved_dtype = manifest[key]["dtype"]
            if saved_dtype in _VIEW_LOAD:
                arr = arr.view(_VIEW_LOAD[saved_dtype])
            if tuple(arr.shape) != tuple(like_leaf.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs "
                    f"model {like_leaf.shape}")
            leaves.append(arr.astype(like_leaf.dtype))
        treedef = jax.tree.structure(like_tree)
        tree = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree

    def prune(self, keep_n: int):
        steps = sorted(s for s in (self.latest_step(),) if s is not None)
        all_steps = sorted(
            int(d[5:]) for d in os.listdir(self.dir)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in all_steps[:-keep_n]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)


class CheckpointManager:
    """Async wrapper: snapshot on the caller thread, write on a worker."""

    def __init__(self, directory: str, keep_n: int = 3):
        self.ckpt = Checkpointer(directory)
        self.keep_n = keep_n
        self._q: queue.Queue = queue.Queue(maxsize=1)
        self._err: Exception | None = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree = item
            try:
                self.ckpt.save(step, tree)
                self.ckpt.prune(self.keep_n)
            except Exception as e:      # surfaced on next save()
                self._err = e
            finally:
                self._q.task_done()

    def save_async(self, step: int, tree):
        if self._err is not None:
            err, self._err = self._err, None
            raise err
        # snapshot now (device_get) so training can mutate donated buffers
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        self._q.put((step, host_tree))

    def wait(self):
        self._q.join()
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def close(self):
        self.wait()
        self._q.put(None)
        self._thread.join(timeout=10)

    # passthroughs
    def latest_step(self):
        return self.ckpt.latest_step()

    def restore(self, step, like_tree, shardings=None):
        return self.ckpt.restore(step, like_tree, shardings)
