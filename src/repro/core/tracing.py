"""jaxpr gather/scatter trace extraction — the paper's §2 for JAX programs.

The paper extracts G/S patterns from DoE mini-apps with an instrumented QEMU
(SVE traces) and distills them into (index buffer, delta) pairs.  The JAX
analogue: walk a computation's jaxpr (recursing through pjit/scan/while/
cond), harvest every indexed-access primitive, and report

  * per-primitive byte counts (Table 1's "G/S MB (%)" column), and
  * concrete Spatter patterns where the access geometry is static.

Usage:
    report = trace_gs(lambda p, x: model.apply(p, x), params, tokens)
    print(report.summary())
    suite = report.to_patterns()     # replayable through GSEngine
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterator

import jax
import numpy as np

from .pattern import Pattern


def normalize_primitive(name: str) -> str:
    """Canonical primitive name: hyphens to underscores, ``_p`` suffix
    stripped.

    JAX spells indexed-update primitives with hyphens (``scatter-add``)
    while callers habitually write the Python binding name
    (``scatter_add``, or ``sort_p`` for the primitive object itself);
    every walker below keys on the canonical spelling so consumers never
    need the historical double-lookup (``counts.get("sort") or
    counts.get("sort_p")``).
    """
    canon = name.replace("-", "_")
    if canon.endswith("_p"):
        canon = canon[:-2]
    return canon


# canonical-name -> access kind.  scatter-min/max (jnp .at[].min/.max) and
# the mode-carrying gather variants (jnp.take(mode=...), .at[].get()) all
# lower to these primitives; keys here are post-normalize_primitive.
_GS_PRIMS = {
    "gather": "gather",
    "scatter": "scatter",
    "scatter_add": "scatter",
    "scatter_mul": "scatter",
    "scatter_min": "scatter",
    "scatter_max": "scatter",
    "dynamic_slice": "gather",
    "dynamic_update_slice": "scatter",
    "take_along_axis": "gather",
}


@dataclasses.dataclass
class TracedAccess:
    primitive: str
    kind: str                      # gather | scatter
    operand_shape: tuple
    out_shape: tuple
    index_shape: tuple
    moved_bytes: int               # bytes delivered by this access
    slice_elems: int               # elements per indexed lookup (row width)
    n_lookups: int                 # number of indexed lookups
    eqn_str: str = ""

    def to_pattern(self) -> Pattern | None:
        """Static proxy: a UNIFORM row pattern with runtime (unknown) indices
        is modeled as stride-`slice_elems` over `n_lookups` ops (the geometry
        Spatter can replay; the *values* of runtime indices need runtime
        tracing, which the dry-run container cannot observe)."""
        if self.n_lookups < 1:
            return None
        return Pattern(
            name=f"traced-{self.primitive}",
            kind=self.kind,
            index=tuple(range(max(1, self.slice_elems))),
            delta=max(1, self.slice_elems),
            count=self.n_lookups,
            source="jaxpr-trace",
        )


@dataclasses.dataclass
class TraceReport:
    accesses: list[TracedAccess]
    total_bytes: int               # all array outputs in the jaxpr

    @property
    def gs_bytes(self) -> int:
        return sum(a.moved_bytes for a in self.accesses)

    @property
    def gs_fraction(self) -> float:
        """Table 1's G/S share of data motion."""
        return self.gs_bytes / max(1, self.total_bytes)

    def gathers(self) -> list[TracedAccess]:
        return [a for a in self.accesses if a.kind == "gather"]

    def scatters(self) -> list[TracedAccess]:
        return [a for a in self.accesses if a.kind == "scatter"]

    def to_patterns(self) -> list[Pattern]:
        out = []
        for a in self.accesses:
            p = a.to_pattern()
            if p is not None:
                out.append(p)
        return out

    def summary(self) -> str:
        lines = [
            f"traced {len(self.accesses)} G/S accesses "
            f"({len(self.gathers())} gathers / {len(self.scatters())} scatters)",
            f"G/S bytes: {self.gs_bytes / 1e6:.1f} MB of "
            f"{self.total_bytes / 1e6:.1f} MB total "
            f"({100 * self.gs_fraction:.1f}%)   [paper Table 1 analogue]",
        ]
        for a in sorted(self.accesses, key=lambda a: -a.moved_bytes)[:12]:
            lines.append(
                f"  {a.primitive:<22} {str(a.operand_shape):<20} "
                f"rows={a.n_lookups:<10} row_elems={a.slice_elems:<8} "
                f"{a.moved_bytes / 1e6:9.2f} MB")
        return "\n".join(lines)


def _array_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def _harvest(jaxpr, accesses: list[TracedAccess], totals: list[int],
             weight: int = 1) -> None:
    for eqn in jaxpr.eqns:
        name = normalize_primitive(eqn.primitive.name)
        # recurse into sub-jaxprs (scan multiplies by trip count)
        for param, val in eqn.params.items():
            sub = None
            if hasattr(val, "jaxpr"):
                sub = val.jaxpr if hasattr(val.jaxpr, "eqns") else None
            if param in ("jaxpr", "call_jaxpr", "cond_jaxpr", "body_jaxpr"):
                sub = getattr(val, "jaxpr", val)
            if sub is not None and hasattr(sub, "eqns"):
                w = weight
                if name == "scan":
                    w *= int(eqn.params.get("length", 1))
                _harvest(sub, accesses, totals, w)
            elif param == "branches":
                for br in val:
                    _harvest(br.jaxpr, accesses, totals, weight)
        for outvar in eqn.outvars:
            if hasattr(outvar, "aval"):
                totals[0] += weight * _array_bytes(outvar.aval)
        if name not in _GS_PRIMS:
            continue
        kind = _GS_PRIMS[name]
        op_aval = eqn.invars[0].aval
        out_aval = eqn.outvars[0].aval
        moved = weight * _array_bytes(out_aval if kind == "gather"
                                      else eqn.invars[-1].aval)
        idx_shape, slice_elems, n_lookups = (), 1, 1
        if name == "gather":
            dn = eqn.params["dimension_numbers"]
            slice_sizes = eqn.params["slice_sizes"]
            idx_aval = eqn.invars[1].aval
            idx_shape = tuple(idx_aval.shape)
            slice_elems = int(np.prod(slice_sizes))
            n_lookups = int(np.prod(idx_shape[:-1])) if idx_shape else 1
        elif name.startswith("scatter"):
            idx_aval = eqn.invars[1].aval
            upd_aval = eqn.invars[2].aval
            idx_shape = tuple(idx_aval.shape)
            n_lookups = int(np.prod(idx_shape[:-1])) if idx_shape else 1
            slice_elems = int(np.prod(upd_aval.shape)) // max(1, n_lookups)
        elif name in ("dynamic_slice", "dynamic_update_slice"):
            slice_elems = int(np.prod(out_aval.shape))
            n_lookups = 1
        accesses.append(TracedAccess(
            primitive=name, kind=kind,
            operand_shape=tuple(op_aval.shape),
            out_shape=tuple(out_aval.shape),
            index_shape=idx_shape,
            moved_bytes=moved,
            slice_elems=slice_elems,
            n_lookups=weight * n_lookups,
            eqn_str=str(eqn)[:120],
        ))


def trace_gs(fn: Callable, *args: Any, **kwargs: Any) -> TraceReport:
    """Extract all gather/scatter accesses from ``fn(*args)``'s jaxpr."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    accesses: list[TracedAccess] = []
    totals = [0]
    _harvest(closed.jaxpr, accesses, totals)
    return TraceReport(accesses=accesses, total_bytes=totals[0])


# ---------------------------------------------------------------------------
# jaxpr census walkers — used by the no-sort regression test
# (tests/test_no_sort.py), the bench trajectory (benchmarks/bench_suite),
# and every executable-scope spatterlint rule (repro.analysis.rules)
# ---------------------------------------------------------------------------

MAX_WALK_DEPTH = 128


class JaxprWalkError(ValueError):
    """A jaxpr nests deeper than the walker's depth budget.

    Raised instead of silently truncating: an under-walked jaxpr would
    report "no sort / one pallas_call" for equations it never visited,
    which is exactly the false-negative a lint must not produce.
    """


def iter_eqns(jaxpr, *, max_depth: int = MAX_WALK_DEPTH
              ) -> Iterator[tuple]:
    """Yield ``(eqn, depth)`` over a (closed) jaxpr and every sub-jaxpr.

    Recurses through pjit bodies, loop/cond branches, and pallas_call
    kernel jaxprs — the ONE traversal every census below shares, so a
    primitive visible to one consumer is visible to all.  Depth is
    bounded by ``max_depth`` (raising JaxprWalkError past it) so a
    pathologically nested program fails loudly rather than recursing
    into the interpreter limit mid-walk.
    """

    def _walk(j, depth):
        if depth > max_depth:
            raise JaxprWalkError(
                f"jaxpr nests deeper than max_depth={max_depth}; "
                f"refusing to silently under-count")
        for eqn in j.eqns:
            yield eqn, depth
            for val in eqn.params.values():
                for sub in (val if isinstance(val, (list, tuple)) else [val]):
                    inner = getattr(sub, "jaxpr", None)
                    if inner is not None and hasattr(inner, "eqns"):
                        yield from _walk(inner, depth + 1)
                    elif hasattr(sub, "eqns"):
                        yield from _walk(sub, depth + 1)

    yield from _walk(getattr(jaxpr, "jaxpr", jaxpr), 0)


def count_primitives(jaxpr, *, max_depth: int = MAX_WALK_DEPTH) -> dict:
    """Recursive primitive histogram of a (closed) jaxpr.

    Walks every sub-jaxpr (pjit bodies, loop/cond branches, pallas_call
    kernel jaxprs) so e.g. ``count_primitives(jax.make_jaxpr(fn)(*args))``
    sees the whole executable.  Keys are canonical
    (``normalize_primitive``): ``scatter-add`` and ``scatter_add`` land
    on one count, and ``counts.get("sort", 0)`` is the only lookup a
    caller ever needs (no ``sort_p`` double-check).
    """
    counts: dict = {}
    for eqn, _ in iter_eqns(jaxpr, max_depth=max_depth):
        canon = normalize_primitive(eqn.primitive.name)
        counts[canon] = counts.get(canon, 0) + 1
    return counts


def find_primitive_eqns(jaxpr, names, *, max_depth: int = MAX_WALK_DEPTH
                        ) -> list[tuple[str, str]]:
    """Locate offending equations: ``[(canonical_name, eqn_str), ...]``.

    ``names`` may use any spelling (``sort``, ``sort_p``,
    ``scatter-add``); matching happens on canonical names.  Equation
    strings are truncated — they are violation evidence, not programs.
    """
    want = {normalize_primitive(n) for n in names}
    hits = []
    for eqn, _ in iter_eqns(jaxpr, max_depth=max_depth):
        canon = normalize_primitive(eqn.primitive.name)
        if canon in want:
            hits.append((canon, str(eqn)[:200]))
    return hits


def shard_map_meshes(jaxpr, *, max_depth: int = MAX_WALK_DEPTH
                     ) -> list[dict]:
    """Axis-name -> size mapping of every ``shard_map`` equation.

    The manual-sharding census the lane-sharded pallas lint reads
    (DESIGN.md §16): each entry is one shard_map's mesh shape (e.g.
    ``{"data": 4, "lane": 2}``), in walk order.  Empty list = no
    shard_map anywhere in the jaxpr.
    """
    out = []
    for eqn, _ in iter_eqns(jaxpr, max_depth=max_depth):
        if eqn.primitive.name == "shard_map":
            shape = getattr(eqn.params.get("mesh"), "shape", None)
            out.append(dict(shape) if shape is not None else {})
    return out


def shard_map_pallas_calls(jaxpr, *, max_depth: int = MAX_WALK_DEPTH
                           ) -> int:
    """Count ``pallas_call`` equations INSIDE shard_map bodies.

    Distinguishes the manual lane-sharded launch path (kernel inside the
    shard_map body: one launch per device) from a GSPMD-routed
    pallas_call outside any shard_map, which the lane-sharded lint
    rules must flag on lane-sharded placements.
    """
    n = 0
    for eqn, _ in iter_eqns(jaxpr, max_depth=max_depth):
        if eqn.primitive.name != "shard_map":
            continue
        body = eqn.params.get("jaxpr")
        if body is not None and hasattr(body, "eqns"):
            n += count_primitives(body, max_depth=max_depth
                                  ).get("pallas_call", 0)
    return n


def find_dtype_eqns(jaxpr, dtype_name: str, *,
                    max_depth: int = MAX_WALK_DEPTH) -> list[str]:
    """Equations touching an aval of ``dtype_name`` (e.g. ``float64``)."""
    hits = []
    for eqn, _ in iter_eqns(jaxpr, max_depth=max_depth):
        for v in (*eqn.invars, *eqn.outvars):
            dt = getattr(getattr(v, "aval", None), "dtype", None)
            if dt is not None and str(dt) == dtype_name:
                hits.append(str(eqn)[:200])
                break
    return hits


# lowered-text (StableHLO) census: the walker's HLO side.  Donation and
# mesh placement are invisible in the jaxpr — they only exist in the
# lowered module — so the donation-honored and sharding-spec-consistency
# rules read these markers instead.  The parsing itself lives in the
# shared ``core.hlo`` walker (DESIGN.md §15); the old private regex
# names stay as aliases for callers.
from repro.core.hlo import (RE_ALIASING as _RE_ALIASING,        # noqa: F401,E402
                            RE_PARTITIONS as _RE_PARTITIONS,
                            RE_SHARDING as _RE_SHARDING,
                            hlo_stats)
