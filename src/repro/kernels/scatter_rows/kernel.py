"""MXU one-hot scatter kernels — the TPU-native Spatter scatter backend.

CPU/GPU scatter relies on hardware write combining / atomics; the TPU has
neither at kernel level.  The TPU-native reformulation (DESIGN.md §2) turns
scatter into dense compute: for each chunk of ``block_n`` (index, row)
pairs, build a (block_v, block_n) one-hot membership matrix for the output
tile and contract it with the chunk's rows on the MXU:

    out[vb] += onehot(idx_chunk in vb) @ vals_chunk

The output tile revisits are *consecutive* (chunk is the innermost grid
dim), so the accumulator stays resident in VMEM across the whole sweep —
the analogue of keeping the scatter target cache-resident in the paper's
CPU backend.  Duplicate indices are handled by construction (they just add).

The payload rows never enter the automatic pipeline: ``vals`` is bound in
``pltpu.ANY`` and each kernel stages its (block_n, D) chunk into a
two-slot VMEM scratch with explicit async copies, starting chunk ``c+1``'s
fetch before contracting chunk ``c`` (double buffering, DESIGN.md §16 —
the same overlap the gather DMA kernel uses for its row copies).

Store mode is a SINGLE PASS over the same grid (``_scatter_store_kernel``):
the host-precomputed last-write-wins mask (backends.keep_last_mask,
DESIGN.md §2.1) routes dropped lanes out of range before launch, so every
surviving lane is its row's unique write — the kernel initializes each
output tile from ``dst`` and overwrites exactly the covered rows with the
one-hot contraction (exact: one nonzero term per row).  This replaces the
old masked-add + coverage-count + blend *triple* launch with one kernel.
With ``with_cov`` the same single launch also emits a per-row int32
coverage map — the lane-sharded combine (core/plan._lane_sharded_fn)
psums it to decide which rows any shard wrote.

All kernels are batch-NATIVE (DESIGN.md §2.2): the grid leads with the
pattern-batch dim and the whole (B, N) index buffer is scalar-prefetched
once, so a planner bucket is ONE launch — and the single-pattern entry
points in ops.py are just the B=1 case of the same kernels (one code
path, no vmap, no parallel single/batched kernel bodies to keep in sync).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _chunk_dma(vals_ref, scratch, sems, b, c, block_n):
    """Async copy of chunk ``c``'s (block_n, D) payload rows into a slot."""
    return pltpu.make_async_copy(
        vals_ref.at[b, pl.ds(c * block_n, block_n), :],
        scratch.at[jax.lax.rem(c, 2)], sems.at[jax.lax.rem(c, 2)])


def _scatter_add_kernel(block_v: int, block_n: int,
                        idx_ref, vals_ref, out_blk, scratch, sems):
    b = pl.program_id(0)
    vb = pl.program_id(1)
    c = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(c == 0)
    def _init():
        out_blk[...] = jnp.zeros_like(out_blk)
        _chunk_dma(vals_ref, scratch, sems, b, 0, block_n).start()

    @pl.when(c + 1 < nc)
    def _prefetch():
        _chunk_dma(vals_ref, scratch, sems, b, c + 1, block_n).start()

    _chunk_dma(vals_ref, scratch, sems, b, c, block_n).wait()
    slot = jax.lax.rem(c, 2)
    chunk = idx_ref[b, pl.ds(c * block_n, block_n)]        # (block_n,)
    local = chunk - vb * block_v                           # relative to tile
    rows = jax.lax.broadcasted_iota(jnp.int32, (block_v, block_n), 0)
    onehot = (rows == local[None, :]).astype(out_blk.dtype)
    out_blk[...] += jax.lax.dot(
        onehot, scratch[slot], precision=jax.lax.Precision.DEFAULT,
        preferred_element_type=out_blk.dtype)[None]


def scatter_add_rows_kernel(idx: jax.Array, vals: jax.Array,
                            v_padded: int, *, block_v: int, block_n: int,
                            interpret: bool) -> jax.Array:
    """sum-scatter ``vals`` (B, N, D) at ``idx`` (B, N) into (B, v_padded, D).

    One launch for the whole pattern batch.  Caller guarantees:
    N % block_n == 0, v_padded % block_v == 0, and padded entries of
    ``idx`` point outside [0, v_padded) so the one-hot drops them.
    """
    bsz, n, d = vals.shape
    grid = (bsz, v_padded // block_v, n // block_n)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((1, block_v, d),
                               lambda b, vb, c, idx_ref: (b, vb, 0)),
        scratch_shapes=[
            pltpu.VMEM((2, block_n, d), vals.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_scatter_add_kernel, block_v, block_n),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, v_padded, d), vals.dtype),
        interpret=interpret,
    )(idx, vals)


def _scatter_store_kernel(block_v: int, block_n: int, with_cov: bool,
                          idx_ref, vals_ref, dst_blk, *rest):
    if with_cov:
        out_blk, cov_blk, scratch, sems = rest
    else:
        out_blk, scratch, sems = rest
    b = pl.program_id(0)
    vb = pl.program_id(1)
    c = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(c == 0)
    def _init():
        out_blk[...] = dst_blk[...]
        if with_cov:
            cov_blk[...] = jnp.zeros_like(cov_blk)
        _chunk_dma(vals_ref, scratch, sems, b, 0, block_n).start()

    @pl.when(c + 1 < nc)
    def _prefetch():
        _chunk_dma(vals_ref, scratch, sems, b, c + 1, block_n).start()

    _chunk_dma(vals_ref, scratch, sems, b, c, block_n).wait()
    slot = jax.lax.rem(c, 2)
    chunk = idx_ref[b, pl.ds(c * block_n, block_n)]
    local = chunk - vb * block_v
    rows = jax.lax.broadcasted_iota(jnp.int32, (block_v, block_n), 0)
    hit = rows == local[None, :]
    # each surviving lane is its row's unique write (host keep mask routed
    # duplicates out of range), so the contraction has one nonzero term per
    # covered row — an exact select, not a sum
    written = jax.lax.dot(
        hit.astype(out_blk.dtype), scratch[slot],
        precision=jax.lax.Precision.DEFAULT,
        preferred_element_type=out_blk.dtype)
    covered = hit.max(axis=1)                              # (block_v,) bool
    out_blk[...] = jnp.where(covered[None, :, None], written[None],
                             out_blk[...])
    if with_cov:
        cov_blk[...] = jnp.maximum(cov_blk[...],
                                   covered.astype(jnp.int32)[None])


def scatter_store_rows_kernel(idx: jax.Array, vals: jax.Array,
                              dst: jax.Array, *, block_v: int, block_n: int,
                              with_cov: bool = False, interpret: bool):
    """Last-write-wins store of ``vals`` (B, N, D) into ``dst`` (B, V_pad, D).

    One single-pass launch for the whole pattern batch.  Caller
    guarantees: N % block_n == 0, V_pad % block_v == 0, dropped / padded
    entries of ``idx`` point outside [0, V_pad), and each in-range index
    value occurs at most once per batch row (the host keep mask's
    contract).  With ``with_cov`` the SAME launch also returns a
    (B, V_pad) int32 coverage map (1 where this call wrote the row) —
    still exactly one ``pallas_call``.
    """
    bsz, n, d = vals.shape
    v_padded = dst.shape[1]
    grid = (bsz, v_padded // block_v, n // block_n)

    out_specs = pl.BlockSpec((1, block_v, d),
                             lambda b, vb, c, idx_ref: (b, vb, 0))
    out_shape = jax.ShapeDtypeStruct((bsz, v_padded, d), dst.dtype)
    if with_cov:
        out_specs = (out_specs,
                     pl.BlockSpec((1, block_v),
                                  lambda b, vb, c, idx_ref: (b, vb)))
        out_shape = (out_shape,
                     jax.ShapeDtypeStruct((bsz, v_padded), jnp.int32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((1, block_v, d),
                         lambda b, vb, c, idx_ref: (b, vb, 0)),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((2, block_n, d), vals.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_scatter_store_kernel, block_v, block_n, with_cov),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(idx, vals, dst)
