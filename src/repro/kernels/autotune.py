"""Deterministic per-bucket-shape tile autotuner for the Pallas kernels.

The G/S kernels used to run every bucket shape with one hardcoded tiling
(``block_n=64`` gather / ``128x128`` scatter).  That is the paper's §3.2
lesson in reverse: gather/scatter throughput is a function of how the
access geometry maps onto the memory hierarchy, so a 4Ki-lane bucket and
a 64-lane bucket should not share a tile.  This module picks the tiling
per *kernel-visible geometry* (a ``TileKey``) with a small, fully
deterministic search:

  * Candidates are powers of two bracketed by the geometry itself (a
    block never exceeds the padded dim it tiles).
  * Each candidate is scored with a closed-form cost model — grid steps
    times per-step work plus a per-step launch overhead — instead of
    wall-clock probes.  Interpret mode (CPU) weighs the per-step
    interpreter overhead very heavily, so the search collapses the grid
    to as few steps as the block caps allow; compiled TPU mode weighs
    VMEM residency and MXU-shaped tiles instead.
  * Ties break toward the FIRST candidate in ascending enumeration
    order, so the choice is reproducible across processes and platforms
    by construction (no timing, no RNG, no dict-order dependence).

Choices are memoized process-wide and can be exported/seeded in a wire
format (``to_wire``/``seed_wire``): ``DiskTier`` persists the choices
recorded while serializing an executable and re-seeds them on restore,
so a warm restart never re-runs the search (``stats()["searched"]``
stays 0).  The choice is a pure function of the TileKey — it must NOT
enter ``ExecKey`` (one key still holds exactly one trace, and
``ExecutorCache.misses`` stays an exact compile count; pinned by the
key-purity lint).

``disabled()`` restores the legacy fixed tiles — the benchmark's
before/after section runs under it to measure what the search buys.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading


@dataclasses.dataclass(frozen=True)
class TileKey:
    """Kernel-visible geometry a tile choice is keyed on.

    Shapes are what the kernel actually sees at trace time — under a
    lane-sharded ``shard_map`` launch these are the per-device LOCAL
    shard dims, so an 8-way lane split of a 4Ki-lane bucket tunes for
    512 lanes, not 4Ki.
    """
    op: str             # "gather_vmem" | "gather_dma" | "scatter"
    batch: int          # pattern-batch dim
    lanes: int          # index lanes per pattern (pre block padding)
    rows: int           # table / destination rows (incl. scratch row)
    width: int          # row width D
    dtype: str
    platform: str       # "interpret" | "tpu"


@dataclasses.dataclass(frozen=True)
class TileChoice:
    """A concrete tiling; fields irrelevant to the op stay 0."""
    block_n: int = 0    # gather_vmem lanes per step / scatter chunk lanes
    block_v: int = 0    # scatter output-tile rows
    block_i: int = 0    # gather_dma rows per DMA block
    block_d: int = 0    # gather_dma row-slice width

    def to_wire(self) -> list[int]:
        return [self.block_n, self.block_v, self.block_i, self.block_d]

    @staticmethod
    def from_wire(v) -> "TileChoice":
        bn, bv, bi, bd = (int(x) for x in v)
        return TileChoice(block_n=bn, block_v=bv, block_i=bi, block_d=bd)


# legacy fixed tiles — what the kernels shipped with before the search
LEGACY = {
    "gather_vmem": TileChoice(block_n=64),
    "gather_dma": TileChoice(block_i=8, block_d=512),
    "scatter": TileChoice(block_n=128, block_v=128),
}

# cost-model constants.  STEP_OVH is the per-grid-step launch overhead in
# "element-work" units: interpret mode executes each grid step as a
# Python-level interpreter iteration, so its overhead dwarfs the per-
# element work and the search minimizes step count; compiled TPU steps
# are cheap, so tile shape (VMEM fit, MXU occupancy) dominates instead.
_STEP_OVH = {"interpret": 1 << 17, "tpu": 64}
# per-candidate feasibility caps, bytes of tile-resident data
_TILE_BYTES_CAP = {"interpret": 1 << 26, "tpu": 1 << 21}
# one-hot membership matrix cap (block_v * block_n elements)
_ONEHOT_CAP = {"interpret": 1 << 22, "tpu": 1 << 15}
_MAX_BLOCK = 4096


def _pow2s(lo: int, hi: int):
    """Powers of two in [lo, hi], ascending (hi included via bracketing)."""
    out = []
    b = lo
    while b <= hi:
        out.append(b)
        b *= 2
    return out


def _next_pow2(n: int) -> int:
    return 1 << (max(1, n) - 1).bit_length()


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _itemsize(dtype: str) -> int:
    import numpy as np
    return int(np.dtype(dtype).itemsize)


def _search_gather_vmem(key: TileKey) -> TileChoice:
    ovh = _STEP_OVH[key.platform]
    cap = _TILE_BYTES_CAP[key.platform]
    item = _itemsize(key.dtype)
    hi = min(_MAX_BLOCK, _next_pow2(key.lanes))
    best, best_cost = None, None
    for bn in _pow2s(8, max(8, hi)):
        # tile residency: the (bn, width) output block, double-buffered
        if 2 * bn * key.width * item > cap:
            continue
        steps = key.batch * _ceil_div(max(1, key.lanes), bn)
        cost = steps * (ovh + bn * key.width)
        if best_cost is None or cost < best_cost:
            best, best_cost = bn, cost
    return TileChoice(block_n=best if best is not None else 8)


def _search_gather_dma(key: TileKey) -> TileChoice:
    ovh = _STEP_OVH[key.platform]
    item = _itemsize(key.dtype)
    # row-slice width: largest pow2 <= min(width, 512) that divides width
    bd = min(512, _next_pow2(key.width))
    while bd > 1 and (key.width % bd or 2 * bd * item > _TILE_BYTES_CAP[key.platform]):
        bd //= 2
    hi = min(256, _next_pow2(key.lanes))
    best, best_cost = None, None
    for bi in _pow2s(8, max(8, hi)):
        if bi * bd * item * 2 > _TILE_BYTES_CAP[key.platform]:
            continue
        steps = key.batch * _ceil_div(max(1, key.lanes), bi)
        # each step issues bi DMAs of bd elements; overlap hides about
        # half the copy latency behind the writeback
        cost = steps * (ovh + bi * (32 + bd))
        if best_cost is None or cost < best_cost:
            best, best_cost = bi, cost
    return TileChoice(block_i=best if best is not None else 8, block_d=bd)


def _search_scatter(key: TileKey) -> TileChoice:
    ovh = _STEP_OVH[key.platform]
    onehot_cap = _ONEHOT_CAP[key.platform]
    cap = _TILE_BYTES_CAP[key.platform]
    item = _itemsize(key.dtype)
    hi_v = min(_MAX_BLOCK, _next_pow2(key.rows))
    hi_n = min(_MAX_BLOCK, _next_pow2(key.lanes))
    best, best_cost = None, None
    for bv in _pow2s(8, max(8, hi_v)):
        for bn in _pow2s(8, max(8, hi_n)):
            if bv * bn > onehot_cap:
                continue
            # residency: out tile + staged vals chunk (x2 buffers) + onehot
            tile_b = (bv * key.width + 2 * bn * key.width + bv * bn) * item
            if tile_b > cap:
                continue
            steps = (key.batch * _ceil_div(max(1, key.rows), bv)
                     * _ceil_div(max(1, key.lanes), bn))
            work = bv * bn + (bv + bn) * key.width
            cost = steps * (ovh + work)
            if best_cost is None or cost < best_cost:
                best, best_cost = (bv, bn), cost
    if best is None:
        return TileChoice(block_v=8, block_n=8)
    return TileChoice(block_v=best[0], block_n=best[1])


_SEARCHERS = {
    "gather_vmem": _search_gather_vmem,
    "gather_dma": _search_gather_dma,
    "scatter": _search_scatter,
}

_LOCK = threading.Lock()
_MEMO: dict[TileKey, TileChoice] = {}
_STATS = {"searched": 0, "hits": 0, "seeded": 0}
_DISABLED = 0
_RECORDERS: list[dict] = []


def choose(key: TileKey) -> TileChoice:
    """The tile choice for ``key``: memo hit, or one deterministic search.

    Under ``disabled()`` returns the legacy fixed tiles without touching
    the memo (the before/after benchmark's "before" leg).
    """
    if key.op not in _SEARCHERS:
        raise ValueError(f"unknown autotune op {key.op!r}")
    if _DISABLED:
        return LEGACY[key.op]
    with _LOCK:
        choice = _MEMO.get(key)
        if choice is None:
            choice = _SEARCHERS[key.op](key)
            _MEMO[key] = choice
            _STATS["searched"] += 1
        else:
            _STATS["hits"] += 1
        for rec in _RECORDERS:
            rec[key] = choice
        return choice


def lookup(key: TileKey) -> TileChoice | None:
    with _LOCK:
        return _MEMO.get(key)


def stats() -> dict:
    with _LOCK:
        return dict(_STATS)


def reset() -> None:
    """Drop the memo and zero the counters (tests only)."""
    with _LOCK:
        _MEMO.clear()
        for k in _STATS:
            _STATS[k] = 0


@contextlib.contextmanager
def disabled():
    """Serve the legacy fixed tiles for the duration of the block."""
    global _DISABLED
    with _LOCK:
        _DISABLED += 1
    try:
        yield
    finally:
        with _LOCK:
            _DISABLED -= 1


@contextlib.contextmanager
def recording():
    """Collect every choice served inside the block: ``{TileKey: choice}``.

    ``DiskTier.store`` wraps executable serialization with this — tracing
    the executable calls ``choose`` for exactly the tiles it bakes in, so
    the recorded dict is precisely what the disk entry must re-seed.
    """
    rec: dict[TileKey, TileChoice] = {}
    with _LOCK:
        _RECORDERS.append(rec)
    try:
        yield rec
    finally:
        with _LOCK:
            # by identity, not ==: nested recorders (DiskTier.store inside
            # a benchmark's recording block) can hold equal dicts
            for i, r in enumerate(_RECORDERS):
                if r is rec:
                    del _RECORDERS[i]
                    break


# -- wire format (DiskTier header) ------------------------------------------

def _key_to_wire(key: TileKey) -> str:
    return (f"{key.op}:{key.batch}:{key.lanes}:{key.rows}:{key.width}:"
            f"{key.dtype}:{key.platform}")


def _key_from_wire(s: str) -> TileKey:
    op, batch, lanes, rows, width, dtype, platform = s.split(":")
    return TileKey(op=op, batch=int(batch), lanes=int(lanes), rows=int(rows),
                   width=int(width), dtype=dtype, platform=platform)


def to_wire(entries: dict) -> dict:
    """``{TileKey: TileChoice}`` -> JSON-safe ``{str: [int, ...]}``."""
    return {_key_to_wire(k): v.to_wire() for k, v in sorted(
        entries.items(), key=lambda kv: _key_to_wire(kv[0]))}


def seed_wire(wire: dict | None) -> int:
    """Seed the memo from a wire dict (disk restore); returns entries
    adopted.  Existing memo entries win — a live search result and a
    disk header can only disagree if the model changed, and the running
    process's own choice is the one its traces bake in."""
    if not wire:
        return 0
    n = 0
    with _LOCK:
        for ks, v in wire.items():
            try:
                key = _key_from_wire(ks)
                choice = TileChoice.from_wire(v)
            except (ValueError, TypeError):
                continue
            if key not in _MEMO:
                _MEMO[key] = choice
                _STATS["seeded"] += 1
                n += 1
    return n
