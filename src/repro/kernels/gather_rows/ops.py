"""Public jit'd wrappers for the row-gather kernels.

Picks the VMEM-resident regime for small tables and the DMA regime
otherwise, pads ragged shapes, and defaults to interpret mode off-TPU.
``gather_rows_batched`` runs a whole pattern batch (a planner bucket) as
one kernel launch (DESIGN.md §2.2); ``gather_rows`` is its B=1 case.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel

# VMEM on v5e is ~128 MiB/core but the pipeline needs headroom; stage tables
# whole only when they take at most this many bytes.
_VMEM_TABLE_BYTES = 4 * 1024 * 1024
# vmem regime: rows gathered per grid step.  64 amortizes the per-step
# overhead over a full (8, 128)-tile-aligned output block (the old default
# of 8 left 8x more grid steps on the table for nothing).
_DEFAULT_BLOCK_N = 64
# dma regime: row DMAs in flight per grid step (multi-row blocking); 8
# concurrent row fetches keeps the DMA engine busy without exhausting the
# double-buffered VMEM block budget.
_DEFAULT_BLOCK_I = 8


def _should_interpret(interpret: bool | None) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _pad_idx(idx, multiple: int):
    n = idx.shape[-1]
    pad = (-n) % multiple
    if not pad:
        return idx
    fill = jnp.zeros(idx.shape[:-1] + (pad,), jnp.int32)   # row 0: harmless
    return jnp.concatenate([idx, fill], axis=-1)


def _pick_block_d(d: int) -> int:
    block_d = d if d <= 512 else 512
    while d % block_d:
        block_d //= 2
        if block_d == 0:
            return d
    return block_d


@functools.partial(jax.jit, static_argnames=("mode", "block_n", "block_d",
                                             "block_i", "interpret"))
def _gather_rows_batched(table, idx, mode: str, block_n: int, block_d: int,
                         block_i: int, interpret: bool):
    bsz, n = idx.shape
    _, v, d = table.shape
    idx = idx.astype(jnp.int32)
    if mode == "vmem":
        out = kernel.gather_rows_vmem(table, _pad_idx(idx, block_n),
                                      block_n=block_n, interpret=interpret)
        return out[:, :n]
    # dma mode: pad D up to a block_d multiple, N up to a block_i multiple
    pad_d = (-d) % block_d
    if pad_d:
        table = jnp.pad(table, ((0, 0), (0, 0), (0, pad_d)))
    out = kernel.gather_rows_dma(table, _pad_idx(idx, block_i),
                                 block_d=block_d, block_i=block_i,
                                 interpret=interpret)
    return out[:, :n, :d]


def gather_rows_batched(table: jax.Array, idx: jax.Array, *,
                        mode: str = "auto",
                        block_n: int = _DEFAULT_BLOCK_N,
                        block_d: int | None = None,
                        block_i: int = _DEFAULT_BLOCK_I,
                        interpret: bool | None = None) -> jax.Array:
    """Batched gather: (B, V, D) tables, (B, N) idx -> (B, N, D).

    One kernel launch for the whole pattern batch (a planner bucket), with
    the index buffers scalar-prefetched once — not a vmap of per-pattern
    launches.  The regime choice sizes VMEM per b-step, so it uses one
    pattern's table bytes, not the whole stack's.
    """
    if table.ndim != 3 or idx.ndim != 2 or table.shape[0] != idx.shape[0]:
        raise ValueError(f"expected (B,V,D) table and (B,N) idx, got "
                         f"{table.shape} / {idx.shape}")
    interp = _should_interpret(interpret)
    if mode == "auto":
        per_pattern_bytes = (table.shape[1] * table.shape[2]
                             * table.dtype.itemsize)
        mode = "vmem" if per_pattern_bytes <= _VMEM_TABLE_BYTES else "dma"
    if block_d is None:
        block_d = _pick_block_d(table.shape[2])
    block_n = min(block_n, max(1, idx.shape[1]))
    block_i = min(block_i, max(1, idx.shape[1]))
    return _gather_rows_batched(table, idx, mode, block_n, block_d, block_i,
                                interp)


def gather_rows(table: jax.Array, idx: jax.Array, *, mode: str = "auto",
                block_n: int = _DEFAULT_BLOCK_N, block_d: int | None = None,
                block_i: int = _DEFAULT_BLOCK_I,
                interpret: bool | None = None) -> jax.Array:
    """Gather rows of ``table`` (V, D) at positions ``idx`` (N,) -> (N, D).

    The B=1 case of the batched kernel — one code path for both.
    """
    if table.ndim != 2 or idx.ndim != 1:
        raise ValueError(f"expected (V,D) table and (N,) idx, got "
                         f"{table.shape} / {idx.shape}")
    return gather_rows_batched(table[None], idx[None], mode=mode,
                               block_n=block_n, block_d=block_d,
                               block_i=block_i, interpret=interpret)[0]
