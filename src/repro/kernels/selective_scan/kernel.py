"""Fused Mamba selective scan — the TPU adaptation of the CUDA scan kernel.

§Perf iteration (falcon-mamba-7b train_4k): the XLA lax.scan lowering
round-trips the (N, d_inner) state and ~10 elementwise temporaries through
HBM on EVERY timestep — the parsed memory term is 9789 s.  The original
paper's CUDA kernel keeps h in shared memory; the TPU-native equivalent
keeps h in VMEM scratch across a sequence-blocked grid and streams only
u/dt/B/C in and y out (HBM traffic = the unavoidable activations).

Layout: u, dt (B, L, D); b_in, c_in (B, L, N); a (N, D) [=-exp(A_log).T];
h scratch (N, D) f32.  Grid (B, L/block_l), seq innermost: the carried
state lives in VMEM for the whole sequence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(block_l: int, u_blk, dt_blk, b_blk, c_blk, a_blk, d_blk,
                 y_blk, hN_blk, h_scr):
    il = pl.program_id(1)
    n_l = pl.num_programs(1)

    @pl.when(il == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_blk[...]                                   # (N, D), negative
    d_skip = d_blk[...]                              # (1, D)

    def step(t, h):
        u_t = u_blk[0, t].astype(jnp.float32)        # (D,)
        dt_t = dt_blk[0, t].astype(jnp.float32)      # (D,)
        b_t = b_blk[0, t].astype(jnp.float32)        # (N,)
        c_t = c_blk[0, t].astype(jnp.float32)        # (N,)
        da = jnp.exp(dt_t[None, :] * a)              # (N, D)
        h = h * da + (dt_t * u_t)[None, :] * b_t[:, None]
        y_t = jnp.sum(h * c_t[:, None], axis=0) + d_skip[0] * u_t
        y_blk[0, t] = y_t.astype(y_blk.dtype)
        return h

    h = jax.lax.fori_loop(0, block_l, step, h_scr[...])
    h_scr[...] = h

    @pl.when(il == n_l - 1)
    def _emit_state():
        hN_blk[0] = h_scr[...]


def selective_scan_fwd(u, dt, b_in, c_in, a, d_skip, *, block_l: int,
                       interpret: bool):
    """Returns (y (B,L,D), h_final (B,N,D))."""
    bsz, l, d = u.shape
    n = b_in.shape[2]
    assert l % block_l == 0, (l, block_l)
    grid = (bsz, l // block_l)

    y, h_final = pl.pallas_call(
        functools.partial(_scan_kernel, block_l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_l, d), lambda b, il: (b, il, 0)),
            pl.BlockSpec((1, block_l, d), lambda b, il: (b, il, 0)),
            pl.BlockSpec((1, block_l, n), lambda b, il: (b, il, 0)),
            pl.BlockSpec((1, block_l, n), lambda b, il: (b, il, 0)),
            pl.BlockSpec((n, d), lambda b, il: (0, 0)),
            pl.BlockSpec((1, d), lambda b, il: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_l, d), lambda b, il: (b, il, 0)),
            pl.BlockSpec((1, n, d), lambda b, il: (b, 0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((n, d), jnp.float32)],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, l, d), u.dtype),
            jax.ShapeDtypeStruct((bsz, n, d), jnp.float32),
        ],
        interpret=interpret,
    )(u, dt, b_in, c_in, a, d_skip)
    return y, h_final
