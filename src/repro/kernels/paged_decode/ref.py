"""Pure-jnp oracle for paged decode attention."""
import jax
import jax.numpy as jnp


def paged_decode_attention_ref(q, k_pages, v_pages, page_table, lengths, *,
                               scale: float) -> jax.Array:
    """Dense reference: materialize each sequence's KV then do softmax attention.

    q          (B, KVH, G, Dh)
    k_pages    (KVH, P, page, Dh)
    page_table (B, pages_per_seq)
    lengths    (B,)
    """
    b, kvh, g, dh = q.shape
    _, _, page_size, _ = k_pages.shape
    pages_per_seq = page_table.shape[1]
    seq = pages_per_seq * page_size

    # gather pages: (B, KVH, pages_per_seq, page, Dh)
    k = jnp.take(k_pages, page_table, axis=1)           # (KVH, B, pp, page, Dh)
    v = jnp.take(v_pages, page_table, axis=1)
    k = jnp.moveaxis(k, 1, 0).reshape(b, kvh, seq, dh)
    v = jnp.moveaxis(v, 1, 0).reshape(b, kvh, seq, dh)

    s = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    pos = jnp.arange(seq)[None, None, None, :]
    s = jnp.where(pos < lengths[:, None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
