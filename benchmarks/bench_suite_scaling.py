"""Suite-scaling: batched planner vs one-compile-per-pattern (plan.py).

A 32-pattern suite whose shapes collapse into a handful of pow-2 buckets
is run both ways; the batched path must (a) compile only #buckets
executables (cache miss counter) and (b) win wall-clock end-to-end,
because per-pattern mode pays 32 XLA compiles.
"""
from __future__ import annotations

import time

from repro.core import ExecutorCache, SuitePlan, make_pattern, run_suite

from .harness import emit


def make_suite(n: int = 32, count: int = 1 << 10):
    """n patterns, half gather / half scatter, strides cycling 1..8."""
    pats = []
    for i in range(n):
        kind = "gather" if i % 2 == 0 else "scatter"
        stride = (i // 2) % 8 + 1
        pats.append(make_pattern(f"UNIFORM:8:{stride}", kind=kind,
                                 delta=8, count=count,
                                 name=f"{kind[0]}{i}"))
    return pats


def run(runs: int = 3) -> dict:
    pats = make_suite()
    plan = SuitePlan.build(pats)

    t0 = time.perf_counter()
    run_suite(pats, backend="xla", runs=runs, batch=False)
    t_per_pattern = time.perf_counter() - t0

    cache = ExecutorCache()
    t0 = time.perf_counter()
    run_suite(pats, backend="xla", runs=runs, cache=cache)
    t_batched_cold = time.perf_counter() - t0
    compiles_cold = cache.misses

    t0 = time.perf_counter()
    run_suite(pats, backend="xla", runs=runs, cache=cache)
    t_batched_warm = time.perf_counter() - t0
    compiles_warm = cache.misses - compiles_cold

    assert compiles_cold == plan.n_buckets < len(pats), \
        (compiles_cold, plan.n_buckets)
    assert compiles_warm == 0, compiles_warm

    emit("suite_scaling/per_pattern", t_per_pattern * 1e6,
         f"{len(pats)}compiles")
    emit("suite_scaling/batched_cold", t_batched_cold * 1e6,
         f"{compiles_cold}compiles")
    emit("suite_scaling/batched_warm", t_batched_warm * 1e6,
         f"{compiles_warm}compiles")
    emit("suite_scaling/speedup_cold", 0.0,
         f"{t_per_pattern / t_batched_cold:.1f}x")
    emit("suite_scaling/speedup_warm", 0.0,
         f"{t_per_pattern / t_batched_warm:.1f}x")
    return {
        "per_pattern_s": t_per_pattern,
        "batched_cold_s": t_batched_cold,
        "batched_warm_s": t_batched_warm,
        "compiles_cold": compiles_cold,
        "n_buckets": plan.n_buckets,
    }


if __name__ == "__main__":
    run()
