from .ops import scatter_add_rows  # noqa: F401
