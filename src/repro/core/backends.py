"""Gather / scatter backends — the TPU adaptation of Spatter's backend set.

Paper backends -> this repo (DESIGN.md §2):

    OpenMP (compiler-vectorized)  ->  "xla"     jnp.take / .at[] — XLA's native
                                               gather/scatter lowering, i.e. what
                                               "the compiler" does with the access.
    CUDA (shared-mem index buf)   ->  "pallas"  explicit scalar-prefetch DMA kernel
                                               (index buffer in SMEM drives the DMA).
    Scalar (#pragma novec)        ->  "scalar"  lax.fori_loop of dynamic_slice,
                                               one row per step — the no-vector
                                               baseline.
    (no analogue on CPU/GPU)      ->  "onehot"  gather as one-hot MXU matmul — the
                                               TPU-only trick of turning data
                                               movement into dense compute.

All backends share one contract:

    gather(src, idx)            src: (F, R) table, idx: (N,) int32 -> (N, R)
    scatter(dst, idx, vals)     vals: (N, R) -> dst' (F, R); mode "store"|"add"

The *row* (R) is the TPU element unit (DESIGN.md §2): Spatter's 8-byte double
becomes a lane-aligned row here.  R=1 recovers the paper's scalar semantics.

Store-mode duplicate handling (DESIGN.md §2.1): the paper's parallel scatter
leaves duplicate-index order unspecified; we pin it to last-write-wins so
backends are cross-checkable.  The keep mask that implements it is a pure
function of the (static) index buffer, so it is computed ONCE on the host
(``keep_last_mask``) at build/plan time and threaded through every store
scatter as a regular operand — the timed executable contains no sort, no
dedup, nothing but the access under test (paper §3.5 measurement
discipline).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

BACKENDS = ("xla", "onehot", "scalar", "pallas")

# Guard for the one-hot backend: a (N, F) one-hot with F beyond this is a
# mistake, not a benchmark (it would build a >2^31-element intermediate).
_ONEHOT_MAX_FOOTPRINT = 1 << 22


# ---------------------------------------------------------------------------
# Gather
# ---------------------------------------------------------------------------

def gather_xla(src: jax.Array, idx: jax.Array) -> jax.Array:
    """XLA-native gather — what the compiler emits for indexed loads."""
    return jnp.take(src, idx, axis=0)


def gather_onehot(src: jax.Array, idx: jax.Array) -> jax.Array:
    """Gather as one-hot matmul: out = onehot(idx) @ src.  MXU-resident on TPU."""
    f = src.shape[0]
    if f > _ONEHOT_MAX_FOOTPRINT:
        raise ValueError(f"onehot backend: footprint {f} too large")
    oh = jax.nn.one_hot(idx, f, dtype=src.dtype)
    return oh @ src


def gather_scalar(src: jax.Array, idx: jax.Array) -> jax.Array:
    """One row per loop step — the paper's non-vectorized Scalar backend."""
    n = idx.shape[0]
    r = src.shape[1]
    out = jnp.zeros((n, r), dtype=src.dtype)

    def body(i, out):
        row = lax.dynamic_slice(src, (idx[i], 0), (1, r))
        return lax.dynamic_update_slice(out, row, (i, 0))

    return lax.fori_loop(0, n, body, out)


def gather_pallas(src: jax.Array, idx: jax.Array) -> jax.Array:
    """Scalar-prefetch DMA gather (Pallas TPU kernel, interpret=True on CPU)."""
    from repro.kernels.gather_rows import ops as gather_ops
    return gather_ops.gather_rows(src, idx)


# ---------------------------------------------------------------------------
# Scatter
# ---------------------------------------------------------------------------

def keep_last_mask(idx: np.ndarray) -> np.ndarray:
    """Host-side last-write-wins keep mask: True at the last occurrence of
    each distinct index value, False elsewhere.

    Pattern indices are static at build/plan time, so this runs ONCE in
    numpy — never inside a timed executable (DESIGN.md §2.1).
    """
    idx = np.asarray(idx)
    n = idx.shape[0]
    if n == 0:
        return np.zeros((0,), bool)
    order = np.argsort(idx, kind="stable")       # stable: ties keep position
    sidx = idx[order]
    is_last = np.concatenate([sidx[1:] != sidx[:-1], np.ones((1,), bool)])
    keep = np.zeros((n,), bool)
    keep[order[is_last]] = True
    return keep


def _keep_last_traced(idx: jax.Array, footprint: int) -> jax.Array:
    """Sort-free traced fallback for ad-hoc store scatters without a
    precomputed mask: scatter-max each lane's position into a (F,) table,
    keep the lanes that hold their row's max.  O(N + F), no sort primitive.

    The engine/planner hot paths never hit this — they pass the host mask.
    """
    n = idx.shape[0]
    pos = jnp.arange(n, dtype=jnp.int32)
    last = jnp.zeros((footprint,), jnp.int32).at[idx].max(pos, mode="drop")
    return last[idx] == pos


def _store_keep(keep, idx: jax.Array, footprint: int) -> jax.Array:
    """Resolve a store scatter's keep mask: the caller-provided operand, the
    host mask when indices are concrete, else the traced fallback."""
    if keep is not None:
        return keep
    if isinstance(idx, jax.core.Tracer):   # indices unknown at trace time
        return _keep_last_traced(idx, footprint)
    return jnp.asarray(keep_last_mask(np.asarray(idx)))


def scatter_xla(dst: jax.Array, idx: jax.Array, vals: jax.Array,
                mode: str = "store", keep: jax.Array | None = None
                ) -> jax.Array:
    if mode == "add":
        return dst.at[idx].add(vals)
    f = dst.shape[0]
    keep = _store_keep(keep, idx, f)
    # route dropped writes out of range; drop-mode scatter discards them
    safe_idx = jnp.where(keep, idx, f)
    return dst.at[safe_idx].set(vals, mode="drop")


def scatter_onehot(dst: jax.Array, idx: jax.Array, vals: jax.Array,
                   mode: str = "store", keep: jax.Array | None = None
                   ) -> jax.Array:
    f = dst.shape[0]
    if f > _ONEHOT_MAX_FOOTPRINT:
        raise ValueError(f"onehot backend: footprint {f} too large")
    if mode == "add":
        oh = jax.nn.one_hot(idx, f, dtype=vals.dtype)      # (N, F)
        return dst + oh.T @ vals
    keep = _store_keep(keep, idx, f)
    oh = jax.nn.one_hot(idx, f, dtype=vals.dtype) * keep[:, None].astype(vals.dtype)
    covered = jnp.clip(oh.sum(axis=0), 0, 1)[:, None]      # (F, 1) in {0,1}
    return dst * (1 - covered) + oh.T @ vals


def scatter_scalar(dst: jax.Array, idx: jax.Array, vals: jax.Array,
                   mode: str = "store", keep: jax.Array | None = None
                   ) -> jax.Array:
    # sequential loop: store order IS last-write-wins; no mask needed
    del keep
    n = idx.shape[0]
    r = dst.shape[1]

    def body(i, dst):
        row = lax.dynamic_slice(vals, (i, 0), (1, r))
        if mode == "add":
            cur = lax.dynamic_slice(dst, (idx[i], 0), (1, r))
            row = row + cur
        return lax.dynamic_update_slice(dst, row, (idx[i], 0))

    return lax.fori_loop(0, n, body, dst)


def scatter_pallas(dst: jax.Array, idx: jax.Array, vals: jax.Array,
                   mode: str = "store", keep: jax.Array | None = None
                   ) -> jax.Array:
    from repro.kernels.scatter_rows import ops as scatter_ops
    if mode == "add":
        return dst + scatter_ops.scatter_add_rows(idx, vals, dst.shape[0])
    # store: one single-pass kernel launch; dropped lanes are routed out of
    # range so the kernel's one-hot never matches them
    keep = _store_keep(keep, idx, dst.shape[0])
    safe_idx = jnp.where(keep, idx, jnp.iinfo(jnp.int32).max)
    return scatter_ops.scatter_store_rows(dst, safe_idx, vals)


# ---------------------------------------------------------------------------
# Dispatch tables
# ---------------------------------------------------------------------------

GATHER_FNS: dict[str, Callable] = {
    "xla": gather_xla,
    "onehot": gather_onehot,
    "scalar": gather_scalar,
    "pallas": gather_pallas,
}

SCATTER_FNS: dict[str, Callable] = {
    "xla": scatter_xla,
    "onehot": scatter_onehot,
    "scalar": scatter_scalar,
    "pallas": scatter_pallas,
}


def gather(src: jax.Array, idx: jax.Array, *, backend: str = "xla") -> jax.Array:
    return GATHER_FNS[backend](src, idx)


def scatter(dst: jax.Array, idx: jax.Array, vals: jax.Array, *,
            mode: str = "store", backend: str = "xla",
            keep: jax.Array | None = None) -> jax.Array:
    return SCATTER_FNS[backend](dst, idx, vals, mode, keep)


# ---------------------------------------------------------------------------
# Batched dispatch (suite planner, core/plan.py): one launch runs a whole
# shape bucket of patterns.  Leading dim is the pattern-batch dim.  The
# pallas backend gets batch-native kernels — a real grid over
# (pattern-batch x tiles) with the index buffers scalar-prefetched once —
# instead of jax.vmap over a per-pattern pallas_call.
# ---------------------------------------------------------------------------

def gather_batched(src: jax.Array, idx: jax.Array, *,
                   backend: str = "xla") -> jax.Array:
    """src: (B, F, R), idx: (B, N) -> (B, N, R); one launch for B patterns."""
    if backend == "pallas":
        from repro.kernels.gather_rows import ops as gather_ops
        return gather_ops.gather_rows_batched(src, idx)
    return jax.vmap(lambda s, i: gather(s, i, backend=backend))(src, idx)


def scatter_batched(dst: jax.Array, idx: jax.Array, vals: jax.Array, *,
                    mode: str = "store", backend: str = "xla",
                    keep: jax.Array | None = None) -> jax.Array:
    """dst: (B, F, R), idx: (B, N), vals: (B, N, R) -> (B, F, R).

    ``keep`` is the (B, N) host-precomputed last-write-wins mask for store
    mode (plan._assemble_bucket computes it over the padded index buffer);
    without it each pattern falls back to per-row resolution.
    """
    if backend == "pallas":
        from repro.kernels.scatter_rows import ops as scatter_ops
        if mode == "add":
            return dst + scatter_ops.scatter_add_rows_batched(
                idx, vals, dst.shape[1])
        if keep is None:
            keep = jax.vmap(
                lambda i: _store_keep(None, i, dst.shape[1]))(idx)
        safe_idx = jnp.where(keep, idx, jnp.iinfo(jnp.int32).max)
        return scatter_ops.scatter_store_rows_batched(dst, safe_idx, vals)
    if mode == "add":
        return jax.vmap(
            lambda d, i, v: scatter(d, i, v, mode="add", backend=backend)
        )(dst, idx, vals)
    if keep is None:
        keep = jax.vmap(lambda i: _store_keep(None, i, dst.shape[1]))(idx)
    return jax.vmap(
        lambda d, i, v, k: scatter(d, i, v, mode="store", backend=backend,
                                   keep=k)
    )(dst, idx, vals, keep)
