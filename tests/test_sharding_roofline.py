"""Sharding-rule resolution + HLO roofline parser tests (single device),
plus a subprocess mini-dryrun on 8 fake devices."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.compat import make_abstract_mesh
from repro.launch.roofline import (Collective, analyze_module,
                                   parse_computations, _shape_bytes)

REPO = os.path.join(os.path.dirname(__file__), "..")


# ---------------------------------------------------------------------------
# logical rules
# ---------------------------------------------------------------------------

class TestLogicalSpec:
    def _mesh(self):
        # fake mesh objects need real devices; use a 1-device mesh with
        # axis sizes read from shape, so build an abstract mesh instead
        return make_abstract_mesh((16, 16), ("data", "model"))

    def test_divisible(self):
        from repro.runtime.sharding import logical_to_spec
        spec = logical_to_spec(("vocab", "embed"), (128256, 4096),
                               self._mesh())
        assert spec == P("model", None)

    def test_divisibility_fallback(self):
        from repro.runtime.sharding import logical_to_spec
        # kv_heads=2 cannot shard over model=16 -> replicated
        spec = logical_to_spec(("embed", "kv_heads", "head_dim"),
                               (4096, 2, 128), self._mesh())
        assert spec == P(None, None, None)

    def test_batch_multi_axis(self):
        from repro.runtime.sharding import logical_to_spec
        mesh3 = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))
        spec = logical_to_spec(("batch", "seq", "embed"), (256, 4096, 4096),
                               mesh3)
        assert spec[0] == ("pod", "data")

    def test_no_axis_reuse(self):
        from repro.runtime.sharding import logical_to_spec
        spec = logical_to_spec(("heads", "mlp"), (32, 128), self._mesh())
        used = [s for s in spec if s]
        assert len(used) == 1          # "model" used once only


class TestZero1:
    def test_moments_fully_sharded(self):
        from repro.runtime.train import zero1_shardings
        mesh = make_abstract_mesh((16, 16), ("data", "model"))
        axes = {"w": ("layers", "experts", "embed", "expert_mlp")}
        avals = {"w": jax.ShapeDtypeStruct((60, 384, 7168, 2048),
                                           jnp.float32)}
        sh = zero1_shardings(axes, avals, mesh)
        spec = sh["w"].spec
        assert spec[1] == "model"       # experts keep their axis
        assert "data" in spec           # + ZeRO over data on a divisible dim


# ---------------------------------------------------------------------------
# HLO parser
# ---------------------------------------------------------------------------

HLO_SAMPLE = textwrap.dedent("""\
    HloModule jit_f, entry_computation_layout={()->f32[]}

    %body (p: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
      %p = (s32[], f32[64,64]{1,0}) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[64,64]{1,0} get-tuple-element(%p), index=1
      %dot.1 = f32[64,64]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[64,64]{1,0} all-reduce(%dot.1), replica_groups={{0,1,2,3}}, to_apply=%add
      %one = s32[] constant(1)
      %i2 = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[64,64]{1,0}) tuple(%i2, %ar)
    }

    %cond (p: (s32[], f32[64,64])) -> pred[] {
      %p.1 = (s32[], f32[64,64]{1,0}) parameter(0)
      %i.1 = s32[] get-tuple-element(%p.1), index=0
      %n = s32[] constant(12)
      ROOT %lt = pred[] compare(%i.1, %n), direction=LT
    }

    ENTRY %main () -> f32[] {
      %c0 = s32[] constant(0)
      %x0 = f32[64,64]{1,0} constant(0)
      %init = (s32[], f32[64,64]{1,0}) tuple(%c0, %x0)
      %w = (s32[], f32[64,64]{1,0}) while(%init), condition=%cond, body=%body
      %xf = f32[64,64]{1,0} get-tuple-element(%w), index=1
      ROOT %s = f32[] reduce(%xf, %c0), dimensions={0,1}, to_apply=%add
    }
    """)


class TestHloParser:
    def test_shape_bytes(self):
        assert _shape_bytes("f32[64,64]{1,0}") == 64 * 64 * 4
        assert _shape_bytes("(s32[], f32[8,2]{1,0})") == 4 + 64
        assert _shape_bytes("bf16[10]") == 20

    def test_while_trip_multiplier(self):
        cost = analyze_module(HLO_SAMPLE, world=4)
        # dot: 2*64*64*64 flops, x12 trips from the cond constant
        assert cost.flops == 12 * 2 * 64 ** 3
        # one all-reduce per trip
        ar = [c for c in cost.collectives if c.kind == "all-reduce"]
        assert len(ar) == 1 and ar[0].count == 12 and ar[0].group_size == 4

    def test_ring_factors(self):
        c = Collective("all-reduce", 1000, 4, 1)
        assert np.isclose(c.ring_bytes(), 2 * 1000 * 3 / 4)
        c = Collective("all-gather", 1000, 4, 2)
        assert np.isclose(c.ring_bytes(), 2 * 1000 * 3 / 4)
        c = Collective("reduce-scatter", 250, 4, 1)
        assert np.isclose(c.ring_bytes(), 250 * 3)

    def test_backend_config_trip_count(self):
        hlo = HLO_SAMPLE.replace(
            "condition=%cond, body=%body",
            'condition=%cond, body=%body, backend_config='
            '{"known_trip_count":{"n":"99"}}')
        cost = analyze_module(hlo, world=4)
        assert cost.flops == 99 * 2 * 64 ** 3


# ---------------------------------------------------------------------------
# mini dry-run on 8 fake devices (subprocess: needs its own XLA_FLAGS)
# ---------------------------------------------------------------------------

MINI_DRYRUN = textwrap.dedent("""\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, os.path.join(%r, "src"))
    import dataclasses, jax, jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models.zoo import Model
    from repro.optim import AdamWConfig
    from repro.runtime.train import assemble_train
    from repro.launch.roofline import analyze_module

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = dataclasses.replace(get_smoke_config("llama3-8b"),
                              n_heads=4, n_kv_heads=4, d_model=64,
                              vocab=512, attn_chunk=16)
    model = Model(cfg)
    specs = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
    fn, (ap, ao), _ = assemble_train(model, mesh, AdamWConfig(),
                                     abstract_batch=specs)
    lowered = fn.lower(ap, ao, specs)
    compiled = lowered.compile()
    print("MEM", compiled.memory_analysis().temp_size_in_bytes)
    cost = analyze_module(compiled.as_text(), world=8)
    assert cost.flops > 0, "parser found no flops"
    assert len(cost.collectives) > 0, "no collectives in sharded train step"
    print("FLOPS", cost.flops)
    print("OK")
    """) % os.path.abspath(REPO)


def test_mini_dryrun_8dev():
    r = subprocess.run([sys.executable, "-c", MINI_DRYRUN],
                       capture_output=True, text=True, timeout=420)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
