"""Multi-device suite execution: sharded bucket launches vs the
single-device planner (core/plan.py ShardedExecutor).

Runs the same bucketed suite twice inside a subprocess that forces
``N_DEV`` fake host devices (XLA_FLAGS must be set before jax initializes,
so this cannot run in the parent process): once through the single-device
planner, once with every bucket launch's pattern-batch dim sharded over a
1-D mesh.  Reports suite harmonic-mean GB/s aggregate and per-device, and
end-to-end wall clock for both paths.

On a CPU host the fake devices share the same cores, so wall-clock parity
(not speedup) is the expected result — the bench verifies the sharded
path's overhead structure; the per-device split is the number that scales
on real multi-chip hardware.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from .harness import emit

N_DEV = 8

_CHILD = textwrap.dedent("""\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(n_dev)d"
    import sys, time, json
    sys.path.insert(0, %(src)r)
    import jax
    from repro.core import ExecutorCache, SuitePlan, make_pattern, run_suite

    def make_suite(n=16, count=1 << 14):
        pats = []
        for i in range(n):
            kind = "gather" if i %% 2 == 0 else "scatter"
            stride = (i // 2) %% 8 + 1
            pats.append(make_pattern("UNIFORM:8:%%d" %% stride, kind=kind,
                                     delta=8, count=count,
                                     name="%%s%%d" %% (kind[0], i)))
        return pats

    pats = make_suite()
    runs = %(runs)d
    mesh = jax.make_mesh((%(n_dev)d,), ("data",))

    cache = ExecutorCache()
    t0 = time.perf_counter()
    single = run_suite(pats, backend="xla", runs=runs, cache=cache)
    t_single = time.perf_counter() - t0

    t0 = time.perf_counter()
    sharded = run_suite(pats, backend="xla", runs=runs, cache=cache,
                        mesh=mesh)
    t_sharded = time.perf_counter() - t0

    print(json.dumps({
        "n_dev": %(n_dev)d,
        "n_buckets": single.plan.n_buckets,
        "single_hmean_gbs": single.hmean_gbs,
        "sharded_hmean_gbs": sharded.hmean_gbs,
        "wall_single_s": t_single,
        "wall_sharded_s": t_sharded,
        "compiles": cache.misses,
    }))
    """)


def run(runs: int = 3) -> dict:
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    code = _CHILD % {"n_dev": N_DEV, "src": src, "runs": runs}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=540)
    if r.returncode != 0:
        raise RuntimeError(f"sharded-suite child failed: {r.stderr[-2000:]}")
    stats = json.loads(r.stdout.strip().splitlines()[-1])

    agg = stats["sharded_hmean_gbs"]
    emit("sharded_suite/single_dev_hmean", stats["wall_single_s"] * 1e6,
         f"{stats['single_hmean_gbs']:.2f}GB/s")
    emit("sharded_suite/sharded_agg_hmean", stats["wall_sharded_s"] * 1e6,
         f"{agg:.2f}GB/s")
    emit("sharded_suite/sharded_per_dev", 0.0,
         f"{agg / stats['n_dev']:.2f}GB/s x{stats['n_dev']}dev")
    emit("sharded_suite/compiles", 0.0,
         f"{stats['compiles']}for{stats['n_buckets']}buckets_x2paths")
    return stats


if __name__ == "__main__":
    run()
