"""Public jit'd wrapper for paged decode attention."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel


def _should_interpret(interpret: bool | None) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def _paged_decode(q, k_pages, v_pages, page_table, lengths, scale, interpret):
    return kernel.paged_decode_kernel(
        q, k_pages, v_pages, page_table.astype(jnp.int32),
        lengths.astype(jnp.int32), scale=scale, interpret=interpret)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, page_table: jax.Array,
                           lengths: jax.Array, *, scale: float | None = None,
                           interpret: bool | None = None) -> jax.Array:
    """Flash-decode over a paged KV cache.

    q (B, KVH, G, Dh); k_pages/v_pages (KVH, P, page, Dh);
    page_table (B, pages_per_seq); lengths (B,) -> (B, KVH, G, Dh).
    """
    if q.ndim != 4 or k_pages.ndim != 4:
        raise ValueError(f"bad shapes q={q.shape} k={k_pages.shape}")
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    return _paged_decode(q, k_pages, v_pages, page_table, lengths,
                         float(scale), _should_interpret(interpret))
