from .adamw import AdamWConfig, init_opt_state, adamw_update, opt_state_axes
from .schedule import warmup_cosine
from .grad_utils import clip_by_global_norm, int8_compress, int8_decompress

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update", "opt_state_axes",
           "warmup_cosine", "clip_by_global_norm", "int8_compress",
           "int8_decompress"]
