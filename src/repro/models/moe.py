"""Mixture-of-Experts with sort-based gather/scatter dispatch.

This is the paper's technique at datacenter scale (DESIGN.md §3): routing is
a *scatter* of token rows into per-expert buffers and a *gather* back — the
exact (index buffer, delta) indexed-access class Spatter measures, with
runtime indices.  The implementation is the TPU-native sort-based form:

  1. top-k routing -> (token, expert) assignments
  2. argsort by expert id (the TPU scatter reformulation: sorting makes all
     writes consecutive, the same trick kernels/scatter_rows uses)
  3. capacity-clipped slot assignment (GShard-style, capacity_factor)
  4. gather token rows into (E, C, d) expert buffers       [Spatter gather]
  5. batched expert FFN, experts sharded over "model" (EP)
  6. gather results back + weighted scatter-add into tokens [Spatter scatter]

FLOPs stay ~active-parameters-only (x capacity_factor) — no dense all-expert
compute — so the roofline MODEL_FLOPS/HLO_FLOPs ratio stays honest.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.compat import axis_size, pcast, shard_map
from repro.runtime.sharding import constrain
from .common import ParamDef, mlp_def, mlp_apply


def moe_defs(cfg) -> dict:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    defs = {
        "router": ParamDef((d, e), ("embed", "experts"), scale=0.02),
        "experts": {
            "wi": ParamDef((e, d, ff), ("experts", "embed", "expert_mlp")),
            "wg": ParamDef((e, d, ff), ("experts", "embed", "expert_mlp")),
            "wo": ParamDef((e, ff, d), ("experts", "expert_mlp", "embed")),
        },
    }
    if cfg.n_shared_experts:
        defs["shared"] = mlp_def(cfg, d, cfg.d_ff_expert * cfg.n_shared_experts)
    return defs


def _capacity(cfg, n_tokens: int) -> int:
    c = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts)
    return max(cfg.top_k, (c + 3) // 4 * 4)


def moe_apply(cfg, p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x (B,S,d) -> (y (B,S,d), aux). Dispatches on cfg.moe_impl."""
    if getattr(cfg, "moe_impl", "gspmd_sort") == "ep_shardmap":
        return moe_apply_ep(cfg, p, x)
    return moe_apply_gspmd(cfg, p, x)


def moe_apply_gspmd(cfg, p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Baseline: pjit-level sort-based dispatch (GSPMD chooses collectives)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(b * s, d)
    n = b * s
    cap = _capacity(cfg, n)

    # --- 1. routing --------------------------------------------------------
    logits = (xt @ p["router"]).astype(jnp.float32)        # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, tope = jax.lax.top_k(probs, k)                   # (N, k)
    topw = topw / jnp.clip(topw.sum(-1, keepdims=True), 1e-9)  # deepseek norm
    topw = topw * cfg.router_scale

    # load-balance aux loss (Switch-style)
    me = probs.mean(axis=0)                                 # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[tope.reshape(-1)].add(
        1.0 / (n * k))
    aux = e * jnp.sum(me * ce)

    # --- 2-3. sort by expert, slot within capacity ---------------------------
    flat_e = tope.reshape(-1)                               # (N*k,)
    flat_w = topw.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    order = jnp.argsort(flat_e)                             # consecutive runs
    se, sw, stok = flat_e[order], flat_w[order], flat_tok[order]
    # slot = position within this expert's run
    starts = jnp.searchsorted(se, jnp.arange(e), side="left")  # (E,)
    slot = jnp.arange(n * k, dtype=jnp.int32) - starts[se]
    keep = slot < cap                                       # capacity drop

    # --- 4. Spatter gather: token rows -> (E, C, d) buffers ------------------
    oob = jnp.iinfo(jnp.int32).max                          # mode="drop"
    buf_idx = jnp.where(keep, se * cap + slot, oob)
    gathered = jnp.take(xt, stok, axis=0)                   # (N*k, d) gather
    gathered = constrain(gathered, ("batch", "embed"))
    zeros = constrain(jnp.zeros((e * cap, d), xt.dtype), ("experts", "embed"))
    buffers = zeros.at[buf_idx].add(gathered, mode="drop")
    buffers = constrain(buffers, ("experts", "embed"))
    buffers = buffers.reshape(e, cap, d)
    buffers = constrain(buffers, ("experts", "capacity", "embed"))

    # --- 5. batched expert FFN (EP: experts sharded over "model") -----------
    h = jnp.einsum("ecd,edf->ecf", buffers, p["experts"]["wi"])
    g = jnp.einsum("ecd,edf->ecf", buffers, p["experts"]["wg"])
    h = jax.nn.silu(g) * h
    out = jnp.einsum("ecf,efd->ecd", h, p["experts"]["wo"])
    out = constrain(out, ("experts", "capacity", "embed"))

    # --- 6. gather back + weighted combine -----------------------------------
    flat_out = constrain(out.reshape(e * cap, d), ("experts", "embed"))
    back = jnp.take(flat_out, jnp.clip(buf_idx, 0, e * cap - 1), axis=0)
    back = back * (sw * keep)[:, None].astype(back.dtype)
    back = constrain(back, ("batch", "embed"))
    y_zeros = constrain(jnp.zeros((n, d), xt.dtype), ("batch", "embed"))
    y = y_zeros.at[stok].add(back)

    if cfg.n_shared_experts:
        y = y + mlp_apply(cfg, p["shared"], xt)
    y = y.reshape(b, s, d)
    return constrain(y, ("batch", "seq", "embed")), aux


# ---------------------------------------------------------------------------
# Optimized EP (§Perf hillclimb #1): shard_map expert parallelism
# ---------------------------------------------------------------------------
#
# GSPMD lowers the pjit sort-based dispatch into per-layer all-reduces of the
# FULL (E*C, d) expert buffer over the model group (measured 14.3 TB/chip on
# deepseek-v2 train_4k -> t_coll 355 s).  Here each model-rank owns E/ep
# experts, selects *only its own* routed tokens with a local sort-compact
# (the Spatter gather, per shard), runs its experts densely, scatter-adds a
# partial output, and the only collective is ONE psum of the (b_loc, s, d)
# activations per layer: ~1.3 GB/chip/layer vs ~240 GB/chip/layer.

def _ep_inner(cfg, axis: str, pp: dict, xt: jax.Array, tope: jax.Array,
              topw: jax.Array):
    """Per-rank body (inside shard_map). xt (N, d) tokens (replicated over
    the model axis); pp expert weights are this rank's (E_loc, d, ff)."""
    # mark the replicated inputs as varying over the EP axis: forward is a
    # no-op broadcast, but the TRANSPOSE becomes an explicit psum — without
    # this the per-rank cotangents of xt/topw (each rank consumed different
    # tokens) are silently treated as replicated and 15/16 of the gradient
    # is dropped (caught by tests/test_moe_ep.py grad-equivalence).
    xt = pcast(xt, axis, to="varying")
    tope = pcast(tope, axis, to="varying")
    topw = pcast(topw, axis, to="varying")
    n, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k
    ep = axis_size(axis)
    e_loc = e // ep
    j = jax.lax.axis_index(axis)
    e_lo = j * e_loc
    cap = min(max(4, int(cfg.capacity_factor * n * k / e)), n * k)
    l = min(e_loc * cap, n * k)                          # compacted rows

    flat_e = tope.reshape(-1)
    flat_w = topw.reshape(-1)
    # my-expert entries sort first (key < e), foreign tokens sort to the end
    key = jnp.where((flat_e >= e_lo) & (flat_e < e_lo + e_loc), flat_e, e)
    order = jnp.argsort(key)[:l]                         # compact: take L
    se = key[order]                                      # sorted expert ids
    stok = order // k
    sw = flat_w[order]
    valid = se < e
    local_e = jnp.where(valid, se - e_lo, 0)
    starts = jnp.searchsorted(
        se, jnp.arange(e_loc, dtype=se.dtype) + e_lo, side="left")
    slot = jnp.arange(l, dtype=jnp.int32) - starts[local_e]
    keep = valid & (slot < cap)

    # local Spatter gather -> (E_loc*cap, d) buffers.  Foreign rows must be
    # zeroed BEFORE the drop-scatter: the transpose of an OOB-dropped
    # scatter-add is a clipped gather, which would leak d_buffers[-1] into
    # every dropped row's cotangent (found by the EP-vs-baseline grad test).
    oob = jnp.iinfo(jnp.int32).max
    buf_idx = jnp.where(keep, local_e * cap + slot, oob)
    rows = jnp.take(xt, stok, axis=0)                    # (L, d) local gather
    rows = rows * keep[:, None].astype(xt.dtype)
    buffers = jnp.zeros((e_loc * cap, d), xt.dtype).at[buf_idx].add(
        rows, mode="drop").reshape(e_loc, cap, d)

    h = jnp.einsum("ecd,edf->ecf", buffers, pp["wi"])
    g = jnp.einsum("ecd,edf->ecf", buffers, pp["wg"])
    out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, pp["wo"])

    back = jnp.take(out.reshape(e_loc * cap, d),
                    jnp.clip(buf_idx, 0, e_loc * cap - 1), axis=0)
    back = back * (sw * keep)[:, None].astype(back.dtype)
    y = jnp.zeros((n, d), xt.dtype).at[stok].add(back)   # local scatter
    return jax.lax.psum(y, axis)                         # the ONE collective


def moe_apply_ep(cfg, p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro.runtime.sharding import current_mesh, resolve_axis

    mesh, rules = current_mesh()
    if mesh is None or "model" not in mesh.axis_names or \
            cfg.n_experts % mesh.shape["model"] != 0:
        return moe_apply_gspmd(cfg, p, x)     # no EP axis -> baseline

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(b * s, d)

    # router in pjit-land (tiny, replicated over model)
    logits = (xt @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, tope = jax.lax.top_k(probs, k)
    topw = topw / jnp.clip(topw.sum(-1, keepdims=True), 1e-9)
    topw = (topw * cfg.router_scale).astype(x.dtype)
    tope = tope.astype(jnp.int32)
    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[tope.reshape(-1)].add(
        1.0 / (b * s * k))
    aux = e * jnp.sum(me * ce)

    batch_axes = resolve_axis("batch", b * s, mesh, rules)
    tok_spec = P(batch_axes)
    expert_specs = {
        "wi": P("model", None, None), "wg": P("model", None, None),
        "wo": P("model", None, None)}

    inner = partial(_ep_inner, cfg, "model")
    y = shard_map(
        inner, mesh=mesh,
        in_specs=(expert_specs, P(batch_axes, None),
                  P(batch_axes, None), P(batch_axes, None)),
        out_specs=P(batch_axes, None),
    )(p["experts"], xt, tope, topw)

    if cfg.n_shared_experts:
        y = y + mlp_apply(cfg, p["shared"], xt)
    y = y.reshape(b, s, d)
    return constrain(y, ("batch", "seq", "embed")), aux
