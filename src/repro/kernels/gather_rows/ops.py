"""Public jit'd wrappers for the row-gather kernels.

Picks the VMEM-resident regime for small tables and the DMA regime
otherwise, pads ragged shapes, and defaults to interpret mode off-TPU.
Block sizes default to the deterministic per-geometry autotuner
(``kernels.autotune``); passing any block explicitly bypasses the search
for that block.  ``gather_rows_batched`` runs a whole pattern batch (a
planner bucket) as one kernel launch (DESIGN.md §2.2); ``gather_rows``
is its B=1 case.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel
from .. import autotune

# VMEM on v5e is ~128 MiB/core but the pipeline needs headroom; stage tables
# whole only when they take at most this many bytes.
_VMEM_TABLE_BYTES = 4 * 1024 * 1024
# legacy fixed tiles — served when the autotuner is disabled()
# (autotune.LEGACY mirrors these; a drift test pins them equal)
_DEFAULT_BLOCK_N = 64
_DEFAULT_BLOCK_I = 8


def _should_interpret(interpret: bool | None) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _pad_idx(idx, multiple: int):
    n = idx.shape[-1]
    pad = (-n) % multiple
    if not pad:
        return idx
    fill = jnp.zeros(idx.shape[:-1] + (pad,), jnp.int32)   # row 0: harmless
    return jnp.concatenate([idx, fill], axis=-1)


def _pick_block_d(d: int) -> int:
    block_d = d if d <= 512 else 512
    while d % block_d:
        block_d //= 2
        if block_d == 0:
            return d
    return block_d


@functools.partial(jax.jit, static_argnames=("mode", "block_n", "block_d",
                                             "block_i", "interpret"))
def _gather_rows_batched(table, idx, mode: str, block_n: int, block_d: int,
                         block_i: int, interpret: bool):
    bsz, n = idx.shape
    _, v, d = table.shape
    idx = idx.astype(jnp.int32)
    if mode == "vmem":
        out = kernel.gather_rows_vmem(table, _pad_idx(idx, block_n),
                                      block_n=block_n, interpret=interpret)
        return out[:, :n]
    # dma mode: pad D up to a block_d multiple, N up to a block_i multiple
    pad_d = (-d) % block_d
    if pad_d:
        table = jnp.pad(table, ((0, 0), (0, 0), (0, pad_d)))
    out = kernel.gather_rows_dma(table, _pad_idx(idx, block_i),
                                 block_d=block_d, block_i=block_i,
                                 interpret=interpret)
    return out[:, :n, :d]


def gather_rows_batched(table: jax.Array, idx: jax.Array, *,
                        mode: str = "auto",
                        block_n: int | None = None,
                        block_d: int | None = None,
                        block_i: int | None = None,
                        interpret: bool | None = None) -> jax.Array:
    """Batched gather: (B, V, D) tables, (B, N) idx -> (B, N, D).

    One kernel launch for the whole pattern batch (a planner bucket), with
    the index buffers scalar-prefetched once — not a vmap of per-pattern
    launches.  The regime choice sizes VMEM per b-step, so it uses one
    pattern's table bytes, not the whole stack's.  Blocks left ``None``
    come from the autotuner, keyed on the geometry the kernel actually
    sees (the local shard under a lane-sharded launch) — a pure function
    of shapes, so one jit signature per geometry and ``misses`` stays an
    exact compile count upstream.
    """
    if table.ndim != 3 or idx.ndim != 2 or table.shape[0] != idx.shape[0]:
        raise ValueError(f"expected (B,V,D) table and (B,N) idx, got "
                         f"{table.shape} / {idx.shape}")
    interp = _should_interpret(interpret)
    bsz, n = idx.shape
    _, v, d = table.shape
    if mode == "auto":
        per_pattern_bytes = v * d * table.dtype.itemsize
        mode = "vmem" if per_pattern_bytes <= _VMEM_TABLE_BYTES else "dma"
    if block_n is None or block_i is None or block_d is None:
        choice = autotune.choose(autotune.TileKey(
            op="gather_vmem" if mode == "vmem" else "gather_dma",
            batch=bsz, lanes=n, rows=v, width=d, dtype=table.dtype.name,
            platform="interpret" if interp else "tpu"))
        if block_n is None:
            block_n = choice.block_n or _DEFAULT_BLOCK_N
        if block_i is None:
            block_i = choice.block_i or _DEFAULT_BLOCK_I
        if block_d is None:
            block_d = choice.block_d or _pick_block_d(d)
    block_n = min(block_n, max(1, n))
    block_i = min(block_i, max(1, n))
    return _gather_rows_batched(table, idx, mode, block_n, block_d, block_i,
                                interp)


def gather_rows(table: jax.Array, idx: jax.Array, *, mode: str = "auto",
                block_n: int | None = None, block_d: int | None = None,
                block_i: int | None = None,
                interpret: bool | None = None) -> jax.Array:
    """Gather rows of ``table`` (V, D) at positions ``idx`` (N,) -> (N, D).

    The B=1 case of the batched kernel — one code path for both.
    """
    if table.ndim != 2 or idx.ndim != 1:
        raise ValueError(f"expected (V,D) table and (N,) idx, got "
                         f"{table.shape} / {idx.shape}")
    return gather_rows_batched(table[None], idx[None], mode=mode,
                               block_n=block_n, block_d=block_d,
                               block_i=block_i, interpret=interpret)[0]
