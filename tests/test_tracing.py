"""jaxpr walker census (core/tracing.py): per-primitive trace_gs
coverage, canonical primitive naming, and the depth-guarded traversal
the spatterlint rules share (ISSUE 6 satellites)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.tracing import (JaxprWalkError, count_primitives,
                                find_dtype_eqns, find_primitive_eqns,
                                hlo_stats, iter_eqns, normalize_primitive,
                                trace_gs)

X = jnp.arange(16.0)
I = jnp.array([1, 5, 9], jnp.int32)
V = jnp.ones(3)


# ---------------------------------------------------------------------------
# trace_gs covers every _GS_PRIMS primitive (one test per primitive)
# ---------------------------------------------------------------------------

def _one_access(fn, *args):
    report = trace_gs(fn, *args)
    assert len(report.accesses) == 1, \
        [a.primitive for a in report.accesses]
    return report.accesses[0]


def test_trace_gs_gather():
    a = _one_access(lambda x, i: x[i], X, I)
    assert (a.primitive, a.kind) == ("gather", "gather")
    assert a.n_lookups == 3 and a.moved_bytes == 3 * 4


def test_trace_gs_scatter():
    a = _one_access(lambda x, i, v: x.at[i].set(v), X, I, V)
    assert (a.primitive, a.kind) == ("scatter", "scatter")
    assert a.n_lookups == 3


def test_trace_gs_scatter_add():
    a = _one_access(lambda x, i, v: x.at[i].add(v), X, I, V)
    assert (a.primitive, a.kind) == ("scatter_add", "scatter")


def test_trace_gs_scatter_mul():
    a = _one_access(lambda x, i, v: x.at[i].mul(v), X, I, V)
    assert (a.primitive, a.kind) == ("scatter_mul", "scatter")


def test_trace_gs_scatter_min():
    a = _one_access(lambda x, i, v: x.at[i].min(v), X, I, V)
    assert (a.primitive, a.kind) == ("scatter_min", "scatter")


def test_trace_gs_scatter_max():
    a = _one_access(lambda x, i, v: x.at[i].max(v), X, I, V)
    assert (a.primitive, a.kind) == ("scatter_max", "scatter")


def test_trace_gs_dynamic_slice():
    a = _one_access(lambda x: jax.lax.dynamic_slice(x, (2,), (4,)), X)
    assert (a.primitive, a.kind) == ("dynamic_slice", "gather")


def test_trace_gs_dynamic_update_slice():
    a = _one_access(
        lambda x, v: jax.lax.dynamic_update_slice(x, v, (2,)), X, V)
    assert (a.primitive, a.kind) == ("dynamic_update_slice", "scatter")


@pytest.mark.parametrize("mode", ["fill", "clip"])
def test_trace_gs_gather_mode_variants(mode):
    # jnp.take(mode=...) wraps the gather in a pjit body — the recursive
    # walk must still count it (the undercount this satellite fixes)
    a = _one_access(lambda x, i: jnp.take(x, i, mode=mode), X, I)
    assert (a.primitive, a.kind) == ("gather", "gather")
    a = _one_access(
        lambda x, i: x.at[i].get(mode=mode, fill_value=0.0), X, I)
    assert a.kind == "gather"


def test_trace_gs_counts_all_scatter_variants_together():
    def mixed(x, i, v):
        x = x.at[i].add(v)
        x = x.at[i].min(v)
        x = x.at[i].max(v)
        return x

    report = trace_gs(mixed, X, I, V)
    assert sorted(a.primitive for a in report.accesses) == \
        ["scatter_add", "scatter_max", "scatter_min"]
    assert all(a.kind == "scatter" for a in report.accesses)
    assert report.gs_bytes == 3 * (3 * 4)     # three 3-lane f32 updates


# ---------------------------------------------------------------------------
# canonical primitive names (the sort/sort_p unification satellite)
# ---------------------------------------------------------------------------

def test_normalize_primitive():
    assert normalize_primitive("sort") == "sort"
    assert normalize_primitive("sort_p") == "sort"
    assert normalize_primitive("scatter-add") == "scatter_add"
    assert normalize_primitive("scatter_add") == "scatter_add"
    assert normalize_primitive("scatter-add_p") == "scatter_add"
    assert normalize_primitive("pallas_call") == "pallas_call"


def test_count_primitives_uses_canonical_names():
    counts = count_primitives(jax.make_jaxpr(jnp.sort)(X))
    # ONE lookup suffices now; no hyphen/underscore/suffix aliases
    assert counts["sort"] == 1
    assert "sort_p" not in counts
    counts = count_primitives(
        jax.make_jaxpr(lambda x, i, v: x.at[i].add(v))(X, I, V))
    assert counts["scatter_add"] == 1
    assert "scatter-add" not in counts


def test_count_primitives_recurses_into_jit_bodies():
    counts = count_primitives(
        jax.make_jaxpr(jax.jit(lambda x: jnp.sort(x) * 2))(X))
    assert counts["sort"] == 1


def test_find_primitive_eqns_matches_any_spelling():
    jaxpr = jax.make_jaxpr(jnp.sort)(X)
    for spelling in ("sort", "sort_p"):
        hits = find_primitive_eqns(jaxpr, (spelling,))
        assert len(hits) == 1 and hits[0][0] == "sort"
        assert "sort" in hits[0][1]


# ---------------------------------------------------------------------------
# depth-guarded traversal + dtype and HLO censuses (walker growth)
# ---------------------------------------------------------------------------

def test_iter_eqns_depth_guard_raises_not_undercounts():
    fn = lambda x: x + 1                               # noqa: E731
    for _ in range(12):
        fn = jax.jit(fn)
    jaxpr = jax.make_jaxpr(fn)(X)
    assert count_primitives(jaxpr)["add"] == 1         # default: deep enough
    with pytest.raises(JaxprWalkError, match="max_depth"):
        count_primitives(jaxpr, max_depth=4)
    with pytest.raises(JaxprWalkError):
        list(iter_eqns(jaxpr, max_depth=4))


def test_find_dtype_eqns():
    from jax.experimental import enable_x64
    with enable_x64():
        j64 = jax.make_jaxpr(lambda x: x * 2.0)(
            jnp.arange(4, dtype=jnp.float64))
    assert find_dtype_eqns(j64, "float64")
    j32 = jax.make_jaxpr(lambda x: x * 2.0)(X)
    assert find_dtype_eqns(j32, "float64") == []


def test_hlo_stats_reads_donation_markers():
    aval = jax.ShapeDtypeStruct((8,), jnp.float32)
    plain = jax.jit(lambda a, b: a + b)
    donating = jax.jit(lambda a, b: a + b, donate_argnums=(0,))
    assert hlo_stats(plain.lower(aval, aval).as_text()) == {
        "num_partitions": 1, "shardings": set(), "aliased_params": 0}
    st = hlo_stats(donating.lower(aval, aval).as_text())
    assert st["aliased_params"] >= 1
