"""Request/response schema for spatterd (DESIGN.md §10).

One request = one JSON suite run.  The wire format accepts either the
bare suite format ``load_suite`` already reads — a JSON list of
``{name, kernel, pattern, delta, count}`` dicts, so every existing
``suites/*.json`` file POSTs unmodified — or an envelope::

    {"patterns": [...],            # required, same entries as the bare list
     "backend": "xla",             # any of core.backends.BACKENDS
     "runs": 3,                    # min-of-K timing (paper §3.5)
     "mode": "store",              # scatter semantics: "store" | "add"
     "metric": "measured",         # table's uniform gbs column
     "row_width": 1,
     "mesh": 0,                    # N: shard bucket launches over N devices
                                   # (pattern-batch axis); [b, l]: a 2-D
                                   # (batch x lane) placement over b*l
                                   # devices (plan.Placement, DESIGN.md §11)
     "mesh_axis": "data",
     "seed": 0,                    # host-buffer RNG seed
     "stream_r": false,            # paper Eq. 1 vs a STREAM-like reference
     "stream_n": 4194304,
     "deadline_ms": 0}             # >0: queue deadline -> 504 on expiry

Every field is validated HERE, before any JAX work starts, so a bad
request is a 400 with a one-line reason and never occupies a scheduler
queue slot.  Unknown envelope keys are rejected too — the
missing-``mode=`` bug class started life as a silently-dropped option.

Responses: a 200 carries ``stats`` (SuiteStats.to_json), ``cache``
(per-request hits/misses — misses is an EXACT compile count, attributed
per launch by the scheduler — plus lifetime counters), ``plan``
(n_buckets/pad_waste), ``serve`` (scheduler telemetry: ``queued_ms``,
``launches``, ``coalesced_launches``; null on a workers=0 daemon), and
``elapsed_s``.  When the scheduler's bounded queue is full the daemon
answers **503 with a Retry-After header** and a ``retry_after_s`` field
— backpressure decided before the request costs anything; clients should
back off and retry the identical request (DESIGN.md §13).

The geometry budget below (``MAX_SUITE_LANES``) does double duty: it
bounds one request's assembled buffers AND caps how many concurrent
requests' work units the scheduler may coalesce into a single launch
(serve/scheduler.py), so a coalesced launch never assembles more than a
maximal single request could.
"""
from __future__ import annotations

import dataclasses
import json

# repro.core imports live INSIDE the validators (not at module top):
# importing core pulls in jax, and the client path — SpatterClient and
# the --client CLI validate requests with this schema before POSTing —
# must stay stdlib-only so a thin HTTP client never pays the multi-second
# JAX import it exists to avoid.

# upper bound on any single pattern's flattened lanes x row_width and
# table footprint x row_width (and on stream_n).  A request at the full
# 2**28-unit budget peaks at several GiB, not 1: each counted unit backs
# ~4-6 concurrent float32/int32 buffers (host idx/vals/table, their
# device copies, the output, and the digest pull), so size serving hosts
# for that — the bound's job is making the ceiling finite and known, a
# handful of request bytes can never allocate unboundedly.  The whole
# suite shares the same budget (summed below): per-pattern caps alone
# would let 64 max-size patterns stack into one bucket launch.
MAX_PATTERN_LANES = 1 << 28
MAX_SUITE_LANES = MAX_PATTERN_LANES
MAX_RUNS = 1000
# a mesh dim beyond this is a typo, not a machine (the daemon separately
# checks the product against the actually-visible device count)
MAX_MESH_DIM = 1 << 16

# wire-level choice sets (duplicated from core to stay import-light;
# tests/test_serve.py asserts they match the real definitions)
WIRE_BACKENDS = ("xla", "onehot", "scalar", "pallas")
WIRE_MODES = ("store", "add")
WIRE_METRICS = ("measured", "measured_cpu_gbs", "modeled",
                "modeled_v5e_gbs")


def parse_mesh(spec: str) -> "int | str | tuple[int, int]":
    """CLI mesh spec -> wire value: ``"8"`` -> 8, ``"4x2"`` -> (4, 2),
    ``"auto"`` -> ``"auto"`` (per-bucket cost-model placement),
    ``"auto-suite"`` -> one cost-model shape for the whole suite.

    Stays stdlib-only (the jax-free client parses ``--mesh`` with this);
    full validation happens in ``SuiteRequest`` like every other field.
    """
    s = spec.strip().lower()
    if s in ("auto", "auto-suite"):
        return s
    try:
        if "x" in s:
            b, l = s.split("x")
            return int(b), int(l)
        return int(s)
    except ValueError:
        raise ValueError(f"mesh must be N, BxL, 'auto', or 'auto-suite' "
                         f"(e.g. 8 or 4x2), got {spec!r}") from None


# the declared index-buffer length is bounded much tighter than lanes:
# generate_index materializes it as a PYTHON TUPLE (~36 bytes/element)
# during parsing, so a lanes-sized budget would still admit ~10 GiB of
# boxed ints.  Real Spatter index buffers are small (the paper's are
# tens of elements); scale belongs on the count axis.
MAX_INDEX_LEN = 1 << 22


def _spec_index_len(spec) -> int:
    """Upper-bound a pattern spec's index-buffer length WITHOUT
    materializing it (mirrors core.pattern.generate_index's grammar:
    UNIFORM/MS1/BROADCAST/STREAM carry N first, LAPLACIAN:D:L yields at
    most 2*D*L+1 offsets, comma lists count their commas).  Fails
    CLOSED: a generator-shaped spec with an unrecognized head — e.g. a
    future core generator this mirror hasn't learned — reports
    oversized, so eager expansion can never sneak past the bound
    (tests/test_serve.py pins the mirror against generate_index).
    Malformed argument lists return 0 and Pattern.from_json raises the
    real error later."""
    if not isinstance(spec, str):
        try:
            return len(spec)
        except TypeError:
            return 0
    s = spec.strip()
    head, sep, rest = s.partition(":")
    args = [a for a in rest.split(":") if a]
    try:
        if head in ("UNIFORM", "MS1", "BROADCAST", "STREAM"):
            return int(args[0])
        if head == "LAPLACIAN":
            return 2 * int(args[0]) * int(args[1]) + 1
        if head == "CUSTOM":
            return rest.count(",") + 1
    except (IndexError, ValueError):
        return 0
    if sep and head.isupper():          # unknown generator head
        return MAX_INDEX_LEN + 1
    return s.count(",") + 1             # bare comma list

@dataclasses.dataclass(frozen=True)
class SuiteRequest:
    """A validated spatterd run request."""
    patterns: tuple[dict, ...]
    backend: str = "xla"
    runs: int = 3
    mode: str = "store"
    metric: str = "measured"
    row_width: int = 1
    mesh: int | str | list = 0  # N (batch-only), [b, l] 2-D placement,
                                # "auto" (per-bucket cost model), or
                                # "auto-suite" (one suite-wide shape);
                                # normalized to int | str | tuple
    mesh_axis: str = "data"
    seed: int = 0
    stream_r: bool = False
    stream_n: int = 2 ** 22
    digest: bool = True      # per-pattern sha256 bit-identity proof;
                             # opt out to skip the device->host pull +
                             # hash on latency-critical sweeps
    deadline_ms: int = 0     # 0 = none; else queue deadline: work still
                             # queued when it expires never launches and
                             # the request returns 504 (DESIGN.md §14)

    def __post_init__(self):
        # choice sets mirrored from core (backends.BACKENDS,
        # engine.SCATTER_MODES, suite._METRIC_COLUMNS) rather than
        # imported — see the module-top note on staying jax-free; the
        # round-trip tests pin these against the real definitions
        if not self.patterns:
            raise ValueError("request needs at least one pattern")
        for i, d in enumerate(self.patterns):
            if not isinstance(d, dict):
                raise ValueError(f"patterns[{i}] is not an object: {d!r}")
        if self.backend not in WIRE_BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"expected one of {sorted(WIRE_BACKENDS)}")
        if self.mode not in WIRE_MODES:
            raise ValueError(f"unknown mode {self.mode!r}; "
                             f"expected one of {WIRE_MODES}")
        if self.metric not in WIRE_METRICS:
            raise ValueError(f"unknown metric {self.metric!r}; "
                             f"expected one of {sorted(WIRE_METRICS)}")
        # runs bounds the min-over-K timing loop executed under the run
        # lock (paper uses 10); row_width multiplies every buffer and is
        # additionally folded into the per-pattern geometry bound below
        for name, hi in (("runs", MAX_RUNS), ("row_width", 4096)):
            v = getattr(self, name)
            if not isinstance(v, int) or isinstance(v, bool) \
                    or not 1 <= v <= hi:
                raise ValueError(f"{name} must be an int in [1, {hi}], "
                                 f"got {v!r}")
        # stream_reference is UNIFORM:8:1 with count = n // 8: below 8 it
        # blows up holding the run lock, and an uncapped n lets a few
        # request bytes allocate terabytes (the body-size limit can't see
        # it) — bound both ends here, before any JAX work
        if not isinstance(self.stream_n, int) or isinstance(self.stream_n,
                                                            bool) \
                or not 8 <= self.stream_n <= MAX_PATTERN_LANES:
            raise ValueError(f"stream_n must be an int in "
                             f"[8, {MAX_PATTERN_LANES}], "
                             f"got {self.stream_n!r}")
        # deadline_ms: 0 disables; capped at 24h so a typo'd value can't
        # pin a ticket's absolute deadline into the far future
        if not isinstance(self.deadline_ms, int) \
                or isinstance(self.deadline_ms, bool) \
                or not 0 <= self.deadline_ms <= 86_400_000:
            raise ValueError(f"deadline_ms must be an int in "
                             f"[0, 86400000], got {self.deadline_ms!r}")
        # mesh: N devices on the pattern-batch axis, [b, l] for a 2-D
        # (batch x lane) placement, "auto" (per-bucket §15 cost-model
        # selection), or "auto-suite" (one cost-model shape for the
        # whole suite).  Validated HERE — before the daemon's run lock,
        # like everything else — and the daemon additionally checks b*l
        # against the visible device count outside the lock.
        if isinstance(self.mesh, list):
            object.__setattr__(self, "mesh", tuple(self.mesh))
        mesh = self.mesh
        mesh_ok = (isinstance(mesh, int) and not isinstance(mesh, bool)
                   and 0 <= mesh <= MAX_MESH_DIM) \
            or mesh in ("auto", "auto-suite")
        if isinstance(mesh, tuple):
            mesh_ok = (len(mesh) == 2 and all(
                isinstance(s, int) and not isinstance(s, bool)
                and 1 <= s <= MAX_MESH_DIM for s in mesh))
        if not mesh_ok:
            raise ValueError(f"mesh must be an int >= 0, a [batch, lane] "
                             f"pair of ints >= 1 (dims <= {MAX_MESH_DIM}), "
                             f"'auto', or 'auto-suite', got {self.mesh!r}")
        if not isinstance(self.seed, int) or isinstance(self.seed, bool) \
                or self.seed < 0:
            raise ValueError(f"seed must be an int >= 0, got {self.seed!r}")
        if not self.mesh_axis.isidentifier():
            raise ValueError(f"mesh_axis must be an identifier-like axis "
                             f"name, got {self.mesh_axis!r}")
        for name in ("stream_r", "digest"):
            if not isinstance(getattr(self, name), bool):
                raise ValueError(f"{name} must be a bool, "
                                 f"got {getattr(self, name)!r}")

    @staticmethod
    def from_json(doc) -> "SuiteRequest":
        """Parse a decoded request body (bare pattern list or envelope)."""
        if isinstance(doc, str):
            doc = json.loads(doc)
        if isinstance(doc, list):
            return SuiteRequest(patterns=tuple(doc))
        if not isinstance(doc, dict):
            raise ValueError(f"request must be a JSON list or object, "
                             f"got {type(doc).__name__}")
        if "patterns" not in doc:
            raise ValueError('request object needs a "patterns" list')
        unknown = set(doc) - set(_OPTION_FIELDS) - {"patterns"}
        if unknown:
            raise ValueError(f"unknown request fields {sorted(unknown)}; "
                             f"expected {sorted(_OPTION_FIELDS)}")
        kw = {}
        for name, ty in _OPTION_FIELDS.items():
            if name in doc:
                v = doc[name]
                ty_name = (ty.__name__ if isinstance(ty, type)
                           else " | ".join(t.__name__ for t in ty))
                # bool is an int subclass: keep the check strict both ways
                if ty is not bool and isinstance(v, bool):
                    raise ValueError(f"{name} must be {ty_name}, got {v!r}")
                if not isinstance(v, ty):
                    raise ValueError(f"{name} must be {ty_name}, "
                                     f"got {v!r}")
                kw[name] = v
        pats = doc["patterns"]
        if not isinstance(pats, list):
            raise ValueError('"patterns" must be a list')
        return SuiteRequest(patterns=tuple(pats), **kw)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["patterns"] = list(d["patterns"])
        if isinstance(d["mesh"], tuple):        # wire form is a JSON list
            d["mesh"] = list(d["mesh"])
        return d

    def build_patterns(self) -> list[Pattern]:
        """Materialize the suite (ValueError on malformed entries).

        Also bounds the buffer geometry — per pattern AND summed over the
        suite (patterns stack into bucket launches, so 64 individually-ok
        patterns could still assemble one enormous batch): a tiny JSON
        body can declare an astronomically large ``count``, and the first
        place it would fail is a host-buffer allocation big enough to OOM
        the daemon — reject it here instead, before any JAX work.
        """
        # bound the declared index-buffer length BEFORE materializing:
        # Pattern.from_json expands generator specs eagerly, so
        # "UNIFORM:2000000000:1" would build a 2-billion-element tuple
        # during parsing — ahead of any size check on the result
        for d in self.patterns:
            n = _spec_index_len(d.get("pattern", ()))
            if n > MAX_INDEX_LEN:
                raise ValueError(
                    f"pattern {d.get('name', '?')!r} declares a "
                    f">{MAX_INDEX_LEN}-element (or unrecognized-"
                    f"generator) index buffer; put scale in count=")
        from repro.core.pattern import Pattern   # lazy: jax-free client
        try:
            pats = [Pattern.from_json(d) for d in self.patterns]
        except (IndexError, KeyError, TypeError, ValueError) as e:
            # IndexError: generator specs with too few args ("UNIFORM",
            # "MS1:8") index into their missing argument list
            raise ValueError(f"bad pattern entry: {e}") from e
        total = 0
        for p in pats:
            lanes = p.count * p.index_len
            size = max(lanes, p.footprint()) * self.row_width
            total += size
            if size > MAX_PATTERN_LANES:
                raise ValueError(
                    f"pattern {p.name!r} too large to serve: "
                    f"count*index_len={lanes}, footprint={p.footprint()}, "
                    f"row_width={self.row_width} (limit: lanes x "
                    f"row_width <= {MAX_PATTERN_LANES})")
        if total > MAX_SUITE_LANES:
            raise ValueError(
                f"suite too large to serve: {total} total lanes x "
                f"row_width > {MAX_SUITE_LANES} budget")
        return pats


# envelope option keys -> wire type, derived from the dataclass itself so
# the two can never drift (a new SuiteRequest field is automatically
# accepted by from_json); patterns is handled separately
_WIRE_TYPES = {"str": str, "int": int, "bool": bool,
               "int | str | list": (int, str, list, tuple)}
_OPTION_FIELDS: dict[str, type] = {
    f.name: _WIRE_TYPES[f.type]
    for f in dataclasses.fields(SuiteRequest) if f.name != "patterns"
}
