"""End-to-end training driver (example application + FT integration).

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Runs the full production loop on whatever devices exist: deterministic
pipeline -> sharded train_step -> supervisor (async checkpoints, crash
restart, straggler log, SIGTERM checkpoint).  With --smoke it trains the
reduced config (CPU-feasible); without, the full config (TPU pod).
"""
from __future__ import annotations

import argparse
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.data import TokenPipeline
from repro.launch.mesh import make_local_mesh
from repro.models.zoo import Model
from repro.optim import AdamWConfig, init_opt_state, warmup_cosine
from repro.runtime.sharding import use_mesh
from repro.runtime.supervisor import SupervisorConfig, TrainSupervisor
from repro.runtime.train import assemble_train, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-feasible)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (e.g. ~100M param runs)")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    overrides = {}
    if args.d_model:
        overrides["d_model"] = args.d_model
        overrides["d_ff"] = args.d_model * 4
        overrides["head_dim"] = max(16, args.d_model // max(1, cfg.n_heads))
    if args.layers:
        overrides["n_layers"] = args.layers
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    model = Model(cfg)
    mesh = make_local_mesh()
    opt_cfg = AdamWConfig(
        lr=warmup_cosine(args.lr, warmup=max(10, args.steps // 20),
                         total=args.steps))
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch, seed=args.seed)

    n_params = sum(np.prod(v.shape)
                   for v in jax.tree.leaves(model.abstract_params()))
    print(f"[train] arch={cfg.arch_id} params={n_params/1e6:.1f}M "
          f"devices={len(jax.devices())} steps={args.steps}")

    def make_batch(i):
        b = pipe.batch(i)
        extra = {}
        if cfg.family == "audio":
            extra["frames"] = np.zeros(
                (args.batch, args.seq // cfg.frame_ratio, cfg.d_model),
                np.float32) + 0.01
        if cfg.family == "vlm":
            extra["img_embeds"] = np.zeros(
                (args.batch, cfg.n_img_tokens, cfg.d_model), np.float32)
        return {**b, **extra}

    step_core = make_train_step(model, opt_cfg,
                                microbatches=args.microbatches)

    def build(ckpt_mgr):
        params = model.init(jax.random.PRNGKey(args.seed))
        opt = init_opt_state(params)
        start = 0
        latest = ckpt_mgr.latest_step()
        if latest is not None:
            state0 = {"params": params, "opt": opt}
            restored = ckpt_mgr.restore(latest, state0)
            params, opt = restored["params"], restored["opt"]
            start = latest
            print(f"[train] restored checkpoint step {latest}")

        @jax.jit
        def jstep(params, opt, batch):
            with use_mesh(mesh):
                return step_core(params, opt, batch)

        def step_fn(state, i):
            batch = {k: jnp.asarray(v) for k, v in make_batch(i).items()}
            p, o, metrics = jstep(state["params"], state["opt"], batch)
            return {"params": p, "opt": o}, metrics

        return {"params": params, "opt": opt}, step_fn, start

    sup = TrainSupervisor(SupervisorConfig(
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every))
    state = sup.run(build, args.steps)
    losses = [s.loss for s in sup.stats]
    if losses:
        k = max(1, len(losses) // 10)
        print(f"[train] loss first-{k}-mean {np.mean(losses[:k]):.4f} -> "
              f"last-{k}-mean {np.mean(losses[-k:]):.4f}  "
              f"stragglers={len(sup.straggler_events)}")
    sup.ckpt.close()
    return state


if __name__ == "__main__":
    main()
