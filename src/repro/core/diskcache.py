"""Crash-safe disk tier for the executor cache (DESIGN.md §14).

A daemon fleet means rolling restarts: without persistence every new
process repays the full cold-compile cost (~20x request latency per
``bench_serve``).  ``DiskTier`` persists compiled bucket executables via
JAX AOT export/serialization, one file per ``ExecKey``, so a restarted
daemon starts warm — and it is built for the failure model, not the
happy path:

* **Atomic writes.**  Entries are written to a tmp file in the same
  directory and ``os.replace``d into place, so a SIGKILL mid-persist
  can never leave a half-written entry under a valid name.
* **Per-entry checksum.**  The serialized payload's sha256 rides in the
  header; a corrupt entry (bit rot, torn write, injected fault) fails
  verification on load and is *quarantined* (renamed aside) and
  recompiled — never loaded, never fatal.
* **Invalidation in the header.**  JAX version, backend platform, and
  the full ``ExecKey`` field dict must match on load; stale entries are
  quarantined like corrupt ones.  Entries whose placement needs more
  devices than the loading process has are skipped (left on disk —
  they are valid for a bigger sibling, just not usable here).
* **Size-budgeted LRU.**  ``store`` evicts oldest-used entries (mtime,
  refreshed on every load hit) past ``budget_bytes``.

Entry format (single file, ``<sha256(key_str)[:40]>.spx``)::

    SPXC1\n
    {json header: format, key fields, key_str, jax, backend, sha256, nbytes}\n
    <serialized executable bytes>

``store`` failures are counted, never raised: ``jax.export`` may refuse
an executable (e.g. unserializable custom calls) and the cache must keep
serving from memory regardless — persistence is an optimization, the
compile path is the fallback.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
from typing import Callable

import jax
from jax import export as jax_export   # not an auto-loaded jax attribute

from repro.kernels import autotune

from .plan import ExecKey, placement_grid

MAGIC = b"SPXC1\n"
SUFFIX = ".spx"
QUAR_SUFFIX = ".quar"
DEFAULT_BUDGET_BYTES = 1 << 30          # 1 GiB: blobs are KB-scale


def exec_key_str(key: ExecKey) -> str:
    """Canonical string form of an ``ExecKey`` — the disk identity."""
    return "|".join(f"{f.name}={getattr(key, f.name)}"
                    for f in dataclasses.fields(ExecKey))


class RestoredExecutable:
    """A deserialized AOT executable, marked with its provenance.

    The restored callable traces to one opaque exported call (its jaxpr
    is a single ``pjit`` wrapping ``call_exported``), so trace-inspecting
    lint rules cannot see inside it; auditors check ``restored`` and fall
    back to key-only rules (analysis/lint.py).
    """
    restored = True
    __slots__ = ("_fn",)

    def __init__(self, fn: Callable):
        self._fn = fn

    def __call__(self, *args):
        return self._fn(*args)


class DiskTier:
    """One directory of serialized executables keyed by ``ExecKey``.

    Thread safety: counters are guarded by an internal lock; file I/O
    runs outside it (the OS-level atomicity of ``os.replace`` is the
    real concurrency contract — two processes racing a store of the
    same key both write whole entries, last replace wins).

    ``mangle`` is the fault-injection seam: when set, it may corrupt
    the payload AFTER the checksum is computed, so an injected
    disk-corruption fault is exactly what the checksum must catch.
    """

    def __init__(self, root: str, *,
                 budget_bytes: int = DEFAULT_BUDGET_BYTES,
                 mangle: Callable[[bytes], bytes] | None = None):
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)
        self.budget_bytes = int(budget_bytes)
        self._mangle = mangle
        self._lock = threading.Lock()
        self.loads = 0              # successful restores
        self.load_misses = 0        # no entry on disk
        self.stores = 0
        self.store_failures = 0     # export refused / write failed
        self.quarantined = 0        # corrupt or stale entries set aside
        self.skipped = 0            # valid but needs more devices
        self.evicted = 0

    # -- paths ---------------------------------------------------------------
    def path_for(self, key: ExecKey) -> str:
        digest = hashlib.sha256(exec_key_str(key).encode()).hexdigest()
        return os.path.join(self.root, digest[:40] + SUFFIX)

    def _count(self, attr: str) -> None:
        with self._lock:
            setattr(self, attr, getattr(self, attr) + 1)

    def _quarantine(self, path: str) -> None:
        try:
            os.replace(path, path + QUAR_SUFFIX)
        except OSError:
            pass
        self._count("quarantined")

    # -- store ---------------------------------------------------------------
    def store(self, key: ExecKey, fn: Callable, avals: tuple) -> bool:
        """Serialize ``fn`` (traced at ``avals``) under ``key``.

        Returns True on success; every failure path counts
        ``store_failures`` and returns False — persistence must never
        take down a serving process that already holds the executable
        in memory.
        """
        if getattr(fn, "restored", False):
            return False                 # came FROM disk: already there
        try:
            # tracing the executable consults the tile autotuner for
            # exactly the tiles it bakes in; record them so a restore can
            # re-seed the memo and skip the search (DESIGN.md §16)
            with autotune.recording() as tiles:
                exported = jax_export.export(fn)(*avals)
            payload = bytes(exported.serialize())
        except Exception:
            self._count("store_failures")
            return False
        header = {
            "format": 1,
            "key": dataclasses.asdict(key),
            "key_str": exec_key_str(key),
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "sha256": hashlib.sha256(payload).hexdigest(),
            "nbytes": len(payload),
        }
        if tiles:
            header["tiles"] = autotune.to_wire(tiles)
        if self._mangle is not None:     # injected corruption (post-checksum)
            payload = self._mangle(payload)
        path = self.path_for(key)
        tmp = path + f".tmp.{os.getpid()}.{threading.get_ident()}"
        try:
            with open(tmp, "wb") as f:
                f.write(MAGIC)
                f.write(json.dumps(header, sort_keys=True).encode())
                f.write(b"\n")
                f.write(payload)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except OSError:
            self._count("store_failures")
            try:
                os.unlink(tmp)
            except OSError:
                pass
            return False
        self._count("stores")
        self._evict_to_budget()
        return True

    # -- load ----------------------------------------------------------------
    def load(self, key: ExecKey) -> Callable | None:
        """Restore ``key``'s executable, or None (miss / quarantined /
        incompatible).  Never raises: any entry that cannot be fully
        verified and deserialized is quarantined and reported as a miss,
        so the caller recompiles."""
        path = self.path_for(key)
        try:
            with open(path, "rb") as f:
                raw = f.read()
        except OSError:
            self._count("load_misses")
            return None
        return self._restore(path, raw, expect=key)

    def load_all(self) -> list[tuple[ExecKey, Callable]]:
        """Restore every verifiable entry (daemon startup preload).

        Corrupt/stale entries are quarantined as in ``load``; entries
        needing more devices than this process has are skipped.
        """
        out: list[tuple[ExecKey, Callable]] = []
        for name in sorted(self._entry_names()):
            path = os.path.join(self.root, name)
            try:
                with open(path, "rb") as f:
                    raw = f.read()
            except OSError:
                continue
            header = self._parse_header(path, raw)
            if header is None:
                continue
            try:
                key = ExecKey(**header["key"])
            except TypeError:
                self._quarantine(path)
                continue
            fn = self._restore(path, raw, expect=key, header=header)
            if fn is not None:
                out.append((key, fn))
        return out

    def _entry_names(self) -> list[str]:
        try:
            return [n for n in os.listdir(self.root) if n.endswith(SUFFIX)]
        except OSError:
            return []

    def _parse_header(self, path: str, raw: bytes) -> dict | None:
        if not raw.startswith(MAGIC):
            self._quarantine(path)
            return None
        nl = raw.find(b"\n", len(MAGIC))
        if nl < 0:
            self._quarantine(path)
            return None
        try:
            header = json.loads(raw[len(MAGIC):nl])
        except ValueError:
            self._quarantine(path)
            return None
        if not isinstance(header, dict) or header.get("format") != 1:
            self._quarantine(path)
            return None
        header["_payload_off"] = nl + 1
        return header

    def _restore(self, path: str, raw: bytes, *, expect: ExecKey,
                 header: dict | None = None) -> Callable | None:
        if header is None:
            header = self._parse_header(path, raw)
            if header is None:
                return None
        # stale: different key under this filename, or another toolchain
        if (header.get("key_str") != exec_key_str(expect)
                or header.get("jax") != jax.__version__
                or header.get("backend") != jax.default_backend()):
            self._quarantine(path)
            return None
        payload = raw[header["_payload_off"]:]
        if (header.get("nbytes") != len(payload)
                or header.get("sha256")
                != hashlib.sha256(payload).hexdigest()):
            self._quarantine(path)
            return None
        # valid entry, but its placement wants more devices than we have
        try:
            ndev = placement_grid(expect.placement)[2]
        except ValueError:
            self._quarantine(path)
            return None
        if ndev > len(jax.devices()):
            self._count("skipped")
            return None
        try:
            exported = jax_export.deserialize(payload)
            fn = jax.jit(exported.call)
        except Exception:
            self._quarantine(path)
            return None
        # re-seed the tile memo with the choices this entry baked in, so
        # a warm restart never re-runs the autotune search
        autotune.seed_wire(header.get("tiles"))
        try:
            os.utime(path)              # LRU recency for the byte budget
        except OSError:
            pass
        self._count("loads")
        return RestoredExecutable(fn)

    # -- eviction ------------------------------------------------------------
    def _evict_to_budget(self) -> None:
        entries = []
        for name in self._entry_names():
            path = os.path.join(self.root, name)
            try:
                st = os.stat(path)
            except OSError:
                continue
            entries.append((st.st_mtime, st.st_size, path))
        total = sum(size for _, size, _ in entries)
        entries.sort()                  # oldest mtime first
        for _, size, path in entries:
            if total <= self.budget_bytes:
                break
            try:
                os.unlink(path)
            except OSError:
                continue
            total -= size
            self._count("evicted")

    # -- telemetry -----------------------------------------------------------
    def stats(self) -> dict:
        entries = self._entry_names()
        nbytes = 0
        for name in entries:
            try:
                nbytes += os.stat(os.path.join(self.root, name)).st_size
            except OSError:
                pass
        with self._lock:
            return {
                "root": self.root,
                "entries": len(entries),
                "bytes": nbytes,
                "budget_bytes": self.budget_bytes,
                "loads": self.loads,
                "load_misses": self.load_misses,
                "stores": self.stores,
                "store_failures": self.store_failures,
                "quarantined": self.quarantined,
                "skipped": self.skipped,
                "evicted": self.evicted,
            }
