"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles.

All kernels run in interpret mode on CPU (the TPU lowering is exercised by
the same pallas_call + BlockSpec on real hardware).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gather_rows import ops as gops
from repro.kernels.gather_rows.ref import gather_rows_ref
from repro.kernels.paged_decode import ops as pops
from repro.kernels.paged_decode.ref import paged_decode_attention_ref
from repro.kernels.scatter_rows import ops as sops
from repro.kernels.scatter_rows.ref import (scatter_add_rows_ref,
                                            scatter_store_rows_ref)

RNG = np.random.default_rng(42)

OOB = np.iinfo(np.int32).max


def _deduped_idx(v, n):
    """Random indices with duplicates routed out of range (the host
    keep-mask contract scatter_store_rows expects)."""
    from repro.core.backends import keep_last_mask
    idx = RNG.integers(0, v, n).astype(np.int32)
    keep = keep_last_mask(idx)
    return np.where(keep, idx, OOB).astype(np.int32), idx, keep


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-5, atol=1e-6)


class TestGatherRows:
    @pytest.mark.parametrize("v,d,n", [
        (8, 8, 1), (64, 16, 37), (128, 128, 128), (1000, 256, 300),
        (33, 48, 7), (4096, 64, 513),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("mode", ["vmem", "dma"])
    def test_sweep(self, v, d, n, dtype, mode):
        table = jnp.asarray(RNG.standard_normal((v, d)), dtype)
        idx = jnp.asarray(RNG.integers(0, v, n), jnp.int32)
        out = gops.gather_rows(table, idx, mode=mode)
        np.testing.assert_allclose(
            np.asarray(out, np.float32),
            np.asarray(gather_rows_ref(table, idx), np.float32),
            **_tol(dtype))

    def test_duplicate_and_boundary_indices(self):
        table = jnp.asarray(RNG.standard_normal((16, 8)), jnp.float32)
        idx = jnp.asarray([0, 15, 15, 0, 7, 7, 7], jnp.int32)
        for mode in ("vmem", "dma"):
            out = gops.gather_rows(table, idx, mode=mode)
            np.testing.assert_allclose(out, np.asarray(table)[idx])

    def test_auto_mode_selection(self):
        small = jnp.zeros((64, 16), jnp.float32)
        big = jnp.zeros((1 << 15, 512), jnp.float32)    # > VMEM budget
        i = jnp.zeros((4,), jnp.int32)
        assert gops.gather_rows(small, i).shape == (4, 16)
        assert gops.gather_rows(big, i).shape == (4, 512)


class TestScatterAddRows:
    @pytest.mark.parametrize("v,d,n", [
        (8, 8, 8), (64, 16, 200), (130, 100, 57), (128, 128, 1000),
        (1000, 32, 64),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32])
    def test_sweep(self, v, d, n, dtype):
        idx = jnp.asarray(RNG.integers(0, v, n), jnp.int32)
        vals = jnp.asarray(RNG.standard_normal((n, d)), dtype)
        out = sops.scatter_add_rows(idx, vals, v)
        ref = scatter_add_rows_ref(idx, vals, v)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)

    def test_all_same_index(self):
        """LULESH-S3 regime: every write lands on one row (delta 0)."""
        n, v, d = 256, 16, 32
        idx = jnp.full((n,), 3, jnp.int32)
        vals = jnp.ones((n, d), jnp.float32)
        out = sops.scatter_add_rows(idx, vals, v)
        np.testing.assert_allclose(np.asarray(out)[3], np.full(d, n))
        assert np.abs(np.asarray(out)[[i for i in range(v) if i != 3]]).max() == 0

    def test_out_of_range_dropped(self):
        idx = jnp.asarray([0, 99, 1], jnp.int32)
        vals = jnp.ones((3, 4), jnp.float32)
        out = sops.scatter_add_rows(idx, vals, 8)
        assert np.asarray(out).sum() == 8.0


class TestScatterStoreRows:
    """Single-pass store kernel: one launch, host-pre-deduped indices."""

    @pytest.mark.parametrize("v,d,n", [
        (8, 8, 8), (64, 16, 200), (130, 100, 57), (128, 128, 1000),
        (1000, 32, 64),
        (5, 3, 2),        # N and V both below the block sizes
        (257, 130, 301),  # ragged D, ragged V, ragged N all at once
    ])
    def test_sweep(self, v, d, n):
        safe_idx, _, _ = _deduped_idx(v, n)
        vals = jnp.asarray(RNG.standard_normal((n, d)), jnp.float32)
        dst = jnp.asarray(RNG.standard_normal((v, d)), jnp.float32)
        out = sops.scatter_store_rows(dst, jnp.asarray(safe_idx), vals)
        ref = scatter_store_rows_ref(dst, jnp.asarray(safe_idx), vals)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_matches_sequential_lww(self):
        """With the keep mask applied, the kernel equals a sequential
        last-write-wins loop over the RAW (duplicate-laden) indices."""
        v, d, n = 40, 12, 150
        safe_idx, raw_idx, _ = _deduped_idx(v, n)
        vals = jnp.asarray(RNG.standard_normal((n, d)), jnp.float32)
        dst = jnp.asarray(RNG.standard_normal((v, d)), jnp.float32)
        ref = np.asarray(dst).copy()
        for i, j in enumerate(raw_idx):
            ref[j] = np.asarray(vals)[i]
        out = sops.scatter_store_rows(dst, jnp.asarray(safe_idx), vals)
        np.testing.assert_array_equal(np.asarray(out), ref)

    def test_untouched_rows_pass_through(self):
        v, d = 64, 8
        dst = jnp.asarray(RNG.standard_normal((v, d)), jnp.float32)
        idx = jnp.asarray([3, 10], jnp.int32)
        vals = jnp.ones((2, d), jnp.float32)
        out = np.asarray(sops.scatter_store_rows(dst, idx, vals))
        np.testing.assert_array_equal(out[[3, 10]], np.ones((2, d)))
        rest = [i for i in range(v) if i not in (3, 10)]
        np.testing.assert_array_equal(out[rest], np.asarray(dst)[rest])

    def test_padding_lanes_dropped(self):
        """keep-mask padding rows: OOB lanes (dropped duplicates and lane
        padding alike) never touch the table, wherever they fall."""
        v, d = 16, 4
        dst = jnp.zeros((v, d), jnp.float32)
        idx = jnp.asarray([OOB, 2, OOB, OOB, 5, OOB], jnp.int32)
        vals = jnp.asarray(RNG.standard_normal((6, d)), jnp.float32)
        out = np.asarray(sops.scatter_store_rows(dst, idx, vals))
        np.testing.assert_array_equal(out[2], np.asarray(vals)[1])
        np.testing.assert_array_equal(out[5], np.asarray(vals)[4])
        assert np.abs(out[[i for i in range(v) if i not in (2, 5)]]).max() == 0


class TestBatchedKernels:
    """Batch-native bucket kernels: one launch per pattern batch."""

    @pytest.mark.parametrize("b,v,d,n", [
        (1, 8, 8, 8), (4, 64, 16, 33), (3, 130, 100, 57), (8, 32, 8, 5),
    ])
    def test_gather_batched(self, b, v, d, n):
        table = jnp.asarray(RNG.standard_normal((b, v, d)), jnp.float32)
        idx = jnp.asarray(RNG.integers(0, v, (b, n)), jnp.int32)
        for mode in ("vmem", "dma"):
            out = gops.gather_rows_batched(table, idx, mode=mode)
            ref = np.stack([np.asarray(table)[i][np.asarray(idx)[i]]
                            for i in range(b)])
            np.testing.assert_array_equal(np.asarray(out), ref,
                                          err_msg=mode)

    @pytest.mark.parametrize("b,v,d,n", [
        (1, 8, 8, 8), (4, 64, 16, 200), (3, 130, 100, 57), (8, 32, 8, 5),
    ])
    def test_scatter_add_batched(self, b, v, d, n):
        idx = jnp.asarray(RNG.integers(0, v, (b, n)), jnp.int32)
        vals = jnp.asarray(RNG.standard_normal((b, n, d)), jnp.float32)
        out = sops.scatter_add_rows_batched(idx, vals, v)
        ref = np.stack([np.asarray(scatter_add_rows_ref(
            idx[i], vals[i], v)) for i in range(b)])
        np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4,
                                   atol=1e-5)

    @pytest.mark.parametrize("b,v,d,n", [
        (1, 8, 8, 8), (4, 64, 16, 200), (3, 130, 100, 57), (8, 32, 8, 5),
    ])
    def test_scatter_store_batched(self, b, v, d, n):
        rows = [_deduped_idx(v, n)[0] for _ in range(b)]
        safe_idx = jnp.asarray(np.stack(rows))
        vals = jnp.asarray(RNG.standard_normal((b, n, d)), jnp.float32)
        dst = jnp.asarray(RNG.standard_normal((b, v, d)), jnp.float32)
        out = sops.scatter_store_rows_batched(dst, safe_idx, vals)
        ref = np.stack([np.asarray(scatter_store_rows_ref(
            dst[i], safe_idx[i], vals[i])) for i in range(b)])
        np.testing.assert_array_equal(np.asarray(out), ref)

    def test_batched_matches_per_pattern_launches(self):
        """The one-launch bucket kernel is bit-identical to B separate
        single-pattern launches (vmap-replacement contract)."""
        b, v, d, n = 5, 48, 24, 70
        table = jnp.asarray(RNG.standard_normal((b, v, d)), jnp.float32)
        idx = jnp.asarray(RNG.integers(0, v, (b, n)), jnp.int32)
        batched = gops.gather_rows_batched(table, idx)
        for i in range(b):
            single = gops.gather_rows(table[i], idx[i])
            np.testing.assert_array_equal(np.asarray(batched)[i],
                                          np.asarray(single))


class TestGatherMultiRowBlocking:
    """dma regime multi-row blocking (block_i rows per grid step)."""

    @pytest.mark.parametrize("n", [1, 3, 7, 8, 9, 64, 513])
    @pytest.mark.parametrize("block_i", [1, 4, 8])
    def test_ragged_n(self, n, block_i):
        table = jnp.asarray(RNG.standard_normal((100, 16)), jnp.float32)
        idx = jnp.asarray(RNG.integers(0, 100, n), jnp.int32)
        out = gops.gather_rows(table, idx, mode="dma", block_i=block_i)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(table)[np.asarray(idx)])

    def test_block_i_invariance(self):
        """Results are invariant to the blocking factor."""
        table = jnp.asarray(RNG.standard_normal((64, 48)), jnp.float32)
        idx = jnp.asarray(RNG.integers(0, 64, 37), jnp.int32)
        ref = np.asarray(gops.gather_rows(table, idx, mode="dma",
                                          block_i=1))
        for block_i in (2, 4, 8, 16):
            out = gops.gather_rows(table, idx, mode="dma", block_i=block_i)
            np.testing.assert_array_equal(np.asarray(out), ref)


class TestPagedDecode:
    @pytest.mark.parametrize("b,kvh,g,dh,pages,page,pps", [
        (1, 1, 1, 16, 4, 8, 2), (2, 2, 4, 16, 12, 8, 3),
        (4, 2, 2, 64, 32, 16, 4), (2, 4, 1, 32, 8, 8, 2),
    ])
    def test_sweep(self, b, kvh, g, dh, pages, page, pps):
        q = jnp.asarray(RNG.standard_normal((b, kvh, g, dh)), jnp.float32)
        kp = jnp.asarray(RNG.standard_normal((kvh, pages, page, dh)),
                         jnp.float32)
        vp = jnp.asarray(RNG.standard_normal((kvh, pages, page, dh)),
                         jnp.float32)
        pt = jnp.asarray(RNG.integers(0, pages, (b, pps)), jnp.int32)
        ln = jnp.asarray(RNG.integers(1, page * pps + 1, (b,)), jnp.int32)
        out = pops.paged_decode_attention(q, kp, vp, pt, ln)
        ref = paged_decode_attention_ref(q, kp, vp, pt, ln,
                                         scale=1.0 / dh ** 0.5)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)

    def test_bf16(self):
        b, kvh, g, dh, pages, page, pps = 2, 2, 2, 32, 8, 8, 2
        q = jnp.asarray(RNG.standard_normal((b, kvh, g, dh)), jnp.bfloat16)
        kp = jnp.asarray(RNG.standard_normal((kvh, pages, page, dh)),
                         jnp.bfloat16)
        vp = jnp.asarray(RNG.standard_normal((kvh, pages, page, dh)),
                         jnp.bfloat16)
        pt = jnp.asarray(RNG.integers(0, pages, (b, pps)), jnp.int32)
        ln = jnp.full((b,), page * pps, jnp.int32)
        out = pops.paged_decode_attention(q, kp, vp, pt, ln)
        ref = paged_decode_attention_ref(q, kp, vp, pt, ln,
                                         scale=1.0 / dh ** 0.5)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=5e-2, atol=5e-2)


class TestFlashAttention:
    @pytest.mark.parametrize("b,kvh,g,s,t,dh,causal,window,cap", [
        (2, 2, 2, 64, 64, 16, True, 0, 0.0),
        (1, 1, 4, 128, 128, 32, True, 32, 0.0),
        (2, 1, 1, 64, 64, 16, True, 0, 50.0),     # gemma2 softcap
        (1, 2, 2, 96, 96, 16, False, 0, 0.0),     # bidirectional (whisper)
    ])
    def test_fwd_and_grad(self, b, kvh, g, s, t, dh, causal, window, cap):
        from repro.kernels.flash_attention import flash_attention
        from repro.kernels.flash_attention.ref import flash_attention_ref
        q = jnp.asarray(RNG.standard_normal((b, kvh, g, s, dh)), jnp.float32)
        k = jnp.asarray(RNG.standard_normal((b, kvh, t, dh)), jnp.float32)
        v = jnp.asarray(RNG.standard_normal((b, kvh, t, dh)), jnp.float32)
        out = flash_attention(q, k, v, causal=causal, window=window,
                              softcap=cap, block_q=32, block_k=32)
        ref = flash_attention_ref(q, k, v, scale=1 / dh ** 0.5,
                                  causal=causal, window=window, softcap=cap)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)
        gk = jax.grad(lambda q: flash_attention(
            q, k, v, causal=causal, window=window, softcap=cap,
            block_q=32, block_k=32).sum())(q)
        gr = jax.grad(lambda q: flash_attention_ref(
            q, k, v, scale=1 / dh ** 0.5, causal=causal, window=window,
            softcap=cap).sum())(q)
        np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-5)

    def test_matches_model_attention(self):
        """flash == the model's chunked_attention on a GQA case."""
        from repro.kernels.flash_attention import flash_attention
        from repro.models.common import chunked_attention
        b, s, kvh, g, dh = 2, 64, 2, 2, 16
        q = jnp.asarray(RNG.standard_normal((b, s, kvh, g, dh)), jnp.float32)
        k = jnp.asarray(RNG.standard_normal((b, s, kvh, dh)), jnp.float32)
        v = jnp.asarray(RNG.standard_normal((b, s, kvh, dh)), jnp.float32)
        ref = chunked_attention(q, k, v, chunk=16, causal=True)
        qf = jnp.moveaxis(q, 1, 3)                     # (B,KVH,G,S,dh)
        kf = jnp.moveaxis(k, 1, 2)                     # (B,KVH,T,dh)
        vf = jnp.moveaxis(v, 1, 2)
        out = flash_attention(qf, kf, vf, causal=True, block_q=16,
                              block_k=16)
        np.testing.assert_allclose(jnp.moveaxis(out, 3, 1), ref,
                                   rtol=1e-4, atol=1e-5)


class TestSelectiveScan:
    @pytest.mark.parametrize("b,l,d,n,bl", [
        (2, 32, 16, 8, 8), (1, 64, 32, 16, 16), (2, 128, 8, 4, 32),
        (1, 48, 16, 8, 16),
    ])
    def test_matches_ref(self, b, l, d, n, bl):
        from repro.kernels.selective_scan import selective_scan
        from repro.kernels.selective_scan.ref import selective_scan_ref
        u = jnp.asarray(RNG.standard_normal((b, l, d)), jnp.float32)
        dt = jnp.asarray(np.abs(RNG.standard_normal((b, l, d))) * 0.1,
                         jnp.float32)
        bi = jnp.asarray(RNG.standard_normal((b, l, n)), jnp.float32)
        ci = jnp.asarray(RNG.standard_normal((b, l, n)), jnp.float32)
        a = jnp.asarray(-np.abs(RNG.standard_normal((n, d))), jnp.float32)
        dsk = jnp.asarray(RNG.standard_normal((1, d)), jnp.float32)
        y, h = selective_scan(u, dt, bi, ci, a, dsk, block_l=bl)
        yr, hr = selective_scan_ref(u, dt, bi, ci, a, dsk)
        np.testing.assert_allclose(y, yr, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(h, hr, rtol=1e-5, atol=1e-5)

    def test_kernel_path_in_model(self):
        """mamba_apply(use_scan_kernel=True) == default XLA path."""
        import dataclasses
        from repro.configs import get_smoke_config
        from repro.models.ssm import mamba_apply, mamba_defs
        from repro.models.common import init_tree
        cfg = dataclasses.replace(get_smoke_config("falcon-mamba-7b"),
                                  dtype="float32")
        p = init_tree(jax.random.PRNGKey(0), mamba_defs(cfg), jnp.float32)
        x = jnp.asarray(RNG.standard_normal((2, 32, cfg.d_model)),
                        jnp.float32)
        y0 = mamba_apply(cfg, p, x)
        y1 = mamba_apply(cfg, p, x, use_scan_kernel=True)
        np.testing.assert_allclose(y0, y1, rtol=1e-4, atol=1e-4)
