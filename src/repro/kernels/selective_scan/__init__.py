from .ops import selective_scan  # noqa: F401
