"""repro.serve — spatterd, the long-lived suite-serving layer.

The "many scenarios per process" product of the planner PRs: a daemon
that accepts streamed JSON suites over HTTP, runs them through the
process-wide warm ``ExecutorCache`` (single-device or mesh-sharded), and
returns SuiteStats as JSON with exact per-request cache telemetry.
See daemon.py and DESIGN.md §10.

Exports resolve lazily (PEP 562) so ``python -m repro.serve.daemon`` /
``python -m repro.serve.client`` — the documented entry points — don't
re-import their own module through the package and trip runpy's
double-import RuntimeWarning.
"""
import importlib

_EXPORTS = {
    "SpatterDaemon": ".daemon",
    "SpatterClient": ".client",
    "ServerError": ".client",
    "SuiteRequest": ".schema",
    "Scheduler": ".scheduler",
    "QueueFull": ".scheduler",
    "SchedulerStopped": ".scheduler",
    "DeadlineExceeded": ".scheduler",
    "RequestCancelled": ".scheduler",
    "FamilyQuarantined": ".scheduler",
    "FaultInjector": ".faults",
    "InjectedFault": ".faults",
    "WorkerKilled": ".faults",
}

__all__ = list(_EXPORTS)


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    return getattr(importlib.import_module(mod, __name__), name)
