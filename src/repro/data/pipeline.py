"""Deterministic, resumable, shardable token pipeline.

Production posture:
  * deterministic: batch i is a pure function of (seed, i) — any worker can
    regenerate any batch, which is what makes straggler re-dispatch and
    elastic restarts correct.
  * resumable: PipelineState is one integer; it lives inside the
    checkpoint, so restore replays from the exact batch boundary.
  * shardable: ``shard_batch(i, host_id, n_hosts)`` yields this host's rows
    only; global batch order is host-count independent.

Two sources: synthetic LM streams (zipf-distributed tokens with local
n-gram structure so the loss actually decreases) and memory-mapped token
files (np.memmap) for real corpora.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class PipelineState:
    next_batch: int = 0

    def to_json(self) -> dict:
        return {"next_batch": self.next_batch}

    @staticmethod
    def from_json(d: dict) -> "PipelineState":
        return PipelineState(next_batch=int(d["next_batch"]))


class TokenPipeline:
    def __init__(self, *, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, token_file: str | None = None):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self._mm = None
        if token_file:
            self._mm = np.memmap(token_file, dtype=np.int32, mode="r")

    # -- synthetic stream -----------------------------------------------------
    def _synthetic(self, batch_idx: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, batch_idx))
        b, s, v = self.global_batch, self.seq_len, self.vocab
        # zipf-ish marginal + short-range repetition structure
        base = rng.zipf(1.3, size=(b, s)).astype(np.int64) % (v - 2) + 2
        # repeat-previous with p=0.3 gives learnable bigram structure
        repeat = rng.random((b, s)) < 0.3
        shifted = np.roll(base, 1, axis=1)
        toks = np.where(repeat, shifted, base)
        toks[:, 0] = 1                                  # BOS
        return toks.astype(np.int32)

    def _from_file(self, batch_idx: int) -> np.ndarray:
        b, s = self.global_batch, self.seq_len
        n = b * (s + 1)
        start = (batch_idx * n) % max(1, len(self._mm) - n)
        flat = np.asarray(self._mm[start:start + n])
        return flat.reshape(b, s + 1)[:, :s].astype(np.int32)

    # -- public ----------------------------------------------------------------
    def batch(self, batch_idx: int) -> dict:
        toks = (self._from_file(batch_idx) if self._mm is not None
                else self._synthetic(batch_idx))
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = 0
        return {"tokens": toks, "labels": labels}

    def shard_batch(self, batch_idx: int, host_id: int,
                    n_hosts: int) -> dict:
        full = self.batch(batch_idx)
        assert self.global_batch % n_hosts == 0
        rows = self.global_batch // n_hosts
        sl = slice(host_id * rows, (host_id + 1) * rows)
        return {k: v[sl] for k, v in full.items()}

    def __iter__(self):
        i = 0
        while True:
            yield self.batch(i)
            i += 1
