"""recurrentgemma-9b [hybrid] — 38L d4096 16H MQA kv=1 d_ff=12288 vocab=256000.

Griffin blocks: RG-LRU temporal mixing + local attention (window 2048) in a
(rec, rec, attn) repeating pattern — "1:2" attention:recurrent.
[arXiv:2402.19427]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab=256000, head_dim=256,
    attn_kind="local_global", window=2048, rope="full",
    mlp_kind="geglu", lru_width=4096,
    block_pattern=("rec", "rec", "attn"), tie_embeddings=True,
)

SMOKE = ModelConfig(
    arch_id="recurrentgemma-9b-smoke", family="hybrid",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=1,
    d_ff=128, vocab=256, head_dim=16,
    attn_kind="local_global", window=16, rope="full",
    mlp_kind="geglu", lru_width=64,
    block_pattern=("rec", "rec", "attn"), tie_embeddings=True, attn_chunk=16,
)
