from .ops import gather_rows  # noqa: F401
