"""internvl2-26b [vlm] — 48L d6144 48H GQA kv=8 d_ff=16384 vocab=92553.

InternLM2-20B language backbone; InternViT frontend STUBBED — input_specs()
provides precomputed patch embeddings (B, n_img_tokens, d).
[arXiv:2404.16821; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=92553, head_dim=128,
    attn_kind="full", rope="full", mlp_kind="swiglu",
    n_img_tokens=256,
)

SMOKE = ModelConfig(
    arch_id="internvl2-26b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, head_dim=16,
    attn_kind="full", rope="full", mlp_kind="swiglu",
    n_img_tokens=8, attn_chunk=16,
)
