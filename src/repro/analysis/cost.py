"""spattercost — static memory-traffic analysis of every executable
(DESIGN.md §15).

Walks the same enumeration spatterlint audits
(``plan.enumerate_executables``) and computes, per ``(bucket,
placement)``, the exact bytes one launch moves — split by *cause*:

  ``useful``      the analytic minimum (``bandwidth.useful_bytes`` summed
                  over the bucket's member patterns)
  ``pad``         pad/scratch traffic from ``pad_batch``/``pad_lanes``
                  (launched lane-data minus useful)
  ``index``       the int32 index operand
  ``table``       the table operand at the padded batch (gather reads it,
                  scatter reads the dst and writes a fresh result)
  ``keep``        scatter's host-dedup keep mask
  ``replicated``  extra table copies along the lane axis — the
                  ``runtime/sharding.gs_specs`` axis rules shard tables
                  by batch only, so every lane shard holds a full copy

``io_bytes`` (everything a launch crosses HBM with at its boundary) is
reconciled against the lowered StableHLO signature via the shared
``core.hlo`` walker — the ``traffic-conservation`` rule; ``device_bytes``
adds the replication term and is what placement auto-selection
(``mesh="auto"``) minimizes.  Bytes convert to predicted GB/s via a
roofline calibrated from the measured bandwidths in ``BENCH_suite.json``.

Module import stays jax-free (like ``analysis.report``): the heavy
planner imports happen inside functions, so parsing a committed
``COST_report.json`` costs no jax import.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re

from repro.analysis.report import Violation

# NOTE: ``repro.core.hlo`` is stdlib-only but lives under the eager
# ``repro.core`` package (whose __init__ imports jax), so it is imported
# inside the functions that reconcile lowered text — never at module
# scope — to keep this module's import jax-free.

# Tolerances (DESIGN.md §15 records the rationale).  TRAFFIC_TOL covers
# layout/token slop in a lowered signature (plus a small absolute floor
# for rank-0 scalars); PAD_WASTE_TOL / GBS_TOL bound how far the auto
# choice may sit from a recorded sweep cell before `auto-placement-sane`
# calls it dominated; TIE_TOL is the band inside which two placements
# count as traffic-equivalent and the tie-break prefers batch shards.
TRAFFIC_TOL = 0.02
TRAFFIC_TOL_FLOOR = 64          # bytes
PAD_WASTE_TOL = 0.02            # absolute pad-waste slack
GBS_TOL = 0.10                  # relative GB/s slack
TIE_TOL = 0.05                  # relative device-bytes tie band

BENCH_ENV = "SPATTER_BENCH"
BENCH_NAME = "BENCH_suite.json"
BASELINE_ENV = "SPATTER_COST_BASELINE"
BASELINE_NAME = "COST_baseline.json"

COST_RULES = ("traffic-conservation", "cost-regression")
# rules computable from ExecKey geometry alone — safe on restored
# (DiskTier) entries whose executable is one opaque exported call
KEY_ONLY_COST_RULES = ("cost-regression",)

_INDEX_BYTES = 4                # int32 index operand
_KEEP_BYTES = 1                 # bool keep mask


def _find_upward(name: str, env: str) -> str | None:
    """Resolve a repo-root data file: $env, then cwd, then the source
    tree's checkout root (``src/repro/analysis`` -> repo root)."""
    p = os.environ.get(env)
    if p:
        return p if os.path.exists(p) else None
    if os.path.exists(name):
        return name
    root = os.path.normpath(os.path.join(os.path.dirname(__file__),
                                         "..", "..", ".."))
    cand = os.path.join(root, name)
    return cand if os.path.exists(cand) else None


def _elem_bytes(dtype) -> int:
    import numpy as np
    return int(np.dtype(dtype).itemsize)


# --------------------------------------------------------------------------
# per-unit accounting
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class UnitCost:
    """Traffic accounting for one ``(bucket, placement)`` executable.

    Plain ints/floats/strings only — ``COST_report.json`` parses without
    jax.  ``-1`` marks *unknown*: ``useful_bytes``/``pad_bytes`` need the
    plan's member patterns (a bare cache ``ExecKey`` doesn't know them),
    ``lowered_bytes`` needs a lowerable executable (restored DiskTier
    entries are opaque), ``predicted_gbs`` needs a calibration.
    """
    exec_key: str
    label: str = ""
    backend: str = ""
    kind: str = ""
    placement: str = ""
    batch: int = 0
    lanes: int = 0
    n_members: int = -1
    useful_bytes: int = -1
    pad_bytes: int = -1
    index_bytes: int = 0
    table_bytes: int = 0
    keep_bytes: int = 0
    replicated_bytes: int = 0
    io_bytes: int = 0
    device_bytes: int = 0
    lowered_bytes: int = -1
    predicted_gbs: float = -1.0

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, doc: dict) -> "UnitCost":
        known = {f.name for f in dataclasses.fields(cls)}
        bad = set(doc) - known
        if bad:
            raise ValueError(f"unknown UnitCost fields: {sorted(bad)}")
        return cls(**doc)


def key_id(key) -> str:
    """The baseline identity of an executable: the canonical ``ExecKey``
    repr (total, pure — the ``cache-key-purity`` contract)."""
    return str(key)


def key_cost(key, *, n_members: int = -1, real_elems: int = -1,
             lowered_bytes: int = -1, calibration=None,
             label: str = "") -> UnitCost:
    """Traffic accounting from ``ExecKey`` geometry alone.

    ``real_elems`` (sum of member ``count * index_len``) splits the
    launched lane-data into useful vs pad; without it both report -1 but
    every launch-geometry term is still exact — the degraded mode
    ``GET /cost`` uses for restored executables.
    """
    from repro.core.plan import pad_lanes, placement_grid
    _, l_shards, _ = placement_grid(key.placement)
    lanes = pad_lanes(key.idx_len, l_shards)
    e = _elem_bytes(key.dtype)
    r = key.row_width
    lane_elems = key.batch * lanes
    lane_data = lane_elems * e * r
    index_b = lane_elems * _INDEX_BYTES
    table_b = key.batch * (key.footprint + 1) * r * e
    scatter = key.kind == "scatter"
    keep_b = lane_elems * _KEEP_BYTES if scatter else 0
    # launch-boundary traffic: operands + results at global shapes.
    # gather:  table + idx -> lane data.  scatter: dst + idx + vals +
    # keep -> fresh dst-shaped result (cached executables never donate).
    copies = 2 if scatter else 1
    io_b = copies * table_b + index_b + lane_data + keep_b
    # lane shards replicate every batch-sharded-only operand/result —
    # except on the pallas backend, whose lane-sharded launches go
    # through shard_map (DESIGN.md §16): the table is explicitly
    # device-local there, and no GSPMD all-gather ever materializes
    repl_b = (0 if key.backend == "pallas"
              else copies * table_b * (l_shards - 1))
    device_b = io_b + repl_b
    useful = real_elems * e * r if real_elems >= 0 else -1
    pad = lane_data - useful if useful >= 0 else -1
    gbs = -1.0
    if calibration is not None and useful > 0:
        bw = calibration.bw_gbs.get(key.backend, 0.0)
        if bw > 0:
            gbs = bw * useful / device_b
    return UnitCost(
        exec_key=key_id(key), label=label, backend=key.backend,
        kind=key.kind, placement=key.placement, batch=key.batch,
        lanes=lanes, n_members=n_members, useful_bytes=useful,
        pad_bytes=pad, index_bytes=index_b, table_bytes=table_b,
        keep_bytes=keep_b, replicated_bytes=repl_b, io_bytes=io_b,
        device_bytes=device_b, lowered_bytes=lowered_bytes,
        predicted_gbs=gbs)


# --------------------------------------------------------------------------
# plan-level accounting + placement selection (pure geometry)
# --------------------------------------------------------------------------

def shape_cost(plan, shape=(1, 1), *, elem_bytes: int = 4,
               row_width: int = 1, backend: str | None = None) -> dict:
    """Aggregate predicted traffic of a plan at a ``(batch, lane)``
    shard shape — pure arithmetic, no mesh or devices required.

    Matches ``key_cost`` summed over ``enumerate_executables`` at the
    same placement (a tests/test_properties.py invariant) — including
    the backend term: pallas launches take the shard_map lane path
    (DESIGN.md §16), so with ``backend="pallas"`` no replication bytes
    are charged.
    """
    from repro.core.plan import pad_batch, pad_lanes
    b, l = int(shape[0]), int(shape[1])
    l_repl = 1 if backend == "pallas" else l
    useful = pad = index_b = table_b = keep_b = repl_b = 0
    for bucket in plan.buckets:
        batch = pad_batch(len(bucket.members), b)
        lanes = pad_lanes(bucket.spec.idx_len, l)
        real = sum(plan.patterns[i].count * plan.patterns[i].index_len
                   for i in bucket.members)
        lane_elems = batch * lanes
        scatter = bucket.spec.kind == "scatter"
        copies = 2 if scatter else 1
        useful += real * elem_bytes * row_width
        pad += (lane_elems - real) * elem_bytes * row_width
        index_b += lane_elems * _INDEX_BYTES
        table_b += copies * batch * (bucket.spec.footprint + 1) \
            * row_width * elem_bytes
        keep_b += lane_elems * _KEEP_BYTES if scatter else 0
        repl_b += copies * batch * (bucket.spec.footprint + 1) \
            * row_width * elem_bytes * (l_repl - 1)
    io_b = useful + pad + index_b + table_b + keep_b
    return {"shape": [b, l], "useful_bytes": useful, "pad_bytes": pad,
            "index_bytes": index_b, "table_bytes": table_b,
            "keep_bytes": keep_b, "replicated_bytes": repl_b,
            "io_bytes": io_b, "device_bytes": io_b + repl_b,
            "overhead": (io_b + repl_b) / useful if useful else float("inf")}


def candidate_shapes(n_devices: int) -> list[tuple[int, int]]:
    """``(1, 1)`` plus every 2-D split of the full device count."""
    shapes = [(1, 1)]
    if n_devices > 1:
        for b in range(1, n_devices + 1):
            if n_devices % b == 0:
                shapes.append((b, n_devices // b))
    return shapes


def select_shape(plan, *, n_devices: int = 1, elem_bytes: int = 4,
                 row_width: int = 1,
                 backend: str | None = None) -> tuple[int, int]:
    """The min-predicted-cost shard shape for a plan.

    Minimizes total predicted device traffic (``device_bytes`` — pad
    and replication both count against a shape); shapes within
    ``TIE_TOL`` of the minimum are traffic-equivalent and the tie breaks
    toward more *batch* shards (free wall-time division on real
    multi-chip hardware, bit-identical results), never toward lane
    shards.  ``backend`` feeds the replication term: pallas lane shards
    move no all-gather bytes (the shard_map path), so lane splits
    compete on pad waste alone there.
    """
    shapes = candidate_shapes(n_devices)
    costs = {s: shape_cost(plan, s, elem_bytes=elem_bytes,
                           row_width=row_width, backend=backend)
             ["device_bytes"]
             for s in shapes}
    best = min(costs.values())
    tied = [s for s in shapes if costs[s] <= best * (1 + TIE_TOL)]
    return max(tied, key=lambda s: (s[0], -s[1]))


def auto_placement(patterns_or_plan, *, n_devices: int | None = None,
                   dtype=None, row_width: int = 1,
                   backend: str | None = None):
    """Resolve an auto mesh to a concrete shard shape (or ``None``
    for single-device — the unplaced ``ExecKey`` placement ``""``).

    Returns a plain ``(batch, lane)`` tuple consumable by every
    ``as_placement`` surface, so auto-placed runs produce exactly the
    ExecKeys an explicit ``--mesh BxL`` run would (PR 5's placement
    strings unchanged) — warm repeats compile 0 and digests match.
    """
    from repro.core.plan import SuitePlan
    plan = patterns_or_plan
    if not hasattr(plan, "buckets"):
        plan = SuitePlan.build(list(patterns_or_plan))
    if n_devices is None:
        import jax
        n_devices = len(jax.devices())
    eb = _elem_bytes("float32" if dtype is None else dtype)
    shape = select_shape(plan, n_devices=n_devices, elem_bytes=eb,
                         row_width=row_width, backend=backend)
    return None if shape == (1, 1) else shape


# --------------------------------------------------------------------------
# calibration + baseline (committed artifacts)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Calibration:
    """Measured bandwidths lifted from ``BENCH_suite.json``.

    ``bw_gbs`` maps backend -> single-device effective GB/s (the
    roofline ceiling the predictor scales by traffic overhead);
    ``sweep`` maps suite -> cell-name -> ``{hmean_gbs, pad_waste}`` from
    the recorded mesh sweep (what ``auto-placement-sane`` audits
    against).
    """
    source: str = ""
    bw_gbs: dict = dataclasses.field(default_factory=dict)
    sweep: dict = dataclasses.field(default_factory=dict)
    n_dev: int = 1

    @classmethod
    def from_bench(cls, path: str | None = None) -> "Calibration":
        if path is None:
            path = _find_upward(BENCH_NAME, BENCH_ENV)
        if path is None or not os.path.exists(path):
            return cls(source="uncalibrated")
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            return cls(source="uncalibrated")
        bw = {bk: rec["hmean_measured_gbs"]
              for bk, rec in doc.get("backends", {}).items()
              if isinstance(rec, dict) and "hmean_measured_gbs" in rec}
        sweep, n_dev = {}, int(doc.get("mesh_sweep", {}).get("n_dev", 1))
        for suite, rec in doc.get("mesh_sweep", {}).get("suites",
                                                        {}).items():
            cells = {}
            if "single" in rec:
                cells["single"] = rec["single"]
            cells.update(rec.get("shapes", {}))
            sweep[suite] = cells
        return cls(source=path, bw_gbs=bw, sweep=sweep, n_dev=n_dev)

    def to_json(self) -> dict:
        return {"source": self.source, "bw_gbs": dict(self.bw_gbs),
                "n_dev": self.n_dev}


_SUITE_RE = re.compile(r"([\w.\-]+)\.json")


def suite_stem(label: str) -> str:
    """The suite name a lint/cost cell label refers to (`"" `if none)."""
    m = _SUITE_RE.search(label)
    return os.path.basename(m.group(1)) if m else ""


def baseline_path() -> str | None:
    return _find_upward(BASELINE_NAME, BASELINE_ENV)


def load_baseline(path: str | None = None) -> dict:
    """``{exec-key-string: predicted io_bytes}``; ``{}`` when nothing is
    committed (absence gates nothing — only a *smaller* committed value
    fires ``cost-regression``)."""
    if path is None:
        path = baseline_path()
    if path is None or not os.path.exists(path):
        return {}
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        return {}
    return {k: int(v) for k, v in doc.get("units", {}).items()}


def write_baseline(units: dict, path: str, meta: dict | None = None
                   ) -> None:
    doc = {"meta": meta or {}, "units": {k: int(v)
                                         for k, v in sorted(units.items())}}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


# --------------------------------------------------------------------------
# the report (same schema discipline as analysis/report.py)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CostReport:
    """Per-unit traffic accounting + any gate violations; jax-free."""
    units: list = dataclasses.field(default_factory=list)
    violations: list = dataclasses.field(default_factory=list)
    calibration: dict = dataclasses.field(default_factory=dict)
    rules: tuple = ()
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def n_units(self) -> int:
        return len(self.units)

    @property
    def n_violations(self) -> int:
        return len(self.violations)

    @property
    def ok(self) -> bool:
        return not any(v.severity == "error" for v in self.violations)

    def merge(self, other: "CostReport") -> "CostReport":
        meta = dict(self.meta)
        for k, v in other.meta.items():
            if k == "cells" and isinstance(meta.get(k), list):
                meta[k] = meta[k] + v
            else:
                meta[k] = v
        cal = self.calibration or other.calibration
        return CostReport(units=self.units + other.units,
                          violations=self.violations + other.violations,
                          calibration=cal,
                          rules=tuple(dict.fromkeys(self.rules
                                                    + other.rules)),
                          meta=meta)

    def to_json(self) -> dict:
        return {"units": [u.to_json() for u in self.units],
                "violations": [v.to_json() for v in self.violations],
                "calibration": dict(self.calibration),
                "rules": list(self.rules), "meta": self.meta,
                "n_units": self.n_units, "ok": self.ok}

    @classmethod
    def from_json(cls, doc: dict) -> "CostReport":
        known = {"units", "violations", "calibration", "rules", "meta",
                 "n_units", "ok"}
        bad = set(doc) - known
        if bad:
            raise ValueError(f"unknown CostReport fields: {sorted(bad)}")
        return cls(units=[UnitCost.from_json(u)
                          for u in doc.get("units", [])],
                   violations=[Violation.from_json(v)
                               for v in doc.get("violations", [])],
                   calibration=dict(doc.get("calibration", {})),
                   rules=tuple(doc.get("rules", ())),
                   meta=dict(doc.get("meta", {})))

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
            f.write("\n")

    def summary(self) -> str:
        io = sum(u.io_bytes for u in self.units)
        useful = sum(u.useful_bytes for u in self.units
                     if u.useful_bytes > 0)
        head = (f"spattercost: {self.n_units} unit(s), "
                f"{io} predicted I/O bytes"
                + (f" ({io / useful:.2f}x analytic minimum)"
                   if useful else "")
                + f", {self.n_violations} violation(s)")
        lines = [head]
        for v in self.violations:
            lines.append(f"  [{v.severity}] {v.rule}: {v.message}"
                         + (f" ({v.exec_key})" if v.exec_key else ""))
        return "\n".join(lines)


# --------------------------------------------------------------------------
# drivers: plan / suite file / live cache
# --------------------------------------------------------------------------

def cost_plan(patterns, *, backend: str = "xla", dtype=None,
              row_width: int = 1, mode: str = "store", placement=None,
              mesh_axis: str = "data", label: str = "",
              calibration=None, lowered: bool = True,
              rules: tuple | None = None) -> CostReport:
    """Cost every executable one plan x placement cell would compile.

    Mirrors ``lint_plan``: same enumeration, same cell labelling; adds
    the member-aware useful/pad split and (when ``lowered``) the
    StableHLO reconciliation that feeds ``traffic-conservation``.
    """
    import jax.numpy as jnp
    from repro.analysis.lint import run_rules
    from repro.analysis.rules import PlanUnit, rules_for
    from repro.core import hlo
    from repro.core.plan import SuitePlan, as_placement
    if calibration is None:
        calibration = Calibration.from_bench()
    plan = patterns if hasattr(patterns, "buckets") \
        else SuitePlan.build(list(patterns))
    if isinstance(placement, str):       # "auto" / "auto-suite"
        from repro.core.plan import auto_placements
        placement = auto_placements(plan, placement, mesh_axis=mesh_axis,
                                    backend=backend, dtype=dtype,
                                    row_width=row_width)
    if isinstance(placement, list):      # per-bucket (mesh="auto")
        place = [as_placement(p, mesh_axis) for p in placement]
        placements = place
        place_str = "auto(" + ",".join(
            p.placement if p else "single" for p in place) + ")"
    else:
        place = as_placement(placement, mesh_axis)
        placements = None
        place_str = place.placement if place else "single"
    cell = f"{label} @ {place_str} backend={backend}" if label \
        else f"@ {place_str} backend={backend}"
    dtype = dtype or jnp.float32
    exec_rules = COST_RULES if rules is None \
        else tuple(n for n in COST_RULES if n in rules)
    units, violations = [], []
    for unit, bucket in zip(_enumerate_units(plan, backend, dtype,
                                             row_width, mode, place),
                            plan.buckets):
        real = sum(plan.patterns[i].count * plan.patterns[i].index_len
                   for i in bucket.members)
        low = hlo.main_io_bytes(unit.lowered_text)["total"] if lowered \
            else -1
        units.append(key_cost(unit.key, n_members=len(bucket.members),
                              real_elems=real, lowered_bytes=low,
                              calibration=calibration, label=unit.label))
        violations.extend(run_rules(unit, exec_rules))
    grid = (1, 1) if placements is not None \
        else (place.grid if place else (1, 1))
    plan_rules = ("auto-placement-sane",) if rules is None \
        or "auto-placement-sane" in rules else ()
    if plan_rules:
        plan_unit = PlanUnit(plan=plan, grid=tuple(grid), label=cell,
                             placements=placements)
        for r in rules_for("plan", plan_rules):
            violations.extend(r.check(plan_unit))
    return CostReport(units=units, violations=violations,
                      calibration=calibration.to_json(),
                      rules=exec_rules + plan_rules,
                      meta={"cells": [{"cell": cell,
                                       "n_units": len(units)}]})


def _enumerate_units(plan, backend, dtype, row_width, mode, place):
    from repro.analysis.rules import ExecUnit
    from repro.core.plan import enumerate_executables
    return [ExecUnit(key=key, builder=builder, avals=avals)
            for key, builder, avals in enumerate_executables(
                plan, backend=backend, dtype=dtype, row_width=row_width,
                mode=mode, placement=place)]


def cost_suite_file(path: str, *, mesh=None, backends=("xla", "pallas"),
                    mode: str = "store", row_width: int = 1, dtype=None,
                    calibration=None, rules: tuple | None = None
                    ) -> CostReport:
    """Cost a suite file across backends at one placement.

    ``mesh="auto"`` resolves PER BUCKET inside each backend's cell (the
    cost model's choice depends on the backend: lane-sharded pallas is
    not charged replication bytes); ``mesh="auto-suite"`` resolves one
    suite-wide shape per backend.  The per-backend choices land in
    ``meta.auto``, and the report's ExecKeys are exactly what explicit
    ``--mesh BxL`` runs of the chosen shapes would compile.
    """
    from repro.core import load_suite
    from repro.core.plan import SuitePlan, auto_placements
    patterns = load_suite(path)
    plan = SuitePlan.build(patterns)
    auto: dict = {}
    report = CostReport()
    for backend in backends:
        placement = mesh
        if mesh in ("auto", "auto-suite"):
            placement = auto_placements(plan, mesh, backend=backend,
                                        dtype=dtype, row_width=row_width)
            if isinstance(placement, list):
                auto[backend] = [p.placement if p else "single"
                                 for p in placement]
            else:
                auto[backend] = (placement.placement if placement
                                 else "single")
        report = report.merge(cost_plan(
            plan, backend=backend, dtype=dtype, row_width=row_width,
            mode=mode, placement=placement, label=path,
            calibration=calibration, rules=rules))
    if auto:
        report.meta["auto"] = {path: auto}
    return report


def cost_cache(cache, *, calibration=None) -> CostReport:
    """``GET /cost``: traffic-account the daemon's live cache.

    Restored (DiskTier) executables are one opaque exported call — no
    lowered signature to reconcile — so they degrade to the key-geometry
    terms plus the key-only rules, mirroring ``lint_cache``'s downgrade.
    """
    from repro.analysis.lint import run_rules
    from repro.analysis.rules import ExecUnit
    from repro.core import hlo
    from repro.core.plan import key_avals
    if calibration is None:
        calibration = Calibration.from_bench()
    units, violations, n_restored = [], [], 0
    for key, fn in cache.entries():
        restored = bool(getattr(fn, "restored", False))
        unit = ExecUnit(key=key, builder=None, avals=key_avals(key),
                        fn=fn)
        low = -1
        if restored:
            n_restored += 1
            names = KEY_ONLY_COST_RULES
        else:
            low = hlo.main_io_bytes(unit.lowered_text)["total"]
            names = COST_RULES
        units.append(key_cost(key, lowered_bytes=low,
                              calibration=calibration, label=unit.label))
        violations.extend(run_rules(unit, names))
    return CostReport(units=units, violations=violations,
                      calibration=calibration.to_json(), rules=COST_RULES,
                      meta={"source": "live-cache",
                            "restored": n_restored})
