"""The paper's CLI, reproduced (§3.4):

    ./spatter -k Gather -p UNIFORM:8:1 -d 8 -l $((2**24))
becomes
    PYTHONPATH=src python examples/spatter_cli.py -k Gather -p UNIFORM:8:1 \
        -d 8 -l 65536 [-b xla|onehot|scalar|pallas] [--json suites/x.json]

Prints the paper's outputs (min-time bandwidth) plus the TPU-model columns
(modeled v5e GB/s, tile efficiency, reuse factor).

Multi-device suites (--json mode): ``--mesh N`` splits every bucket
launch's pattern-batch dim over N devices (the paper §3.4 thread-scaling
story, scaled to devices); ``--mesh BxL`` (e.g. ``4x2``) places launches
on a 2-D (pattern-batch x lane) mesh — the lane axis splits *within*
each pattern, so suites with few patterns but huge counts still fill the
mesh (core/plan.py Placement, DESIGN.md §11).  On a CPU-only host, force
fake devices first:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python examples/spatter_cli.py --json suite.json \\
        --mesh 4x2

Scatter write semantics: ``--mode store`` (last-write-wins, the paper's
default) or ``--mode add`` (accumulation), on both single-pattern and
suite runs.

Static audit (spatterlint, DESIGN.md §12) — check every executable the
planner would build for a suite WITHOUT running it, plus the serving
layer's lock-discipline lint; non-zero exit on any violation::

    PYTHONPATH=src python examples/spatter_cli.py --lint suites/demo.json \\
        [--mesh 4x2] [--lint-out LINT_report.json]

Static traffic accounting (spattercost, DESIGN.md §15) — predict the
exact bytes every executable moves, reconcile against the lowered
StableHLO, and convert to GB/s via the BENCH-calibrated roofline; with
``--mesh auto`` the placement is chosen by minimum predicted traffic::

    PYTHONPATH=src python examples/spatter_cli.py --cost suites/demo.json \\
        [--mesh auto|4x2] [--cost-out COST_report.json]

``--mesh auto`` also works on a live run (--json): the suite executes on
the min-predicted-cost placement, with ExecKeys (and digests) identical
to the same explicit --mesh run.

spatterd quickstart (the serving layer, DESIGN.md §10) — one process
keeps the ExecutorCache warm across requests, so only the FIRST request
for a suite shape compiles anything:

    # terminal 1: the daemon (add XLA_FLAGS=...device_count=8 for --mesh 8)
    PYTHONPATH=src python examples/spatter_cli.py --serve --port 8089
    # terminal 2: any number of clients, any number of times
    PYTHONPATH=src python examples/spatter_cli.py \\
        --client http://127.0.0.1:8089 --json suites/demo.json
    # the response prints "cache ... misses 0" from the second request on,
    # with per-pattern sha256 digests proving bit-identical results
"""
import argparse

# argparse defaults are None sentinels so the --serve/--client branches
# can tell "flag omitted" from "flag given" exactly (comparing against a
# real default would silently drop an explicit `--runs 10`); LOCAL_DEFAULTS
# is applied only on the local execution path, and the help text is the
# single place each default is narrated.
LOCAL_DEFAULTS = dict(kernel="Gather", pattern="UNIFORM:8:1", delta=8,
                      count=1 << 16, backend="xla", runs=10, row_width=1,
                      mesh=0, mode="store", host="127.0.0.1", port=8089)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("-k", "--kernel", default=None,
                    choices=["Gather", "Scatter", "gather", "scatter"],
                    help="access kind (default Gather)")
    ap.add_argument("-p", "--pattern", default=None,
                    help="UNIFORM:N:S | MS1:N:B:G | LAPLACIAN:D:L:S | "
                         "BROADCAST:N:R | i0,i1,...  (default UNIFORM:8:1)")
    ap.add_argument("-d", "--delta", type=int, default=None,
                    help="stride between accesses (default 8)")
    ap.add_argument("-l", "--count", type=int, default=None,
                    help="access count (default 65536)")
    ap.add_argument("-b", "--backend", default=None,
                    choices=["xla", "onehot", "scalar", "pallas"],
                    help="backend (default xla)")
    ap.add_argument("-r", "--runs", type=int, default=None,
                    help="min-of-K timing (paper §3.5, default 10)")
    ap.add_argument("--row-width", type=int, default=None,
                    help="TPU row granularity (default 1 = paper's scalar "
                         "element)")
    ap.add_argument("--json", default=None,
                    help="run a JSON suite file instead (paper §3.3)")
    ap.add_argument("--no-batch", action="store_true",
                    help="suite mode: one compile per pattern instead of "
                         "the bucketed planner (plan.py)")
    ap.add_argument("--mesh", default=None, metavar="N|BxL|auto",
                    help="suite mode: shard bucket launches over N devices "
                         "(pattern-batch axis) or a BxL (batch x lane) 2-D "
                         "placement, e.g. 4x2; 'auto' picks the minimum "
                         "predicted-traffic shape (DESIGN.md §15); "
                         "default 0 = off")
    ap.add_argument("--mode", default=None, choices=["store", "add"],
                    help="scatter write semantics: last-write-wins store "
                         "(paper default) or add accumulation")
    ap.add_argument("--stream-r", action="store_true",
                    help="suite mode: also time a STREAM-like reference "
                         "and report paper Eq. 1 Pearson's R")
    ap.add_argument("--lint", default=None, metavar="SUITE",
                    help="spatterlint: statically audit every executable "
                         "the planner would build for SUITE (no execution; "
                         "repro.analysis, DESIGN.md §12) plus the serving-"
                         "layer concurrency lint; honors --mesh/--backend/"
                         "--mode/--row-width and exits non-zero on any "
                         "violation")
    ap.add_argument("--lint-out", default=None, metavar="FILE",
                    help="--lint: also write the JSON lint report (the "
                         "same schema GET /lint serves)")
    ap.add_argument("--cost", default=None, metavar="SUITE",
                    help="spattercost: statically predict the bytes every "
                         "executable the planner would build for SUITE "
                         "moves (no execution; repro.analysis.cost, "
                         "DESIGN.md §15), reconciled against the lowered "
                         "HLO and converted to GB/s via the calibrated "
                         "roofline; honors --mesh (incl. 'auto')/"
                         "--backend/--mode/--row-width and exits non-zero "
                         "on any violation")
    ap.add_argument("--cost-out", default=None, metavar="FILE",
                    help="--cost: also write the JSON cost report (the "
                         "same schema GET /cost serves; jax-free to "
                         "consume)")
    ap.add_argument("--serve", action="store_true",
                    help="run spatterd: serve JSON suites over HTTP off "
                         "the warm executor cache (repro.serve)")
    ap.add_argument("--client", default=None, metavar="URL",
                    help="POST --json to a running spatterd instead of "
                         "executing locally")
    ap.add_argument("--stats", action="store_true",
                    help="--client: print the daemon's /stats document "
                         "(cache counters + scheduler queue/worker "
                         "snapshot) instead of posting a suite")
    ap.add_argument("--host", default=None,
                    help="--serve bind address (default 127.0.0.1)")
    ap.add_argument("--port", type=int, default=None,
                    help="--serve port (default 8089)")
    ap.add_argument("--cache-dir", default=None, metavar="DIR",
                    help="--serve: persist compiled executables here so a "
                         "restarted daemon starts warm (DESIGN.md §14)")
    args = ap.parse_args()

    def _given(names):
        # identity checks: 0 is a legitimate explicit value (--port 0
        # binds ephemeral), and 0 == False would swallow it
        return [f"--{n.replace('_', '-')}" for n in names
                if getattr(args, n) is not None
                and getattr(args, n) is not False]

    if args.lint is not None:
        # a static audit executes nothing: run-shaped options are a
        # contradiction, not something to drop silently
        bad = _given(("json", "no_batch", "client", "kernel", "pattern",
                      "delta", "count", "runs", "stream_r", "host",
                      "port", "stats", "cache_dir", "cost",
                      "cost_out")) + (["--serve"] if args.serve else [])
        if bad:
            ap.error(f"{', '.join(bad)}: not applicable to --lint "
                     f"(static audit; only --mesh/--backend/--mode/"
                     f"--row-width apply)")
        from repro.analysis.lint import lint_serve, lint_suite_file
        from repro.serve.schema import parse_mesh
        try:
            mesh = parse_mesh(str(args.mesh)) if args.mesh is not None \
                else 0
        except ValueError as e:
            ap.error(f"--mesh: {e}")
        if mesh == "auto":
            # resolve before the audit so the report names the concrete
            # shape the cost model chose (DESIGN.md §15)
            from repro.analysis.cost import auto_placement
            from repro.core import load_suite
            mesh = auto_placement(
                load_suite(args.lint),
                row_width=args.row_width or LOCAL_DEFAULTS["row_width"],
            ) or 0
        backends = (args.backend,) if args.backend else ("xla", "pallas")
        try:
            report = lint_serve().merge(lint_suite_file(
                args.lint, mesh=mesh, backends=backends,
                mode=args.mode or LOCAL_DEFAULTS["mode"],
                row_width=args.row_width or LOCAL_DEFAULTS["row_width"]))
        except (ValueError, OSError) as e:
            ap.error(f"--lint: {e}")
        if args.lint_out:
            report.dump(args.lint_out)
        print(report.summary())
        if not report.ok:
            raise SystemExit(1)
        return

    if args.lint_out is not None:
        ap.error("--lint-out requires --lint SUITE")

    if args.cost is not None:
        # like --lint, a static traffic analysis executes nothing
        bad = _given(("json", "no_batch", "client", "kernel", "pattern",
                      "delta", "count", "runs", "stream_r", "host",
                      "port", "stats",
                      "cache_dir")) + (["--serve"] if args.serve else [])
        if bad:
            ap.error(f"{', '.join(bad)}: not applicable to --cost "
                     f"(static analysis; only --mesh/--backend/--mode/"
                     f"--row-width apply)")
        from repro.analysis.cost import cost_suite_file
        from repro.serve.schema import parse_mesh
        try:
            mesh = parse_mesh(str(args.mesh)) if args.mesh is not None \
                else 0
        except ValueError as e:
            ap.error(f"--mesh: {e}")
        backends = (args.backend,) if args.backend else ("xla", "pallas")
        try:
            report = cost_suite_file(
                args.cost, mesh=mesh or None, backends=backends,
                mode=args.mode or LOCAL_DEFAULTS["mode"],
                row_width=args.row_width or LOCAL_DEFAULTS["row_width"])
        except (ValueError, OSError) as e:
            ap.error(f"--cost: {e}")
        if args.cost_out:
            report.dump(args.cost_out)
        print(report.summary())
        if not report.ok:
            raise SystemExit(1)
        return

    if args.cost_out is not None:
        ap.error("--cost-out requires --cost SUITE")

    if args.serve:
        if args.client:
            ap.error("--serve and --client are exclusive modes: run the "
                     "daemon OR talk to one")
        # execution options are PER-REQUEST in serve mode (they ride in
        # each POST body): refuse them rather than dropping them silently
        dropped = _given(("json", "no_batch", "mesh", "mode", "backend",
                          "row_width", "runs", "kernel", "pattern",
                          "delta", "count", "stream_r", "stats"))
        if dropped:
            ap.error(f"{', '.join(dropped)}: per-request options — pass "
                     f"them to --client (or in the POST body), not --serve")
        from repro.serve import daemon
        host = LOCAL_DEFAULTS["host"] if args.host is None else args.host
        port = LOCAL_DEFAULTS["port"] if args.port is None else args.port
        argv = ["--host", host, "--port", str(port)]
        if args.cache_dir is not None:
            argv += ["--cache-dir", args.cache_dir]
        daemon.main(argv)
        return

    if args.client:
        if args.stats:
            # the read-only stats verb: no suite, no execution options
            extra = _given(("json", "no_batch", "mesh", "mode", "backend",
                            "row_width", "runs", "kernel", "pattern",
                            "delta", "count", "stream_r", "host", "port",
                            "cache_dir"))
            if extra:
                ap.error(f"{', '.join(extra)}: --stats is a read-only "
                         f"query; it takes only --client URL")
            from repro.serve import client as sc
            sc.main(["--url", args.client, "--stats"])
            return
        if not args.json:
            ap.error("--client needs --json SUITE to post (or --stats)")
        if args.no_batch:
            ap.error("--no-batch is local-only: spatterd always runs the "
                     "bucketed planner")
        single = _given(("kernel", "pattern", "delta", "count"))
        if single:
            ap.error(f"{', '.join(single)}: single-pattern options don't "
                     f"apply to --client suite posts (use --json)")
        local = _given(("host", "port", "cache_dir"))
        if local:
            ap.error(f"{', '.join(local)}: --serve options — the target "
                     f"daemon is the --client URL")
        # delegate to the client CLI (like --serve delegates to
        # daemon.main): this wrapper forwards the paper CLI's common
        # options; the FULL wire surface (--metric, --seed, --stream-n,
        # --no-digest, envelope files) lives on `python -m
        # repro.serve.client`.  Only flags the user gave are forwarded
        # (None = omitted), so an envelope suite file's own fields are
        # never silently overridden by CLI defaults
        from repro.serve import client as sc
        argv = ["--url", args.client, "--json", args.json]
        for flag, name in (("--backend", "backend"), ("--runs", "runs"),
                           ("--mode", "mode"), ("--mesh", "mesh"),
                           ("--row-width", "row_width")):
            v = getattr(args, name)
            if v is not None:
                argv += [flag, str(v)]
        if args.stream_r:
            argv += ["--stream-r"]
        sc.main(argv)
        return

    stray = _given(("host", "port", "cache_dir"))
    if stray:
        ap.error(f"{', '.join(stray)}: --serve options (add --serve, or "
                 f"target a running daemon with --client URL)")
    if args.stats:
        ap.error("--stats queries a running daemon: add --client URL")

    # local execution from here on: resolve the omitted flags to the
    # paper defaults, then pay the JAX startup the --serve/--client
    # branches above deliberately avoid
    opt = {k: v if getattr(args, k) is None else getattr(args, k)
           for k, v in LOCAL_DEFAULTS.items()}
    if opt["runs"] < 1:
        ap.error("--runs must be >= 1 (min-of-K timing needs a run)")
    if args.stream_r and not args.json:
        ap.error("--stream-r only applies to --json suite mode")
    from repro.core import GSEngine, Placement, load_suite, make_pattern, \
        run_suite

    mesh = None
    mesh_grid = (1, 1)
    from repro.serve.schema import parse_mesh
    try:
        mesh_shape = parse_mesh(str(opt["mesh"]))
    except ValueError as e:
        ap.error(f"--mesh: {e}")
    if mesh_shape:                             # 0 = off (the default)
        if not args.json:
            ap.error("--mesh only applies to --json suite mode")
        if args.no_batch:
            ap.error("--mesh requires the bucketed planner (drop --no-batch)")
        if mesh_shape == "auto":
            # §15 cost model picks the shape; the run below then uses the
            # same ExecKeys an explicit --mesh BxL would, so warm caches
            # and digests are shared with explicit-mesh runs
            from repro.analysis.cost import auto_placement
            mesh_shape = auto_placement(load_suite(args.json),
                                        row_width=opt["row_width"])
            chosen = "single (1x1)" if mesh_shape is None \
                else "x".join(map(str, mesh_shape))
            print(f"mesh : auto-selected {chosen} "
                  f"(min predicted traffic, DESIGN.md §15)")
        if mesh_shape:
            try:
                mesh = Placement.create(mesh_shape)  # validates devices
            except ValueError as e:
                ap.error(f"--mesh: {e}")
            mesh_grid = mesh.grid

    if args.json:
        stats = run_suite(load_suite(args.json), backend=opt["backend"],
                          runs=opt["runs"], row_width=opt["row_width"],
                          mode=opt["mode"], stream_r=args.stream_r,
                          batch=not args.no_batch, mesh=mesh)
        print(f"{'name':24s} {'type':16s} {'cpu GB/s':>9s} {'v5e GB/s':>9s} "
              f"{'tile_eff':>8s}")
        for r in stats.results:
            print(f"{r.pattern.name:24s} {r.pattern.classify():16s} "
                  f"{r.measured_gbs:9.2f} {r.modeled_gbs:9.1f} "
                  f"{r.tile_efficiency:8.3f}")
        print(f"\nsuite: min {stats.min_gbs:.2f}  max {stats.max_gbs:.2f}  "
              f"harmonic-mean {stats.hmean_gbs:.2f} GB/s   (paper §3.5)")
        if stats.stream_gbs is not None:
            print(f"stream: {stats.stream_gbs:.2f} GB/s reference   "
                  f"Pearson R={stats.stream_r:.3f} (paper Eq. 1)")
        if stats.plan is not None:
            print(f"plan : {len(stats.results)} patterns -> "
                  f"{stats.plan.n_buckets} shape buckets "
                  f"(pad waste {stats.plan.pad_waste(*mesh_grid):.1%})")
        if mesh is not None:
            b, l = mesh_grid
            n_dev = b * l
            print(f"mesh : {mesh.placement} — pattern-batch x{b}, "
                  f"lanes x{l} (aggregate GB/s above; per-device = /"
                  f"{n_dev})")
        return

    p = make_pattern(opt["pattern"], kind=opt["kernel"].lower(),
                     delta=opt["delta"], count=opt["count"])
    print(f"pattern  : {list(p.index)}")
    print(f"type     : {p.classify()}   delta={p.delta}  count={p.count}")
    print(f"footprint: {p.footprint()} elems   reuse={p.reuse_factor():.2f}x")
    r = GSEngine(p, backend=opt["backend"], mode=opt["mode"],
                 row_width=opt["row_width"]).run(runs=opt["runs"])
    print(f"time     : {r.time_s*1e6:.1f} us (min of {opt['runs']})")
    print(f"bandwidth: {r.measured_gbs:.2f} GB/s measured(cpu)   "
          f"{r.modeled_gbs:.1f} GB/s modeled(v5e)   "
          f"tile_eff={r.tile_efficiency:.3f}")


if __name__ == "__main__":
    main()
