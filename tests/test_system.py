"""End-to-end behaviour tests: train-to-lower-loss, serve, trace, suite."""
import dataclasses
import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import appdb, harmonic_mean, pearson_r, run_suite, trace_gs
from repro.data import TokenPipeline
from repro.models.zoo import Model
from repro.optim import AdamWConfig, init_opt_state, warmup_cosine
from repro.runtime.train import make_train_step


def test_train_loss_decreases():
    """A tiny LM must learn the synthetic bigram structure within 40 steps."""
    cfg = dataclasses.replace(get_smoke_config("llama3-8b"),
                              dtype="float32", remat="none")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    opt_cfg = AdamWConfig(lr=warmup_cosine(3e-3, warmup=5, total=40),
                          weight_decay=0.0)
    step = jax.jit(make_train_step(model, opt_cfg))
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=0)
    losses = []
    for i in range(40):
        b = {k: jnp.asarray(v) for k, v in pipe.batch(i).items()}
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses


def test_microbatched_matches_full_batch():
    """Gradient accumulation must be numerically equivalent (fp32)."""
    cfg = dataclasses.replace(get_smoke_config("llama3-8b"),
                              dtype="float32", remat="none")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=16, global_batch=8, seed=0)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    opt_cfg = AdamWConfig(lr=1e-3)

    p1, _, m1 = jax.jit(make_train_step(model, opt_cfg))(
        params, init_opt_state(params), batch)
    p4, _, m4 = jax.jit(make_train_step(model, opt_cfg, microbatches=4))(
        params, init_opt_state(params), batch)
    assert np.isclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-5)


def test_serve_driver_runs():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "llama3-8b",
         "--smoke", "--batch", "2", "--prompt-len", "8", "--gen", "4"],
        capture_output=True, text=True, timeout=420, env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "decode:" in r.stdout


def test_trace_gs_on_model():
    """§2 analogue: the jaxpr tracer must find the embedding gather."""
    cfg = dataclasses.replace(get_smoke_config("llama3-8b"),
                              dtype="float32", remat="none")
    model = Model(cfg)
    params = model.abstract_params(jnp.float32)
    toks = jax.ShapeDtypeStruct((2, 16), jnp.int32)

    def fwd(p, t):
        from repro.models.transformer import forward
        return forward(cfg, p, t)[0]

    rep = trace_gs(fwd, params, toks)
    assert len(rep.gathers()) >= 1
    assert rep.gs_fraction > 0
    assert "G/S bytes" in rep.summary()
    pats = rep.to_patterns()
    assert all(p.count >= 1 for p in pats)


def test_app_suite_and_correlation():
    """Table 4 machinery: per-app harmonic means + Pearson R vs STREAM."""
    pats = appdb.scale_counts(appdb.PENNANT_GATHERS[:4] +
                              appdb.LULESH_GATHERS[:2], 1 / 512)
    stats = run_suite(pats, backend="xla", runs=2)
    assert stats.hmean_gbs > 0
    assert stats.min_gbs <= stats.hmean_gbs <= stats.max_gbs
    xs = [r.measured_gbs for r in stats.results]
    r = pearson_r(xs, xs)
    assert np.isclose(r, 1.0)
    assert harmonic_mean([1, 1, 1]) == 1.0
