"""Shared model machinery: declarative params, norms, RoPE, MLPs, attention.

Parameters are described declaratively with ParamDef (shape + logical axes +
init), so the same tree yields:  real arrays (init), ShapeDtypeStructs
(dry-run — no allocation), and PartitionSpecs (runtime/sharding.py rules).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.sharding import constrain

# ---------------------------------------------------------------------------
# Declarative parameters
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]          # logical axis names per dim
    init: str = "normal"                  # normal | zeros | ones
    scale: float | None = None            # None -> 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _fan_in(shape: tuple[int, ...]) -> int:
    return shape[0] if len(shape) > 1 else max(1, shape[0])


def init_tree(key: jax.Array, defs, dtype) -> dict:
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, d in zip(keys, leaves):
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dtype))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dtype))
        else:
            scale = d.scale if d.scale is not None else 1.0 / math.sqrt(
                _fan_in(d.shape))
            out.append((jax.random.normal(k, d.shape, jnp.float32)
                        * scale).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def abstract_tree(defs, dtype) -> dict:
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs, is_leaf=is_def)


def axes_tree(defs) -> dict:
    return jax.tree.map(lambda d: d.axes, defs, is_leaf=is_def)


def stack_defs(defs, n: int, axis_name: str = "layers") -> dict:
    """Prepend a scan dimension of length n to every ParamDef."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, (axis_name,) + d.axes,
                           d.init, d.scale),
        defs, is_leaf=is_def)


def tree_bytes(defs, dtype) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    itemsize = jnp.dtype(dtype).itemsize
    return sum(int(np.prod(d.shape)) * itemsize for d in leaves)


def tree_params(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return sum(int(np.prod(d.shape)) for d in leaves)


# ---------------------------------------------------------------------------
# Norms / activations / MLPs
# ---------------------------------------------------------------------------

def rms_norm_def(d: int) -> dict:
    return {"scale": ParamDef((d,), ("embed",), init="ones")}


def rms_norm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def mlp_def(cfg, d_in: int, d_ff: int, expert: bool = False) -> dict:
    mlp_ax = "expert_mlp" if expert else "mlp"
    if cfg.mlp_kind in ("swiglu", "geglu"):
        return {
            "wi": ParamDef((d_in, d_ff), ("embed", mlp_ax)),
            "wg": ParamDef((d_in, d_ff), ("embed", mlp_ax)),
            "wo": ParamDef((d_ff, d_in), (mlp_ax, "embed")),
        }
    return {   # plain gelu
        "wi": ParamDef((d_in, d_ff), ("embed", mlp_ax)),
        "wo": ParamDef((d_ff, d_in), (mlp_ax, "embed")),
    }


def mlp_apply(cfg, p: dict, x: jax.Array) -> jax.Array:
    h = x @ p["wi"]
    if cfg.mlp_kind == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * h
    elif cfg.mlp_kind == "geglu":
        h = jax.nn.gelu(x @ p["wg"]) * h
    else:
        h = jax.nn.gelu(h)
    return h @ p["wo"]


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(dh: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               mode: str = "full") -> jax.Array:
    """x (..., S, H, dh); positions (..., S). mode: full | 2d (half-dim) | none."""
    if mode == "none":
        return x
    dh = x.shape[-1]
    rot = dh if mode == "full" else dh // 2
    freqs = rope_freqs(rot, theta)                          # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :]                        # (..., S, 1, rot/2)
    sin = jnp.sin(ang)[..., None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                              axis=-1).astype(x.dtype)
    if rot == dh:
        return rotated
    return jnp.concatenate([rotated, x[..., rot:]], axis=-1)


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention — pure lax, pjit-friendly
# ---------------------------------------------------------------------------

def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      chunk: int, causal: bool = True, window: int = 0,
                      attn_softcap: float = 0.0, q_offset: int = 0,
                      scale: float | None = None,
                      pin_heads: bool = False) -> jax.Array:
    """Query-chunked attention.

    q (B, S, KVH, G, dh); k/v (B, T, KVH, dh).  Chunking the query dim keeps
    the score tensor at (B, KVH, G, chunk, T) instead of (…, S, T) — the
    standard memory-capping trick for long sequences without a fused kernel.
    """
    b, s, kvh, g, dh = q.shape
    t = k.shape[1]
    dv = v.shape[-1]                   # may differ from dh (MLA)
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    chunk = min(chunk, s)
    if s % chunk:
        chunk = s  # ragged: fall back to single chunk
    n_chunks = s // chunk

    kv_pos = jnp.arange(t)

    # remat each chunk: without this, XLA saves every chunk's (chunk, T)
    # softmax probabilities as backward residuals — the classic quadratic
    # attention-memory blow-up (measured: 59 GB/device temp on llama3-8b
    # train_4k; 8.9 GB with chunk remat — EXPERIMENTS.md §Perf).
    @jax.checkpoint
    def one_chunk(ci, q_chunk):
        q0 = ci * chunk + q_offset
        scores = jnp.einsum("bqkgd,btkd->bkgqt",
                            q_chunk.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        scores = softcap(scores, attn_softcap)
        q_pos = q0 + jnp.arange(chunk)
        mask = jnp.ones((chunk, t), bool)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if window > 0:
            mask &= (q_pos[:, None] - kv_pos[None, :]) < window
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("bkgqt,btkd->bqkgd", p,
                          v.astype(jnp.float32)).astype(q_chunk.dtype)

    if n_chunks == 1:
        return one_chunk(0, q)
    qc = q.reshape(b, n_chunks, chunk, kvh, g, dh)
    qc = jnp.moveaxis(qc, 1, 0)                         # (n, B, chunk, ...)
    # pin the stacked-chunk sharding only for MLA-style attention
    # (pin_heads=True, kvh == n_heads): there it kills a ~6 GB f32
    # all-gather per layer pass on deepseek.  For GQA the pin is neutral
    # (gemma2 kvh=16) or actively harmful (llama3 kvh=8 would force
    # replication, 7x the memory term) — §Perf iterations 2/4.
    if pin_heads:
        from repro.runtime.sharding import constrain_if_sharded
        qc = constrain_if_sharded(
            qc, (None, "batch", None, "kv_heads", None, "head_dim"), 3)
    out = jax.lax.map(lambda args: one_chunk(args[0], args[1]),
                      (jnp.arange(n_chunks), qc))
    if pin_heads:
        from repro.runtime.sharding import constrain_if_sharded
        out = constrain_if_sharded(
            out, (None, "batch", None, "kv_heads", None, "head_dim"), 3)
    return jnp.moveaxis(out, 0, 1).reshape(b, s, kvh, g, dv)
