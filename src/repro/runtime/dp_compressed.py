"""Cross-pod data parallelism with int8-compressed gradient all-reduce.

The multi-pod mesh's "pod" axis crosses data-center interconnect; the one
collective it carries is the per-step gradient all-reduce (DESIGN.md §5).
This module provides the shard_map DP layer that quantizes that traffic to
int8 with per-tensor scales and error feedback (optim/grad_utils): 4x less
cross-pod bytes, bias-corrected over steps by the residual carry.

Scope: pure data parallelism over the given axis (the model runs unsharded
inside the body — use this as the *outer* layer around a per-pod TP step,
or standalone for small models).  Validated against the uncompressed step
in tests/test_dp_compressed.py (single-step tolerance + error-feedback
drift bound over multiple steps).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import axis_size, shard_map_unchecked
from repro.optim import AdamWConfig, adamw_update, clip_by_global_norm
from repro.optim.grad_utils import compressed_psum


def make_compressed_dp_step(model, opt_cfg: AdamWConfig, mesh: Mesh, *,
                            axis: str = "data", compress: bool = True):
    """Returns step(params, opt_state, residuals, batch) ->
    (params', opt_state', residuals', metrics).  ``residuals`` is the
    error-feedback pytree (zeros_like(params) at step 0)."""

    def body(params, opt_state, residuals, batch):
        # params replicated over `axis`; batch sharded on dim 0
        n = axis_size(axis)
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        loss = jax.lax.pmean(loss, axis)
        if compress:
            synced = {}
            new_res = {}
            flat_g, tdef = jax.tree.flatten(grads)
            flat_r = jax.tree.leaves(residuals)
            out_g, out_r = [], []
            for g, r in zip(flat_g, flat_r):
                s, nr = compressed_psum(g, axis, residual=r)
                out_g.append(s)
                out_r.append(nr)
            grads = jax.tree.unflatten(tdef, out_g)
            residuals = jax.tree.unflatten(tdef, out_r)
        else:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, axis), grads)
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        params, opt_state = adamw_update(opt_cfg, params, grads, opt_state)
        return params, opt_state, residuals, {
            "loss": loss, "grad_norm": gnorm}

    def to_spec(tree, spec):
        return jax.tree.map(lambda _: spec, tree)

    def step(params, opt_state, residuals, batch):
        batch_specs = jax.tree.map(lambda _: P(axis), batch)
        # residuals are rank-local error-feedback state threaded through a
        # nominally-replicated spec; the replication checker must be off
        return shard_map_unchecked(
            body, mesh=mesh,
            in_specs=(to_spec(params, P()), to_spec(opt_state, P()),
                      to_spec(residuals, P()), batch_specs),
            out_specs=(to_spec(params, P()), to_spec(opt_state, P()),
                       to_spec(residuals, P()),
                       {"loss": P(), "grad_norm": P()}),
        )(params, opt_state, residuals, batch)

    return step


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
