"""Canonical suite benchmark -> BENCH_suite.json (perf trajectory).

Runs a JSON suite (default ``suites/demo.json``) through the planner on
each backend and writes one machine-readable record per (pattern, backend):
measured/modeled GB/s, attributed wall time, plus per-backend compile
counts (ExecutorCache.misses — exact) and the pallas one-launch-per-bucket
census (pallas_call primitives in each store/gather bucket executable's
jaxpr).  CI uploads the file as an artifact so the perf trajectory is
tracked across PRs; compare against the committed baseline with::

    PYTHONPATH=src python -m benchmarks.run --quick --only suite

``--quick`` scales pattern counts down (recorded in ``meta.count_cap``) so
the pallas interpret-mode grids stay small on CPU; absolute numbers are
only comparable within a matching ``meta`` block.
"""
from __future__ import annotations

import dataclasses
import json
import platform
import time

import jax
import jax.numpy as jnp

from repro.core import ExecutorCache, SuitePlan, load_suite, run_suite
from repro.core.plan import _assemble_bucket, _build_executable
from repro.core.tracing import count_primitives

from .harness import emit

DEFAULT_SUITE = "suites/demo.json"
DEFAULT_OUT = "BENCH_suite.json"
BACKENDS = ("xla", "onehot", "scalar", "pallas")


def _pallas_launch_census(plan: SuitePlan) -> list[dict]:
    """pallas_call count per bucket executable (acceptance: store == 1)."""
    rows = []
    for bucket in plan.buckets:
        spec = bucket.spec
        mode = "store" if spec.kind == "scatter" else ""
        args, _ = _assemble_bucket(plan, bucket, jnp.float32, 1, 0)
        fn = _build_executable("pallas", spec.kind, mode or "store")
        counts = count_primitives(jax.make_jaxpr(fn)(*args))
        rows.append({
            "kind": spec.kind, "idx_len": spec.idx_len,
            "footprint": spec.footprint, "batch": args[1].shape[0],
            "pallas_calls": counts.get("pallas_call", 0),
            "sort_prims": counts.get("sort", 0),
        })
    return rows


def run(runs: int = 3, *, suite: str = DEFAULT_SUITE,
        out_path: str | None = DEFAULT_OUT, count_cap: int | None = None,
        backends=BACKENDS) -> dict:
    quick = runs <= 3
    if count_cap is None:
        count_cap = 512 if quick else 0          # 0 = uncapped
    patterns = load_suite(suite)
    if count_cap:
        patterns = [dataclasses.replace(p, count=min(p.count, count_cap))
                    for p in patterns]
    plan = SuitePlan.build(patterns)

    results = []
    per_backend = {}
    for backend in backends:
        cache = ExecutorCache()
        t0 = time.perf_counter()
        stats = run_suite(patterns, backend=backend, runs=runs, cache=cache)
        wall = time.perf_counter() - t0
        per_backend[backend] = {
            "compiles": cache.misses,
            "n_buckets": stats.plan.n_buckets,
            "wall_s": wall,
            "hmean_measured_gbs": stats.hmean_gbs,
        }
        for r in stats.results:
            results.append({
                "pattern": r.pattern.name,
                "kind": r.pattern.kind,
                "type": r.pattern.classify(),
                "backend": backend,
                "measured_gbs": r.measured_gbs,
                "modeled_gbs": r.modeled_gbs,
                "time_s": r.time_s,
            })
        emit(f"suite/{backend}", wall * 1e6,
             f"{cache.misses}compiles;hmean={stats.hmean_gbs:.3f}gbs")

    doc = {
        "meta": {
            "suite": suite,
            "runs": runs,
            "count_cap": count_cap,
            "n_patterns": len(patterns),
            "n_buckets": plan.n_buckets,
            "jax": jax.__version__,
            "device": jax.devices()[0].platform,
            "host": platform.machine(),
        },
        "backends": per_backend,
        "pallas_bucket_launches": _pallas_launch_census(plan),
        "results": results,
    }
    if out_path:                       # None = CSV only, no trajectory write
        with open(out_path, "w") as f:
            json.dump(doc, f, indent=2)
        emit("suite/json", 0.0, out_path)
    return doc


if __name__ == "__main__":
    run()
