"""Decoder-LM assembly: embedding (Spatter gather), block groups, loss, decode.

Layer stacks are scanned (jax.lax.scan over stacked params) in homogeneous
*groups* so heterogeneous archs stay scannable:

    dense family:      [(L, ("dense",))]
    gemma2:            [(L/2, ("local", "global"))]
    moe (deepseek):    [(n_dense, ("dense",)), (L-n_dense, ("moe",))]
    ssm:               [(L, ("mamba",))]
    recurrentgemma:    [(12, ("rec","rec","attn_local")), (1, ("rec","rec"))]

Scan keeps the lowered HLO one-block-sized — this is what makes 61-layer ×
512-device dry-run compiles tractable, and it is also the production-grade
choice (constant compile time in depth).
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import backends as gs_backends
from repro.runtime.sharding import constrain
from . import attention as attn
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .common import (ParamDef, abstract_tree, axes_tree, init_tree, mlp_apply,
                     mlp_def, rms_norm, rms_norm_def, softcap, stack_defs)

# ---------------------------------------------------------------------------
# Embedding — the Spatter gather (vocab tables up to 256k rows)
# ---------------------------------------------------------------------------


def embed_defs(cfg) -> dict:
    d = {"table": ParamDef((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                           scale=1.0)}
    if not cfg.tie_embeddings:
        d["unembed"] = ParamDef((cfg.d_model, cfg.vocab), ("embed", "vocab"))
    return d


def embed_lookup(cfg, p: dict, tokens: jax.Array,
                 backend: str = "xla") -> jax.Array:
    """(B,S) int32 -> (B,S,d).  A row gather over the vocab table — the
    framework's highest-volume Spatter pattern (BROADCAST class when tokens
    repeat).  backend switches between core.backends implementations."""
    b, s = tokens.shape
    flat = gs_backends.gather(p["table"], tokens.reshape(-1), backend=backend)
    x = flat.reshape(b, s, cfg.d_model)
    return constrain(x, ("batch", "seq", "embed"))


def unembed_logits(cfg, p: dict, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, p["table"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, p["unembed"])
    logits = softcap(logits, cfg.logit_softcap)
    return constrain(logits, ("batch", "seq", "vocab"))


# ---------------------------------------------------------------------------
# Block registry
# ---------------------------------------------------------------------------

def _mixer_defs(cfg, kind: str) -> dict:
    if kind in ("dense", "local", "global", "attn_local"):
        return attn.mla_defs(cfg) if cfg.attn_kind == "mla" else attn.gqa_defs(cfg)
    if kind == "moe":
        return attn.mla_defs(cfg) if cfg.attn_kind == "mla" else attn.gqa_defs(cfg)
    if kind == "mamba":
        return ssm_mod.mamba_defs(cfg)
    if kind == "rec":
        return rglru_mod.rglru_defs(cfg)
    raise ValueError(kind)


def block_defs(cfg, kind: str) -> dict:
    d = {"ln1": rms_norm_def(cfg.d_model),
         "mixer": _mixer_defs(cfg, kind)}
    if kind == "mamba":
        return d    # mamba block has no separate channel-MLP
    d["ln2"] = rms_norm_def(cfg.d_model)
    if kind == "moe":
        d["mlp"] = moe_mod.moe_defs(cfg)
    elif kind == "dense" and cfg.n_dense_layers and cfg.d_ff_dense:
        d["mlp"] = mlp_def(cfg, cfg.d_model, cfg.d_ff_dense)
    else:
        d["mlp"] = mlp_def(cfg, cfg.d_model, cfg.d_ff)
    return d


def _block_window(cfg, kind: str) -> int:
    if kind in ("local", "attn_local"):
        return cfg.window
    return 0


def block_apply(cfg, kind: str, p: dict, x: jax.Array,
                positions: jax.Array, collect_cache: bool = False):
    """Returns (x', aux_loss, cache_entry_or_None)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(p["ln1"], x, cfg.norm_eps)
    cache = None
    if kind == "mamba":
        y = ssm_mod.mamba_apply(cfg, p["mixer"], h)
        return x + y, aux, None
    if kind == "rec":
        y = rglru_mod.rglru_apply(cfg, p["mixer"], h)
    elif cfg.attn_kind == "mla":
        if collect_cache:
            y, cache = attn.mla_apply_cache(cfg, p["mixer"], h, positions)
        else:
            y = attn.mla_apply(cfg, p["mixer"], h, positions)
    else:
        w = _block_window(cfg, kind)
        out = attn.gqa_apply(cfg, p["mixer"], h, positions, window=w,
                             return_kv=collect_cache)
        if collect_cache:
            y, (k, v) = out
            if w > 0:   # keep only the trailing window for local layers
                k, v = k[:, -w:], v[:, -w:]
            cache = {"k": k, "v": v}
        else:
            y = out
    x = x + y
    h2 = rms_norm(p["ln2"], x, cfg.norm_eps)
    if kind == "moe":
        y2, aux = moe_mod.moe_apply(cfg, p["mlp"], h2)
    else:
        y2 = mlp_apply(cfg, p["mlp"], h2)
    return x + y2, aux, cache


def block_decode(cfg, kind: str, p: dict, x: jax.Array, pos: jax.Array,
                 cache: Any):
    """Single-token decode through one block. Returns (x', cache')."""
    h = rms_norm(p["ln1"], x, cfg.norm_eps)
    if kind == "mamba":
        y, cache = ssm_mod.mamba_decode(cfg, p["mixer"], h, cache)
        return x + y, cache
    if kind == "rec":
        y, cache = rglru_mod.rglru_decode(cfg, p["mixer"], h, cache)
    elif cfg.attn_kind == "mla":
        y, cache = attn.mla_decode(cfg, p["mixer"], h, pos, cache)
    else:
        w = _block_window(cfg, kind)
        y, cache = attn.gqa_decode(cfg, p["mixer"], h, pos, cache, window=w)
    x = x + y
    h2 = rms_norm(p["ln2"], x, cfg.norm_eps)
    if kind == "moe":
        y2, _ = moe_mod.moe_apply(cfg, p["mlp"], h2)
    else:
        y2 = mlp_apply(cfg, p["mlp"], h2)
    return x + y2, cache


def block_init_cache(cfg, kind: str, batch: int, max_len: int, dtype):
    if kind == "mamba":
        return ssm_mod.mamba_init_cache(cfg, batch, dtype)
    if kind == "rec":
        return rglru_mod.rglru_init_cache(cfg, batch, dtype)
    if cfg.attn_kind == "mla":
        return attn.mla_init_cache(cfg, batch, max_len, dtype)
    w = _block_window(cfg, kind)
    return attn.gqa_init_cache(cfg, batch, max_len, dtype, window=w)


def block_cache_axes(cfg, kind: str):
    if kind == "mamba":
        return ssm_mod.mamba_cache_axes()
    if kind == "rec":
        return rglru_mod.rglru_cache_axes()
    if cfg.attn_kind == "mla":
        return attn.mla_cache_axes()
    return attn.gqa_cache_axes()


# ---------------------------------------------------------------------------
# Stage (group) layout per architecture family
# ---------------------------------------------------------------------------

def stage_layout(cfg) -> list[tuple[int, tuple[str, ...]]]:
    """[(n_groups, kinds_per_group), ...] — total layers must match."""
    fam = cfg.family
    if fam == "ssm":
        return [(cfg.n_layers, ("mamba",))]
    if fam == "hybrid":
        pat = cfg.block_pattern or ("rec", "rec", "attn")
        pat = tuple("attn_local" if k == "attn" else k for k in pat)
        full = cfg.n_layers // len(pat)
        rem = cfg.n_layers - full * len(pat)
        out = [(full, pat)]
        if rem:
            out.append((1, pat[:rem]))
        return out
    if fam == "moe":
        out = []
        if cfg.n_dense_layers:
            out.append((cfg.n_dense_layers, ("dense",)))
        out.append((cfg.n_layers - cfg.n_dense_layers, ("moe",)))
        return out
    if cfg.attn_kind == "local_global":
        assert cfg.n_layers % 2 == 0, "local/global alternation needs even L"
        return [(cfg.n_layers // 2, ("local", "global"))]
    return [(cfg.n_layers, ("dense",))]


def stack_stage_defs(cfg) -> dict:
    """ParamDef tree: {"stages": [{kind_i: stacked defs}], "embed", "ln_f"}.

    Every stage is scan-stacked (even count==1) so forward/decode handle all
    stages uniformly with lax.scan.
    """
    stages = []
    for count, kinds in stage_layout(cfg):
        group = {f"b{i}_{k}": block_defs(cfg, k) for i, k in enumerate(kinds)}
        stages.append(stack_defs(group, count))
    return {
        "embed": embed_defs(cfg),
        "stages": stages,
        "ln_f": rms_norm_def(cfg.d_model),
    }


def _stage_scan(cfg, kinds, stacked_params, x, positions, collect_cache):
    """Scan one homogeneous stage; optionally emit per-group caches."""
    def body(carry, group_params):
        x, aux = carry
        caches = {}
        for i, kind in enumerate(kinds):
            key = f"b{i}_{kind}"
            x, a, c = block_apply(cfg, kind, group_params[key], x, positions,
                                  collect_cache)
            # sequence-parallel residual stream: the block boundary value is
            # what scan saves for backward — shard its seq dim over "model"
            x = constrain(x, ("batch", "seq_resid", "embed"))
            if cfg.remat == "block":
                from jax.ad_checkpoint import checkpoint_name
                x = checkpoint_name(x, "block_out")
            aux = aux + a
            if collect_cache:
                caches[key] = c
        return (x, aux), (caches if collect_cache else None)

    if cfg.remat in ("block", "full"):
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable
                              if cfg.remat == "full" else
                              jax.checkpoint_policies.save_only_these_names(
                                  "block_out"))
    aux0 = (x.ravel()[0] * 0.0).astype(jnp.float32)   # vma-matched zero
    (x, aux), caches = jax.lax.scan(body, (x, aux0), stacked_params)
    return x, aux, caches


def forward(cfg, params: dict, tokens: jax.Array, *,
            img_embeds: jax.Array | None = None,
            collect_cache: bool = False, gs_backend: str = "xla"):
    """tokens (B,S) -> hidden (B,S,d) [+ caches]; aux loss accumulated."""
    x = embed_lookup(cfg, params["embed"], tokens, backend=gs_backend)
    if cfg.family == "vlm" and img_embeds is not None:
        x = jnp.concatenate([img_embeds.astype(x.dtype), x], axis=1)
    x = x * math.sqrt(cfg.d_model)
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    aux_total = jnp.zeros((), jnp.float32)
    all_caches = []
    for stage_params, (count, kinds) in zip(params["stages"],
                                            stage_layout(cfg)):
        x, aux, caches = _stage_scan(cfg, kinds, stage_params, x,
                                     positions, collect_cache)
        aux_total = aux_total + aux
        all_caches.append(caches)
    x = rms_norm(params["ln_f"], x, cfg.norm_eps)
    x = constrain(x, ("batch", "seq", "embed"))
    if collect_cache:
        return x, aux_total, all_caches
    return x, aux_total


# ---------------------------------------------------------------------------
# Loss (chunked cross-entropy: no (B,S,V) materialization)
# ---------------------------------------------------------------------------

def chunked_xent(cfg, params: dict, hidden: jax.Array, labels: jax.Array,
                 chunk: int = 512) -> jax.Array:
    """Mean token cross-entropy, scanning seq chunks of the unembedding."""
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    if s % chunk:
        chunk = s
    n = s // chunk
    hc = jnp.moveaxis(hidden.reshape(b, n, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, n, chunk), 1, 0)

    # remat: recompute each chunk's logits in backward instead of saving the
    # (B, chunk, V) f32 stack (2.1 GB x n_chunks on llama3 train_4k)
    @jax.checkpoint
    def one(carry, xs):
        h, l = xs
        logits = unembed_logits(cfg, params["embed"], h).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    # derive the carry zero from the data so its varying-axes type matches
    # under shard_map (vma system): a literal zeros(()) is axis-invariant
    zero = (hc.ravel()[0] * 0.0).astype(jnp.float32)
    total, _ = jax.lax.scan(one, zero, (hc, lc))
    return total / (b * s)


def lm_loss(cfg, params: dict, batch: dict, *, aux_weight: float = 0.01,
            gs_backend: str = "xla") -> jax.Array:
    img = batch.get("img_embeds")
    hidden, aux = forward(cfg, params, batch["tokens"], img_embeds=img,
                          gs_backend=gs_backend)
    if cfg.family == "vlm" and img is not None:
        hidden = hidden[:, img.shape[1]:]      # loss over text positions only
    loss = chunked_xent(cfg, params, hidden, batch["labels"])
    return loss + aux_weight * aux


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, dtype) -> list:
    caches = []
    for count, kinds in stage_layout(cfg):
        group = {}
        for i, kind in enumerate(kinds):
            one = block_init_cache(cfg, kind, batch, max_len, dtype)
            group[f"b{i}_{kind}"] = jax.tree.map(
                lambda t: jnp.broadcast_to(t[None], (count,) + t.shape), one)
        caches.append(group)
    return caches


def cache_axes(cfg) -> list:
    out = []
    for count, kinds in stage_layout(cfg):
        group = {}
        for i, kind in enumerate(kinds):
            ax = block_cache_axes(cfg, kind)
            group[f"b{i}_{kind}"] = jax.tree.map(
                lambda a: (None,) + a,
                ax, is_leaf=lambda v: isinstance(v, tuple) and all(
                    isinstance(e, (str, type(None))) for e in v))
        out.append(group)
    return out


def decode_step(cfg, params: dict, caches: list, tokens: jax.Array,
                pos: jax.Array, *, gs_backend: str = "xla"):
    """One decode step: tokens (B,1) + caches -> (logits (B,V), caches')."""
    x = embed_lookup(cfg, params["embed"], tokens, backend=gs_backend)
    x = x * math.sqrt(cfg.d_model)
    new_caches = []
    for stage_params, stage_cache, (count, kinds) in zip(
            params["stages"], caches, stage_layout(cfg)):
        def body(x, xs):
            gp, gc = xs
            new_gc = {}
            for i, kind in enumerate(kinds):
                key = f"b{i}_{kind}"
                x, c = block_decode(cfg, kind, gp[key], x, pos, gc[key])
                new_gc[key] = c
            return x, new_gc
        x, gc = jax.lax.scan(body, x, (stage_params, stage_cache))
        new_caches.append(gc)
    x = rms_norm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed_logits(cfg, params["embed"], x)[:, 0]
    return logits, new_caches
