"""Deterministic fault injection for spatterd (DESIGN.md §14).

Chaos testing only proves anything if the chaos is reproducible: a
``FaultInjector`` is a seeded registry of fault rules consulted at fixed
sites in the serving stack, so CI can exercise every recovery path —
compile failure, launch exception, injected latency, disk-cache
corruption, worker kill — and a failing run replays exactly from its
spec + seed.

Spec grammar (env ``SPATTERD_FAULTS`` or ``--faults``)::

    site:action:times[:arg][,site:action:times[:arg]...]

    compile:fail:1            first compile raises InjectedFault
    launch:fail:3             first three launches raise
    launch:delay:2:0.05       two launches sleep ~0.05 s (seeded jitter)
    worker:kill:1             one worker thread dies (supervisor respawns)
    disk:corrupt:1            one persisted entry is bit-flipped
    load:fail:1               the startup disk preload raises once

Sites are consulted via ``check(site)`` (which may sleep or raise) and
``mangle(site, payload)`` (the disk tier's corruption hook).  Each rule
fires at most ``times`` times; exhausted rules pass cleanly, so a test
injects exactly N faults and then observes recovery.  All decisions are
made under one lock; sleeping happens outside it.
"""
from __future__ import annotations

import dataclasses
import os
import random
import threading
import time

ENV_SPEC = "SPATTERD_FAULTS"
SITES = ("compile", "launch", "worker", "disk", "load")
ACTIONS = ("fail", "kill", "delay", "corrupt")


class InjectedFault(RuntimeError):
    """An exception raised on purpose by the fault harness."""


class WorkerKilled(InjectedFault):
    """Worker-kill flavor: escapes the item loop to kill the thread."""


@dataclasses.dataclass
class _Rule:
    site: str
    action: str
    times: int
    arg: float = 0.0
    triggered: int = 0


def _parse_rule(part: str) -> _Rule:
    bits = part.strip().split(":")
    if not 3 <= len(bits) <= 4:
        raise ValueError(f"bad fault rule {part!r}: want "
                         f"site:action:times[:arg]")
    site, action, times = bits[0], bits[1], bits[2]
    if site not in SITES:
        raise ValueError(f"unknown fault site {site!r} (sites: {SITES})")
    if action not in ACTIONS:
        raise ValueError(f"unknown fault action {action!r} "
                         f"(actions: {ACTIONS})")
    try:
        n = int(times)
    except ValueError:
        n = -1
    if n < 1:
        raise ValueError(f"fault times must be a positive int, got {times!r}")
    arg = 0.0
    if len(bits) == 4:
        try:
            arg = float(bits[3])
        except ValueError:
            raise ValueError(f"bad fault arg {bits[3]!r} in {part!r}")
    return _Rule(site=site, action=action, times=n, arg=arg)


class FaultInjector:
    """Seeded, counted fault rules consulted at fixed sites.

    Thread safe: rule selection and counters live under one lock;
    injected latency sleeps OUTSIDE it so a delay fault cannot serialize
    unrelated sites through the injector.
    """

    def __init__(self, rules, seed: int = 0):
        self._rules = list(rules)
        self.seed = int(seed)
        self._rng = random.Random(self.seed)
        self._lock = threading.Lock()
        self._consults: dict[str, int] = {}

    @classmethod
    def from_spec(cls, spec: str, seed: int = 0) -> "FaultInjector":
        rules = [_parse_rule(p) for p in spec.split(",") if p.strip()]
        return cls(rules, seed=seed)

    @classmethod
    def from_env(cls, environ=None) -> "FaultInjector | None":
        environ = os.environ if environ is None else environ
        spec = environ.get(ENV_SPEC, "").strip()
        if not spec:
            return None
        return cls.from_spec(spec, seed=int(environ.get(
            ENV_SPEC + "_SEED", "0")))

    def _arm_locked(self, site: str, actions: tuple[str, ...]) -> _Rule | None:
        # caller holds self._lock; first matching un-exhausted rule fires
        self._consults[site] = self._consults.get(site, 0) + 1
        for rule in self._rules:
            if (rule.site == site and rule.action in actions
                    and rule.triggered < rule.times):
                rule.triggered += 1
                return rule
        return None

    def check(self, site: str) -> None:
        """Consult ``site``: may sleep (delay) or raise (fail/kill)."""
        delay = 0.0
        exc = None
        with self._lock:
            rule = self._arm_locked(site, ("fail", "kill", "delay"))
            if rule is not None:
                if rule.action == "delay":
                    # seeded jitter in [0.5, 1.5) x arg: deterministic
                    # given (spec, seed, consult order)
                    delay = rule.arg * (0.5 + self._rng.random())
                elif rule.action == "kill":
                    exc = WorkerKilled(
                        f"injected kill @{site} "
                        f"({rule.triggered}/{rule.times})")
                else:
                    exc = InjectedFault(
                        f"injected fail @{site} "
                        f"({rule.triggered}/{rule.times})")
        if delay > 0.0:
            time.sleep(delay)
        if exc is not None:
            raise exc

    def mangle(self, site: str, payload: bytes) -> bytes:
        """Corruption hook (``DiskTier`` ``mangle=``): bit-flip one byte
        of ``payload`` when a ``corrupt`` rule for ``site`` fires."""
        with self._lock:
            rule = self._arm_locked(site, ("corrupt",))
        if rule is None:
            return payload
        if not payload:
            return b"\xff"
        i = len(payload) // 2
        return payload[:i] + bytes([payload[i] ^ 0xFF]) + payload[i + 1:]

    def snapshot(self) -> dict:
        """Telemetry for ``GET /stats``: spec-shaped rules + counters."""
        with self._lock:
            return {
                "seed": self.seed,
                "consults": dict(self._consults),
                "rules": [dataclasses.asdict(r) for r in self._rules],
                "triggered": sum(r.triggered for r in self._rules),
            }
