"""Paper Fig 6: % improvement of vectorized G/S over the scalar backend.

The paper compares compiler-vectorized OpenMP against `#pragma novec`;
here the vector backends are "xla" (compiler) and "onehot" (MXU matmul —
TPU-only trick) against the fori_loop "scalar" baseline (DESIGN.md §2).
"""
from __future__ import annotations

from repro.core import GSEngine, make_pattern
from .harness import emit

STRIDES = [1, 4, 16, 64]
COUNT = 1 << 10       # scalar loop is slow; keep the sweep honest but small
ONEHOT_COUNT = 128    # one-hot materializes (N, footprint); keep it small


def run(runs: int = 3):
    out = []
    for kind in ("gather", "scatter"):
        for s in STRIDES:
            res = {}
            for backend in ("scalar", "xla", "onehot"):
                count = ONEHOT_COUNT if backend == "onehot" else COUNT
                p = make_pattern(f"UNIFORM:16:{s}", kind=kind,
                                 delta=16 * s, count=count,
                                 name=f"vs-{kind}-s{s}")
                try:
                    res[backend] = GSEngine(p, backend=backend).run(
                        runs=runs).measured_gbs
                except ValueError:
                    res[backend] = float("nan")
            for vec in ("xla", "onehot"):
                imp = 100.0 * (res[vec] - res["scalar"]) / res["scalar"]
                emit(f"vector_vs_scalar/{kind}/{vec}/s{s}", 0.0,
                     f"improvement={imp:+.0f}% "
                     f"({res[vec]:.2f} vs {res['scalar']:.2f} GB/s)")
            out.append((kind, s, res))
    return out


if __name__ == "__main__":
    run()
