"""Suite execution planner: plan -> compile -> execute for pattern suites.

DESIGN NOTE (referenced from suite.py)
======================================

Problem.  ``run_suite`` used to build one ``GSEngine`` per pattern, so an
N-pattern suite paid N XLA compiles — compile time dwarfed execute time for
the paper's JSON suites (§3.3) and made streamed/repeated suite runs (the
"many scenarios per process" regime) unusable.

Plan.  ``SuitePlan.build`` groups patterns into **shape buckets**: the two
shape-bearing dims of a pattern's executable — the flattened index length
``count * index_len`` and the table ``footprint`` — are padded up to the
next power of two, and patterns whose ``(kind, padded_idx_len,
padded_footprint)`` agree share one bucket.  Pow-2 padding trades at most
2x wasted lanes for an O(log) number of distinct executable shapes.

Compile.  One executable per bucket shape: a ``jax.jit``-wrapped ``vmap``
of the single-pattern backend op (backends.gather_batched /
scatter_batched), with the pattern-batch as the mapped dim.  Executables
live in an ``ExecutorCache`` — an LRU keyed on ``(backend, kind, idx_len,
footprint, dtype, row_width, mode, batch, placement)`` — so repeated or
streamed suite runs reuse warm executables across ``run_suite`` calls.
The cache's ``misses`` counter is the compile counter: a 32-pattern suite
compiles ``n_buckets`` (< 32) executables, and a second identical run
compiles zero.

Batch polymorphism.  The pattern-batch dim itself is padded to the next
power of two (``pad_batch``), exactly like the lane dims: a bucket whose
member count drifts between streamed suite runs (31 patterns today, 29
tomorrow) keeps hitting the same padded batch, the same ``ExecKey``, and
the same traced executable — zero re-traces, where the unpadded batch dim
used to make jax silently re-trace on every membership change.  Lookup is
additionally batch-polymorphic across pow-2 brackets
(``ExecutorCache.best_batch``): a bucket whose membership *shrank* below
its old bracket reuses the smallest warm executable with a larger batch,
padding with more scratch patterns, so only genuine shape growth ever
compiles.  Because the padded batch is part of the ``ExecKey``,
``ExecutorCache.misses`` is an *exact* compile count: one cached
executable is only ever called with one input signature (each jitted
entry holds exactly one trace — asserted by tests).

Padded batch rows are scratch *patterns*: their index lanes all point at
the scratch table row, their tables/payloads are zeros, and their vmap
outputs are dropped before results are attributed — the same
can't-touch-real-data / never-in-the-numerator semantics as padded lanes.

Sharded launches.  ``run_plan(..., mesh=...)`` places every bucket launch
on a ``Placement`` — a device mesh of shape ``(batch, lane)`` with either
axis degenerate (DESIGN.md §11).  ``mesh=`` accepts an int ``N`` (batch-
only, the PR 2 behavior), a ``(b, l)`` tuple, a raw ``Mesh`` (batch-only
over ``mesh_axis``), or a ``Placement``.  The batch axis splits the
pattern-batch dim — each device runs whole patterns, the multi-device
form of the paper's §3.4 thread scaling — and the lane axis splits the
flattened lane dim *within* each pattern, the same split
``GSEngine.sharded`` applies to a single pattern, so buckets with few
members but huge lanes still fill the mesh.  Axis semantics live in ONE
rule table (``runtime.sharding.gs_specs``) shared by every sharded path.
``pad_batch`` rounds the batch up to a shard-multiple of the batch axis
and ``pad_lanes`` rounds the launched lane dim up to a shard-multiple of
the lane axis, so both splits are always even; results stay bit-identical
to the single-device launch (store-mode scatter dedup is decided by the
host keep mask *before* the lane split, so at most one write per row
survives globally).  The canonical placement string is part of the
``ExecKey`` (differently-placed executables never collide).

Execute.  Same-bucket patterns are stacked: indices into a (B_pad, N_pad)
int32 array, tables into (B_pad, F_pad + 1, R).  Row ``F_pad`` of every
table is a scratch row; padded lanes (both the lane tail up to N_pad and,
for scatters, their payload) point at it, so they can never touch real
rows, and they never enter the bandwidth numerator — ``measured_gbs`` /
``modeled_gbs`` keep exactly the paper's §3.5 useful-bytes formula.
Per-pattern buffers come from ``engine.make_host_buffers`` — the same
function ``GSEngine`` uses — so batched results are bit-identical to
per-pattern execution (asserted by tests/test_suite_plan.py on all four
backends, and by tests/test_sharded_plan.py for the sharded path).

Hot-path hygiene.  Store-mode scatter needs last-write-wins dedup; its
keep mask is a pure function of the (static) padded index buffer, so
``_assemble_bucket`` computes it once on the host (backends.keep_last_mask)
and passes it to the executable as a fourth operand — no sort or dedup
primitive ever appears in a timed executable's jaxpr (asserted by
tests/test_no_sort.py).  On the pallas backend the batched ops are
batch-NATIVE kernels (a real grid over pattern-batch x tiles with the
index buffers scalar-prefetched once) rather than jax.vmap of per-pattern
pallas_calls, and store mode is one single-pass kernel launch per bucket.

Timing attribution.  A bucket launch is timed like GSEngine.run (min over
K runs, §3.5); each member pattern is attributed wall time proportional to
its share of the bucket's *launched* pattern lanes — scratch batch rows
count in the denominator (their share belongs to padding, not to any
member), so a member's reported bandwidth is invariant to how much batch
padding the serving executable carried, and every pattern in a bucket
reports the bandwidth the launch achieved.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import threading
import time
from collections import OrderedDict
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from . import backends as B
from . import bandwidth as bw
from .engine import SCATTER_MODES, RunResult, make_host_buffers
from .pattern import Pattern


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    if n < 1:
        raise ValueError(f"need n >= 1, got {n}")
    return 1 << (n - 1).bit_length()


def _bracket_multiple(n: int, n_shards: int) -> int:
    """Smallest multiple of ``n_shards`` >= ``next_pow2(n)`` — the ONE
    padding contract both mesh axes share (``pad_batch``/``pad_lanes``),
    so the bracket-stability rule can never drift between them."""
    if n_shards < 1:
        raise ValueError(f"need n_shards >= 1, got {n_shards}")
    return math.ceil(next_pow2(n) / n_shards) * n_shards


def pad_batch(nb: int, n_shards: int = 1) -> int:
    """Padded pattern-batch dim: the smallest multiple of ``n_shards`` that
    is >= ``next_pow2(nb)`` (with ``n_shards=1`` that is exactly the next
    pow2).

    Pow-2 bracketing makes bucket executables batch-polymorphic in practice
    (member-count drift between suite runs lands on the same padded batch);
    the shard-count multiple keeps a sharded launch's batch split even.
    The shard round-up is applied ON TOP of the pow-2 bracket — never
    instead of it — so every member count in a bracket maps to ONE padded
    batch per shard count.  (The old behavior rounded ``ceil(nb/n_shards)``
    to a pow2 and could land *below* the bracket: nb=5, n_shards=3 gave 6
    while nb=7 gave 12, fragmenting the ``ExecKey.batch`` values that
    ``ExecutorCache.best_batch`` assumes are bracket-stable.)
    """
    return _bracket_multiple(nb, n_shards)


def pad_lanes(n: int, n_shards: int = 1) -> int:
    """Padded flattened-lane dim: the lane-axis twin of ``pad_batch``,
    sharing the same bracket-multiple contract (``_bracket_multiple``).

    ``BucketSpec.idx_len`` is already a pow2, so with a pow-2 lane-shard
    count this is the identity; non-pow2 lane axes (e.g. ``--mesh 2x3``)
    pad the launched lane dim up to the next shard multiple, and the
    extra lanes are ordinary padding lanes (they point at the scratch
    row and never enter the bandwidth numerator).
    """
    return _bracket_multiple(n, n_shards)


# ---------------------------------------------------------------------------
# Plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """Shape signature shared by every pattern in a bucket."""
    kind: str           # "gather" | "scatter"
    idx_len: int        # count * index_len, padded to pow2
    footprint: int      # table footprint, padded to pow2

    @staticmethod
    def of(p: Pattern) -> "BucketSpec":
        return BucketSpec(kind=p.kind,
                          idx_len=next_pow2(p.count * p.index_len),
                          footprint=next_pow2(p.footprint()))


@dataclasses.dataclass(frozen=True)
class Bucket:
    spec: BucketSpec
    members: tuple[int, ...]      # positions into the suite's pattern list


@dataclasses.dataclass(frozen=True)
class SuitePlan:
    patterns: tuple[Pattern, ...]
    buckets: tuple[Bucket, ...]

    @staticmethod
    def build(patterns: Sequence[Pattern]) -> "SuitePlan":
        groups: dict[BucketSpec, list[int]] = {}
        for i, p in enumerate(patterns):
            groups.setdefault(BucketSpec.of(p), []).append(i)
        buckets = tuple(
            Bucket(spec=spec, members=tuple(groups[spec]))
            for spec in sorted(groups,
                               key=lambda s: (s.kind, s.idx_len, s.footprint)))
        return SuitePlan(patterns=tuple(patterns), buckets=buckets)

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    def pad_waste(self, n_shards: int = 1, lane_shards: int = 1) -> float:
        """Fraction of launched lanes that are padding (0 = no waste).

        Counts lane padding (pow-2 bracket plus the ``lane_shards``
        multiple on the lane axis) and the scratch patterns added by
        batch-dim padding (``pad_batch``, including the ``n_shards``
        multiple on the batch axis).
        """
        real = sum(p.count * p.index_len for p in self.patterns)
        launched = sum(pad_lanes(b.spec.idx_len, lane_shards)
                       * pad_batch(len(b.members), n_shards)
                       for b in self.buckets)
        return 1.0 - real / max(1, launched)

    def pad_waste_for(self, placements) -> float:
        """``pad_waste`` under a per-bucket placement list (the
        ``mesh="auto"`` resolution) — each bucket pads to its own
        placement's shard multiples; ``None`` entries are unsharded."""
        real = sum(p.count * p.index_len for p in self.patterns)
        launched = 0
        for b, pl in zip(self.buckets, placements):
            bs, ls = pl.grid if pl is not None else (1, 1)
            launched += (pad_lanes(b.spec.idx_len, ls)
                         * pad_batch(len(b.members), bs))
        return 1.0 - real / max(1, launched)


# ---------------------------------------------------------------------------
# Executor cache
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExecKey:
    backend: str
    kind: str
    idx_len: int
    footprint: int
    dtype: str
    row_width: int
    mode: str           # "store" | "add" for scatter, "" for gather
    batch: int          # padded pattern-batch dim (pad_batch)
    placement: str      # Placement.placement, "" = single-device


@dataclasses.dataclass(frozen=True)
class CacheStats:
    """Point-in-time ``ExecutorCache`` counters (one consistent snapshot).

    ``misses`` is the exact compile count (see ExecutorCache).  The
    serving layer brackets each request with two snapshots and reports
    ``after.delta(before)`` — the request's own hits/misses — so a warm
    repeat request can *prove* it compiled nothing.

    ``batch_hits`` counts cross-batch (polymorphic) hits: launches served
    by a warm executable with a *larger* pattern-batch via ``best_batch``
    instead of compiling an exact-size one.  They are a subset of
    ``hits`` — each one also counts as a plain hit on the larger key.

    ``disk_hits`` counts serves satisfied by the persistent tier
    (restored AOT executables — no compile ran, so they are NOT misses:
    a warm restart proves itself with ``misses == 0``).  ``degraded``
    counts fallback compiles: a requested builder failed and the key was
    served by the ``xla`` fallback instead (DESIGN.md §14) — a subset of
    ``misses`` (the fallback did compile).
    """
    hits: int
    misses: int
    size: int
    batch_hits: int = 0
    disk_hits: int = 0
    degraded: int = 0

    def delta(self, before: "CacheStats") -> "CacheStats":
        """Elementwise difference — every field of the result is a delta
        (``size`` is net entry growth, which eviction can make negative);
        report absolute occupancy from the *after* snapshot instead."""
        return CacheStats(hits=self.hits - before.hits,
                          misses=self.misses - before.misses,
                          size=self.size - before.size,
                          batch_hits=self.batch_hits - before.batch_hits,
                          disk_hits=self.disk_hits - before.disk_hits,
                          degraded=self.degraded - before.degraded)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class _BuildFuture:
    """In-flight compile slot: the owning thread publishes the built
    executable (or the builder's exception) and every racing thread on
    the same key waits instead of building a duplicate.  ``degraded`` is
    set (before ``done``) when the owner served the fallback builder."""
    __slots__ = ("done", "fn", "exc", "degraded")

    def __init__(self):
        self.done = threading.Event()
        self.fn = None
        self.exc = None
        self.degraded = False


class ExecutorCache:
    """LRU of compiled bucket executables; ``misses`` counts compiles.

    Keys carry the full input signature (bucket shape, padded batch, and
    mesh placement), so one entry is only ever invoked with one trace:
    ``misses`` equals the number of XLA compiles performed through the
    cache, exactly.

    Thread safety: all structure mutation (the LRU order, eviction, the
    hit/miss counters) happens under one internal lock, because the
    serving daemon's request handlers share the process-wide cache from
    multiple threads.  ``builder()`` itself runs *outside* the lock with
    per-key build futures and double-checked locking: distinct keys
    compile concurrently (holding the global lock across a builder used
    to serialize every compile in the process behind a mutex meant for
    bookkeeping), while threads racing on the SAME key wait on the one
    in-flight future — a key is still built at most once and ``misses``
    never double-counts a race (the waiters count as hits: they compiled
    nothing).

    ``best_batch`` is indexed: entries are grouped by their batch-
    stripped key (``_family``), so the polymorphic lookup scans only that
    family's candidate batches instead of every cached entry under the
    lock on each bucket launch.

    ``disk`` is the optional persistent tier (core/diskcache.DiskTier,
    DESIGN.md §14): a build owner probes it before compiling (restores
    count ``disk_hits``, not ``misses``) and persists fresh non-degraded
    builds after publishing them.  ``fault_hook`` is the fault-injection
    seam: when set it is called with ``"compile"`` immediately before a
    builder runs and may raise (serve/faults.py).
    """

    def __init__(self, maxsize: int = 128, *, disk=None, fault_hook=None):
        self.maxsize = maxsize
        self.disk = disk
        self.fault_hook = fault_hook
        self._entries: OrderedDict[ExecKey, Callable] = OrderedDict()
        self._pending: dict[ExecKey, _BuildFuture] = {}
        self._families: dict[ExecKey, set[int]] = {}   # family -> batches
        self._degraded_keys: set[ExecKey] = set()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.batch_hits = 0
        self.disk_hits = 0
        self.degraded = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    @staticmethod
    def _family(key: ExecKey) -> ExecKey:
        """Batch-stripped index key (real batches are >= 1, 0 is free)."""
        return dataclasses.replace(key, batch=0)

    def _insert(self, key: ExecKey, fn: Callable) -> None:
        # caller holds self._lock
        self._entries[key] = fn
        self._families.setdefault(self._family(key), set()).add(key.batch)
        while len(self._entries) > self.maxsize:
            old, _ = self._entries.popitem(last=False)
            fam = self._family(old)
            batches = self._families.get(fam)
            if batches is not None:
                batches.discard(old.batch)
                if not batches:
                    del self._families[fam]

    def _hit_locked(self, key: ExecKey) -> Callable | None:
        # caller holds self._lock
        fn = self._entries.get(key)
        if fn is not None:
            self._entries.move_to_end(key)
            self.hits += 1
        return fn

    def _claim_locked(self, key: ExecKey) -> tuple[_BuildFuture, bool]:
        # caller holds self._lock; returns (future, this thread owns build).
        # The owner is NOT counted as a miss here: whether the claim
        # becomes a miss (builder ran) or a disk_hit (restored from the
        # persistent tier) is only known when the build resolves
        # (_await_or_build) — misses must stay the exact compile count.
        fut = self._pending.get(key)
        if fut is None:
            fut = _BuildFuture()
            self._pending[key] = fut
            return fut, True
        self.hits += 1                 # raced: that build is in flight
        return fut, False

    def _fail_build(self, key: ExecKey, fut: _BuildFuture,
                    exc: BaseException) -> None:
        fut.exc = exc
        with self._lock:
            if self._pending.get(key) is fut:
                del self._pending[key]
        fut.done.set()

    def _await_or_build(self, key: ExecKey, fut: _BuildFuture, owner: bool,
                        builder: Callable[[], Callable],
                        fallback: Callable[[], Callable] | None = None
                        ) -> tuple[Callable, bool, bool]:
        """Resolve a claimed build; returns ``(fn, compiled, degraded)``.

        Runs OUTSIDE the lock: distinct keys compile concurrently.  The
        owner first probes the disk tier (a restore compiles nothing —
        ``disk_hits``, not ``misses``), then runs ``builder`` — and on
        builder failure, ``fallback`` when given (the pallas→xla
        degradation path; the key is marked degraded so telemetry can
        flag every launch it serves).  ``compiled`` is True only when a
        builder actually ran, which is what keeps ``misses`` exact.
        """
        if not owner:
            fut.done.wait()
            if fut.exc is not None:
                raise fut.exc
            return fut.fn, False, fut.degraded
        fn = None
        degraded = False
        disk = self.disk
        if disk is not None:
            try:
                fn = disk.load(key)
            except Exception:
                fn = None
        from_disk = fn is not None
        if fn is None:
            hook = self.fault_hook
            try:
                if hook is not None:
                    hook("compile")
                fn = builder()
            except BaseException as e:
                if fallback is None:
                    self._fail_build(key, fut, e)
                    raise
                try:
                    if hook is not None:
                        hook("compile")
                    fn = fallback()
                    degraded = True
                except BaseException:
                    self._fail_build(key, fut, e)   # report the root cause
                    raise e
        with self._lock:
            # insert only if this build's claim is still current — a
            # clear() while we compiled outside the lock emptied _pending,
            # and re-inserting would desync the freshly reset counters
            # (size > 0 with misses == 0).  Counters move under the same
            # hold as the insert so a stats() snapshot never sees one
            # without the other.
            if self._pending.get(key) is fut:
                del self._pending[key]
                self._insert(key, fn)
                if from_disk:
                    self.disk_hits += 1
                else:
                    self.misses += 1   # exactly one thread ran the builder
                if degraded:
                    self.degraded += 1
                    self._degraded_keys.add(key)
        fut.degraded = degraded
        fut.fn = fn
        fut.done.set()
        # persist fresh, non-degraded builds (degradation must not become
        # sticky across restarts — §14); store failures are counted by
        # the tier and never surface here
        if disk is not None and not from_disk and not degraded:
            try:
                disk.store(key, fn, key_avals(key))
            except Exception:
                pass
        return fn, not from_disk, degraded

    def attach_disk(self, tier, preload: bool = True) -> int:
        """Adopt a persistent tier; optionally preload every verifiable
        entry into memory (a restarted daemon starts warm).  Returns the
        number of executables restored.  Deserialization runs outside
        the lock; only the inserts are serialized."""
        self.disk = tier
        if not preload:
            return 0
        restored = tier.load_all()
        n = 0
        with self._lock:
            for key, fn in restored:
                if key not in self._entries:
                    self._insert(key, fn)
                    self.disk_hits += 1
                    n += 1
        return n

    def get(self, key: ExecKey, builder: Callable[[], Callable]) -> Callable:
        with self._lock:
            fn = self._hit_locked(key)
            if fn is not None:
                return fn
            fut, owner = self._claim_locked(key)
        return self._await_or_build(key, fut, owner, builder)[0]

    def serve_poly(self, key: ExecKey, builder: Callable[[], Callable]
                   ) -> tuple[Callable, ExecKey]:
        """Batch-polymorphic fetch: ``(fn, served_key)`` where served_key
        is ``key`` or its smallest warm larger-batch sibling.

        Lookup and serve happen under ONE lock hold, so the
        ``batch_hits`` counter records only launches actually served by a
        larger warm executable (a lookup/serve race with eviction can
        neither count a phantom cross-batch hit nor compile at a stale
        larger batch), and ``misses`` stays the exact compile count.
        """
        fn, served, _, _ = self.serve_poly_info(key, builder)
        return fn, served

    def serve_poly_info(self, key: ExecKey, builder: Callable[[], Callable],
                        fallback: Callable[[], Callable] | None = None
                        ) -> tuple[Callable, ExecKey, bool, bool]:
        """``serve_poly`` plus compile attribution: ``(fn, served_key,
        compiled, degraded)`` where ``compiled`` is True iff THIS call
        claimed the key's ``_BuildFuture`` and ran a builder (a restore
        from the disk tier compiles nothing and reports False).

        Exactly one caller per compile sees ``compiled=True`` (racers on
        the same key wait on the in-flight future and see False), so a
        caller-side sum of ``compiled`` equals the cache's ``misses``
        delta exactly — the serving scheduler uses this to attribute each
        compile to the one request that claimed it, keeping per-request
        ``misses`` exact without bracketing global counters.

        ``degraded`` is True when the serve is backed by the fallback
        builder — either this call degraded, or it hit a key an earlier
        call degraded (``_degraded_keys`` remembers) — so every launch
        on a degraded executable is flagged, not just the first.
        """
        with self._lock:
            best = self._best_batch_locked(key)
            if best is not None:
                # the family index only tracks inserted entries, so under
                # this same lock hold the hit cannot fail
                fn = self._hit_locked(best)
                if fn is not None:
                    if best.batch > key.batch:
                        self.batch_hits += 1
                    return fn, best, False, best in self._degraded_keys
            fut, owner = self._claim_locked(key)
        fn, compiled, degraded = self._await_or_build(key, fut, owner,
                                                      builder, fallback)
        return fn, key, compiled, degraded

    def _best_batch_locked(self, key: ExecKey) -> ExecKey | None:
        # caller holds self._lock
        batches = self._families.get(self._family(key))
        if not batches:
            return None
        cands = [b for b in batches if b >= key.batch]
        if not cands:
            return None
        return dataclasses.replace(key, batch=min(cands))

    def best_batch(self, key: ExecKey) -> ExecKey | None:
        """Smallest cached key differing from ``key`` only by a >= batch.

        The batch-polymorphic lookup: a warm executable compiled for a
        larger pattern-batch serves a smaller bucket by padding with more
        scratch patterns, so bucket-membership shrink never compiles.
        O(candidate batches) via the family index — not O(cache size).
        Pure lookup; the serving path (``serve_poly``) counts
        ``batch_hits`` at actual serve time.
        """
        with self._lock:
            return self._best_batch_locked(key)

    def stats(self) -> CacheStats:
        """Consistent counter snapshot (one lock hold)."""
        with self._lock:
            return CacheStats(hits=self.hits, misses=self.misses,
                              size=len(self._entries),
                              batch_hits=self.batch_hits,
                              disk_hits=self.disk_hits,
                              degraded=self.degraded)

    def entries(self) -> list[tuple[ExecKey, Callable]]:
        """Read-only snapshot of ``(key, executable)`` pairs, LRU order.

        For auditors (the daemon's ``GET /lint`` walks the live cache):
        touches neither the LRU order nor the hit/miss counters, so an
        audit can never perturb the telemetry the serving layer reports.
        """
        with self._lock:
            return list(self._entries.items())

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._families.clear()
            # orphan in-flight builds: their completion sees its claim is
            # gone and skips the insert (waiters still receive the fn)
            self._pending.clear()
            self._degraded_keys.clear()
            self.hits = 0
            self.misses = 0
            self.batch_hits = 0
            self.disk_hits = 0
            self.degraded = 0


_DEFAULT_CACHE = ExecutorCache()


def default_cache() -> ExecutorCache:
    """Process-wide cache: repeated run_suite calls share warm executables."""
    return _DEFAULT_CACHE


def _raw_batched_fn(backend: str, kind: str, mode: str) -> Callable:
    """The (unjitted) bucket op — single source of truth for the bucket
    executable's signature, shared by the single-device and sharded
    builders so their operand lists can never drift apart."""
    if kind == "gather":
        def fn(src_b, idx_b):
            return B.gather_batched(src_b, idx_b, backend=backend)
    else:
        # keep is the host-precomputed last-write-wins mask over the padded
        # index buffer (unused in add mode); the traced body never sorts
        def fn(dst_b, idx_b, vals_b, keep_b):
            return B.scatter_batched(dst_b, idx_b, vals_b, mode=mode,
                                     backend=backend, keep=keep_b)
    return fn


def _build_executable(backend: str, kind: str, mode: str) -> Callable:
    return jax.jit(_raw_batched_fn(backend, kind, mode))


def _lane_body(kind: str, mode: str, lane_axis: str) -> Callable:
    """Per-device body of a lane-sharded pallas launch (DESIGN.md §16).

    Runs the batch-native Pallas kernel on the LOCAL lane shard — so
    every device executes the real kernel instead of falling back around
    an opaque ``pallas_call`` — and combines across the lane axis:

      * gather: no combine; each shard produces its own output lanes.
      * scatter-add: shards hold disjoint lanes of the same pattern, so
        partial sums ``psum`` into the full result (floating-point adds
        reassociate across the shard boundary — add mode's documented
        ~1-ulp tolerance).
      * scatter-store: the host keep mask deduped writes BEFORE the lane
        split, so globally at most one shard writes each row.  The store
        kernel's ``with_covered`` output says which rows this shard
        wrote; psum of disjoint contributions is an exact select, and
        uncovered rows keep ``dst`` — bit-identical to the single-device
        launch.

    The signature mirrors ``_raw_batched_fn`` exactly, so the lane path
    launches with the same operand list as every other placement.
    """
    from repro.kernels.gather_rows import ops as gather_ops
    from repro.kernels.scatter_rows import ops as scatter_ops

    if kind == "gather":
        def fn(src_b, idx_b):
            return gather_ops.gather_rows_batched(src_b, idx_b)
    elif mode == "add":
        def fn(dst_b, idx_b, vals_b, keep_b):
            del keep_b                       # add mode never dedups
            part = scatter_ops.scatter_add_rows_batched(
                idx_b, vals_b, dst_b.shape[1])
            return dst_b + jax.lax.psum(part, lane_axis)
    else:
        def fn(dst_b, idx_b, vals_b, keep_b):
            safe = jnp.where(keep_b, idx_b, jnp.iinfo(jnp.int32).max)
            out_l, cov_l = scatter_ops.scatter_store_rows_batched(
                jnp.zeros_like(dst_b), safe, vals_b, with_covered=True)
            covered = jax.lax.psum(cov_l, lane_axis)
            return jnp.where(covered[..., None] > 0,
                             jax.lax.psum(out_l, lane_axis), dst_b)
    return fn


# ---------------------------------------------------------------------------
# Placement: the 2-D (pattern-batch x lane) distribution layer
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Placement:
    """A device placement of shape ``(batch, lane)`` for G/S executables.

    One abstraction serves every distributed path (DESIGN.md §11): the
    batch axis splits a bucket launch's pattern-batch dim (whole patterns
    per device — the PR 2 ``ShardedExecutor``), the lane axis splits the
    flattened lane dim *within* a pattern (the paper's OpenMP-thread dim
    — ``GSEngine.sharded``), and a 2-D placement composes both.  Either
    axis may be degenerate (``None``); the axis *rules* live in
    ``runtime.sharding.gs_specs``, so no sharding policy is duplicated
    across paths.

    ``placement`` is the canonical string that feeds ``ExecKey`` —
    batch-only placements keep the PR 2 format (``data=8/8dev``) so warm
    caches stay warm; 2-D shapes read ``data=4xlane=2/8dev``.
    """
    mesh: Mesh
    batch_axis: str | None = "data"
    lane_axis: str | None = None

    def __post_init__(self):
        if self.batch_axis is None and self.lane_axis is None:
            raise ValueError("placement needs at least one mesh axis")
        if self.batch_axis == self.lane_axis:
            raise ValueError(f"batch and lane axes must differ, both "
                             f"{self.batch_axis!r}")
        for ax in (self.batch_axis, self.lane_axis):
            if ax is not None and ax not in self.mesh.axis_names:
                raise ValueError(f"mesh has no axis {ax!r} "
                                 f"(axes: {self.mesh.axis_names})")

    # -- construction --------------------------------------------------------
    @staticmethod
    def create(shape, *, batch_axis: str = "data",
               lane_axis: str = "lane") -> "Placement":
        """Build a placement (and its mesh) from a shape: an int ``N``
        (batch-only over N devices) or a ``(b, l)`` tuple.  Degenerate
        tuple dims collapse to 1-D meshes, so ``(8, 1)`` and ``8`` give
        the SAME canonical placement (and hence the same ``ExecKey``
        executables), and ``(1, 8)`` is lane-only over 8 devices.
        """
        if isinstance(shape, int):
            shape = (shape,)
        shape = tuple(int(s) for s in shape)
        if not 1 <= len(shape) <= 2 or any(s < 1 for s in shape):
            raise ValueError(f"placement shape must be N or (b, l) with "
                             f"b, l >= 1, got {shape}")
        b, l = shape[0], shape[1] if len(shape) == 2 else 1
        n_dev = len(jax.devices())
        if b * l > n_dev:
            raise ValueError(
                f"placement {b}x{l} needs {b * l} devices, have {n_dev} "
                f"(set XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{b * l} to fake devices on CPU)")
        if l == 1:
            return Placement(jax.make_mesh((b,), (batch_axis,)),
                             batch_axis=batch_axis, lane_axis=None)
        if b == 1:
            return Placement(jax.make_mesh((l,), (lane_axis,)),
                             batch_axis=None, lane_axis=lane_axis)
        return Placement(jax.make_mesh((b, l), (batch_axis, lane_axis)),
                         batch_axis=batch_axis, lane_axis=lane_axis)

    @staticmethod
    def from_mesh(mesh: Mesh, *, batch_axis: str | None = None,
                  lane_axis: str | None = None) -> "Placement":
        """Wrap an existing mesh; name which axes play which role."""
        return Placement(mesh, batch_axis=batch_axis, lane_axis=lane_axis)

    # -- geometry ------------------------------------------------------------
    @property
    def batch_shards(self) -> int:
        return self.mesh.shape[self.batch_axis] if self.batch_axis else 1

    @property
    def lane_shards(self) -> int:
        return self.mesh.shape[self.lane_axis] if self.lane_axis else 1

    @property
    def grid(self) -> tuple[int, int]:
        """(batch_shards, lane_shards) — feeds pad_batch/pad_lanes."""
        return self.batch_shards, self.lane_shards

    @property
    def placement(self) -> str:
        """Canonical ``ExecKey`` string; 1-D batch keeps the PR 2 form."""
        ndev = len(self.mesh.devices.flat)
        if self.lane_axis is None:
            return f"{self.batch_axis}={self.batch_shards}/{ndev}dev"
        if self.batch_axis is None:
            return f"lane:{self.lane_axis}={self.lane_shards}/{ndev}dev"
        return (f"{self.batch_axis}={self.batch_shards}"
                f"x{self.lane_axis}={self.lane_shards}/{ndev}dev")

    # -- executables ---------------------------------------------------------
    def shardings(self, kind: str, *, batched: bool = True):
        """(in_shardings, out_sharding) on this placement's mesh."""
        from repro.runtime.sharding import gs_specs, named_shardings
        in_specs, out_spec = gs_specs(kind, batched=batched,
                                      batch_axis=self.batch_axis,
                                      lane_axis=self.lane_axis)
        in_sh = named_shardings(self.mesh, *in_specs)
        (out_sh,) = named_shardings(self.mesh, out_spec)
        return in_sh, out_sh

    def build(self, backend: str, kind: str, mode: str) -> Callable:
        """Jit the batched bucket op with this placement's shardings.

        A pallas launch with a non-degenerate lane axis routes through
        ``compat.shard_map_unchecked`` (DESIGN.md §16): GSPMD has no
        partitioning rule for ``pallas_call``, so the GSPMD jit path
        would all-gather the lane shards and run the kernel replicated —
        the manual shard_map body instead runs the kernel on each
        device's lane shard and combines explicitly
        (``_lane_body``).  Every other placement keeps the plain GSPMD
        jit (XLA partitions its ops natively; batch-only pallas shards
        cleanly along the grid's leading dim).
        """
        in_sh, out_sh = self.shardings(kind)
        if (backend == "pallas" and self.lane_axis is not None
                and self.lane_shards > 1):
            from repro.runtime.sharding import gs_specs

            from . import compat
            in_specs, out_spec = gs_specs(kind, batched=True,
                                          batch_axis=self.batch_axis,
                                          lane_axis=self.lane_axis)
            body = compat.shard_map_unchecked(
                _lane_body(kind, mode, self.lane_axis), mesh=self.mesh,
                in_specs=tuple(in_specs), out_specs=out_spec)
            return jax.jit(body, in_shardings=in_sh, out_shardings=out_sh)
        return jax.jit(_raw_batched_fn(backend, kind, mode),
                       in_shardings=in_sh, out_shardings=out_sh)

    def place(self, kind: str, args: tuple) -> tuple:
        """Commit assembled host buffers to their launch shardings.

        Keeps the device layout transfer out of the timed region (the jit
        would otherwise reshard uncommitted arrays inside every call).
        """
        in_sh, _ = self.shardings(kind)
        return tuple(jax.device_put(a, s) for a, s in zip(args, in_sh))


def ShardedExecutor(mesh: Mesh, axis: str = "data") -> Placement:
    """Legacy PR 2 constructor: a batch-only (1-D) placement over ``axis``.

    Kept as a shim so existing callers/tests keep working; the placement
    layer (``Placement``) is the real implementation.
    """
    return Placement(mesh, batch_axis=axis, lane_axis=None)


def as_placement(mesh, mesh_axis: str = "data") -> Placement | None:
    """Normalize every accepted ``mesh=`` form to a Placement (or None).

    ``None``/``0``/empty -> None; a ``Placement`` passes through; a raw
    ``Mesh`` becomes batch-only over ``mesh_axis`` (the pre-placement
    behavior); an int ``N`` or a ``(b, l)`` tuple goes through
    ``Placement.create`` (which validates the device count).
    """
    if mesh is None or isinstance(mesh, Placement):
        return mesh
    if isinstance(mesh, Mesh):
        return Placement(mesh, batch_axis=mesh_axis, lane_axis=None)
    if isinstance(mesh, int):
        return Placement.create(mesh, batch_axis=mesh_axis) if mesh else None
    shape = tuple(mesh)
    if not shape:
        return None
    return Placement.create(shape, batch_axis=mesh_axis)


def auto_placements(plan: SuitePlan, mesh: str, *, mesh_axis: str = "data",
                    backend: str = "xla", dtype=None, row_width: int = 1):
    """Resolve ``mesh="auto"``/``"auto-suite"`` against the cost model.

    ``"auto"`` (the default auto mode) picks a placement PER BUCKET:
    each bucket's members form a single-bucket sub-plan (they re-bucket
    to the identical spec) and ``analysis.cost.select_shape`` scores the
    candidate shapes on that sub-plan alone, so a lane-heavy bucket can
    take a lane split while a member-heavy sibling in the same suite
    shards its batch dim.  Returns a per-bucket list for ``make_work``.

    ``"auto-suite"`` is the pre-PR-10 escape hatch: ONE shape for the
    whole suite (``analysis.cost.auto_placement``), returned as a single
    Placement (or None for a 1x1 choice).

    Both paths hand the cost model the launch backend: lane-sharded
    pallas placements are not charged the GSPMD all-gather replication
    bytes the shard_map path no longer moves (analysis/cost.key_cost).
    Equal shapes share one Placement object, and because the canonical
    placement string is the only placement input to ``ExecKey``, a
    bucket auto-placed at shape (b, l) hits exactly the warm cache
    entries a hand-placed ``mesh=(b, l)`` run of that bucket built.
    """
    from repro.analysis import cost as _cost
    if mesh == "auto-suite":
        shape = _cost.auto_placement(plan, dtype=dtype, row_width=row_width,
                                     backend=backend)
        return as_placement(shape, mesh_axis)
    if mesh != "auto":
        raise ValueError(f"unknown auto mesh mode {mesh!r}; "
                         f"expected 'auto' or 'auto-suite'")
    memo: dict = {}
    out = []
    for bucket in plan.buckets:
        sub = SuitePlan(
            patterns=tuple(plan.patterns[p] for p in bucket.members),
            buckets=(Bucket(spec=bucket.spec,
                            members=tuple(range(len(bucket.members)))),))
        shape = _cost.auto_placement(sub, dtype=dtype, row_width=row_width,
                                     backend=backend)
        if shape not in memo:
            memo[shape] = as_placement(shape, mesh_axis)
        out.append(memo[shape])
    return out


def placement_grid(placement: str) -> tuple[int, int, int]:
    """Parse a canonical ``ExecKey.placement`` string back to
    ``(batch_shards, lane_shards, n_devices)``; ``""`` is ``(1, 1, 1)``.

    The inverse of ``Placement.placement`` for the three canonical forms
    (``data=8/8dev``, ``lane:lane=8/8dev``, ``data=4xlane=2/8dev``),
    used by auditors that only hold an ``ExecKey`` — the live-cache lint
    reconstructs launch avals from it, and the sharding-spec-consistency
    rule checks the lowered module against exactly this grid.  A
    drift-guard test round-trips it against ``Placement`` (the canonical
    batch axis name contains no ``x``, which the 2-D split relies on).
    """
    if not placement:
        return (1, 1, 1)
    body, sep, dev = placement.rpartition("/")
    if not sep or not dev.endswith("dev"):
        raise ValueError(f"not a canonical placement string: {placement!r}")
    ndev = int(dev[:-len("dev")])
    if body.startswith("lane:"):
        return (1, int(body.split("=", 1)[1]), ndev)
    if "x" in body:
        b_part, l_part = body.split("x", 1)
        return (int(b_part.split("=", 1)[1]),
                int(l_part.split("=", 1)[1]), ndev)
    return (int(body.split("=", 1)[1]), 1, ndev)


def placement_axes(placement: str) -> dict[str, int]:
    """Parse a canonical ``ExecKey.placement`` string to its named
    mesh axes, e.g. ``"data=4xlane=2/8dev"`` -> ``{"data": 4,
    "lane": 2}``; ``""`` -> ``{}``.

    The named companion of ``placement_grid``: ``Placement.create``
    builds its Mesh with exactly the non-degenerate axes, so this is
    what a lowered shard_map's ``mesh.shape`` must equal — the
    sharding-spec-consistency rule compares the two (DESIGN.md §16).
    """
    if not placement:
        return {}
    body, sep, dev = placement.rpartition("/")
    if not sep or not dev.endswith("dev"):
        raise ValueError(f"not a canonical placement string: {placement!r}")
    if body.startswith("lane:"):
        body = body[len("lane:"):]
        parts = [body]
    elif "x" in body:
        parts = body.split("x", 1)
    else:
        parts = [body]
    out = {}
    for part in parts:
        name, _, size = part.partition("=")
        out[name] = int(size)
    return out


def bucket_key(backend: str, spec: BucketSpec, dtype, row_width: int,
               mode: str, n_members: int,
               placement: Placement | None) -> ExecKey:
    """The ``ExecKey`` a bucket launch compiles/serves under.

    Single source of truth shared by the hot path
    (``_bucket_executable``) and the static auditor
    (``enumerate_executables``): what spatterlint checks is by
    construction what the cache would build.
    """
    b_shards = placement.batch_shards if placement else 1
    return ExecKey(backend=backend, kind=spec.kind, idx_len=spec.idx_len,
                   footprint=spec.footprint, dtype=jnp.dtype(dtype).name,
                   row_width=row_width,
                   mode=mode if spec.kind == "scatter" else "",
                   batch=pad_batch(n_members, b_shards),
                   placement=placement.placement if placement else "")


def bucket_builder(backend: str, spec: BucketSpec, mode: str,
                   placement: Placement | None) -> Callable[[], Callable]:
    """Zero-arg builder for a bucket executable (what a cache miss runs).

    ``mode`` is the key's mode — already ``""`` for gathers.
    """
    if placement is not None:
        return lambda: placement.build(backend, spec.kind, mode)
    return lambda: _build_executable(backend, spec.kind, mode)


def bucket_avals(spec: BucketSpec, batch: int, lanes: int, dtype,
                 row_width: int) -> tuple:
    """Abstract launch operands for a bucket executable —
    ``jax.ShapeDtypeStruct``s mirroring ``_assemble_bucket``'s concrete
    buffers exactly (gather: table, idx; scatter: dst, idx, vals, keep),
    so an executable can be traced/lowered without materializing host
    buffers or touching devices.
    """
    dtype = jnp.dtype(dtype)
    f_pad, r = spec.footprint, row_width
    idx = jax.ShapeDtypeStruct((batch, lanes), jnp.int32)
    table = jax.ShapeDtypeStruct((batch, f_pad + 1, r), dtype)
    if spec.kind == "gather":
        return (table, idx)
    vals = jax.ShapeDtypeStruct((batch, lanes, r), dtype)
    keep = jax.ShapeDtypeStruct((batch, lanes), jnp.bool_)
    return (table, idx, vals, keep)


def key_avals(key: ExecKey) -> tuple:
    """Abstract launch operands reconstructed from an ``ExecKey`` alone.

    Every field the avals need rides in the key (that is the cache's
    one-entry-one-trace contract), so auditors and the persistence layer
    can trace/serialize an executable without its originating bucket:
    the live-cache lint (analysis/lint.py), ``DiskTier.store``, and the
    daemon's ``POST /warm`` zero-buffer calls all reconstruct from here.
    """
    _, l_shards, _ = placement_grid(key.placement)
    spec = BucketSpec(kind=key.kind, idx_len=key.idx_len,
                      footprint=key.footprint)
    return bucket_avals(spec, key.batch, pad_lanes(key.idx_len, l_shards),
                        jnp.dtype(key.dtype), key.row_width)


def enumerate_executables(plan: SuitePlan, *, backend: str = "xla",
                          dtype=jnp.float32, row_width: int = 1,
                          mode: str = "store", placement=None,
                          mesh_axis: str = "data"
                          ) -> list[tuple[ExecKey, Callable, tuple]]:
    """Every executable ``run_plan`` would ask the cache for, statically.

    Returns ``[(key, builder, avals), ...]`` — one per bucket — without
    compiling or running anything: the enumeration spatterlint audits.
    ``key``/``builder`` come from the same ``bucket_key``/
    ``bucket_builder`` the hot path uses; ``avals`` are the launch
    operands at the key's exact batch (``pad_batch`` of the member
    count — ``best_batch`` polymorphic serving can only substitute a
    *larger* warm batch of the same family, which changes no invariant a
    rule checks).  ``placement`` accepts any ``as_placement`` form, the
    auto strings (``"auto"``/``"auto-suite"``, resolved through
    ``auto_placements`` exactly as ``run_plan`` resolves them), or a
    per-bucket placement list matching ``plan.buckets`` in order.
    """
    if backend not in B.BACKENDS:
        raise ValueError(f"unknown backend {backend!r}")
    if mode not in SCATTER_MODES:
        raise ValueError(f"unknown mode {mode!r}; "
                         f"expected one of {SCATTER_MODES}")
    if isinstance(placement, str):
        placement = auto_placements(plan, placement, mesh_axis=mesh_axis,
                                    backend=backend, dtype=dtype,
                                    row_width=row_width)
    if isinstance(placement, list):
        if len(placement) != len(plan.buckets):
            raise ValueError(f"{len(placement)} placements for "
                             f"{len(plan.buckets)} buckets")
        placements = [as_placement(p, mesh_axis) for p in placement]
    else:
        placements = [as_placement(placement, mesh_axis)] * len(plan.buckets)
    out = []
    for bucket, pl_b in zip(plan.buckets, placements):
        spec = bucket.spec
        key = bucket_key(backend, spec, dtype, row_width, mode,
                         len(bucket.members), pl_b)
        _, l_shards = pl_b.grid if pl_b else (1, 1)
        lanes = pad_lanes(spec.idx_len, l_shards)
        out.append((key, bucket_builder(backend, spec, key.mode, pl_b),
                    bucket_avals(spec, key.batch, lanes, dtype, row_width)))
    return out


def _bucket_executable(cache: ExecutorCache, backend: str, spec: BucketSpec,
                       dtype, row_width: int, mode: str, n_members: int,
                       placement: Placement | None
                       ) -> tuple[Callable, int, int]:
    """Fetch (or compile) a bucket executable; returns (fn, batch, lanes).

    ``batch`` is the pattern-batch dim the executable was traced for —
    ``pad_batch`` of the member count, or the smallest warm executable's
    larger batch when one exists (``ExecutorCache.serve_poly``); callers
    must assemble the bucket at exactly that batch.  ``lanes`` is the
    launched lane dim — ``pad_lanes`` of the bucket's padded idx_len over
    the placement's lane axis.  Both are pure functions of the ``ExecKey``
    fields (``lanes`` of idx_len + placement), so one cached executable
    still holds exactly one trace and ``misses`` stays an exact compile
    count.
    """
    _, l_shards = placement.grid if placement else (1, 1)
    key = bucket_key(backend, spec, dtype, row_width, mode, n_members,
                     placement)
    builder = bucket_builder(backend, spec, key.mode, placement)
    fn, served = cache.serve_poly(key, builder)
    return fn, served.batch, pad_lanes(spec.idx_len, l_shards)


# ---------------------------------------------------------------------------
# Bucket assembly + execution
# ---------------------------------------------------------------------------

def _assemble_members(spec: BucketSpec, patterns: Sequence[Pattern],
                      dtype, row_width: int, seeds: Sequence[int],
                      batch: int | None = None, mode: str = "store",
                      lanes: int | None = None):
    """Stack member patterns (of one bucket shape) into batched buffers.

    Returns (args, real_lanes) where args feeds the bucket executable and
    real_lanes[b] is member b's un-padded lane count.  Table row F_pad is
    the scratch row every padded lane points at.  ``batch`` (>= member
    count; default ``pad_batch``) sets the padded pattern-batch dim: rows
    past the member count are scratch patterns — all-scratch indices, zero
    tables/payloads — whose outputs the callers drop.  ``lanes`` (>= the
    bucket's idx_len; default exactly it) sets the launched lane dim —
    ``pad_lanes`` hands a lane-sharded launch a shard-multiple here, and
    the extra columns are ordinary padding lanes (scratch-row indices,
    zero payloads).  ``seeds`` gives member b its host-buffer RNG seed —
    per member, because a COALESCED launch (serve/scheduler) stacks
    members from different requests whose seeds may differ; each member's
    buffers are exactly what its own serial run would assemble, which is
    why coalescing preserves bit-identity row by row.

    Scatter buckets also carry the (B_pad, N_pad) last-write-wins keep
    mask for store mode: real lanes reuse the per-pattern mask
    ``make_host_buffers`` already computed (real indices never reach the
    scratch row F_pad, so padding can't change their dedup), and of the
    padding lanes — which ALL point at F_pad — only each row's final lane
    keeps, so the single-pass store kernel's at-most-one-write-per-row
    contract holds for every row including scratch.  In add mode (and in
    gather buckets) no mask is computed; the add executable's keep
    operand is an all-False placeholder it never reads.
    """
    nb = len(patterns)
    if len(seeds) != nb:
        raise ValueError(f"{len(seeds)} seeds for {nb} members")
    b_pad = pad_batch(nb) if batch is None else batch
    if b_pad < nb:
        raise ValueError(f"batch {b_pad} < member count {nb}")
    n_pad = spec.idx_len if lanes is None else lanes
    if n_pad < spec.idx_len:
        raise ValueError(f"lanes {n_pad} < bucket idx_len {spec.idx_len}")
    f_pad, r = spec.footprint, row_width
    idx_b = np.full((b_pad, n_pad), f_pad, np.int32)       # pad -> scratch
    table_b = (np.zeros((b_pad, f_pad + 1, r), np.float32)
               if spec.kind == "gather" else None)
    vals_b = (np.zeros((b_pad, n_pad, r), np.float32)
              if spec.kind == "scatter" else None)
    keep_b = (np.zeros((b_pad, n_pad), bool)
              if spec.kind == "scatter" else None)
    store = spec.kind == "scatter" and mode == "store"
    if store:
        keep_b[:, -1] = True       # scratch row's single write (pad lanes)
    real_lanes = []
    for b, p in enumerate(patterns):
        src, abs_idx, vals, keep = make_host_buffers(p, r, seed=seeds[b])
        n = abs_idx.shape[0]
        real_lanes.append(n)
        idx_b[b, :n] = abs_idx
        if spec.kind == "gather":
            table_b[b, :src.shape[0]] = src
        else:
            vals_b[b, :n] = vals
            if store:
                keep_b[b, :n] = keep      # n == n_pad overwrites the True
    idx = jnp.asarray(idx_b)
    if spec.kind == "gather":
        return (jnp.asarray(table_b, dtype), idx), real_lanes
    dst = jnp.zeros((b_pad, f_pad + 1, r), dtype)
    return (dst, idx, jnp.asarray(vals_b, dtype),
            jnp.asarray(keep_b)), real_lanes


def _assemble_bucket(plan: SuitePlan, bucket: Bucket, dtype, row_width: int,
                     seed: int, batch: int | None = None,
                     mode: str = "store", lanes: int | None = None):
    """One-plan wrapper over ``_assemble_members`` (one seed for all
    members — the serial ``run_plan`` regime)."""
    patterns = [plan.patterns[pos] for pos in bucket.members]
    return _assemble_members(bucket.spec, patterns, dtype, row_width,
                             [seed] * len(patterns), batch=batch,
                             mode=mode, lanes=lanes)


def execute_bucket(plan: SuitePlan, bucket: Bucket, *, backend: str = "xla",
                   dtype=jnp.float32, row_width: int = 1,
                   mode: str = "store", seed: int = 0,
                   cache: ExecutorCache | None = None,
                   mesh=None,
                   mesh_axis: str = "data") -> list[np.ndarray]:
    """Run one bucket once and return per-member un-padded outputs.

    Gathers give member i its (count*index_len, R) rows; scatters give the
    (footprint, R) result table (scratch row trimmed).  ``mesh`` accepts
    any ``as_placement`` form (int, ``(b, l)`` tuple, Mesh, Placement):
    the batch axis splits the launch's pattern-batch dim, the lane axis
    the lane dim.
    """
    if mode not in SCATTER_MODES:
        raise ValueError(f"unknown mode {mode!r}; "
                         f"expected one of {SCATTER_MODES}")
    cache = cache if cache is not None else default_cache()
    placement = as_placement(mesh, mesh_axis)
    spec = bucket.spec
    fn, batch, lanes = _bucket_executable(cache, backend, spec, dtype,
                                          row_width, mode,
                                          len(bucket.members), placement)
    args, real_lanes = _assemble_bucket(plan, bucket, dtype, row_width, seed,
                                        batch=batch, mode=mode, lanes=lanes)
    if placement is not None:
        args = placement.place(spec.kind, args)
    out = np.asarray(jax.block_until_ready(fn(*args)))
    trimmed = []
    for b, pos in enumerate(bucket.members):
        if spec.kind == "gather":
            trimmed.append(out[b, :real_lanes[b]])
        else:
            trimmed.append(out[b, :plan.patterns[pos].footprint()])
    return trimmed


# ---------------------------------------------------------------------------
# Work units: the addressable request-path decomposition
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BucketWork:
    """One bucket's worth of a suite request: the addressable work unit.

    A suite decomposes into one ``BucketWork`` per bucket (``make_work``);
    each carries everything a ``launch`` needs — the member patterns, the
    execution options, the placement — with NO reference back to the
    originating plan, so work units from *different* requests can be
    stacked into one coalesced launch (serve/scheduler.py).  ``family``
    is the coalescing identity: the batch-stripped ``ExecKey`` — two work
    units with equal families (and equal ``runs``, the timing contract)
    launch the same executable family and may share a launch.

    ``dtype`` is the dtype NAME (a str) so the unit is plain data; the
    launch path re-parses it.  ``seed`` is per work unit — a coalesced
    assembly seeds each member segment with its own work's seed, so every
    member's buffers are exactly what its serial run would build.
    """
    spec: BucketSpec
    patterns: tuple[Pattern, ...]     # member patterns, bucket order
    positions: tuple[int, ...]        # members' positions in their suite
    backend: str
    dtype: str
    row_width: int
    mode: str                         # request scatter mode (store | add)
    runs: int
    seed: int
    digest: bool
    placement: Placement | None

    def __post_init__(self):
        if len(self.patterns) != len(self.positions):
            raise ValueError(f"{len(self.patterns)} patterns vs "
                             f"{len(self.positions)} positions")
        if not self.patterns:
            raise ValueError("work unit needs at least one member")

    @property
    def n_members(self) -> int:
        return len(self.patterns)

    @property
    def family(self) -> ExecKey:
        """Batch-stripped ExecKey — the coalescing identity.  Real keys
        have batch >= 1, so batch=0 can never collide with one."""
        key = bucket_key(self.backend, self.spec, jnp.dtype(self.dtype),
                         self.row_width, self.mode, self.n_members,
                         self.placement)
        return dataclasses.replace(key, batch=0)

    @property
    def real_lanes_total(self) -> int:
        """Un-padded lanes this unit contributes to a launch — what the
        scheduler budgets coalesced assembly size with."""
        return sum(p.count * p.index_len for p in self.patterns)


@dataclasses.dataclass(frozen=True)
class LaunchResult:
    """What one (possibly coalesced) bucket launch produced.

    ``real_lanes``/``out`` rows are in launch order: work unit i of the
    launch owns rows ``[offset_i, offset_i + n_members_i)`` where
    ``offset_i`` is the member count of the units before it — ``demux``
    slices a unit's rows back out with that offset.  ``compiled`` is True
    iff THIS launch claimed the executable's build
    (``ExecutorCache.serve_poly_info``): summed over launches it equals
    the cache's ``misses`` delta exactly, which is how the scheduler
    attributes each compile to one request.  ``degraded`` marks a launch
    served by the xla fallback after the requested backend's builder
    failed (DESIGN.md §14) — set on EVERY launch of a degraded
    executable, not only the one that fell back.
    """
    key: ExecKey                      # the key actually served (best_batch)
    t_bucket: float                   # min over runs (paper §3.5)
    batch: int                        # launched pattern-batch dim
    lanes: int                        # launched lane dim (pad_lanes)
    n_members: int                    # real members across all units
    real_lanes: tuple[int, ...]       # per member, launch order
    out: np.ndarray | None            # batched output (digest launches)
    compiled: bool
    degraded: bool = False


def make_work(plan: SuitePlan, *, backend: str = "xla", dtype=None,
              row_width: int = 1, runs: int = 10, mode: str = "store",
              seed: int = 0, placement=None,
              digest: bool = False) -> list[BucketWork]:
    """Decompose a suite plan into one ``BucketWork`` per bucket.

    Validates the options once (the same checks ``run_plan`` applies), so
    a work unit is always launchable as-is.  ``placement`` is one
    ``Placement | None`` for every bucket, or a per-bucket sequence of
    them (``auto_placements``'s per-bucket mode) matching
    ``plan.buckets`` in order — each work unit carries its own placement
    either way, so nothing downstream changes.
    """
    if backend not in B.BACKENDS:
        raise ValueError(f"unknown backend {backend!r}")
    if mode not in SCATTER_MODES:
        raise ValueError(f"unknown mode {mode!r}; "
                         f"expected one of {SCATTER_MODES}")
    dtype = jnp.dtype(dtype or jnp.float32)
    if isinstance(placement, (list, tuple)):
        if len(placement) != len(plan.buckets):
            raise ValueError(f"{len(placement)} placements for "
                             f"{len(plan.buckets)} buckets")
        placements = list(placement)
    else:
        placements = [placement] * len(plan.buckets)
    return [
        BucketWork(spec=bucket.spec,
                   patterns=tuple(plan.patterns[pos]
                                  for pos in bucket.members),
                   positions=bucket.members, backend=backend,
                   dtype=dtype.name, row_width=row_width, mode=mode,
                   runs=runs, seed=seed, digest=digest,
                   placement=placements[i])
        for i, bucket in enumerate(plan.buckets)
    ]


def launch(works: Sequence[BucketWork],
           cache: ExecutorCache | None = None) -> LaunchResult:
    """Execute one (possibly coalesced) bucket launch: the pure step.

    ``works`` is one or more work units sharing a ``family`` and ``runs``
    (validated here — the scheduler's coalescing contract); their member
    patterns are stacked in order into ONE padded launch whose batch is
    ``pad_batch`` of the combined member count (or a larger warm
    executable via ``serve_poly``).  With a single work unit this is
    byte-identical to the serial ``run_plan`` bucket step.

    The timed region is exactly the serial path's: one warm-up call,
    then ``runs`` timed executions (fresh zeroed dst per run for
    scatters), min-over-K.  The batched output is pulled to the host
    only when some unit wants digests.
    """
    if not works:
        raise ValueError("launch needs at least one work unit")
    w0 = works[0]
    fam, runs = w0.family, w0.runs
    for w in works[1:]:
        if w.family != fam or w.runs != runs:
            raise ValueError(
                f"cannot coalesce work units with different families/runs: "
                f"{fam}/r{runs} vs {w.family}/r{w.runs}")
    cache = cache if cache is not None else default_cache()
    spec, placement = w0.spec, w0.placement
    dtype = jnp.dtype(w0.dtype)
    _, l_shards = placement.grid if placement else (1, 1)
    n_members = sum(w.n_members for w in works)
    key = bucket_key(w0.backend, spec, dtype, w0.row_width, w0.mode,
                     n_members, placement)
    builder = bucket_builder(w0.backend, spec, key.mode, placement)
    # graceful degradation: a non-xla builder that fails to compile is
    # served by the xla builder for the SAME key, flagged degraded —
    # availability over backend fidelity (DESIGN.md §14)
    fb = (bucket_builder("xla", spec, key.mode, placement)
          if w0.backend != "xla" else None)
    fn, served, compiled, degraded = cache.serve_poly_info(key, builder, fb)
    batch, lanes = served.batch, pad_lanes(spec.idx_len, l_shards)
    patterns = [p for w in works for p in w.patterns]
    seeds = [w.seed for w in works for _ in w.patterns]
    args, real_lanes = _assemble_members(spec, patterns, dtype,
                                         w0.row_width, seeds, batch=batch,
                                         mode=w0.mode, lanes=lanes)
    if placement is not None:
        args = placement.place(spec.kind, args)
    if spec.kind == "scatter":
        dst, idx, vals, keep = args
        jax.block_until_ready(fn(dst, idx, vals, keep))    # compile & warm
        times = []
        for _ in range(runs):
            d = jnp.zeros_like(dst)
            if placement is not None:
                d = placement.place(spec.kind, (d,))[0]
            jax.block_until_ready(d)
            t0 = time.perf_counter()
            out = fn(d, idx, vals, keep)
            jax.block_until_ready(out)
            times.append(time.perf_counter() - t0)
    else:
        jax.block_until_ready(fn(*args))                   # compile & warm
        times = []
        for _ in range(runs):
            t0 = time.perf_counter()
            out = fn(*args)
            jax.block_until_ready(out)
            times.append(time.perf_counter() - t0)
    want_out = any(w.digest for w in works)
    return LaunchResult(key=served, t_bucket=min(times),   # paper §3.5
                        batch=batch, lanes=lanes, n_members=n_members,
                        real_lanes=tuple(real_lanes),
                        out=np.asarray(out) if want_out else None,
                        compiled=compiled, degraded=degraded)


def demux(result: LaunchResult, work: BucketWork,
          offset: int = 0) -> list[tuple[int, RunResult]]:
    """Slice one work unit's per-pattern results back out of a launch.

    ``offset`` is the unit's first row in the launch (sum of member
    counts of the units stacked before it; 0 for a solo launch).
    Returns ``(position, RunResult)`` pairs in the unit's member order.

    Wall time is attributed proportionally to each member's real
    (un-padded) lanes over the launch's TOTAL lanes — scratch batch rows
    count in the denominator at the launched lane width, so a member's
    reported bandwidth is invariant to batch padding, best_batch reuse,
    AND how many foreign members a coalesced launch carried: every
    member reports the bandwidth the launch achieved on its share.
    Digests hash the member's trimmed rows only, so they are a pure
    function of (pattern, seed, mode, dtype) — bit-identical between
    solo and coalesced launches.
    """
    spec = work.spec
    dtype = jnp.dtype(work.dtype)
    elem_bytes = dtype.itemsize * work.row_width
    total_lanes = (sum(result.real_lanes)
                   + (result.batch - result.n_members) * result.lanes)
    out: list[tuple[int, RunResult]] = []
    for i, pos in enumerate(work.positions):
        b = offset + i
        p = work.patterns[i]
        t_i = result.t_bucket * result.real_lanes[b] / total_lanes
        tm = bw.tpu_tile_model(p, elem_bytes)
        dg = None
        if work.digest:
            trim = (result.out[b, :result.real_lanes[b]]
                    if spec.kind == "gather"
                    else result.out[b, :p.footprint()])
            dg = hashlib.sha256(
                np.ascontiguousarray(trim).tobytes()).hexdigest()
        out.append((pos, RunResult(
            pattern=p, backend=work.backend, elem_bytes=elem_bytes,
            row_width=work.row_width, runs=work.runs, time_s=t_i,
            measured_gbs=bw.paper_bandwidth(p, t_i, elem_bytes) / 1e9,
            modeled_gbs=tm.modeled_gbs,
            tile_efficiency=tm.tile_efficiency,
            out_digest=dg,
        )))
    return out


def run_plan(plan: SuitePlan, *, backend: str = "xla", dtype=None,
             row_width: int = 1, runs: int = 10, mode: str = "store",
             seed: int = 0,
             cache: ExecutorCache | None = None,
             mesh=None,
             mesh_axis: str = "data",
             digest: bool = False) -> list[RunResult]:
    """Execute a SuitePlan with paper-style timing (min over ``runs``).

    Returns one RunResult per pattern, in the suite's original order.
    Wall time of a bucket launch is attributed to members proportionally
    to their real (un-padded) lanes.

    A thin serial driver over the work-unit pipeline: the suite
    decomposes into one ``BucketWork`` per bucket (``make_work``), each
    launches solo (``launch``), and per-pattern results demultiplex back
    out (``demux``) — the same three steps the serving scheduler runs
    concurrently with cross-request coalescing (serve/scheduler.py,
    DESIGN.md §13), so the serial and scheduled paths can never drift.

    With ``mesh`` — any ``as_placement`` form: an int N (batch-only), a
    ``(b, l)`` tuple, a raw Mesh (batch-only over ``mesh_axis``), or a
    ``Placement`` — every bucket launch is placed on the 2-D
    (pattern-batch x lane) mesh: the multi-device suite regime.
    Reported bandwidth stays the paper's useful-bytes formula over the
    *aggregate* launch: divide by the device count for per-device numbers.

    With ``digest``, each RunResult carries the sha256 of its trimmed
    computed output (``out_digest``).  The output is a pure function of
    (pattern, seed, mode, dtype) — batch padding and best_batch reuse
    never reach real rows — so equal digests across runs/processes mean
    bit-identical results; the serving layer uses this as its warm-repeat
    identity proof.

    ``mesh="auto"`` places each bucket on the shape the §15 cost model
    prefers for it (``auto_placements`` per-bucket mode);
    ``mesh="auto-suite"`` keeps the old one-shape-per-suite choice.
    """
    cache = cache if cache is not None else default_cache()
    if isinstance(mesh, str):
        placement = auto_placements(plan, mesh, mesh_axis=mesh_axis,
                                    backend=backend, dtype=dtype,
                                    row_width=row_width)
    elif isinstance(mesh, list):
        # explicit per-bucket placements — the hand-placed twin of "auto"
        placement = [as_placement(m, mesh_axis) for m in mesh]
    else:
        placement = as_placement(mesh, mesh_axis)
    works = make_work(plan, backend=backend, dtype=dtype,
                      row_width=row_width, runs=runs, mode=mode, seed=seed,
                      placement=placement, digest=digest)
    results: list[RunResult | None] = [None] * len(plan.patterns)
    for work in works:
        res = launch((work,), cache)
        for pos, r in demux(res, work):
            results[pos] = r
    return results  # type: ignore[return-value]
