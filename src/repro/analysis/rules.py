"""spatterlint rules — the invariants PRs 1–5 established, as code
(DESIGN.md §12).

Three rule scopes, one registry:

``executable`` rules see an ``ExecUnit`` — one (ExecKey, executable,
abstract launch operands) triple, with its closed jaxpr and lowered
StableHLO text computed lazily.  ``plan`` rules see a ``PlanUnit`` — a
whole-suite view (the SuitePlan, the placement grid, and a re-runnable
enumeration).  ``serve`` rules see a ``ServeUnit`` — source files of the
serving layer (the Python-``ast`` front-end, ``ast_lint``).

Rules return ``list[Violation]`` (empty = clean) and must be pure: an
audit can run against a live daemon's cache and must neither execute nor
mutate anything.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from .report import Violation

# thresholds -----------------------------------------------------------------

# pad_waste budget: the worst shipped suite x placement cell today is
# widelane @ 8x1 at ~83% (few huge-lane patterns, batch-padded 8-wide);
# 90% leaves headroom for membership drift while still catching the
# pathological cells the ROADMAP auto-placement item exists to fix.
PAD_WASTE_BUDGET = 0.90

# host-boundary primitives that must never appear in a timed executable:
# each one is a device<->host round trip inside the §3.5 timed region
HOST_BOUNDARY_PRIMS = (
    "pure_callback", "io_callback", "callback", "debug_callback",
    "host_callback", "outside_call", "device_put", "infeed", "outfeed",
)


# units ----------------------------------------------------------------------

@dataclasses.dataclass
class ExecUnit:
    """One executable under audit: the ExecKey plus lazy views of it.

    ``builder`` compiles nothing — jit wrapping is lazy — and the jaxpr/
    lowered text are traced from abstract ``avals``, so auditing a unit
    never touches a device.  ``fn`` may be pre-set (live-cache audits
    hand the cached executable over directly).  ``cached=True`` marks
    executables that live in the ExecutorCache across calls — what the
    donation rule keys on.
    """
    key: object                       # plan.ExecKey
    builder: Callable[[], Callable] | None
    avals: tuple
    fn: Callable | None = None
    cached: bool = True
    _jaxpr: object = None
    _counts: dict | None = None
    _lowered: str | None = None

    @property
    def label(self) -> str:
        k = self.key
        place = k.placement or "single"
        mode = f" {k.mode}" if k.mode else ""
        return (f"{k.backend}/{k.kind} idx={k.idx_len} fp={k.footprint} "
                f"{k.dtype} r{k.row_width}{mode} b{k.batch} @{place}")

    @property
    def executable(self) -> Callable:
        if self.fn is None:
            self.fn = self.builder()
        return self.fn

    @property
    def jaxpr(self):
        if self._jaxpr is None:
            import jax
            self._jaxpr = jax.make_jaxpr(self.executable)(*self.avals)
        return self._jaxpr

    @property
    def counts(self) -> dict:
        if self._counts is None:
            from repro.core.tracing import count_primitives
            self._counts = count_primitives(self.jaxpr)
        return self._counts

    @property
    def lowered_text(self) -> str:
        if self._lowered is None:
            self._lowered = self.executable.lower(*self.avals).as_text()
        return self._lowered


@dataclasses.dataclass
class PlanUnit:
    """A suite-level audit unit: the plan, the placement grid it would
    launch on, and a zero-arg re-enumeration of its executables.

    A ``mesh="auto"`` cell launches on per-bucket placements rather
    than one grid; ``placements`` then carries the resolved
    ``Placement | None`` list (bucket order) and ``grid`` is vestigial.
    """
    plan: object                      # plan.SuitePlan
    grid: tuple[int, int]             # (batch_shards, lane_shards)
    label: str                        # e.g. "suites/demo.json @ 4x2"
    enumerate: Callable[[], list] | None = None   # -> [(key, builder, avals)]
    placements: list | None = None    # per-bucket [Placement | None]


@dataclasses.dataclass
class ServeUnit:
    """The serving layer's source files: [(path, source), ...]."""
    files: list


# registry -------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Rule:
    name: str
    scope: str                        # "executable" | "plan" | "serve"
    doc: str
    fn: Callable

    def check(self, unit) -> list[Violation]:
        return self.fn(unit)


RULES: dict[str, Rule] = {}


def rule(name: str, scope: str):
    def deco(fn):
        if name in RULES:
            raise ValueError(f"duplicate rule {name!r}")
        RULES[name] = Rule(name=name, scope=scope,
                           doc=(fn.__doc__ or "").strip(), fn=fn)
        return fn
    return deco


def rules_for(scope: str, names=None) -> list[Rule]:
    picked = [r for r in RULES.values() if r.scope == scope]
    if names is not None:
        names = set(names)
        unknown = names - set(RULES)
        if unknown:
            raise ValueError(f"unknown rule(s): {sorted(unknown)}")
        picked = [r for r in picked if r.name in names]
    return picked


# executable-scope rules -----------------------------------------------------

@rule("no-sort-in-hot-path", scope="executable")
def _no_sort(unit: ExecUnit) -> list[Violation]:
    """No ``sort`` primitive in a timed executable (PR 3: store-mode
    dedup is a host-precomputed keep mask, never an on-device sort)."""
    n = unit.counts.get("sort", 0)
    if not n:
        return []
    from repro.core.tracing import find_primitive_eqns
    eqns = find_primitive_eqns(unit.jaxpr, ("sort",))
    return [Violation(
        rule="no-sort-in-hot-path", exec_key=unit.label,
        location=eqns[0][1] if eqns else "",
        message=(f"{n} sort primitive(s) in a timed executable — "
                 f"index preprocessing belongs on the host (§4: the "
                 f"bandwidth number times only the gather/scatter)"))]


@rule("single-pallas-call-per-bucket", scope="executable")
def _single_pallas(unit: ExecUnit) -> list[Violation]:
    """The pallas backend launches exactly ONE kernel per bucket PER
    DEVICE (PR 3's single-pass store kernel); other backends launch
    zero.  The census walks into shard_map bodies (core.tracing
    descends every sub-jaxpr), where one ``pallas_call`` equation IS
    one launch on each mesh device — so on the lane-sharded path the
    same ``count == 1`` is the per-device launch census.  Lane-sharded
    pallas keys must additionally run that launch INSIDE the shard_map
    body: a pallas_call left outside is GSPMD-routed, the replicated
    fallback the §16 manual path exists to avoid."""
    from repro.core.plan import placement_grid
    n = unit.counts.get("pallas_call", 0)
    want = 1 if unit.key.backend == "pallas" else 0
    if n != want:
        return [Violation(
            rule="single-pallas-call-per-bucket", exec_key=unit.label,
            message=(f"{n} pallas_call(s) in the jaxpr, expected {want} "
                     f"per device for backend={unit.key.backend!r}"
                     + (" — multi-launch buckets re-pay kernel dispatch "
                        "per tile pass (the pre-PR 3 masked-add + count "
                        "+ blend split)" if want == 1 else "")))]
    if want == 1 and placement_grid(unit.key.placement)[1] > 1:
        from repro.core.tracing import shard_map_pallas_calls
        inside = shard_map_pallas_calls(unit.jaxpr)
        if inside != 1:
            return [Violation(
                rule="single-pallas-call-per-bucket", exec_key=unit.label,
                message=(f"lane-sharded pallas key "
                         f"{unit.key.placement!r} but {inside} "
                         f"pallas_call(s) inside shard_map bodies "
                         f"(expected 1): the launch is GSPMD-routed, "
                         f"not the §16 manual lane split"))]
    return []


@rule("no-host-callback-or-device-put-in-timed-region", scope="executable")
def _no_host_boundary(unit: ExecUnit) -> list[Violation]:
    """No host callback / device_put / infeed inside a timed executable:
    placement transfers happen before timing (``Placement.place``),
    never inside the jitted body (PR 5)."""
    hits = [(p, unit.counts[p]) for p in HOST_BOUNDARY_PRIMS
            if unit.counts.get(p, 0)]
    if not hits:
        return []
    from repro.core.tracing import find_primitive_eqns
    eqns = find_primitive_eqns(unit.jaxpr, [p for p, _ in hits])
    return [Violation(
        rule="no-host-callback-or-device-put-in-timed-region",
        exec_key=unit.label,
        location=eqns[0][1] if eqns else "",
        message=("host-boundary primitive(s) in a timed executable: "
                 + ", ".join(f"{p} x{n}" for p, n in hits)
                 + " — each is a device<->host round trip inside the "
                   "timed region"))]


@rule("donation-honored", scope="executable")
def _donation(unit: ExecUnit) -> list[Violation]:
    """Cached executables never donate operands.  ``GSEngine.build``
    may donate its dst (fresh buffer every call), but an ExecutorCache
    entry is invoked repeatedly with held arrays — donation there is the
    PR 4 'buffer deleted or donated' crash, caught here statically."""
    if not unit.cached:
        return []
    from repro.core.tracing import hlo_stats
    n = hlo_stats(unit.lowered_text)["aliased_params"]
    if not n:
        return []
    return [Violation(
        rule="donation-honored", exec_key=unit.label,
        location=f"{n} aliased/donated operand marker(s) in lowered HLO",
        message=("cached executable donates input buffer(s): the second "
                 "call on the held operands raises 'buffer deleted or "
                 "donated' (the PR 4 repeated-run crash class)"))]


@rule("no-f64-promotion-drift", scope="executable")
def _no_f64(unit: ExecUnit) -> list[Violation]:
    """No float64 aval appears unless the ExecKey says float64: a silent
    x64 promotion doubles bytes moved and falsifies the §3.5 bandwidth
    arithmetic keyed on the declared dtype."""
    if unit.key.dtype == "float64":
        return []
    from repro.core.tracing import find_dtype_eqns
    eqns = find_dtype_eqns(unit.jaxpr, "float64")
    if not eqns:
        return []
    return [Violation(
        rule="no-f64-promotion-drift", exec_key=unit.label,
        location=eqns[0],
        message=(f"{len(eqns)} equation(s) touch float64 in an executable "
                 f"keyed dtype={unit.key.dtype} — promotion drift breaks "
                 f"the useful-bytes bandwidth formula"))]


@rule("sharding-spec-consistency", scope="executable")
def _sharding_consistency(unit: ExecUnit) -> list[Violation]:
    """The ExecKey placement string matches the lowered module: the
    partition count equals the placement's device count and some operand
    carries the ``devices=[b,l]`` tile the grid promises (PR 5's 2-D
    placement layer; a mismatch means the key lies about where the
    executable runs)."""
    from repro.core.plan import placement_grid
    from repro.core.tracing import hlo_stats
    b, l, ndev = placement_grid(unit.key.placement)
    stats = hlo_stats(unit.lowered_text)
    out = []
    if ndev == 1:
        if stats["num_partitions"] > 1:
            out.append(Violation(
                rule="sharding-spec-consistency", exec_key=unit.label,
                message=(f"single-device key but lowered module has "
                         f"num_partitions={stats['num_partitions']}")))
        return out
    if stats["num_partitions"] != ndev:
        out.append(Violation(
            rule="sharding-spec-consistency", exec_key=unit.label,
            message=(f"placement {unit.key.placement!r} promises {ndev} "
                     f"devices but the lowered module has "
                     f"num_partitions={stats['num_partitions']}")))
        return out
    tile = f"devices=[{b},{l}]"
    if not any(tile in s for s in stats["shardings"]):
        out.append(Violation(
            rule="sharding-spec-consistency", exec_key=unit.label,
            location=f"shardings seen: {sorted(stats['shardings'])[:4]}",
            message=(f"placement {unit.key.placement!r} promises tile "
                     f"{tile} but no lowered operand sharding carries it")))
    # the §16 manual path: a lane-sharded pallas executable splits its
    # axes with shard_map, and every shard_map's mesh must be exactly
    # the named axes the placement string promises — a drifted mesh
    # (wrong split, renamed axis, stale grid) would still lower and run,
    # just on the wrong decomposition
    if unit.key.backend == "pallas" and l > 1:
        from repro.core.plan import placement_axes
        from repro.core.tracing import shard_map_meshes
        want = placement_axes(unit.key.placement)
        meshes = shard_map_meshes(unit.jaxpr)
        if not meshes:
            out.append(Violation(
                rule="sharding-spec-consistency", exec_key=unit.label,
                message=(f"lane-sharded pallas key "
                         f"{unit.key.placement!r} but the jaxpr has no "
                         f"shard_map — the launch relies on GSPMD "
                         f"replication, not the §16 manual lane split")))
        for got in meshes:
            live = {k: v for k, v in got.items() if v > 1}
            if live != want:
                out.append(Violation(
                    rule="sharding-spec-consistency", exec_key=unit.label,
                    location=f"shard_map mesh: {got}",
                    message=(f"shard_map splits axes {live} but the key "
                             f"placement {unit.key.placement!r} promises "
                             f"{want}")))
    return out


@rule("canonical-exec-key", scope="executable")
def _canonical_key(unit: ExecUnit) -> list[Violation]:
    """Every cached ExecKey is in the canonical ``bucket_key`` format:
    pow-2 bucketed geometry, bracket-stable batch, parseable placement
    string, canonical dtype name, kind-consistent mode.  The coalescing
    scheduler (serve/scheduler.py) stacks concurrent requests' work
    units and re-derives the launch key from the COMBINED member count —
    this rule is the backstop proving those launches reuse the same key
    grammar as solo launches: a raw un-padded batch, a novel placement
    spelling, or a non-canonical dtype alias leaking into the cache
    would fragment the family index ``best_batch`` coalesces through
    and silently break the exact-compile-count telemetry.

    Ad-hoc units (``lint.unit_for`` wraps executables that never came
    from the planner, with zeroed geometry) are out of scope.
    """
    from repro.core.backends import BACKENDS
    from repro.core.engine import SCATTER_MODES
    from repro.core.plan import next_pow2, pad_batch, placement_grid
    k = unit.key
    if k.idx_len == 0 and k.footprint == 0 and k.batch == 0:
        return []                     # unit_for ad-hoc wrapper
    probs = []
    if k.backend not in BACKENDS:
        probs.append(f"backend {k.backend!r} not in {sorted(BACKENDS)}")
    if k.kind not in ("gather", "scatter"):
        probs.append(f"kind {k.kind!r} not gather|scatter")
    try:
        b_shards, _, _ = placement_grid(k.placement)
    except (ValueError, IndexError):
        probs.append(f"placement {k.placement!r} is not a canonical "
                     f"placement string (placement_grid cannot parse it)")
        b_shards = 1
    for name in ("idx_len", "footprint"):
        v = getattr(k, name)
        if v < 1 or next_pow2(v) != v:
            probs.append(f"{name}={v} is not pow-2 bucketed")
    if k.batch < 1 or pad_batch(k.batch, b_shards) != k.batch:
        probs.append(f"batch={k.batch} is not bracket-stable for "
                     f"{b_shards} batch shard(s) (expected "
                     f"pad_batch(batch)==batch; a coalesced launch must "
                     f"pad its combined member count)")
    import jax.numpy as jnp
    try:
        canon = jnp.dtype(k.dtype).name
    except TypeError:
        canon = None
    if canon != k.dtype:
        probs.append(f"dtype {k.dtype!r} is not the canonical dtype name"
                     + (f" ({canon!r})" if canon else ""))
    want_modes = SCATTER_MODES if k.kind == "scatter" else ("",)
    if k.kind in ("gather", "scatter") and k.mode not in want_modes:
        probs.append(f"mode {k.mode!r} invalid for kind={k.kind} "
                     f"(expected one of {want_modes})")
    return [Violation(rule="canonical-exec-key", exec_key=unit.label,
                      location=p.split(" ", 1)[0], message=p)
            for p in probs]


# plan-scope rules -----------------------------------------------------------

@rule("pad-waste-threshold", scope="plan")
def _pad_waste(unit: PlanUnit) -> list[Violation]:
    """``pad_waste(b, l)`` of a suite x placement cell stays within
    budget: pathological padding (one huge-lane pattern batch-padded
    8-wide) silently launches >90% scratch lanes — the signal the
    ROADMAP per-bucket auto-placement item needs surfaced, not buried."""
    if unit.placements is not None:           # mesh="auto": per-bucket
        b, l = "auto", "auto"
        waste = unit.plan.pad_waste_for(unit.placements)
    else:
        b, l = unit.grid
        waste = unit.plan.pad_waste(b, l)
    if waste <= PAD_WASTE_BUDGET:
        return []
    return [Violation(
        rule="pad-waste-threshold", exec_key=unit.label,
        severity="error",
        message=(f"pad_waste({b}, {l}) = {waste:.1%} exceeds the "
                 f"{PAD_WASTE_BUDGET:.0%} budget — "
                 f"{unit.plan.n_buckets} bucket(s), "
                 f"{len(unit.plan.patterns)} pattern(s); pick a smaller "
                 f"batch axis or lane-shard this suite"))]


@rule("cache-key-purity", scope="plan")
def _key_purity(unit: PlanUnit) -> list[Violation]:
    """ExecKeys are a pure function of pattern geometry + placement:
    re-enumerating the same suite yields the identical key sequence, and
    every key field is a plain str/int (an object identity — a Mesh
    repr, an id() — leaking into a key would split the cache and break
    the exact-compile-count telemetry)."""
    if unit.enumerate is None:
        return []
    out = []
    keys1 = [k for k, _, _ in unit.enumerate()]
    keys2 = [k for k, _, _ in unit.enumerate()]
    if keys1 != keys2:
        drift = next((i for i, (a, b) in enumerate(zip(keys1, keys2))
                      if a != b), min(len(keys1), len(keys2)))
        out.append(Violation(
            rule="cache-key-purity", exec_key=unit.label,
            location=f"first drift at bucket {drift}",
            message=("re-enumerating the suite produced different "
                     "ExecKeys — keys are not a pure function of "
                     "geometry + placement, so warm lookups will miss "
                     "and 'misses' stops being an exact compile count")))
    for k in keys1:
        for f in dataclasses.fields(k):
            v = getattr(k, f.name)
            if not isinstance(v, (str, int)):
                out.append(Violation(
                    rule="cache-key-purity", exec_key=unit.label,
                    location=f"{f.name}={v!r}",
                    message=(f"ExecKey.{f.name} is {type(v).__name__}, "
                             f"not str/int — unhashable or "
                             f"identity-keyed fields fragment the cache")))
            elif isinstance(v, str) and "0x" in v:
                out.append(Violation(
                    rule="cache-key-purity", exec_key=unit.label,
                    location=f"{f.name}={v!r}",
                    message=(f"ExecKey.{f.name} embeds what looks like "
                             f"an object address — keys must not depend "
                             f"on object identity")))
    return out


# cost rules (DESIGN.md §15) -------------------------------------------------

@rule("traffic-conservation", scope="executable")
def _traffic_conservation(unit: ExecUnit) -> list[Violation]:
    """Every byte in the lowered StableHLO signature is accounted for by
    the key's traffic model (useful + pad + index + table + keep), and
    vice versa: excess means redundant materialization the planner does
    not know about; deficit means the key lies about its geometry."""
    k = unit.key
    if k.idx_len == 0 and k.footprint == 0 and k.batch == 0:
        return []                      # ad-hoc unit, no planner geometry
    from repro.analysis import cost as C
    from repro.core import hlo
    uc = C.key_cost(k)
    lowered = hlo.main_io_bytes(unit.lowered_text)["total"]
    # backends that dedup without the keep operand (scalar's serial
    # writes) legitimately prune it from the lowered signature — the
    # one allowed deficit; anything else is a geometry lie
    floor = uc.io_bytes - uc.keep_bytes
    tol = max(C.TRAFFIC_TOL * uc.io_bytes, C.TRAFFIC_TOL_FLOOR)
    if lowered > uc.io_bytes + tol or lowered < floor - tol:
        kind = "unaccounted lowered traffic (redundant " \
            "materialization?)" if lowered > uc.io_bytes else \
            "key geometry overstates the lowered module"
        return [Violation(
            rule="traffic-conservation", exec_key=unit.label,
            location=f"lowered={lowered}B predicted={uc.io_bytes}B",
            message=(f"lowered I/O is {lowered} B but the key's traffic "
                     f"model predicts {uc.io_bytes} B "
                     f"(allowed deficit: the {uc.keep_bytes} B keep "
                     f"mask; tolerance {tol:.0f} B): {kind}"))]
    return []


@rule("auto-placement-sane", scope="plan")
def _auto_placement_sane(unit: PlanUnit) -> list[Violation]:
    """On suites with a recorded mesh sweep, the placement ``mesh="auto"``
    would pick must not be dominated by a recorded cell — no cell may
    beat it on *both* measured pad waste and measured GB/s beyond
    tolerance (the cost model may trade the axes, but not lose both)."""
    from repro.analysis import cost as C
    cal = C.Calibration.from_bench()
    cells = cal.sweep.get(C.suite_stem(unit.label))
    if not cells:
        return []                      # no recorded sweep: nothing to audit
    shape = C.select_shape(unit.plan, n_devices=cal.n_dev)
    name = "single" if shape == (1, 1) else f"{shape[0]}x{shape[1]}"
    chosen = cells.get(name)
    if chosen is None:
        return []                      # auto chose an unrecorded cell
    out = []
    for other_name, other in cells.items():
        if other_name == name:
            continue
        if (other["pad_waste"] < chosen["pad_waste"] - C.PAD_WASTE_TOL
                and other["hmean_gbs"] > chosen["hmean_gbs"]
                * (1 + C.GBS_TOL)):
            out.append(Violation(
                rule="auto-placement-sane", exec_key=unit.label,
                location=f"auto={name} dominated-by={other_name}",
                message=(f"auto placement {name} (pad waste "
                         f"{chosen['pad_waste']:.3f}, "
                         f"{chosen['hmean_gbs']:.4g} GB/s) is dominated "
                         f"by recorded cell {other_name} "
                         f"({other['pad_waste']:.3f}, "
                         f"{other['hmean_gbs']:.4g} GB/s) — the cost "
                         f"model disagrees with the measured sweep")))
    return out


@rule("cost-regression", scope="executable")
def _cost_regression(unit: ExecUnit) -> list[Violation]:
    """Predicted I/O bytes per executable may not grow versus the
    committed ``COST_baseline.json`` without updating it (regenerate via
    ``python -m repro.analysis --cost --write-baseline``).  Key-geometry
    only, so it also audits restored DiskTier entries."""
    k = unit.key
    if k.idx_len == 0 and k.footprint == 0 and k.batch == 0:
        return []                      # ad-hoc unit, no planner geometry
    from repro.analysis import cost as C
    baseline = C.load_baseline()
    committed = baseline.get(C.key_id(k))
    if committed is None:
        return []                      # nothing committed for this key
    predicted = C.key_cost(k).io_bytes
    if predicted > committed:
        return [Violation(
            rule="cost-regression", exec_key=unit.label,
            location=f"baseline={committed}B predicted={predicted}B",
            message=(f"predicted I/O bytes grew {committed} -> "
                     f"{predicted} vs the committed baseline — update "
                     f"COST_baseline.json (--write-baseline) if the "
                     f"growth is intended"))]
    return []


# serve-scope rules ----------------------------------------------------------

@rule("serve-lock-discipline", scope="serve")
def _serve_locks(unit: ServeUnit) -> list[Violation]:
    """Shared daemon state is mutated only under its lock (mostly-locked
    inference over repro/serve; PR 4's thread-safety contract)."""
    import ast as _ast

    from .ast_lint import check_lock_discipline
    out = []
    for path, src in unit.files:
        out.extend(check_lock_discipline(_ast.parse(src, filename=path),
                                         path))
    return out


@rule("serve-blocking-under-lock", scope="serve")
def _serve_blocking(unit: ServeUnit) -> list[Violation]:
    """No blocking I/O while holding a daemon lock (the run lock
    serializes execution; everything else must stay cheap)."""
    import ast as _ast

    from .ast_lint import check_blocking_under_lock
    out = []
    for path, src in unit.files:
        out.extend(check_blocking_under_lock(
            _ast.parse(src, filename=path), path))
    return out
