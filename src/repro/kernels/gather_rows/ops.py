"""Public jit'd wrapper for the row-gather kernel.

Picks the VMEM-resident regime for small tables and the DMA regime
otherwise, pads ragged shapes, and defaults to interpret mode off-TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel

# VMEM on v5e is ~128 MiB/core but the pipeline needs headroom; stage tables
# whole only when they take at most this many bytes.
_VMEM_TABLE_BYTES = 4 * 1024 * 1024
_DEFAULT_BLOCK_N = 8


def _should_interpret(interpret: bool | None) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("mode", "block_n", "block_d", "interpret"))
def _gather_rows(table, idx, mode: str, block_n: int, block_d: int,
                 interpret: bool):
    n = idx.shape[0]
    v, d = table.shape
    idx = idx.astype(jnp.int32)
    if mode == "vmem":
        pad = (-n) % block_n
        if pad:
            idx_p = jnp.concatenate([idx, jnp.zeros((pad,), jnp.int32)])
        else:
            idx_p = idx
        out = kernel.gather_rows_vmem(table, idx_p, block_n=block_n,
                                      interpret=interpret)
        return out[:n]
    # dma mode: pad D up to a block_d multiple
    pad_d = (-d) % block_d
    if pad_d:
        table = jnp.pad(table, ((0, 0), (0, pad_d)))
    out = kernel.gather_rows_dma(table, idx, block_d=block_d,
                                 interpret=interpret)
    return out[:, :d]


def gather_rows(table: jax.Array, idx: jax.Array, *, mode: str = "auto",
                block_n: int = _DEFAULT_BLOCK_N, block_d: int | None = None,
                interpret: bool | None = None) -> jax.Array:
    """Gather rows of ``table`` (V, D) at positions ``idx`` (N,) -> (N, D)."""
    if table.ndim != 2 or idx.ndim != 1:
        raise ValueError(f"expected (V,D) table and (N,) idx, got "
                         f"{table.shape} / {idx.shape}")
    interp = _should_interpret(interpret)
    if mode == "auto":
        table_bytes = table.size * table.dtype.itemsize
        mode = "vmem" if table_bytes <= _VMEM_TABLE_BYTES else "dma"
    if block_d is None:
        d = table.shape[1]
        block_d = d if d <= 512 else 512
        while table.shape[1] % block_d:
            block_d //= 2
            if block_d == 0:
                block_d = table.shape[1]
                break
    return _gather_rows(table, idx, mode, block_n, block_d, interp)
