"""GPipe pipeline parallelism via shard_map + lax.ppermute.

The production meshes (launch/mesh.py) default to DP x TP; for depth-bound
models at >=4 pods, pipeline parallelism splits the layer stack across a
"pipe" axis.  This implements the classic GPipe schedule:

  * each pipe-rank holds n_layers/P consecutive blocks (stacked params),
  * M microbatches stream through; rank r computes microbatch m at tick
    t = r + m, activations hop rank->rank with a single collective-permute
    per tick (the cheapest collective on a TPU torus: one hop),
  * the bubble overhead is the standard (P-1)/(M+P-1).

Reverse-mode AD through ppermute transposes to the reverse permutation, so
``jax.grad`` of a pipelined forward IS GPipe backward (fill-drain order,
same bubble) — no custom VJP needed.

Used by tests/test_pipeline.py (vs. sequential oracle) and available to
launch/train.py via --pp; the dry-run meshes stay DP x TP by default.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import axis_size, pcast, shard_map


def _pipe_body(block_fn: Callable, n_micro: int, axis: str,
               stage_params, x_stack):
    """Per-rank body. stage_params: this rank's stacked layer params
    (L/P, ...); x_stack: (M, mb, ...) microbatched inputs (replicated).
    Returns (M, mb, ...) final activations (valid on the last rank)."""
    p_rank = jax.lax.axis_index(axis)
    p_size = axis_size(axis)
    m_shape = x_stack.shape[1:]
    n_ticks = n_micro + p_size - 1

    def run_stage(carry_x):
        # apply this rank's layer block (scan over local layers)
        def one(x, lp):
            return block_fn(lp, x), None
        y, _ = jax.lax.scan(one, carry_x, stage_params)
        return y

    def tick(state, t):
        buf, outs = state          # buf: activation entering this rank
        # rank 0 ingests microbatch t (while available)
        mb_idx = jnp.clip(t, 0, n_micro - 1)
        fresh = jax.lax.dynamic_index_in_dim(x_stack, mb_idx, 0,
                                             keepdims=False)
        inp = jnp.where(p_rank == 0, fresh, buf)
        out = run_stage(inp)
        # last rank retires microbatch t - (P-1)
        retire = t - (p_size - 1)
        write_idx = jnp.clip(retire, 0, n_micro - 1)
        do_write = (p_rank == p_size - 1) & (retire >= 0)
        cur = jax.lax.dynamic_index_in_dim(outs, write_idx, 0,
                                           keepdims=False)
        new = jnp.where(do_write, out, cur)
        outs = jax.lax.dynamic_update_index_in_dim(outs, new, write_idx, 0)
        # hop activations one rank forward (ring; last->first carries junk)
        perm = [(i, (i + 1) % p_size) for i in range(p_size)]
        buf = jax.lax.ppermute(out, axis, perm)
        return (buf, outs), None

    # the carry becomes rank-varying after the first tick (axis_index,
    # ppermute); mark it varying from the start so scan types match
    buf0 = pcast(jnp.zeros(m_shape, x_stack.dtype), axis, to="varying")
    outs0 = pcast(jnp.zeros((n_micro,) + m_shape, x_stack.dtype),
                  axis, to="varying")
    (buf, outs), _ = jax.lax.scan(tick, (buf0, outs0),
                                  jnp.arange(n_ticks))
    # broadcast the last rank's outputs to every rank (replicated result)
    mask = (p_rank == p_size - 1).astype(outs.dtype)
    return jax.lax.psum(outs * mask, axis)


def pipeline_apply(mesh: Mesh, block_fn: Callable, stacked_params,
                   x: jax.Array, *, n_micro: int, axis: str = "pipe"):
    """Run x (B, ...) through n_layers of ``block_fn`` pipelined over
    ``axis``.  stacked_params leaves have leading dim n_layers (must be
    divisible by the pipe-axis size); B must be divisible by n_micro."""
    p_size = mesh.shape[axis]
    b = x.shape[0]
    assert b % n_micro == 0, (b, n_micro)
    mb = b // n_micro
    x_stack = x.reshape((n_micro, mb) + x.shape[1:])

    # params: leading layer dim sharded over the pipe axis
    param_specs = jax.tree.map(lambda _: P(axis), stacked_params)
    fn = shard_map(
        functools.partial(_pipe_body, block_fn, n_micro, axis),
        mesh=mesh,
        in_specs=(param_specs, P()),
        out_specs=P(),
    )
    out = fn(stacked_params, x_stack)
    return out.reshape((b,) + out.shape[2:])
