"""spatterd — a long-lived suite-serving daemon on the warm ExecutorCache.

The paper's value proposition is sweeping *many* configurations cheaply
(§3.3 JSON suites, §3.5 min-over-K timing); the planner PRs made a repeat
suite run compile nothing, but only inside one-shot scripts.  spatterd is
the process that makes repeated execution the product (DESIGN.md §10):
it holds the process-wide ``ExecutorCache`` open across HTTP requests, so
the FIRST identical suite request compiles ``n_buckets`` executables and
every later one — from any client — compiles zero, and each response
carries the telemetry that proves it (per-request cache hits/misses,
where ``misses`` is an exact compile count, plus per-pattern output
digests for bit-identity).

Endpoints (all JSON; stdlib ``http.server``, no dependencies):

    POST /run      run a suite (schema.SuiteRequest; bare ``suites/*.json``
                   lists work as-is).  ``mesh: N`` in the request shards
                   every bucket launch's pattern-batch dim over N devices;
                   ``mesh: [b, l]`` places launches on a 2-D (batch x
                   lane) mesh (plan.Placement, DESIGN.md §11).  503 +
                   ``Retry-After`` when the scheduler queue is full;
                   ``deadline_ms`` in the request arms a queue deadline
                   mapped to 504 when it expires before launch.
    POST /warm     prewarm: compile/restore + first-call every executable
                   a suite needs (zero-filled buffers, nothing timed) so
                   later /run requests are execute-only
    GET  /healthz  liveness + device/backend inventory + lifetime stats
    GET  /readyz   readiness, SPLIT from liveness: 503 while the disk
                   cache preload is running, the scheduler is paused, or
                   a drain is in progress — a fleet router stops routing
                   here without declaring the process dead
    GET  /cache    lifetime ExecutorCache counters
    GET  /stats    cache counters + live scheduler snapshot (queue depth,
                   worker occupancy, launch/coalesce totals, supervision
                   ledger) + fault-injection and disk-tier telemetry
    GET  /lint     spatterlint audit of the live cache's compiled
                   executables (repro.analysis, DESIGN.md §12) — the
                   report schema the --lint CLI shares

Fault tolerance (DESIGN.md §14): ``cache_dir=`` attaches a crash-safe
persistent executable tier (core/diskcache.DiskTier) preloaded on a
background thread at startup, so a restarted daemon serves previously
seen suites with ``misses == 0``; SIGTERM begins a graceful drain
(readiness flips off, queued work completes, then the port closes); and
``faults=`` arms the deterministic fault-injection registry
(serve/faults.py) whose sites thread through the cache (compile), the
scheduler (launch, worker), and the disk tier (corruption) — chaos
tests and the CI ``chaos`` job drive every recovery path through it.

Quickstart::

    PYTHONPATH=src python -m repro.serve.daemon --port 8089 &
    PYTHONPATH=src python -m repro.serve.client --url http://127.0.0.1:8089 \
        --json suites/demo.json

Concurrency model: request *handling* is multi-threaded
(``ThreadingHTTPServer`` — parsing, validation, and serialization overlap
freely), and suite *execution* goes through the coalescing work-unit
scheduler (serve/scheduler.py, DESIGN.md §13): each request decomposes
into ``BucketWork`` items on a bounded queue, worker threads batch items
sharing an ``ExecKey`` family into single padded launches, and the
handler thread blocks on its ticket.  Per-request telemetry stays exact
WITHOUT a global lock: each compile is attributed to the one launch that
claimed the executable's ``_BuildFuture``, so summed per-request
``misses`` equal the cache's lifetime compile count.  ``workers=0``
retains the PR 4 serialized path — one run lock, stats-snapshot deltas —
as the scheduling baseline ``benchmarks/bench_serve.py`` measures
against.  The cache itself is additionally lock-protected
(plan.ExecutorCache) so /cache and /healthz can read counters mid-run.
"""
from __future__ import annotations

import argparse
import json
import signal
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.core import backends as B
from repro.core.plan import ExecutorCache, SuitePlan, default_cache, make_work
from repro.core.suite import aggregate_stats, run_suite, stream_reference

from .faults import ENV_SPEC, FaultInjector
from .schema import SuiteRequest
from .scheduler import (DEFAULT_MAX_QUEUE, DEFAULT_WORKERS, DeadlineExceeded,
                        QueueFull, Scheduler, SchedulerStopped)

# how long a handler thread waits on its scheduler ticket before giving
# the client a 500 — far above any admissible suite (schema bounds runs
# and geometry), so it only fires on a genuinely wedged device
TICKET_TIMEOUT_S = 600.0

# extra wait past a request's own deadline before the handler abandons
# the ticket itself (normally a worker retires expired items first; the
# grace covers a paused or fully busy pool, where no worker ever looks)
DEADLINE_GRACE_S = 0.25


def _bounded_put(memo: dict, key, value, bound: int = 32) -> None:
    """FIFO-bounded insert: client-controlled memo keys must never grow a
    long-lived daemon's memory without limit."""
    while len(memo) >= bound:
        memo.pop(next(iter(memo)))
    memo[key] = value


class SpatterDaemon:
    """The serving process around one (usually process-wide) ExecutorCache.

    ``port=0`` binds an ephemeral port (read it back from ``.port``) —
    tests and benchmarks use that to avoid collisions.  ``start()`` serves
    from a background thread; ``serve_forever()`` blocks (the CLI path).
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 8089, *,
                 cache: ExecutorCache | None = None, quiet: bool = True,
                 workers: int = DEFAULT_WORKERS,
                 max_queue: int = DEFAULT_MAX_QUEUE,
                 cache_dir: str | None = None,
                 faults: FaultInjector | None = None):
        self.cache = cache if cache is not None else default_cache()
        self.quiet = quiet
        self.started_at = time.time()
        self.n_requests = 0
        self.faults = faults
        if faults is not None and self.cache.fault_hook is None:
            self.cache.fault_hook = faults.check
        self.disk = None
        if cache_dir:
            from repro.core.diskcache import DiskTier
            mangle = ((lambda payload: faults.mangle("disk", payload))
                      if faults is not None else None)
            self.disk = DiskTier(cache_dir, mangle=mangle)
        # readiness is NOT liveness: _ready is set once the (background)
        # disk-cache preload finishes; _draining flips on SIGTERM/stop —
        # /readyz reports 503 in either state while /healthz stays 200
        self._ready = threading.Event()
        self._draining = False
        # workers >= 1: the coalescing scheduler serves every run.
        # workers == 0: PR 4 behavior — execution serialized on _run_lock,
        # telemetry from stats-snapshot deltas — kept as the measurable
        # scheduling baseline (bench_serve) and a debugging fallback.
        self.scheduler = None if workers == 0 else Scheduler(
            self.cache, workers=workers, max_queue=max_queue, faults=faults)
        self._run_lock = threading.Lock()
        self._memo_lock = threading.Lock()     # guards _placements mutation
        self._state_lock = threading.Lock()    # guards request counters
        self._placements: dict[tuple, object] = {}   # (shape, axis) -> Placement
        self._stream_refs: dict[tuple, object] = {}   # memoized STREAM runs
        self._thread: threading.Thread | None = None
        self._httpd = ThreadingHTTPServer((host, port), _make_handler(self))
        # ThreadingHTTPServer defaults block process exit on hung handlers
        self._httpd.daemon_threads = True

    # -- lifecycle -----------------------------------------------------------
    @property
    def host(self) -> str:
        return self._httpd.server_address[0]

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def _load(self) -> None:
        """Background startup loader: preload the disk tier (restored
        executables count ``disk_hits``, never ``misses``), then flip
        readiness on.  A failing preload leaves the daemon READY but
        cold — persistence is an optimization, not a dependency."""
        try:
            if self.faults is not None:
                self.faults.check("load")
            if self.disk is not None:
                n = self.cache.attach_disk(self.disk, preload=True)
                self._log("restored %d executable(s) from %s",
                          n, self.disk.root)
        except Exception as e:
            self._log("disk-cache preload failed (serving cold): %s", e)
        finally:
            self._ready.set()

    def _start_loader(self) -> None:
        threading.Thread(target=self._load, name="spatterd-loader",
                         daemon=True).start()

    def start(self) -> "SpatterDaemon":
        self._start_loader()
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="spatterd", daemon=True)
        self._thread.start()
        return self

    def serve_forever(self) -> None:
        self._start_loader()
        self._httpd.serve_forever()

    def begin_drain(self) -> None:
        """SIGTERM entry point: flip readiness off NOW (a fleet router
        stops sending new work), then run the blocking drain on a helper
        thread — ``shutdown()`` must never run on the serving thread, and
        a signal frame interrupts exactly that thread in the CLI path."""
        self._draining = True
        threading.Thread(target=self.stop, name="spatterd-drain",
                         daemon=True).start()

    def stop(self) -> None:
        """Graceful drain: stop accepting connections, let queued and
        in-flight scheduler work finish (their handler threads still
        write responses — ``daemon_threads`` only abandons them at
        process exit), then release the port."""
        self._draining = True
        self._httpd.shutdown()
        if self.scheduler is not None:
            self.scheduler.stop(drain=True)
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None

    def __enter__(self) -> "SpatterDaemon":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- request execution ---------------------------------------------------
    def _placement(self, mesh, axis: str):
        """Placement per (shape, batch axis), memoized by shape tuple: the
        canonical placement string — not the Mesh object's identity — keys
        the ExecutorCache, but reusing the object keeps mesh/sharding
        construction out of repeat requests.  ``mesh`` is the validated
        wire value: an int N (batch-only) or a (b, l) tuple (2-D).
        Called OUTSIDE the run lock so an oversized mesh fails fast even
        while a long run is in flight; _memo_lock covers the check +
        bounded FIFO eviction + insert (concurrent handler threads)."""
        import jax
        from repro.core.plan import Placement
        shape = (mesh, 1) if isinstance(mesh, int) else tuple(mesh)
        key = (shape, axis)
        with self._memo_lock:
            if key not in self._placements:
                n_dev = len(jax.devices())
                need = shape[0] * shape[1]
                if need > n_dev:
                    raise ValueError(
                        f"mesh={mesh} needs {need} devices, {n_dev} visible "
                        f"(start the daemon under XLA_FLAGS=--xla_force_"
                        f"host_platform_device_count={need} to fake devices "
                        f"on CPU)")
                _bounded_put(self._placements, key,
                             Placement.create(shape, batch_axis=axis))
            return self._placements[key]

    def _resolve_mesh(self, req: SuiteRequest, patterns):
        """The request's placement, auto-selected when unpinned.

        An explicit ``mesh=N``/``[b, l]`` resolves exactly as before.
        ``mesh="auto-suite"`` goes through the §15 cost model for ONE
        min-predicted-traffic shape for the whole suite (the pre-PR-10
        auto behavior).  ``mesh="auto"`` — and requests that pass no
        ``mesh=`` at all — returns the literal string: the run paths
        resolve it per bucket against the built plan
        (``plan.auto_placements``, DESIGN.md §16).  Either way the
        selection only names plain (batch, lane) shapes — placement
        strings and ExecKeys are exactly what explicit ``--mesh BxL``
        requests would produce, so warm repeats stay compile-free and
        digests bit-identical.  Single device (or no traffic win from
        sharding) resolves to ``None``, the unplaced fast path.
        """
        if req.mesh == "auto-suite":
            from repro.analysis.cost import auto_placement
            shape = auto_placement(patterns, backend=req.backend,
                                   row_width=req.row_width)
            if shape is None:
                return None
            return self._placement(tuple(shape), req.mesh_axis)
        if req.mesh == "auto" or not req.mesh:
            return "auto"
        return self._placement(req.mesh, req.mesh_axis)

    def _stream_ref_for(self, req: SuiteRequest):
        """Memoized STREAM reference RunResult for a stream_r request.

        The reference is its own jitted engine, outside the
        ExecutorCache; memoizing its RunResult means only the FIRST
        stream_r request per (backend, n, runs) compiles and times it —
        warm requests stay execute-only, keeping the misses==0
        warm-repeat proof honest.  Cold references run outside any lock
        (two racing cold requests may both compute one; the memo keeps
        whichever lands last — identical inputs, so nothing drifts).
        """
        skey = (req.backend, req.stream_n, req.runs)
        with self._memo_lock:
            ref = self._stream_refs.get(skey)
        if ref is None:
            ref = stream_reference(n=req.stream_n, runs=req.runs,
                                   backend=req.backend)
            with self._memo_lock:
                _bounded_put(self._stream_refs, skey, ref)
        return ref

    def run_request(self, req: SuiteRequest) -> dict:
        """Execute one validated request; returns the response document.

        Raises ValueError for request-shaped problems (bad pattern entry,
        mesh larger than the device count) — the handler maps those to
        400s — ``QueueFull``/``SchedulerStopped`` for backpressure (503),
        ``DeadlineExceeded`` for an expired ``deadline_ms`` (504), and
        lets genuine execution failures propagate to a 500.
        """
        # block until the startup disk preload finished: serving a known
        # suite cold while its warm executables are still deserializing
        # would break the warm-restart misses==0 proof
        self._ready.wait(TICKET_TIMEOUT_S)
        # request-shaped failures (bad patterns, oversized mesh) resolve
        # BEFORE any queueing: a 400 never occupies a queue slot
        patterns = req.build_patterns()
        mesh = self._resolve_mesh(req, patterns)
        if self.scheduler is None:
            doc = self._run_serial(req, patterns, mesh)
        else:
            doc = self._run_scheduled(req, patterns, mesh)
        with self._state_lock:
            self.n_requests += 1
        return doc

    def _run_scheduled(self, req: SuiteRequest, patterns, mesh) -> dict:
        """Submit the request's work units to the scheduler and wait.

        ``elapsed_s`` covers submit -> resolve, so it INCLUDES queue
        wait (reported separately as ``serve.queued_ms``) — under
        concurrency that is the latency the client actually saw.

        A request ``deadline_ms`` arms a scheduler queue deadline:
        normally a worker retires expired items (``DeadlineExceeded``
        resolves the ticket); if no worker ever looks (paused/wedged
        pool) the handler gives up itself after a grace period and
        CANCELS the ticket, so the expired work is removed from the
        queue — either way nothing launches after expiry and the client
        gets a 504.  Any ticket abandoned by timeout is cancelled too
        (the abandoned-ticket fix: workers must not launch work whose
        handler — and therefore client — is gone).
        """
        t0 = time.perf_counter()
        stream_ref = self._stream_ref_for(req) if req.stream_r else None
        plan = SuitePlan.build(patterns)
        if isinstance(mesh, str):          # "auto": per-bucket cost model
            from repro.core.plan import auto_placements
            mesh = auto_placements(plan, mesh, mesh_axis=req.mesh_axis,
                                   backend=req.backend,
                                   row_width=req.row_width)
        works = make_work(plan, backend=req.backend, runs=req.runs,
                          row_width=req.row_width, mode=req.mode,
                          seed=req.seed, placement=mesh, digest=req.digest)
        deadline_s = req.deadline_ms / 1e3 if req.deadline_ms else None
        ticket = self.scheduler.submit(works, deadline_s=deadline_s)
        wait_s = (TICKET_TIMEOUT_S if deadline_s is None
                  else min(TICKET_TIMEOUT_S, deadline_s + DEADLINE_GRACE_S))
        try:
            ticket.wait(wait_s)
        except TimeoutError:
            self.scheduler.cancel(ticket)
            if deadline_s is not None:
                raise DeadlineExceeded(
                    f"deadline_ms={req.deadline_ms} expired before the "
                    f"request's work launched") from None
            raise
        results = [ticket.results[i] for i in range(len(patterns))]
        stats = aggregate_stats(results, metric=req.metric, plan=plan,
                                stream_ref=stream_ref)
        return self._response(req, stats, mesh,
                              hits=ticket.hits, misses=ticket.misses,
                              serve=ticket.telemetry(),
                              elapsed_s=time.perf_counter() - t0)

    def _run_serial(self, req: SuiteRequest, patterns, mesh) -> dict:
        """PR 4 baseline path (``workers=0``): one run lock, telemetry
        from cache-stats snapshot deltas bracketing the run."""
        with self._run_lock:
            # timed inside the lock: elapsed_s is THIS request's
            # execution, not time spent queued behind other requests
            t0 = time.perf_counter()
            stream_ref = self._stream_ref_for(req) if req.stream_r else None
            before = self.cache.stats()
            stats = run_suite(
                patterns, backend=req.backend, runs=req.runs,
                row_width=req.row_width, metric=req.metric, mode=req.mode,
                seed=req.seed, cache=self.cache, mesh=mesh,
                mesh_axis=req.mesh_axis, stream_r=req.stream_r,
                stream_n=req.stream_n, stream_ref=stream_ref,
                digest=req.digest)
            after = self.cache.stats()
        delta = after.delta(before)
        return self._response(req, stats, mesh,
                              hits=delta.hits, misses=delta.misses,
                              serve=None,
                              elapsed_s=time.perf_counter() - t0)

    def _response(self, req: SuiteRequest, stats, mesh, *, hits: int,
                  misses: int, serve: dict | None,
                  elapsed_s: float) -> dict:
        # the serial path hands the unresolved "auto" string through
        # (run_suite resolved its own copy); re-resolve here for
        # reporting — the per-bucket selection is a pure function of
        # (plan, backend, row_width, devices), so this names exactly the
        # placements the run used
        if isinstance(mesh, str):
            from repro.core.plan import auto_placements
            mesh = auto_placements(stats.plan, mesh,
                                   mesh_axis=req.mesh_axis,
                                   backend=req.backend,
                                   row_width=req.row_width)
        if isinstance(mesh, list):
            pad_waste = stats.plan.pad_waste_for(mesh)
            placement = [m.placement if m is not None else "single"
                         for m in mesh]
        else:
            pad_waste = stats.plan.pad_waste(
                *(mesh.grid if mesh is not None else (1, 1)))
            placement = mesh.placement if mesh is not None else "single"
        return {
            "ok": True,
            "stats": stats.to_json(req.metric),
            "cache": {
                # this request's traffic; misses == exact compile count
                # (attributed per launch on the scheduler path)
                "hits": hits,
                "misses": misses,
                "size": self.cache.stats().size,
                "lifetime": self.cache.stats().to_json(),
            },
            "plan": {
                "n_buckets": stats.plan.n_buckets,
                # the plan's static padding waste at exact-fit batches — a
                # lower bound when best_batch serves a larger warm
                # executable (member bandwidth attribution already uses
                # the actual launched batch, plan.run_plan)
                "pad_waste": pad_waste,
                # the placement(s) actually used — for mesh="auto" (and
                # unpinned requests) a per-bucket list of the cost
                # model's choices, in bucket order
                "placement": placement,
            },
            # scheduler telemetry: queued_ms, launches, coalesced_launches
            # (null on the workers=0 baseline path)
            "serve": serve,
            "elapsed_s": elapsed_s,
        }

    def warm(self, req: SuiteRequest) -> dict:
        """POST /warm: make every executable the suite needs hot.

        For each bucket executable the request's plan implies, serve it
        through the cache (disk restore > compile, with the same
        pallas→xla degradation the run path gets) and then CALL it once
        on zero-filled buffers — an AOT-compiled ``fn.lower().compile()``
        alone would not populate the jit dispatch cache, so the first
        real request would still pay tracing overhead.  Zero buffers are
        safe for both kinds: a gather reads row 0, a scatter's all-False
        keep mask writes nothing.  Nothing is timed and no results are
        produced; later /run requests are execute-only.
        """
        import jax
        import jax.numpy as jnp

        from repro.core.plan import (bucket_builder, enumerate_executables,
                                     key_avals)
        t0 = time.perf_counter()
        self._ready.wait(TICKET_TIMEOUT_S)
        patterns = req.build_patterns()
        mesh = self._resolve_mesh(req, patterns)
        plan = SuitePlan.build(patterns)
        if isinstance(mesh, str):          # "auto": per-bucket cost model
            from repro.core.plan import auto_placements
            mesh = auto_placements(plan, mesh, mesh_axis=req.mesh_axis,
                                   backend=req.backend,
                                   row_width=req.row_width)
        placements = (mesh if isinstance(mesh, list)
                      else [mesh] * len(plan.buckets))
        units = enumerate_executables(plan, backend=req.backend,
                                      row_width=req.row_width, mode=req.mode,
                                      placement=mesh)
        before = self.cache.stats()
        compiled = 0
        for bucket, pl_b, (key, builder, _) in zip(plan.buckets, placements,
                                                   units):
            fb = (bucket_builder("xla", bucket.spec, key.mode, pl_b)
                  if req.backend != "xla" else None)
            fn, served, built, _ = self.cache.serve_poly_info(key, builder,
                                                              fb)
            compiled += bool(built)
            # first-call at the SERVED batch (best_batch may be larger)
            args = tuple(jnp.zeros(a.shape, a.dtype)
                         for a in key_avals(served))
            if pl_b is not None:
                args = pl_b.place(key.kind, args)
            jax.block_until_ready(fn(*args))
        delta = self.cache.stats().delta(before)
        with self._state_lock:
            self.n_requests += 1
        return {
            "ok": True,
            "n_executables": len(units),
            "compiled": compiled,
            "cache": {"hits": delta.hits, "misses": delta.misses,
                      "disk_hits": delta.disk_hits,
                      "degraded": delta.degraded,
                      "lifetime": self.cache.stats().to_json()},
            "elapsed_s": time.perf_counter() - t0,
        }

    def readiness(self) -> dict:
        """GET /readyz: can this process take NEW traffic right now?

        Distinct from /healthz liveness — a loading, paused, or draining
        daemon is alive (health 200) but not ready (503), which is what
        a fleet router needs to stop routing without killing the pod.
        """
        snap = (self.scheduler.snapshot()
                if self.scheduler is not None else None)
        loading = not self._ready.is_set()
        paused = bool(snap and snap["paused"])
        draining = self._draining or bool(snap and snap["stopping"])
        ready = not (loading or paused or draining)
        return {"ok": ready, "ready": ready, "loading": loading,
                "paused": paused, "draining": draining}

    def stats(self) -> dict:
        """GET /stats: lifetime cache counters + live scheduler state +
        fault-injection and disk-tier telemetry."""
        return {
            "ok": True,
            "n_requests": self.n_requests,
            "uptime_s": time.time() - self.started_at,
            "cache": self.cache.stats().to_json(),
            # null when running the workers=0 serialized baseline
            "scheduler": (self.scheduler.snapshot()
                          if self.scheduler is not None else None),
            "disk": self.disk.stats() if self.disk is not None else None,
            "faults": (self.faults.snapshot()
                       if self.faults is not None else None),
        }

    def lint(self) -> dict:
        """Static audit of every compiled executable in the live cache.

        Runs the executable-scope spatterlint rules against each cached
        ExecKey, reconstructing launch avals from the key alone — so the
        audit also proves the keys describe their executables honestly.
        Read-only (``ExecutorCache.entries``): it can run mid-request
        without perturbing the hits/misses telemetry, and it takes no
        lock the run path needs.
        """
        from repro.analysis.lint import lint_cache
        report = lint_cache(self.cache)
        return {"ok": report.ok, "report": report.to_json()}

    def cost(self) -> dict:
        """GET /cost: static traffic accounting of the live cache.

        Every cached ExecKey gets the §15 per-unit byte split (useful
        terms need the plan, so a bare key reports launch geometry
        only), reconciled against its lowered StableHLO signature
        (``traffic-conservation``) and the committed byte baseline
        (``cost-regression``).  Restored DiskTier entries are opaque
        exported calls — they degrade to key-geometry terms and the
        key-only rules, exactly like ``GET /lint``'s downgrade.
        Read-only, same as ``lint``.
        """
        from repro.analysis.cost import cost_cache
        report = cost_cache(self.cache)
        return {"ok": report.ok, "report": report.to_json()}

    def health(self) -> dict:
        import jax
        return {
            "ok": True,
            "service": "spatterd",
            "n_devices": len(jax.devices()),
            "backends": sorted(B.BACKENDS),
            "n_requests": self.n_requests,
            "uptime_s": time.time() - self.started_at,
            "cache": self.cache.stats().to_json(),
        }

    def _log(self, fmt: str, *args) -> None:
        if not self.quiet:
            print(f"spatterd: {fmt % args}", flush=True)


MAX_BODY_BYTES = 64 << 20     # one request can't OOM a long-lived daemon


def _make_handler(daemon: SpatterDaemon):
    class Handler(BaseHTTPRequestHandler):
        server_version = "spatterd/1.0"
        protocol_version = "HTTP/1.1"
        # socket timeout: a stalled upload or an idle keep-alive
        # connection must not pin a handler thread forever (the
        # stdlib default is no timeout at all)
        timeout = 120

        def log_message(self, fmt, *args):          # route through the daemon
            daemon._log(fmt, *args)

        def _reply(self, code: int, doc: dict,
                   headers: dict | None = None) -> None:
            body = json.dumps(doc).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            for k, v in (headers or {}).items():
                self.send_header(k, v)
            self.end_headers()
            self.wfile.write(body)

        def do_GET(self):
            if self.path in ("/healthz", "/health"):
                self._reply(200, daemon.health())
            elif self.path == "/readyz":
                doc = daemon.readiness()
                self._reply(200 if doc["ready"] else 503, doc)
            elif self.path == "/cache":
                self._reply(200, {"ok": True,
                                  "cache": daemon.cache.stats().to_json()})
            elif self.path == "/stats":
                self._reply(200, daemon.stats())
            elif self.path == "/lint":
                self._reply(200, daemon.lint())
            elif self.path == "/cost":
                self._reply(200, daemon.cost())
            else:
                self._reply(404, {"ok": False,
                                  "error": f"no such path {self.path!r}"})

        def do_POST(self):
            # a body we cannot fully drain would desync HTTP/1.1
            # keep-alive (leftover bytes parse as the NEXT request's
            # start line): bad framing gets an error AND a closed
            # connection
            te = (self.headers.get("Transfer-Encoding") or "").lower()
            if "chunked" in te:
                self.close_connection = True
                self._reply(411, {"ok": False,
                                  "error": "chunked bodies unsupported; "
                                           "send Content-Length"})
                return
            try:
                length = int(self.headers.get("Content-Length", 0))
                if length < 0:
                    raise ValueError(length)
            except (TypeError, ValueError):
                self.close_connection = True
                self._reply(400, {"ok": False,
                                  "error": "bad Content-Length header"})
                return
            if length > MAX_BODY_BYTES:
                self.close_connection = True
                self._reply(413, {"ok": False,
                                  "error": f"body {length} bytes > "
                                           f"{MAX_BODY_BYTES} limit"})
                return
            # drain the body unconditionally: on HTTP/1.1 keep-alive an
            # unread body would be parsed as the NEXT request's start line
            body = self.rfile.read(length)
            if self.path not in ("/run", "/warm"):
                self._reply(404, {"ok": False,
                                  "error": f"no such path {self.path!r}; "
                                           f"POST /run or /warm"})
                return
            try:
                doc = json.loads(body)
                req = SuiteRequest.from_json(doc)
            except (ValueError, KeyError, TypeError) as e:
                self._reply(400, {"ok": False, "error": f"bad request: {e}"})
                return
            try:
                if self.path == "/warm":
                    self._reply(200, daemon.warm(req))
                else:
                    self._reply(200, daemon.run_request(req))
            except (QueueFull, SchedulerStopped) as e:
                # backpressure, decided BEFORE the run touched a queue
                # slot: the client should retry, not fail — Retry-After
                # scales with how deep the backlog is
                retry = 1 if isinstance(e, SchedulerStopped) else max(
                    1, round(e.depth / max(1, e.limit) * 5))
                self._reply(503, {"ok": False, "error": str(e),
                                  "retry_after_s": retry},
                            headers={"Retry-After": str(retry)})
            except DeadlineExceeded as e:
                # the request's own deadline_ms expired in-queue: the
                # expired work never launched (scheduler contract)
                self._reply(504, {"ok": False, "error": str(e),
                                  "deadline_ms": req.deadline_ms})
            except ValueError as e:
                self._reply(400, {"ok": False, "error": str(e)})
            except Exception as e:   # execution failure: report, stay alive
                self._reply(500, {"ok": False,
                                  "error": f"{type(e).__name__}: {e}"})

    return Handler


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="spatterd: long-lived Spatter suite server "
                    "(warm ExecutorCache across requests)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8089)
    ap.add_argument("--workers", type=int, default=DEFAULT_WORKERS,
                    help="scheduler worker threads (0 = PR 4 serialized "
                         "run-lock baseline)")
    ap.add_argument("--max-queue", type=int, default=DEFAULT_MAX_QUEUE,
                    help="bounded scheduler queue (BucketWork items); "
                         "overflow returns 503 + Retry-After")
    ap.add_argument("--cache-dir", default=None,
                    help="persistent executable cache directory (JAX AOT "
                         "serialization): a restarted daemon preloads it "
                         "and serves previously seen suites with 0 "
                         "compiles")
    ap.add_argument("--faults", default=None,
                    help="fault-injection spec, e.g. "
                         "'compile:fail:1,worker:kill:2' (default: env "
                         f"{ENV_SPEC}); see repro.serve.faults")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for injected-latency jitter (reproducible "
                         "chaos)")
    ap.add_argument("--verbose", action="store_true",
                    help="log one line per handled request")
    args = ap.parse_args(argv)
    faults = (FaultInjector.from_spec(args.faults, seed=args.fault_seed)
              if args.faults else FaultInjector.from_env())
    daemon = SpatterDaemon(args.host, args.port, quiet=not args.verbose,
                           workers=args.workers, max_queue=args.max_queue,
                           cache_dir=args.cache_dir, faults=faults)

    def _on_sigterm(signum, frame):
        # graceful drain off the signal frame: readiness flips 503
        # immediately, the blocking shutdown runs on a helper thread
        # (shutdown() deadlocks if called from the serving thread)
        daemon.begin_drain()

    signal.signal(signal.SIGTERM, _on_sigterm)
    print(f"spatterd listening on {daemon.url}  "
          f"(POST /run /warm, GET /healthz /readyz /stats)", flush=True)
    try:
        daemon.serve_forever()
        print("spatterd drained cleanly", flush=True)
    except KeyboardInterrupt:
        daemon.stop()


if __name__ == "__main__":
    main()
