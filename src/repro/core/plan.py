"""Suite execution planner: plan -> compile -> execute for pattern suites.

DESIGN NOTE (referenced from suite.py)
======================================

Problem.  ``run_suite`` used to build one ``GSEngine`` per pattern, so an
N-pattern suite paid N XLA compiles — compile time dwarfed execute time for
the paper's JSON suites (§3.3) and made streamed/repeated suite runs (the
"many scenarios per process" regime) unusable.

Plan.  ``SuitePlan.build`` groups patterns into **shape buckets**: the two
shape-bearing dims of a pattern's executable — the flattened index length
``count * index_len`` and the table ``footprint`` — are padded up to the
next power of two, and patterns whose ``(kind, padded_idx_len,
padded_footprint)`` agree share one bucket.  Pow-2 padding trades at most
2x wasted lanes for an O(log) number of distinct executable shapes.

Compile.  One executable per bucket: a ``jax.jit``-wrapped ``vmap`` of the
single-pattern backend op (backends.gather_batched / scatter_batched),
with the pattern-batch as the mapped dim.  Executables live in an
``ExecutorCache`` — an LRU keyed on ``(backend, kind, idx_len, footprint,
dtype, row_width, mode)`` — so repeated or streamed suite runs reuse warm
executables across ``run_suite`` calls.  The cache's ``misses`` counter is
the compile counter: a 32-pattern suite compiles ``n_buckets`` (< 32)
executables, and a second identical run compiles zero.  (jax itself
re-traces a cached executable if the *batch* size changes; the bucket
shapes, which dominate compile cost, stay fixed.)

Execute.  Same-bucket patterns are stacked: indices into a (B, N_pad)
int32 array, tables into (B, F_pad + 1, R).  Row ``F_pad`` of every table
is a scratch row; padded lanes (both the lane tail up to N_pad and, for
scatters, their payload) point at it, so they can never touch real rows,
and they never enter the bandwidth numerator — ``measured_gbs`` /
``modeled_gbs`` keep exactly the paper's §3.5 useful-bytes formula.
Per-pattern buffers come from ``engine.make_host_buffers`` — the same
function ``GSEngine`` uses — so batched results are bit-identical to
per-pattern execution (asserted by tests/test_suite_plan.py on all four
backends).

Timing attribution.  A bucket launch is timed like GSEngine.run (min over
K runs, §3.5); each member pattern is attributed wall time proportional to
its share of the bucket's real lanes, so every pattern in a bucket reports
the bandwidth the *launch* achieved.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import backends as B
from . import bandwidth as bw
from .engine import RunResult, make_host_buffers
from .pattern import Pattern


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    if n < 1:
        raise ValueError(f"need n >= 1, got {n}")
    return 1 << (n - 1).bit_length()


# ---------------------------------------------------------------------------
# Plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """Shape signature shared by every pattern in a bucket."""
    kind: str           # "gather" | "scatter"
    idx_len: int        # count * index_len, padded to pow2
    footprint: int      # table footprint, padded to pow2

    @staticmethod
    def of(p: Pattern) -> "BucketSpec":
        return BucketSpec(kind=p.kind,
                          idx_len=next_pow2(p.count * p.index_len),
                          footprint=next_pow2(p.footprint()))


@dataclasses.dataclass(frozen=True)
class Bucket:
    spec: BucketSpec
    members: tuple[int, ...]      # positions into the suite's pattern list


@dataclasses.dataclass(frozen=True)
class SuitePlan:
    patterns: tuple[Pattern, ...]
    buckets: tuple[Bucket, ...]

    @staticmethod
    def build(patterns: Sequence[Pattern]) -> "SuitePlan":
        groups: dict[BucketSpec, list[int]] = {}
        for i, p in enumerate(patterns):
            groups.setdefault(BucketSpec.of(p), []).append(i)
        buckets = tuple(
            Bucket(spec=spec, members=tuple(groups[spec]))
            for spec in sorted(groups,
                               key=lambda s: (s.kind, s.idx_len, s.footprint)))
        return SuitePlan(patterns=tuple(patterns), buckets=buckets)

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    def pad_waste(self) -> float:
        """Fraction of launched lanes that are padding (0 = no waste)."""
        real = sum(p.count * p.index_len for p in self.patterns)
        launched = sum(b.spec.idx_len * len(b.members) for b in self.buckets)
        return 1.0 - real / max(1, launched)


# ---------------------------------------------------------------------------
# Executor cache
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExecKey:
    backend: str
    kind: str
    idx_len: int
    footprint: int
    dtype: str
    row_width: int
    mode: str           # "store" | "add" for scatter, "" for gather


class ExecutorCache:
    """LRU of compiled bucket executables; ``misses`` counts compiles."""

    def __init__(self, maxsize: int = 128):
        self.maxsize = maxsize
        self._entries: OrderedDict[ExecKey, Callable] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: ExecKey, builder: Callable[[], Callable]) -> Callable:
        fn = self._entries.get(key)
        if fn is not None:
            self._entries.move_to_end(key)
            self.hits += 1
            return fn
        self.misses += 1
        fn = builder()
        self._entries[key] = fn
        while len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)
        return fn

    def clear(self) -> None:
        self._entries.clear()
        self.hits = 0
        self.misses = 0


_DEFAULT_CACHE = ExecutorCache()


def default_cache() -> ExecutorCache:
    """Process-wide cache: repeated run_suite calls share warm executables."""
    return _DEFAULT_CACHE


def _build_executable(backend: str, kind: str, mode: str) -> Callable:
    if kind == "gather":
        def fn(src_b, idx_b):
            return B.gather_batched(src_b, idx_b, backend=backend)
    else:
        def fn(dst_b, idx_b, vals_b):
            return B.scatter_batched(dst_b, idx_b, vals_b, mode=mode,
                                     backend=backend)
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# Bucket assembly + execution
# ---------------------------------------------------------------------------

def _assemble_bucket(plan: SuitePlan, bucket: Bucket, dtype, row_width: int,
                     seed: int):
    """Stack a bucket's patterns into batched device buffers.

    Returns (args, real_lanes) where args feeds the bucket executable and
    real_lanes[b] is member b's un-padded lane count.  Table row F_pad is
    the scratch row every padded lane points at.
    """
    spec = bucket.spec
    nb = len(bucket.members)
    n_pad, f_pad, r = spec.idx_len, spec.footprint, row_width
    idx_b = np.full((nb, n_pad), f_pad, np.int32)          # pad -> scratch
    table_b = (np.zeros((nb, f_pad + 1, r), np.float32)
               if spec.kind == "gather" else None)
    vals_b = (np.zeros((nb, n_pad, r), np.float32)
              if spec.kind == "scatter" else None)
    real_lanes = []
    for b, pos in enumerate(bucket.members):
        p = plan.patterns[pos]
        src, abs_idx, vals = make_host_buffers(p, r, seed=seed)
        n = abs_idx.shape[0]
        real_lanes.append(n)
        idx_b[b, :n] = abs_idx
        if spec.kind == "gather":
            table_b[b, :src.shape[0]] = src
        else:
            vals_b[b, :n] = vals
    idx = jnp.asarray(idx_b)
    if spec.kind == "gather":
        return (jnp.asarray(table_b, dtype), idx), real_lanes
    dst = jnp.zeros((nb, f_pad + 1, r), dtype)
    return (dst, idx, jnp.asarray(vals_b, dtype)), real_lanes


def execute_bucket(plan: SuitePlan, bucket: Bucket, *, backend: str = "xla",
                   dtype=jnp.float32, row_width: int = 1,
                   mode: str = "store", seed: int = 0,
                   cache: ExecutorCache | None = None) -> list[np.ndarray]:
    """Run one bucket once and return per-member un-padded outputs.

    Gathers give member i its (count*index_len, R) rows; scatters give the
    (footprint, R) result table (scratch row trimmed).
    """
    cache = cache if cache is not None else default_cache()
    spec = bucket.spec
    key = _exec_key(backend, spec, dtype, row_width, mode)
    fn = cache.get(key, lambda: _build_executable(backend, spec.kind,
                                                  key.mode))
    args, real_lanes = _assemble_bucket(plan, bucket, dtype, row_width, seed)
    out = np.asarray(jax.block_until_ready(fn(*args)))
    trimmed = []
    for b, pos in enumerate(bucket.members):
        if spec.kind == "gather":
            trimmed.append(out[b, :real_lanes[b]])
        else:
            trimmed.append(out[b, :plan.patterns[pos].footprint()])
    return trimmed


def _exec_key(backend: str, spec: BucketSpec, dtype, row_width: int,
              mode: str) -> ExecKey:
    return ExecKey(backend=backend, kind=spec.kind, idx_len=spec.idx_len,
                   footprint=spec.footprint, dtype=jnp.dtype(dtype).name,
                   row_width=row_width,
                   mode=mode if spec.kind == "scatter" else "")


def run_plan(plan: SuitePlan, *, backend: str = "xla", dtype=None,
             row_width: int = 1, runs: int = 10, mode: str = "store",
             seed: int = 0,
             cache: ExecutorCache | None = None) -> list[RunResult]:
    """Execute a SuitePlan with paper-style timing (min over ``runs``).

    Returns one RunResult per pattern, in the suite's original order.
    Wall time of a bucket launch is attributed to members proportionally
    to their real (un-padded) lanes.
    """
    if backend not in B.BACKENDS:
        raise ValueError(f"unknown backend {backend!r}")
    dtype = jnp.dtype(dtype or jnp.float32)
    cache = cache if cache is not None else default_cache()
    elem_bytes = dtype.itemsize * row_width
    results: list[RunResult | None] = [None] * len(plan.patterns)

    for bucket in plan.buckets:
        spec = bucket.spec
        key = _exec_key(backend, spec, dtype, row_width, mode)
        fn = cache.get(key, lambda: _build_executable(backend, spec.kind,
                                                      key.mode))
        args, real_lanes = _assemble_bucket(plan, bucket, dtype, row_width,
                                            seed)
        if spec.kind == "scatter":
            dst, idx, vals = args
            jax.block_until_ready(fn(dst, idx, vals))       # compile & warm
            times = []
            for _ in range(runs):
                d = jnp.zeros_like(dst)
                jax.block_until_ready(d)
                t0 = time.perf_counter()
                out = fn(d, idx, vals)
                jax.block_until_ready(out)
                times.append(time.perf_counter() - t0)
        else:
            jax.block_until_ready(fn(*args))                # compile & warm
            times = []
            for _ in range(runs):
                t0 = time.perf_counter()
                out = fn(*args)
                jax.block_until_ready(out)
                times.append(time.perf_counter() - t0)
        t_bucket = min(times)                                # paper §3.5

        total_lanes = sum(real_lanes)
        for b, pos in enumerate(bucket.members):
            p = plan.patterns[pos]
            t_i = t_bucket * real_lanes[b] / total_lanes
            tm = bw.tpu_tile_model(p, elem_bytes)
            results[pos] = RunResult(
                pattern=p, backend=backend, elem_bytes=elem_bytes,
                row_width=row_width, runs=runs, time_s=t_i,
                measured_gbs=bw.paper_bandwidth(p, t_i, elem_bytes) / 1e9,
                modeled_gbs=tm.modeled_gbs,
                tile_efficiency=tm.tile_efficiency,
            )
    return results  # type: ignore[return-value]
