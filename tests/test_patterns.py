"""Pattern-language unit tests — anchored on the paper's own examples."""
import numpy as np
import pytest

from repro.core import (Pattern, dump_suite, generate_index, load_suite,
                        make_pattern)
from repro.core.pattern import broadcast, laplacian, ms1, uniform


class TestGenerators:
    def test_uniform_paper_semantics(self):
        # paper §3.3.1 example prints [0,4,8,12] but defines length-N
        # buffers; released Spatter semantics (followed here, DESIGN.md §9):
        assert uniform(8, 4) == (0, 4, 8, 12, 16, 20, 24, 28)
        assert uniform(4, 1) == (0, 1, 2, 3)

    def test_ms1_paper_example(self):
        # §3.3.2: MS1:8:4:20 -> [0,1,2,3,23,24,25,26]
        assert ms1(8, 4, 20) == (0, 1, 2, 3, 23, 24, 25, 26)

    def test_laplacian_paper_example(self):
        # §3.3.3: LAPLACIAN:2:2:100 -> classic 5-point(ish) stencil
        assert laplacian(2, 2, 100) == (0, 100, 198, 199, 200, 201, 202,
                                        300, 400)

    def test_laplacian_1d(self):
        assert laplacian(1, 1, 10) == (0, 1, 2)

    def test_broadcast(self):
        assert broadcast(16, 4) == (0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2,
                                    3, 3, 3, 3)

    def test_parse_strings(self):
        assert generate_index("UNIFORM:8:1") == tuple(range(8))
        assert generate_index("MS1:8:4:20") == (0, 1, 2, 3, 23, 24, 25, 26)
        assert generate_index("LAPLACIAN:2:2:100")[4] == 200
        assert generate_index("0,4,8,12") == (0, 4, 8, 12)
        assert generate_index("CUSTOM:7,3,1") == (7, 3, 1)
        assert generate_index("STREAM:4") == (0, 1, 2, 3)

    def test_parse_bad(self):
        with pytest.raises(ValueError):
            generate_index("NOPE:broken:x")


class TestPattern:
    def test_stream_like_example(self):
        # paper §3.4: ./spatter -k Gather -p UNIFORM:8:1 -d 8 -l 2**24
        p = make_pattern("UNIFORM:8:1", kind="gather", delta=8, count=2 ** 10)
        assert p.index_len == 8
        assert p.footprint() == 8 * (2 ** 10 - 1) + 8
        assert p.useful_elements() == 8 * 2 ** 10
        assert p.reuse_factor() == 1.0          # delta == span: no reuse

    def test_overlap_reuse(self):
        p = make_pattern("UNIFORM:8:1", delta=1, count=64)
        assert p.reuse_factor() > 4

    def test_absolute_indices(self):
        p = make_pattern("UNIFORM:4:2", delta=3, count=3)
        abs_idx = p.absolute_indices()
        assert abs_idx.shape == (3, 4)
        np.testing.assert_array_equal(abs_idx[0], [0, 2, 4, 6])
        np.testing.assert_array_equal(abs_idx[2], [6, 8, 10, 12])

    def test_classify(self):
        assert make_pattern("UNIFORM:8:4").classify() == "Stride-4"
        assert make_pattern("UNIFORM:8:1").classify() == "Stride-1"
        assert make_pattern("BROADCAST:16:4").classify() == "Broadcast"
        assert make_pattern("MS1:8:4:20").classify() == "Mostly Stride-1"

    def test_validation(self):
        with pytest.raises(ValueError):
            Pattern("x", "gather", (), 1, 1)
        with pytest.raises(ValueError):
            Pattern("x", "upside-down", (0,), 1, 1)
        with pytest.raises(ValueError):
            Pattern("x", "gather", (0,), 1, 0)


class TestSuiteIO:
    def test_json_roundtrip(self):
        ps = [make_pattern("UNIFORM:8:2", delta=4, count=16),
              make_pattern("MS1:8:4:20", kind="scatter", delta=2, count=8)]
        text = dump_suite(ps)
        back = load_suite(text)
        assert [p.index for p in back] == [p.index for p in ps]
        assert [p.kind for p in back] == ["gather", "scatter"]

    def test_json_pattern_string(self):
        back = load_suite('[{"kernel":"gather","pattern":"UNIFORM:4:1",'
                          '"delta":4,"count":10}]')
        assert back[0].index == (0, 1, 2, 3)


class TestAppDB:
    def test_table5_integrity(self):
        from repro.core import appdb
        assert len(appdb.ALL_GATHERS) == 29     # 16 PENNANT + 8 LULESH + 3 NEK + 2 AMG
        assert len(appdb.ALL_SCATTERS) == 5     # 1 PENNANT + 4 LULESH (incl. S3)
        g4 = appdb.get("PENNANT-G4")
        assert g4.classify() == "Broadcast"
        assert g4.delta == 4
        s3 = appdb.get("LULESH-S3")
        assert s3.delta == 0                    # the §5.4 pathology
        assert appdb.get("PENNANT-G15").delta == 1882384

    def test_scale_counts(self):
        from repro.core import appdb
        scaled = appdb.scale_counts(appdb.ALL_PATTERNS, 1 / 1024)
        assert all(p.count >= 1 for p in scaled)
        assert scaled[0].index == appdb.ALL_PATTERNS[0].index
