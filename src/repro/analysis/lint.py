"""spatterlint drivers: enumerate -> audit -> report (DESIGN.md §12).

Entry points:

    lint_plan(patterns, ...)     one suite x placement cell, statically
    lint_suite_file(path, ...)   a suites/*.json file over backends
    lint_cache(cache)            a LIVE ExecutorCache's compiled entries
                                 (what the daemon's GET /lint serves)
    lint_serve()                 the ast concurrency lint over repro/serve
    unit_for(fn, args, ...)      wrap an ad-hoc executable for rule checks
                                 (how tests/test_no_sort.py consumes rules)

Everything here audits without running: executables are traced/lowered
from abstract avals (``plan.bucket_avals``), never invoked.  The suite
enumeration goes through ``plan.enumerate_executables``, which shares
``bucket_key``/``bucket_builder`` with the hot path — what the lint
checks is by construction what the cache would build.
"""
from __future__ import annotations

from .report import LintReport, Violation
from .rules import ExecUnit, PlanUnit, ServeUnit, rules_for


def _rule_names(*scopes) -> tuple[str, ...]:
    names: list[str] = []
    for scope in scopes:
        names.extend(r.name for r in rules_for(scope))
    return tuple(names)


def run_rules(unit: ExecUnit, names=None) -> list[Violation]:
    """Run executable-scope rules (all by default) on one unit."""
    out: list[Violation] = []
    for r in rules_for("executable", names):
        out.extend(r.check(unit))
    return out


def unit_for(fn, args, *, backend: str, kind: str, mode: str = "",
             placement: str = "", dtype=None, cached: bool = True,
             jaxpr=None) -> ExecUnit:
    """Wrap a concrete executable + example args as an ExecUnit.

    For ad-hoc audits (tests, notebooks) of executables that did not come
    from the planner: geometry fields the rules don't read are zeroed;
    ``jaxpr=`` overrides the traced jaxpr (e.g. one captured under
    ``enable_x64``).
    """
    import jax

    from repro.core.plan import ExecKey
    avals = tuple(jax.ShapeDtypeStruct(a.shape, a.dtype) for a in args)
    if dtype is None:
        dtype = next((str(a.dtype) for a in avals
                      if "float" in str(a.dtype)), "float32")
    key = ExecKey(backend=backend, kind=kind, idx_len=0, footprint=0,
                  dtype=str(dtype), row_width=1, mode=mode, batch=0,
                  placement=placement)
    return ExecUnit(key=key, builder=None, avals=avals, fn=fn,
                    cached=cached, _jaxpr=jaxpr)


def lint_plan(patterns, *, backend: str = "xla", mode: str = "store",
              dtype=None, row_width: int = 1, placement=None,
              mesh_axis: str = "data", label: str = "",
              rules=None) -> LintReport:
    """Audit one suite x placement cell without running anything.

    ``placement`` accepts any ``as_placement`` form, the auto strings
    (``"auto"`` resolves per bucket against this cell's backend —
    the cost model's choice is backend-dependent — and
    ``"auto-suite"`` to one suite-wide shape), or a per-bucket
    placement list.
    """
    import jax.numpy as jnp

    from repro.core.plan import (SuitePlan, as_placement, auto_placements,
                                 enumerate_executables)
    dtype = jnp.dtype(dtype or jnp.float32)
    patterns = tuple(patterns)
    plan = SuitePlan.build(patterns)
    if isinstance(placement, str):
        placement = auto_placements(plan, placement, mesh_axis=mesh_axis,
                                    backend=backend, dtype=dtype,
                                    row_width=row_width)
    if isinstance(placement, list):
        placement = [as_placement(p, mesh_axis) for p in placement]
        grid, placements = (1, 1), placement
        place_str = "auto(" + ",".join(
            p.placement if p else "single" for p in placement) + ")"
    else:
        placement = as_placement(placement, mesh_axis)
        grid, placements = (placement.grid if placement else (1, 1)), None
        place_str = placement.placement if placement else "single"
    label = label or f"suite[{len(patterns)}]"
    cell = f"{label} @ {place_str} backend={backend}"

    def enumerate_again():
        return enumerate_executables(
            SuitePlan.build(patterns), backend=backend, dtype=dtype,
            row_width=row_width, mode=mode, placement=placement)

    violations: list[Violation] = []
    units = enumerate_again()
    for key, builder, avals in units:
        unit = ExecUnit(key=key, builder=builder, avals=avals)
        violations.extend(run_rules(unit, rules))
    plan_unit = PlanUnit(plan=plan, grid=grid, label=cell,
                         enumerate=enumerate_again, placements=placements)
    for r in rules_for("plan", rules):
        violations.extend(r.check(plan_unit))
    return LintReport(
        violations=violations,
        n_units=len(units) + 1,                 # buckets + the plan itself
        rules=_rule_names("executable", "plan"),
        meta={"cells": [{"cell": cell, "backend": backend,
                         "placement": place_str,
                         "n_buckets": plan.n_buckets}]})


def lint_suite_file(path: str, *, mesh=None, backends=("xla", "pallas"),
                    mode: str = "store", row_width: int = 1,
                    dtype=None, rules=None) -> LintReport:
    """Audit a suites/*.json file across backends on one placement.

    ``mesh`` may be the strings ``"auto"``/``"auto-suite"``; they
    resolve inside each backend's cell (the §15 choice depends on the
    backend — lane-sharded pallas is not charged replication bytes).
    """
    from repro.core import load_suite
    patterns = load_suite(path)
    report = LintReport()
    for backend in backends:
        report = report.merge(lint_plan(
            patterns, backend=backend, mode=mode, dtype=dtype,
            row_width=row_width, placement=mesh, label=path, rules=rules))
    return report


def lint_cache(cache, rules=None) -> LintReport:
    """Audit every compiled entry of a LIVE ExecutorCache.

    Launch avals are reconstructed from each ExecKey alone
    (``plan.key_avals``), so the audit holds exactly the information the
    key promises — if the key lies about its executable, a rule fires.
    Read-only: ``cache.entries()`` perturbs neither counters nor LRU
    order.

    Disk-restored entries (``fn.restored`` — diskcache.py) trace to an
    opaque ``call_exported`` primitive, so trace-based rules cannot see
    inside them; they get only the key-shape rules that need no jaxpr.
    The ``restored`` count in ``meta`` says how many were downgraded.
    """
    from repro.core.plan import key_avals

    # the rule subset that inspects ONLY the ExecKey, never the jaxpr —
    # safe on an opaque restored executable (the cost rules that need
    # the lowered signature are skipped; cost-regression reads nothing
    # but key geometry, so restored entries keep their perf gate)
    key_only = ("canonical-exec-key", "cost-regression")
    violations: list[Violation] = []
    entries = cache.entries()
    n_restored = 0
    for key, fn in entries:
        avals = key_avals(key)
        unit = ExecUnit(key=key, builder=None, avals=avals, fn=fn)
        if getattr(fn, "restored", False):
            n_restored += 1
            names = key_only if rules is None else \
                tuple(n for n in key_only if n in rules)
            violations.extend(run_rules(unit, names))
        else:
            violations.extend(run_rules(unit, rules))
    return LintReport(violations=violations, n_units=len(entries),
                      rules=_rule_names("executable"),
                      meta={"source": "live-cache", "restored": n_restored})


def lint_serve(paths=None, rules=None) -> LintReport:
    """Run the serve-scope (ast concurrency) rules over repro/serve."""
    from .ast_lint import serve_sources
    paths = list(paths) if paths is not None else serve_sources()
    files = []
    for p in paths:
        with open(p) as f:
            files.append((p, f.read()))
    unit = ServeUnit(files=files)
    violations: list[Violation] = []
    for r in rules_for("serve", rules):
        violations.extend(r.check(unit))
    return LintReport(violations=violations, n_units=len(files),
                      rules=_rule_names("serve"),
                      meta={"source": "serve-ast"})
