"""Paged-KV decode attention — Spatter's gather fused into flash-decode.

Serving-time decode is the single largest *indexed-access* workload in an
LLM (DESIGN.md §3): every step gathers the whole KV cache through a page
table.  Instead of gathering pages to a contiguous buffer and then running
attention (two HBM round-trips), this kernel lets the page-table drive the
K/V ``BlockSpec.index_map`` directly — the Spatter scalar-prefetch gather —
and consumes each page immediately with an online-softmax update.

Layout:  q            (B, KVH, G, Dh)      G = query heads per KV head (GQA)
         k_pages      (KVH, P, page, Dh)   P = physical page pool
         v_pages      (KVH, P, page, Dh)
         page_table   (B, pages_per_seq)   int32, scalar-prefetched
         lengths      (B,)                 valid KV length per sequence

Grid (B, KVH, pages_per_seq); the (m, l, acc) running state lives in VMEM
scratch and the output block is written once on the final page.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _decode_kernel(page_size: int, scale: float,
                   page_table_ref, lengths_ref,
                   q_blk, k_blk, v_blk,
                   out_blk,
                   m_scr, l_scr, acc_scr):
    b = pl.program_id(0)
    p = pl.program_id(2)
    n_pages = pl.num_programs(2)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_blk[0, 0].astype(jnp.float32)                    # (G, Dh)
    k = k_blk[0, 0].astype(jnp.float32)                    # (page, Dh)
    v = v_blk[0, 0].astype(jnp.float32)                    # (page, Dh)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # mask out positions past the sequence length
    pos = p * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < lengths_ref[b], s, _NEG_INF)

    m_prev = m_scr[...]                                    # (G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    e = jnp.exp(s - m_new)                                 # (G, page)
    l_scr[...] = l_scr[...] * corr + e.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
        e, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(p == n_pages - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        out_blk[0, 0] = (acc_scr[...] / denom).astype(out_blk.dtype)


def paged_decode_kernel(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                        page_table: jax.Array, lengths: jax.Array, *,
                        scale: float, interpret: bool) -> jax.Array:
    b, kvh, g, dh = q.shape
    _, p_total, page_size, _ = k_pages.shape
    pages_per_seq = page_table.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,   # page_table, lengths
        grid=(b, kvh, pages_per_seq),
        in_specs=[
            pl.BlockSpec((1, 1, g, dh),
                         lambda b, h, p, pt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, page_size, dh),
                         lambda b, h, p, pt, ln: (h, pt[b, p], 0, 0)),
            pl.BlockSpec((1, 1, page_size, dh),
                         lambda b, h, p, pt, ln: (h, pt[b, p], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh),
                               lambda b, h, p, pt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_decode_kernel, page_size, scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, dh), q.dtype),
        interpret=interpret,
    )(page_table, lengths, q, k_pages, v_pages)
