"""Public jit'd wrappers for the MXU scatter kernels.

``scatter_add_rows_batched`` / ``scatter_store_rows_batched`` run a whole
pattern batch (a planner bucket) as ONE kernel launch with the (B, N)
index buffer scalar-prefetched once (DESIGN.md §2.2); the per-pattern
entry points are the B=1 case of the same kernels.  Store mode expects
its index buffer pre-deduped on the host (dropped lanes routed out of
range — backends.keep_last_mask), so the kernel is a single pass with no
sort and no coverage-count launch.  Block sizes default to the
deterministic per-geometry autotuner (``kernels.autotune``); passing a
block explicitly bypasses the search.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel
from .. import autotune

# legacy fixed tiles — served when the autotuner is disabled()
# (autotune.LEGACY mirrors these; a drift test pins them equal)
_DEFAULT_BLOCK_V = 128
_DEFAULT_BLOCK_N = 128


def _oob() -> int:
    return jnp.iinfo(jnp.int32).max


def _should_interpret(interpret: bool | None) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _clip_blocks(v: int, n: int, block_v: int, block_n: int):
    return min(block_v, max(8, v)), min(block_n, max(8, n))


def _pick_blocks(v: int, n: int, bsz: int, d: int, dtype,
                 block_v: int | None, block_n: int | None,
                 interpret: bool):
    """Resolve block sizes: explicit args win, the rest are autotuned."""
    if block_v is None or block_n is None:
        choice = autotune.choose(autotune.TileKey(
            op="scatter", batch=bsz, lanes=n, rows=v, width=d,
            dtype=jnp.dtype(dtype).name,
            platform="interpret" if interpret else "tpu"))
        if block_v is None:
            block_v = choice.block_v or _DEFAULT_BLOCK_V
        if block_n is None:
            block_n = choice.block_n or _DEFAULT_BLOCK_N
    return _clip_blocks(v, n, block_v, block_n)


def _pad_lanes(idx, vals, block_n: int):
    """Pad the lane dim to a block_n multiple; pad lanes point past every
    tile so the one-hot drops them."""
    bsz, n = idx.shape
    pad_n = (-n) % block_n
    if not pad_n:
        return idx, vals
    idx = jnp.concatenate(
        [idx, jnp.full((bsz, pad_n), _oob(), jnp.int32)], axis=1)
    vals = jnp.concatenate(
        [vals, jnp.zeros((bsz, pad_n, vals.shape[2]), vals.dtype)], axis=1)
    return idx, vals


# ---------------------------------------------------------------------------
# scatter-add
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("v", "block_v", "block_n", "interpret"))
def _scatter_add_batched(idx, vals, v: int, block_v: int, block_n: int,
                         interpret: bool):
    idx, vals = _pad_lanes(idx.astype(jnp.int32), vals, block_n)
    v_padded = v + ((-v) % block_v)
    out = kernel.scatter_add_rows_kernel(
        idx, vals, v_padded, block_v=block_v, block_n=block_n,
        interpret=interpret)
    return out[:, :v]


def scatter_add_rows_batched(idx: jax.Array, vals: jax.Array, v: int, *,
                             block_v: int | None = None,
                             block_n: int | None = None,
                             interpret: bool | None = None) -> jax.Array:
    """Batched scatter-add: idx (B, N), vals (B, N, D) -> (B, V, D).

    One kernel launch for the whole pattern batch.  Out-of-range indices
    are dropped (matching ``.at[].add(mode="drop")``).
    """
    if vals.ndim != 3 or idx.ndim != 2 or idx.shape != vals.shape[:2]:
        raise ValueError(f"bad shapes idx={idx.shape} vals={vals.shape}")
    interp = _should_interpret(interpret)
    bsz, n, d = vals.shape
    block_v, block_n = _pick_blocks(v, n, bsz, d, vals.dtype,
                                    block_v, block_n, interp)
    return _scatter_add_batched(idx, vals, v, block_v, block_n, interp)


def scatter_add_rows(idx: jax.Array, vals: jax.Array, v: int, *,
                     block_v: int | None = None,
                     block_n: int | None = None,
                     interpret: bool | None = None) -> jax.Array:
    """Scatter-add ``vals`` (N, D) at row indices ``idx`` (N,) into (V, D).

    The B=1 case of the batched kernel — one code path for both.
    """
    if vals.ndim != 2 or idx.ndim != 1 or idx.shape[0] != vals.shape[0]:
        raise ValueError(f"bad shapes idx={idx.shape} vals={vals.shape}")
    return scatter_add_rows_batched(idx[None], vals[None], v,
                                    block_v=block_v, block_n=block_n,
                                    interpret=interpret)[0]


# ---------------------------------------------------------------------------
# single-pass store
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("block_v", "block_n", "with_covered",
                                    "interpret"))
def _scatter_store_batched(dst, idx, vals, block_v: int, block_n: int,
                           with_covered: bool, interpret: bool):
    bsz, _, d = vals.shape
    v = dst.shape[1]
    idx, vals = _pad_lanes(idx.astype(jnp.int32), vals, block_n)
    pad_v = (-v) % block_v
    if pad_v:
        dst = jnp.concatenate(
            [dst, jnp.zeros((bsz, pad_v, d), dst.dtype)], axis=1)
    out = kernel.scatter_store_rows_kernel(
        idx, vals, dst, block_v=block_v, block_n=block_n,
        with_cov=with_covered, interpret=interpret)
    if with_covered:
        out, cov = out
        return out[:, :v], cov[:, :v]
    return out[:, :v]


def scatter_store_rows_batched(dst: jax.Array, idx: jax.Array,
                               vals: jax.Array, *,
                               block_v: int | None = None,
                               block_n: int | None = None,
                               with_covered: bool = False,
                               interpret: bool | None = None):
    """Batched store: dst (B, V, D), idx (B, N), vals (B, N, D) -> (B, V, D).

    One single-pass kernel launch for the whole pattern batch.  Contract:
    each in-range index value occurs at most once per batch row (the host
    keep mask already dropped duplicate writes by routing them out of
    range); out-of-range indices are dropped.  With ``with_covered`` the
    same single launch also returns the (B, V) int32 coverage map (1
    where this call wrote) — the lane-sharded combine's ballot.
    """
    if (vals.ndim != 3 or idx.ndim != 2 or dst.ndim != 3
            or idx.shape != vals.shape[:2] or dst.shape[2] != vals.shape[2]):
        raise ValueError(f"bad shapes dst={dst.shape} idx={idx.shape} "
                         f"vals={vals.shape}")
    interp = _should_interpret(interpret)
    bsz, n, d = vals.shape
    block_v, block_n = _pick_blocks(dst.shape[1], n, bsz, d, dst.dtype,
                                    block_v, block_n, interp)
    return _scatter_store_batched(dst, idx, vals, block_v, block_n,
                                  with_covered, interp)


def scatter_store_rows(dst: jax.Array, idx: jax.Array, vals: jax.Array, *,
                       block_v: int | None = None,
                       block_n: int | None = None,
                       interpret: bool | None = None) -> jax.Array:
    """Store ``vals`` (N, D) into ``dst`` (V, D) at rows ``idx`` (N,).

    The B=1 case of the batched kernel — one code path for both.
    """
    if (vals.ndim != 2 or idx.ndim != 1 or dst.ndim != 2
            or idx.shape[0] != vals.shape[0] or dst.shape[1] != vals.shape[1]):
        raise ValueError(f"bad shapes dst={dst.shape} idx={idx.shape} "
                         f"vals={vals.shape}")
    return scatter_store_rows_batched(dst[None], idx[None], vals[None],
                                      block_v=block_v, block_n=block_n,
                                      interpret=interpret)[0]
