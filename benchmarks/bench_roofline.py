"""Roofline-terms bench: reads the dry-run cell JSONs (deliverable g).

Emits one CSV row per (arch x shape) cell on the single-pod mesh with the
three roofline terms and the dominant bottleneck — the `derived` column is
the §Roofline table in benchmark form.  Requires the dry-run sweep to have
run (experiments/dryrun/*.json); emits a pointer row if absent.
"""
from __future__ import annotations

import glob
import json
import os

from .harness import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def run(runs: int = 0):
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*__16x16.json")))
    if not files:
        emit("roofline/missing", 0.0,
             "run: PYTHONPATH=src python -m repro.launch.dryrun --all")
        return
    for fn in files:
        with open(fn) as f:
            j = json.load(f)
        r = j["roofline"]
        emit(f"roofline/{r['arch']}/{r['shape']}",
             max(r["t_compute_s"], r["t_memory_s"],
                 r["t_collective_s"]) * 1e6,
             f"comp={r['t_compute_s']:.2f}s mem={r['t_memory_s']:.2f}s "
             f"coll={r['t_collective_s']:.2f}s dom={r['dominant']} "
             f"frac={100*r['roofline_fraction']:.1f}% "
             f"useful={r['useful_flops_ratio']:.2f}")


if __name__ == "__main__":
    run()
