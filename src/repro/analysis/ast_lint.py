"""Python-``ast`` concurrency lint for the serving layer (DESIGN.md §12).

spatterd handles requests from ``ThreadingHTTPServer`` threads, so every
piece of daemon state shared across handlers must be mutated under a lock
(DESIGN.md §10) — and, dually, nothing slow may run *while holding* one
(the run lock serializes execution on purpose; the memo lock must stay
cheap).  Those two properties are what the ROADMAP coalescing-scheduler
rewrite will lean on, so they are enforced here structurally rather than
by per-method tests.

Two checks, both over the source of ``repro/serve`` (no imports — this
module stays jax-free like ``report.py``):

``serve-lock-discipline`` — *mostly-locked inference* in the RacerD
style: within a class, an attribute counts as **lock-guarded** when at
least one of its mutations happens inside a ``with self.<lock>:`` block
(any attribute whose name contains ``lock``).  Every other mutation of a
guarded attribute outside ``__init__`` (construction happens before the
threads exist) must then also hold a lock, or it is flagged.  Mutations
are assignments/augmented assignments to ``self.x`` or ``self.x[...]``,
mutator method calls (``self.x.append(...)``, ``.pop``, ``.update``,
...), and passing ``self.x`` as a call argument (how
``_bounded_put(self._placements, ...)`` mutates a memo).  Attributes
never mutated under any lock are presumed handler-local by design
(e.g. the server thread handle) and not flagged — the inference adds no
annotation burden, and seeding one locked use is what opts state in.

``serve-blocking-under-lock`` — flags calls that can block (sleep,
socket/HTTP I/O, file reads, subprocess waits) lexically inside a
``with self.<lock>:`` body.  The *executable* run under the run lock is
exempt by construction: this is a source-level check of the serving
code, and ``run_suite`` executing on-device is the lock's entire
purpose — the check names specific host-blocking calls instead of
guessing at cost.
"""
from __future__ import annotations

import ast
import os

from .report import Violation

# self.<attr>.<method>(...) calls that mutate the receiver in place
MUTATOR_METHODS = frozenset({
    "append", "extend", "insert", "add", "update", "setdefault", "pop",
    "popitem", "clear", "remove", "discard", "move_to_end", "appendleft",
    "popleft",
})

# call names (last dotted component) that can block the holding thread
BLOCKING_CALLS = frozenset({
    "sleep", "urlopen", "recv", "recv_into", "accept", "connect",
    "getresponse", "read", "readline", "readlines", "wait", "wait_for",
    "join", "run", "check_call", "check_output", "communicate", "select",
    "getaddrinfo",
})
# bare open() — a Name call, not an Attribute — blocks too
BLOCKING_NAMES = frozenset({"open", "input"})

# attribute-name tokens that denote a lock-like object: plain locks,
# mutexes, and condition variables (a Condition IS its lock — entering
# ``with self._cv:`` acquires it)
_LOCK_TOKENS = ("lock", "mutex", "cond", "cv")

# methods-called-with-lock-held convention: a method named ``*_locked``
# asserts its callers hold the class lock (the scheduler's
# ``_take_locked``/``_fail_locked`` helpers).  Mutations inside count as
# locked under this synthetic lock name — and blocking calls inside are
# flagged, same as any lexical ``with self.<lock>:`` body.
_LOCKED_METHOD_LOCK = "<caller-held lock>"


def _is_lock_expr(node: ast.expr) -> bool:
    """``self.<lock-like attr>`` — the with-item shape that marks a
    guarded region.  Matches underscore-delimited tokens so ``_cv``
    and ``state_cond`` count while ``_recv`` does not."""
    if not (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return False
    return any(tok in _LOCK_TOKENS
               for tok in node.attr.lower().split("_") if tok)


def _self_attr(node: ast.expr) -> str | None:
    """The attribute name when ``node`` is exactly ``self.<attr>``."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _mutation_targets(node: ast.AST) -> list[str]:
    """Attribute names this statement/expression mutates on ``self``."""
    hit: list[str] = []
    if isinstance(node, (ast.Assign, ast.AugAssign)):
        targets = node.targets if isinstance(node, ast.Assign) \
            else [node.target]
        for t in targets:
            attr = _self_attr(t)
            if attr is None and isinstance(t, ast.Subscript):
                attr = _self_attr(t.value)        # self.x[k] = v
            if attr is not None:
                hit.append(attr)
    elif isinstance(node, ast.Call):
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in MUTATOR_METHODS:
            attr = _self_attr(node.func.value)    # self.x.append(v)
            if attr is not None:
                hit.append(attr)
        for arg in node.args:                     # f(self.x, ...) may mutate
            attr = _self_attr(arg)
            if attr is not None:
                hit.append(attr)
    return hit


def _call_name(node: ast.Call) -> str:
    if isinstance(node.func, ast.Attribute):
        return node.func.attr
    if isinstance(node.func, ast.Name):
        return node.func.id
    return ""


class _ClassWalker(ast.NodeVisitor):
    """Collect per-class mutation and under-lock call sites."""

    def __init__(self):
        # (attr, lineno, method, locks_held: frozenset[str])
        self.mutations: list[tuple[str, int, str, frozenset]] = []
        # (call_name, lineno, lock_attr)
        self.locked_calls: list[tuple[str, int, str]] = []
        self._method = ""
        self._locks: list[str] = []

    def visit_FunctionDef(self, node: ast.FunctionDef):
        prev, self._method = self._method, node.name
        held = node.name.endswith("_locked")
        if held:
            self._locks.append(_LOCKED_METHOD_LOCK)
        self.generic_visit(node)
        if held:
            self._locks.pop()
        self._method = prev

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_With(self, node: ast.With):
        held = [item.context_expr.attr for item in node.items
                if _is_lock_expr(item.context_expr)]
        self._locks.extend(held)
        self.generic_visit(node)
        if held:
            del self._locks[-len(held):]

    def generic_visit(self, node):
        for attr in _mutation_targets(node):
            self.mutations.append((attr, node.lineno, self._method,
                                   frozenset(self._locks)))
        if isinstance(node, ast.Call) and self._locks:
            name = _call_name(node)
            blocking = (name in BLOCKING_CALLS
                        if isinstance(node.func, ast.Attribute)
                        else name in BLOCKING_NAMES)
            if blocking and self._is_sanctioned_wait(node, name):
                blocking = False
            if blocking:
                self.locked_calls.append((name, node.lineno,
                                          self._locks[-1]))
        super().generic_visit(node)

    def _is_sanctioned_wait(self, node: ast.Call, name: str) -> bool:
        """``self.<cv>.wait()`` / ``.wait_for()`` on a condition variable
        the block is HOLDING is the one blocking call condition-variable
        code cannot exist without — wait atomically releases the lock
        while sleeping, so it never pins other threads the way the rule's
        other targets do.  Waiting on anything else (an Event, a foreign
        lock) under a held lock stays flagged: that is a real deadlock
        shape."""
        return (name in ("wait", "wait_for")
                and isinstance(node.func, ast.Attribute)
                and _is_lock_expr(node.func.value)
                and node.func.value.attr in self._locks)


def _walk_classes(tree: ast.Module):
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            w = _ClassWalker()
            for stmt in node.body:
                w.visit(stmt)
            yield node.name, w


def check_lock_discipline(tree: ast.Module, path: str) -> list[Violation]:
    """Guarded attributes mutated without their lock (rule
    ``serve-lock-discipline``)."""
    out = []
    for cls, w in _walk_classes(tree):
        guarded = {attr for attr, _, method, locks in w.mutations
                   if locks and method != "__init__"}
        for attr, lineno, method, locks in w.mutations:
            if attr in guarded and not locks and method != "__init__":
                out.append(Violation(
                    rule="serve-lock-discipline",
                    exec_key=os.path.basename(path),
                    location=f"{path}:{lineno}",
                    message=(f"{cls}.{attr} is lock-guarded elsewhere but "
                             f"mutated in {method}() with no lock held — "
                             f"handler threads race on it")))
    return out


def check_blocking_under_lock(tree: ast.Module, path: str
                              ) -> list[Violation]:
    """Blocking calls lexically inside a ``with self.<lock>:`` body (rule
    ``serve-blocking-under-lock``)."""
    out = []
    for cls, w in _walk_classes(tree):
        for name, lineno, lock in w.locked_calls:
            out.append(Violation(
                rule="serve-blocking-under-lock",
                exec_key=os.path.basename(path),
                location=f"{path}:{lineno}",
                message=(f"{cls} calls blocking {name}() while holding "
                         f"self.{lock} — every handler thread queues "
                         f"behind it")))
    return out


def lint_source(source: str, path: str = "<string>") -> list[Violation]:
    """Run both concurrency checks over one module's source."""
    tree = ast.parse(source, filename=path)
    return (check_lock_discipline(tree, path)
            + check_blocking_under_lock(tree, path))


def lint_files(paths) -> tuple[list[Violation], int]:
    """Lint source files; returns (violations, files_checked)."""
    violations: list[Violation] = []
    n = 0
    for p in paths:
        with open(p) as f:
            violations.extend(lint_source(f.read(), p))
        n += 1
    return violations, n


def serve_sources() -> list[str]:
    """The ``repro/serve`` module files, located relative to this package
    (no repro.serve import — that may pull jax via daemon)."""
    pkg = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    serve = os.path.join(pkg, "serve")
    return sorted(os.path.join(serve, f) for f in os.listdir(serve)
                  if f.endswith(".py"))
