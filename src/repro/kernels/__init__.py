"""Pallas TPU kernels for the gather/scatter hot paths.

Each kernel package has:
    kernel.py  -- pl.pallas_call + BlockSpec (the TPU kernel proper)
    ops.py     -- jit'd public wrapper (padding, mode selection, interpret)
    ref.py     -- pure-jnp oracle used by tests

All kernels are validated on CPU with interpret=True against ref.py across
shape/dtype sweeps (tests/test_kernels_*.py).
"""
