"""Shared HLO / StableHLO text parsing (DESIGN.md §15).

One walker for the two textual formats JAX shows us:

  * **optimized HLO** (``compiled.as_text()``) — shapes spelled
    ``f32[4,8193,1]``; consumed by ``launch.roofline``'s computation
    walk (``shape_bytes`` / ``shape_dims``).
  * **lowered StableHLO** (``fn.lower(*avals).as_text()``) — MLIR
    ``tensor<4x8193x1xf32>`` types plus module attributes
    (``mhlo.num_partitions``, ``mhlo.sharding``, donation markers);
    consumed by ``core.tracing.hlo_stats`` and the static traffic
    accounting in ``analysis.cost`` (``main_io_bytes``).

PR 6 landed the dtype table and shape regexes twice (``launch/roofline``
and ``core/tracing`` each carried a private copy); this module is now
the single home — both re-export from here.  Stdlib-only on purpose:
report tooling parses HLO text without importing jax.
"""
from __future__ import annotations

import re

# --------------------------------------------------------------------------
# optimized-HLO side: f32[4,8193,1]
# --------------------------------------------------------------------------

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def shape_bytes(type_str: str) -> int:
    """Total bytes of every ``dtype[dims]`` shape in an HLO type string.

    Tuple types contribute the sum of their elements; unknown dtypes
    (opaque, token) are skipped.
    """
    total = 0
    for dtype, dims in SHAPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def shape_dims(type_str: str) -> list[int]:
    """Dimensions of the first shape in an HLO type string."""
    m = SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


# --------------------------------------------------------------------------
# StableHLO (MLIR) side: tensor<4x8193x1xf32>
# --------------------------------------------------------------------------

TENSOR_RE = re.compile(r"tensor<([^>]*)>")

# MLIR element types; signless iN covers both signed and unsigned jax ints
MLIR_DTYPE_BYTES = {
    "i1": 1, "i8": 1, "ui8": 1, "f8E4M3FN": 1, "f8E5M2": 1,
    "i16": 2, "ui16": 2, "f16": 2, "bf16": 2,
    "i32": 4, "ui32": 4, "f32": 4,
    "i64": 8, "ui64": 8, "f64": 8,
}


def tensor_bytes(inner: str) -> int:
    """Bytes of one MLIR tensor type body (``4x9x1xf32``, ``f32``, ...).

    Unknown element types (complex, dynamic ``?`` dims) count as 0 —
    same convention as ``shape_bytes`` skipping opaque dtypes.
    """
    parts = inner.split("x")
    elem = parts[-1]
    if elem not in MLIR_DTYPE_BYTES:
        return 0
    n = 1
    for d in parts[:-1]:
        if not d.isdigit():
            return 0
        n *= int(d)
    return n * MLIR_DTYPE_BYTES[elem]


_MAIN_RE = re.compile(r"func\.func\s+(?:public\s+)?@main\s*\(")


def main_signature(text: str) -> tuple[str, str]:
    """The ``@main`` argument and result type substrings of a lowered
    StableHLO module (balanced-paren scan; the signature may wrap).

    Returns ``(args, results)`` raw text; ``("", "")`` if no ``@main``.
    """
    m = _MAIN_RE.search(text)
    if not m:
        return "", ""
    # attribute strings ("{devices=[4,2]<=[8]}") contain unbalanced
    # brackets — both scans must skip over quoted spans
    i = m.end()                       # just past the arg-list "("
    depth = 1
    start = i
    while i < len(text) and depth:
        c = text[i]
        if c == '"':
            i = text.find('"', i + 1)
            if i < 0:
                return text[start:], ""
        elif c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
        i += 1
    args = text[start:i - 1]
    rest = text[i:]
    arrow = rest.find("->")
    if arrow < 0:
        return args, ""
    # results run from "->" to the body "{" at paren depth 0
    j = arrow + 2
    depth = 0
    while j < len(rest):
        c = rest[j]
        if c == '"':
            j = rest.find('"', j + 1)
            if j < 0:
                break
        elif c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
        elif c == "{" and depth == 0:
            break
        j += 1
    return args, rest[arrow + 2:j]


def main_io_bytes(text: str) -> dict:
    """Launch-boundary traffic of a lowered module: bytes of every
    tensor in the ``@main`` signature.

    ``{"arg_bytes", "result_bytes", "total"}`` — the static analogue of
    what one launch moves across HBM at the executable's boundary
    (global logical shapes; sharding divides them across devices but
    never changes the total).  ``analysis.cost`` reconciles this against
    the byte count predicted from ``ExecKey`` geometry
    (``traffic-conservation``).
    """
    args, results = main_signature(text)
    arg_b = sum(tensor_bytes(t) for t in TENSOR_RE.findall(args))
    res_b = sum(tensor_bytes(t) for t in TENSOR_RE.findall(results))
    return {"arg_bytes": arg_b, "result_bytes": res_b,
            "total": arg_b + res_b}


# --------------------------------------------------------------------------
# lowered-module attribute census (donation / partitioning markers)
# --------------------------------------------------------------------------

RE_PARTITIONS = re.compile(r"num_partitions\s*=\s*(\d+)")
RE_SHARDING = re.compile(r'mhlo\.sharding\s*=\s*"([^"]*)"')
RE_ALIASING = re.compile(r"tf\.aliasing_output|jax\.buffer_donor")


def hlo_stats(text: str) -> dict:
    """Structured census of a lowered module's text
    (``fn.lower(*avals).as_text()``).

    Returns ``num_partitions`` (1 when unpartitioned), the set of
    ``mhlo.sharding`` attribute strings, and ``aliased_params`` — the
    number of input/output aliasing (donation) markers.
    """
    m = RE_PARTITIONS.search(text)
    return {
        "num_partitions": int(m.group(1)) if m else 1,
        "shardings": set(RE_SHARDING.findall(text)),
        "aliased_params": len(RE_ALIASING.findall(text)),
    }
