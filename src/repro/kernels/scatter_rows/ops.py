"""Public jit'd wrapper for the MXU scatter-add kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel

_DEFAULT_BLOCK_V = 128
_DEFAULT_BLOCK_N = 128


def _should_interpret(interpret: bool | None) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit,
                   static_argnames=("v", "block_v", "block_n", "interpret"))
def _scatter_add(idx, vals, v: int, block_v: int, block_n: int,
                 interpret: bool):
    n, d = vals.shape
    idx = idx.astype(jnp.int32)
    pad_n = (-n) % block_n
    if pad_n:
        # padded entries point past every tile -> dropped by the one-hot
        idx = jnp.concatenate(
            [idx, jnp.full((pad_n,), jnp.iinfo(jnp.int32).max, jnp.int32)])
        vals = jnp.concatenate([vals, jnp.zeros((pad_n, d), vals.dtype)])
    v_padded = v + ((-v) % block_v)
    out = kernel.scatter_add_rows_kernel(
        idx, vals, v_padded, block_v=block_v, block_n=block_n,
        interpret=interpret)
    return out[:v]


def scatter_add_rows(idx: jax.Array, vals: jax.Array, v: int, *,
                     block_v: int = _DEFAULT_BLOCK_V,
                     block_n: int = _DEFAULT_BLOCK_N,
                     interpret: bool | None = None) -> jax.Array:
    """Scatter-add ``vals`` (N, D) at row indices ``idx`` (N,) into (V, D).

    Out-of-range indices are dropped (matching ``.at[].add(mode="drop")``).
    """
    if vals.ndim != 2 or idx.ndim != 1 or idx.shape[0] != vals.shape[0]:
        raise ValueError(f"bad shapes idx={idx.shape} vals={vals.shape}")
    block_v = min(block_v, max(8, v))
    block_n = min(block_n, max(8, idx.shape[0]))
    return _scatter_add(idx, vals, v, block_v, block_n,
                        _should_interpret(interpret))
