"""Suite planner: bucketing, executor cache, and batched-vs-per-pattern
numerical equivalence (plan.py DESIGN NOTE)."""
import random

import jax.numpy as jnp
import numpy as np

from repro.core import (BucketSpec, ExecutorCache, GSEngine, Pattern,
                        SuitePlan, execute_bucket, make_pattern, run_plan,
                        run_suite)
from repro.core import backends as B
from repro.core.engine import make_host_buffers
from repro.core.plan import next_pow2


def _suite(n_gather=4, n_scatter=4, count=32):
    pats = []
    for i in range(n_gather):
        pats.append(make_pattern(f"UNIFORM:8:{i + 1}", kind="gather",
                                 delta=8, count=count, name=f"g{i}"))
    for i in range(n_scatter):
        pats.append(make_pattern(f"UNIFORM:8:{i + 1}", kind="scatter",
                                 delta=8, count=count, name=f"s{i}"))
    return pats


# ---------------------------------------------------------------------------
# bucketing
# ---------------------------------------------------------------------------

def test_next_pow2():
    assert [next_pow2(n) for n in (1, 2, 3, 4, 5, 255, 256, 257)] == \
        [1, 2, 4, 4, 8, 256, 256, 512]


def test_bucket_pad_amounts():
    # count*index_len = 32*8 = 256 (already pow2); footprint = 8*31+57 = 305
    p = make_pattern("UNIFORM:8:8", kind="gather", delta=8, count=32)
    spec = BucketSpec.of(p)
    assert spec.idx_len == 256
    assert p.footprint() == 305 and spec.footprint == 512


def test_same_bucket_shares_spec():
    # strides 2..8 with delta 8, count 32: footprints 9..105 all pad to <=128
    a = make_pattern("UNIFORM:8:2", kind="gather", delta=8, count=32)
    b = make_pattern("UNIFORM:8:5", kind="gather", delta=8, count=32)
    assert BucketSpec.of(a).idx_len == BucketSpec.of(b).idx_len
    # but kind always splits buckets
    c = make_pattern("UNIFORM:8:2", kind="scatter", delta=8, count=32)
    assert BucketSpec.of(a) != BucketSpec.of(c)


def test_plan_determinism_and_order():
    pats = _suite()
    p1, p2 = SuitePlan.build(pats), SuitePlan.build(pats)
    assert p1 == p2
    # member positions cover the suite exactly once, plan order is sorted
    members = [i for b in p1.buckets for i in b.members]
    assert sorted(members) == list(range(len(pats)))
    specs = [b.spec for b in p1.buckets]
    assert specs == sorted(specs, key=lambda s: (s.kind, s.idx_len,
                                                 s.footprint))
    # shuffling the suite changes member positions but not the bucket specs
    rng = random.Random(0)
    shuffled = pats[:]
    rng.shuffle(shuffled)
    p3 = SuitePlan.build(shuffled)
    assert [b.spec for b in p3.buckets] == specs


# ---------------------------------------------------------------------------
# cache behavior
# ---------------------------------------------------------------------------

def test_second_run_compiles_nothing():
    pats = _suite()
    cache = ExecutorCache()
    stats1 = run_suite(pats, backend="xla", runs=2, cache=cache)
    misses_after_first = cache.misses
    assert misses_after_first == stats1.plan.n_buckets
    stats2 = run_suite(pats, backend="xla", runs=2, cache=cache)
    assert cache.misses == misses_after_first        # zero new compiles
    assert cache.hits >= stats2.plan.n_buckets


def test_32_pattern_suite_compiles_at_most_buckets():
    # acceptance: 32 patterns on xla compile #buckets (< 32) executables
    pats = []
    for i in range(16):
        pats.append(make_pattern(f"UNIFORM:8:{(i % 8) + 1}", kind="gather",
                                 delta=8, count=32, name=f"g{i}"))
        pats.append(make_pattern(f"UNIFORM:8:{(i % 8) + 1}", kind="scatter",
                                 delta=8, count=32, name=f"s{i}"))
    assert len(pats) == 32
    cache = ExecutorCache()
    stats = run_suite(pats, backend="xla", runs=2, cache=cache)
    assert cache.misses == stats.plan.n_buckets
    assert cache.misses < 32
    # results come back in suite order with the paper's numerator
    for p, r in zip(pats, stats.results):
        assert r.pattern is p
        assert r.measured_gbs > 0 and r.time_s > 0


def test_cache_lru_eviction_recompiles():
    pats = _suite()
    cache = ExecutorCache(maxsize=1)          # every bucket evicts the last
    run_suite(pats, backend="xla", runs=1, cache=cache)
    first = cache.misses
    run_suite(pats, backend="xla", runs=1, cache=cache)
    assert cache.misses > first               # eviction forced recompiles


# ---------------------------------------------------------------------------
# numerical equivalence: batched == per-pattern, all backends, all modes
# ---------------------------------------------------------------------------

def test_batched_gather_matches_engine_exactly():
    pats = [make_pattern(f"UNIFORM:4:{s}", kind="gather", delta=4, count=16,
                         name=f"g{s}") for s in (1, 2, 3, 5)]
    plan = SuitePlan.build(pats)
    cache = ExecutorCache()
    for backend in B.BACKENDS:
        for bucket in plan.buckets:
            outs = execute_bucket(plan, bucket, backend=backend, cache=cache)
            for out, pos in zip(outs, bucket.members):
                fn, args = GSEngine(pats[pos], backend=backend).build()
                ref = np.asarray(fn(*args))
                np.testing.assert_array_equal(
                    out, ref, err_msg=f"{backend}/{pats[pos].name}")


def test_batched_scatter_matches_unbatched_both_modes():
    # delta 2 < index span -> duplicate writes exercise dedup + add order
    pats = [make_pattern(f"UNIFORM:4:{s}", kind="scatter", delta=2, count=16,
                         name=f"s{s}") for s in (1, 2, 3, 5)]
    plan = SuitePlan.build(pats)
    for backend in B.BACKENDS:
        for mode in ("store", "add"):
            cache = ExecutorCache()
            for bucket in plan.buckets:
                outs = execute_bucket(plan, bucket, backend=backend,
                                      mode=mode, cache=cache)
                for out, pos in zip(outs, bucket.members):
                    p = pats[pos]
                    _, abs_idx, vals, _ = make_host_buffers(p, 1)
                    dst = jnp.zeros((p.footprint(), 1), jnp.float32)
                    ref = np.asarray(B.scatter(
                        dst, jnp.asarray(abs_idx), jnp.asarray(vals),
                        mode=mode, backend=backend))
                    if backend == "onehot" and mode == "add":
                        # onehot-add is a matmul; vmap reassociates the
                        # contraction, so agreement is up to f32 rounding
                        np.testing.assert_allclose(
                            out, ref, rtol=1e-6, atol=1e-6,
                            err_msg=f"{backend}/{mode}/{p.name}")
                    else:
                        np.testing.assert_array_equal(
                            out, ref, err_msg=f"{backend}/{mode}/{p.name}")


def test_padded_lanes_stay_in_scratch():
    # footprint 11 pads to a 16-row bucket + scratch; count*idx_len 24 -> 32.
    # If any padded lane leaked into a real row the store result would
    # differ from the unbatched reference on untouched rows.
    p = Pattern("odd", "scatter", (0, 3, 10), delta=0, count=8)
    plan = SuitePlan.build([p])
    spec = plan.buckets[0].spec
    assert spec.idx_len == 32 and spec.footprint == 16
    outs = execute_bucket(plan, plan.buckets[0], backend="xla", mode="store")
    _, abs_idx, vals, _ = make_host_buffers(p, 1)
    dst = jnp.zeros((p.footprint(), 1), jnp.float32)
    ref = np.asarray(B.scatter(dst, jnp.asarray(abs_idx),
                               jnp.asarray(vals), mode="store",
                               backend="xla"))
    np.testing.assert_array_equal(outs[0], ref)
    untouched = [i for i in range(p.footprint()) if i not in p.index]
    assert np.all(outs[0][untouched] == 0)


def test_suite_stats_table_respects_metric():
    stats = run_suite(_suite(n_gather=2, n_scatter=0), backend="xla",
                      runs=1, cache=ExecutorCache())
    measured = stats.table("measured")
    modeled = stats.table("modeled")
    for row_m, row_v, r in zip(measured, modeled, stats.results):
        assert row_m["gbs"] == r.measured_gbs == row_m["measured_cpu_gbs"]
        assert row_v["gbs"] == r.modeled_gbs == row_v["modeled_v5e_gbs"]
    # full column names work as aliases; unknown metrics raise
    assert stats.table("modeled_v5e_gbs") == modeled
    try:
        stats.table("bogus")
    except ValueError:
        pass
    else:
        raise AssertionError("table() accepted an unknown metric")


def test_run_suite_rejects_unknown_metric():
    pats = _suite(n_gather=1, n_scatter=0)
    try:
        run_suite(pats, metric="measurd", runs=1, cache=ExecutorCache())
    except ValueError as e:
        assert "metric" in str(e)
    else:
        raise AssertionError("run_suite accepted a typo'd metric")
    # the modeled alias orders stats by the modeled column
    stats = run_suite(pats, metric="modeled", runs=1, cache=ExecutorCache())
    assert stats.min_gbs == stats.results[0].modeled_gbs


def test_run_plan_bandwidth_uses_useful_bytes_only():
    # pattern with heavy padding: numerator must still be count*index_len
    p = make_pattern("UNIFORM:5:1", kind="gather", delta=5, count=13)
    plan = SuitePlan.build([p])
    res = run_plan(plan, backend="xla", runs=2, cache=ExecutorCache())[0]
    useful = p.index_len * p.count * 4
    np.testing.assert_allclose(res.measured_gbs,
                               useful / res.time_s / 1e9, rtol=1e-9)


# ---------------------------------------------------------------------------
# run_suite mode= / stream_r / digest / to_json (PR 4 satellites)
# ---------------------------------------------------------------------------

def test_run_suite_mode_reaches_scatters():
    # duplicate-write pattern: add accumulates, store keeps the last write,
    # so the two modes must produce different outputs (witnessed by digest)
    dup = [Pattern("dup", "scatter", (0, 0, 1), delta=0, count=8)]
    st_store = run_suite(dup, runs=1, mode="store", cache=ExecutorCache(),
                         digest=True)
    st_add = run_suite(dup, runs=1, mode="add", cache=ExecutorCache(),
                       digest=True)
    assert st_store.results[0].out_digest != st_add.results[0].out_digest
    # the unbatched path takes mode= too and agrees with the planner
    st_nb = run_suite(dup, runs=1, mode="add", batch=False)
    assert st_nb.results[0].measured_gbs > 0


def test_run_suite_rejects_unknown_mode():
    pats = _suite(n_gather=1, n_scatter=0)
    try:
        run_suite(pats, mode="min", runs=1, cache=ExecutorCache())
    except ValueError as e:
        assert "mode" in str(e)
    else:
        raise AssertionError("run_suite accepted an unknown mode")


def test_run_suite_stream_r_wires_the_reference():
    # row_width 8: the v5e tile model separates stride 1 / 64 / MS1, so
    # both correlated columns have variance and R is defined
    pats = [make_pattern(f"UNIFORM:8:{s}", kind="gather", delta=8,
                         count=64, name=f"g{s}") for s in (1, 16, 64)]
    pats.append(make_pattern("MS1:8:4:64", kind="gather", delta=8,
                             count=64, name="ms1"))
    stats = run_suite(pats, runs=1, row_width=8, cache=ExecutorCache(),
                      stream_r=True, stream_n=1024)
    assert stats.stream_gbs is not None and stats.stream_gbs > 0
    # R is a correlation: defined and bounded
    assert -1.0 <= stats.stream_r <= 1.0
    # default: the reference never runs and the fields stay None
    stats2 = run_suite(pats, runs=1, cache=ExecutorCache())
    assert stats2.stream_gbs is None and stats2.stream_r is None


def test_suite_stats_to_json_is_strict_json():
    import json as _json
    pats = _suite(n_gather=2, n_scatter=1)
    stats = run_suite(pats, runs=1, cache=ExecutorCache(), digest=True)
    doc = stats.to_json("measured")
    _json.loads(_json.dumps(doc, allow_nan=False))    # strict JSON
    assert doc["n_patterns"] == 3 and doc["n_buckets"] == stats.plan.n_buckets
    assert [r["name"] for r in doc["table"]] == [p.name for p in pats]
    assert all(len(r["digest"]) == 64 for r in doc["table"])
    # NaN stream_r serializes as null
    one = run_suite(pats[:1], runs=1, cache=ExecutorCache(),
                    stream_r=True, stream_n=1024)
    assert np.isnan(one.stream_r)
    assert one.to_json()["stream_r"] is None


def test_run_plan_digest_deterministic_across_caches():
    pats = _suite(n_gather=2, n_scatter=2)
    plan = SuitePlan.build(pats)
    r1 = run_plan(plan, runs=1, cache=ExecutorCache(), digest=True)
    r2 = run_plan(plan, runs=1, cache=ExecutorCache(), digest=True)
    assert [r.out_digest for r in r1] == [r.out_digest for r in r2]
    assert all(r.out_digest for r in r1)
    # digest off by default: results carry None
    r3 = run_plan(plan, runs=1, cache=ExecutorCache())
    assert all(r.out_digest is None for r in r3)
