"""Extract Spatter patterns from a real model — the paper's §2 for JAX.

The paper traced DoE mini-apps through an instrumented QEMU to harvest
gather/scatter patterns (Table 5).  Here we trace an LLM's jaxpr instead:
every gather/scatter/dynamic-slice primitive is harvested with its byte
volume (Table 1's "G/S MB (%)" column) and distilled into replayable
patterns.

    PYTHONPATH=src python examples/trace_model_patterns.py [arch]
"""
import dataclasses
import sys

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import dump_suite, run_suite, trace_gs
from repro.models import transformer as T
from repro.models.zoo import Model

arch = sys.argv[1] if len(sys.argv) > 1 else "deepseek-v2-236b"
cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
model = Model(cfg)
params = model.abstract_params(jnp.float32)

print(f"=== tracing {cfg.arch_id} (reduced config) forward pass ===")
report = trace_gs(lambda p, t: T.forward(cfg, p, t)[0],
                  params, jax.ShapeDtypeStruct((2, 64), jnp.int32))
print(report.summary())

print("\n=== distilled Spatter patterns (replayable) ===")
patterns = report.to_patterns()[:6]
print(dump_suite(patterns))

print("\n=== replaying them through the engine ===")
stats = run_suite(patterns, runs=2)
for r in stats.results:
    print(f"{r.pattern.name:24s} rows={r.pattern.count:<8} "
          f"row_elems={r.pattern.index_len:<6} {r.measured_gbs:6.2f} GB/s")
