"""Distributed runtime: sharding rules, step functions, fault tolerance."""
