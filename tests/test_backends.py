"""Cross-backend equivalence — hypothesis property tests.

The paper's four backends must be bit-compatible up to dtype rounding:
gather is exact; scatter-add is compared with tolerance (summation order).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dep: property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import backends as B

BACKENDS = list(B.BACKENDS)


def _src(f, r, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((f, r)), jnp.float32)


@st.composite
def gather_case(draw):
    f = draw(st.integers(4, 200))
    r = draw(st.sampled_from([1, 2, 8, 128]))
    n = draw(st.integers(1, 300))
    idx = draw(st.lists(st.integers(0, f - 1), min_size=n, max_size=n))
    return f, r, np.asarray(idx, np.int32)


@settings(max_examples=25, deadline=None)
@given(gather_case())
def test_gather_backends_agree(case):
    f, r, idx = case
    src = _src(f, r)
    ref = np.asarray(src)[idx]
    for b in BACKENDS:
        out = B.gather(src, jnp.asarray(idx), backend=b)
        np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-6,
                                   err_msg=f"backend={b}")


@settings(max_examples=25, deadline=None)
@given(gather_case())
def test_scatter_add_backends_agree(case):
    f, r, idx = case
    vals = _src(len(idx), r, seed=1)
    dst = jnp.zeros((f, r), jnp.float32)
    ref = np.zeros((f, r), np.float32)
    np.add.at(ref, idx, np.asarray(vals))
    for b in BACKENDS:
        out = B.scatter(dst, jnp.asarray(idx), vals, mode="add", backend=b)
        np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4,
                                   atol=1e-5, err_msg=f"backend={b}")


@settings(max_examples=15, deadline=None)
@given(gather_case())
def test_scatter_store_last_write_wins(case):
    """Store semantics are pinned to deterministic last-write-wins on every
    backend (the paper leaves duplicate order unspecified; we don't)."""
    f, r, idx = case
    vals = _src(len(idx), r, seed=2)
    dst = _src(f, r, seed=3)
    ref = np.asarray(dst).copy()
    for i, j in enumerate(idx):            # sequential = last write wins
        ref[j] = np.asarray(vals)[i]
    for b in BACKENDS:
        out = B.scatter(dst, jnp.asarray(idx), vals, mode="store", backend=b)
        np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-6,
                                   err_msg=f"backend={b}")


def test_onehot_guard():
    big = jnp.zeros((B._ONEHOT_MAX_FOOTPRINT + 1, 1))
    with pytest.raises(ValueError):
        B.gather_onehot(big, jnp.zeros((4,), jnp.int32))


def test_engine_end_to_end():
    from repro.core import GSEngine, make_pattern
    p = make_pattern("UNIFORM:8:2", kind="gather", delta=4, count=64)
    for b in BACKENDS:
        r = GSEngine(p, backend=b).run(runs=2)
        assert r.measured_gbs > 0
        assert r.time_s > 0
    ps = make_pattern("UNIFORM:8:2", kind="scatter", delta=4, count=64)
    r = GSEngine(ps, backend="xla").run(runs=2)
    assert r.measured_gbs > 0


def test_sharded_engine_subprocess():
    """GSEngine.sharded(): the count dim splits over the data axis (the
    paper's OpenMP-thread dimension) — 8 fake devices, subprocess."""
    import os
    import subprocess
    import sys
    import textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, %r)
        import jax, numpy as np
        from repro.core import GSEngine, make_pattern

        mesh = jax.make_mesh((8,), ("data",))
        p = make_pattern("UNIFORM:8:2", kind="gather", delta=16, count=128)
        eng = GSEngine(p, backend="xla")
        fn, args = eng.sharded(mesh, "data")
        out = fn(*args)
        # oracle: unsharded gather
        src, idx = args
        ref = np.asarray(src)[np.asarray(idx)]
        assert np.allclose(np.asarray(out), ref)
        # sharded scatter keeps build()'s store (last-write-wins) semantics,
        # including on duplicate indices (delta 4 < span 15 -> overlaps)
        import jax.numpy as jnp
        from repro.core import backends as B
        ps = make_pattern("UNIFORM:8:2", kind="scatter", delta=4, count=128)
        engs = GSEngine(ps, backend="xla")
        fns, argss = engs.sharded(mesh, "data")
        dst, idx, vals, keep = argss
        outs = fns(dst, idx, vals, keep)
        ref = np.asarray(B.scatter(jnp.zeros_like(dst), idx, vals,
                                   mode="store", backend="xla"))
        assert np.array_equal(np.asarray(outs), ref)
        print("OK")
    """) % os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                        "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
