"""Scalar-prefetch DMA row gather — the TPU-native Spatter gather kernel.

Two regimes, mirroring the paper's cache-resident vs memory-resident split
(DESIGN.md §2):

  * ``dma``  — the table stays in HBM; the index buffer is scalar-prefetched
    into SMEM and drives the input ``BlockSpec.index_map``, so the *DMA
    engine itself* performs the gather.  Each grid step covers ``block_i``
    rows (multi-row blocking): the table operand is bound ``block_i``
    times, each binding's index_map selecting one gathered row, so the
    pipeline keeps ``block_i`` row DMAs in flight per step instead of one
    — the TPU analogue of the HW prefetcher's outstanding-miss depth
    studied in paper Fig 4.
  * ``vmem`` — small tables are staged whole into VMEM and gathered with an
    in-register ``take`` over ``block_n`` rows per step (the "cache-resident"
    regime: once the table is in VMEM, arbitrary reuse is free).

The CUDA backend's trick of staging the index buffer in shared memory (paper
§3.2) maps exactly onto scalar prefetch: indices live in SMEM for the whole
kernel invocation.

Both kernels are batch-NATIVE (DESIGN.md §2.2): the grid leads with the
pattern-batch dim so a whole planner bucket — (B, V, D) tables, (B, N)
indices — is ONE launch with the index buffers scalar-prefetched once;
the single-pattern entry point in ops.py is just the B=1 case.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _vmem_take_kernel(block_n: int, idx_ref, table_blk, out_blk):
    b = pl.program_id(0)
    i = pl.program_id(1)
    rows = idx_ref[b, pl.ds(i * block_n, block_n)]
    out_blk[...] = jnp.take(table_blk[0], rows, axis=0)[None]


def gather_rows_vmem(table: jax.Array, idx: jax.Array, *,
                     block_n: int, interpret: bool) -> jax.Array:
    """VMEM-resident gather: (B, V, D) tables, (B, N) idx -> (B, N, D).

    One launch for the whole pattern batch; pattern b's table is staged
    whole per b-step.  Caller guarantees n % block_n == 0 (ops.py pads).
    """
    bsz, n = idx.shape
    _, v, d = table.shape
    assert n % block_n == 0, (n, block_n)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bsz, n // block_n),
        in_specs=[pl.BlockSpec((1, v, d), lambda b, i, idx_ref: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, block_n, d),
                               lambda b, i, idx_ref: (b, i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_vmem_take_kernel, block_n),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, n, d), table.dtype),
        interpret=interpret,
    )(idx, table)


def _copy_rows_kernel(block_i: int, idx_ref, *refs):
    # The gather already happened in the DMA (each table binding's index_map
    # read idx_ref); the body reassembles block_i row-slices into the tile.
    del idx_ref
    row_blks, out_blk = refs[:block_i], refs[block_i]
    for r, blk in enumerate(row_blks):
        out_blk[0, r, :] = blk[0, 0, :]


def gather_rows_dma(table: jax.Array, idx: jax.Array, *,
                    block_d: int, block_i: int, interpret: bool) -> jax.Array:
    """HBM-resident gather: grid (B, N/block_i, D/block_d), block_i rows/step.

    Caller guarantees n % block_i == 0 and d % block_d == 0 (ops.py pads).
    """
    bsz, n = idx.shape
    _, v, d = table.shape
    assert d % block_d == 0, (d, block_d)
    assert n % block_i == 0, (n, block_i)
    grid = (bsz, n // block_i, d // block_d)

    def row_spec(r):
        return pl.BlockSpec(
            (1, 1, block_d),
            lambda b, i, j, idx_ref, r=r: (b, idx_ref[b, i * block_i + r], j))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[row_spec(r) for r in range(block_i)],
        out_specs=pl.BlockSpec((1, block_i, block_d),
                               lambda b, i, j, idx_ref: (b, i, j)),
    )
    return pl.pallas_call(
        functools.partial(_copy_rows_kernel, block_i),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, n, d), table.dtype),
        interpret=interpret,
    )(idx, *([table] * block_i))
