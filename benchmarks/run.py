"""Benchmark entry point: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Emits ``name,us_per_call,derived`` CSV rows.  Mapping (DESIGN.md §7):
    Fig 3/5  -> bench_uniform_stride     Table 4 -> bench_app_patterns
    Fig 4    -> bench_prefetch           Table 3 STREAM -> bench_stream
    Fig 6    -> bench_vector_vs_scalar   beyond-paper   -> bench_llm_gs

The ``suite`` bench additionally writes ``BENCH_suite.json`` (per-pattern
measured/modeled GB/s, per-backend compile counts, pallas launch census) —
the canonical cross-PR perf trajectory record; CI uploads it as an
artifact.  ``--suite-json`` overrides the output path.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer timing repetitions")
    ap.add_argument("--only", default=None,
                    help="run a single bench by name")
    ap.add_argument("--suite-json", default=None, metavar="PATH",
                    help="output path for the suite bench's "
                         "BENCH_suite.json record")
    args = ap.parse_args()
    runs = 3 if args.quick else 5

    from . import (bench_app_patterns, bench_llm_gs, bench_prefetch,
                   bench_roofline, bench_serve, bench_sharded_suite,
                   bench_stream, bench_suite, bench_suite_scaling,
                   bench_uniform_stride, bench_vector_vs_scalar)
    # only an explicit request (--suite-json or --only suite) writes the
    # canonical BENCH_suite.json; a full CSV sweep must not silently
    # clobber a committed baseline in the cwd
    if args.suite_json:
        suite_kw = {"out_path": args.suite_json}
    elif args.only == "suite":
        suite_kw = {}
    else:
        suite_kw = {"out_path": None}
    # same guard for the mesh-shape sweep's merge into BENCH_suite.json
    sharded_kw = {} if args.only == "sharded_suite" else {"out_path": None}
    # and for the cost-model record's cost_model key
    roofline_kw = {} if args.only == "roofline" else {"out_path": None}
    # and for the serving-concurrency sweep's serve_concurrency key
    serve_kw = {} if args.only == "serve" else {"out_path": None}
    benches = {
        "stream": lambda: bench_stream.run(runs=runs),
        "uniform_stride": lambda: bench_uniform_stride.run(runs=runs),
        "prefetch": lambda: bench_prefetch.run(runs=runs),
        "vector_vs_scalar": lambda: bench_vector_vs_scalar.run(runs=runs),
        "app_patterns": lambda: bench_app_patterns.run(runs=runs),
        "llm_gs": lambda: bench_llm_gs.run(runs=runs),
        "roofline": lambda: bench_roofline.run(runs=runs, **roofline_kw),
        "suite_scaling": lambda: bench_suite_scaling.run(runs=runs),
        "sharded_suite": lambda: bench_sharded_suite.run(runs=runs,
                                                         **sharded_kw),
        "suite": lambda: bench_suite.run(runs=runs, **suite_kw),
        "serve": lambda: bench_serve.run(runs=runs, **serve_kw),
    }
    print("name,us_per_call,derived")
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        try:
            fn()
        except Exception as e:      # report, keep the suite running
            print(f"{name}/ERROR,0.0,{type(e).__name__}: {e}",
                  file=sys.stderr)
            raise
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
