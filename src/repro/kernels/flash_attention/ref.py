"""Pure-jnp oracle for flash attention."""
import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, scale: float, causal: bool = True,
                        window: int = 0, softcap: float = 0.0) -> jax.Array:
    """q (B,KVH,G,S,dh); k/v (B,KVH,T,dh) -> (B,KVH,G,S,dh)."""
    s_len, t_len = q.shape[3], k.shape[2]
    s = jnp.einsum("bhgqd,bhtd->bhgqt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = jnp.arange(s_len)[:, None]
    k_pos = jnp.arange(t_len)[None, :]
    mask = jnp.ones((s_len, t_len), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqt,bhtd->bhgqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
