"""repro.core — Spatter's contribution as a composable JAX module.

Public API:
    Pattern, make_pattern, generate_index, load_suite   (pattern language)
    GSEngine, RunResult                                 (executable patterns)
    run_suite, stream_reference, harmonic_mean, pearson_r
    gather, scatter                                     (backend dispatch)
    trace_gs                                            (jaxpr G/S extraction)
    appdb                                               (paper Table 5)
"""
from .pattern import (Pattern, make_pattern, generate_index, load_suite,
                      dump_suite, uniform, ms1, laplacian, broadcast)
from .backends import gather, scatter, BACKENDS
from .engine import GSEngine, RunResult, gs_shardings, SCATTER_MODES
from .plan import (SuitePlan, BucketSpec, Bucket, ExecutorCache, CacheStats,
                   Placement, ShardedExecutor, as_placement, run_plan,
                   execute_bucket, default_cache, pad_batch, pad_lanes)
from .diskcache import DiskTier, RestoredExecutable, exec_key_str
from .suite import run_suite, run_suite_file, stream_reference, \
    harmonic_mean, pearson_r, SuiteStats
from .tracing import trace_gs, TraceReport, TracedAccess
from . import appdb, bandwidth, compat

__all__ = [
    "Pattern", "make_pattern", "generate_index", "load_suite", "dump_suite",
    "uniform", "ms1", "laplacian", "broadcast",
    "gather", "scatter", "BACKENDS",
    "GSEngine", "RunResult", "gs_shardings", "SCATTER_MODES",
    "SuitePlan", "BucketSpec", "Bucket", "ExecutorCache", "CacheStats",
    "Placement", "ShardedExecutor", "as_placement",
    "run_plan", "execute_bucket", "default_cache", "pad_batch", "pad_lanes",
    "DiskTier", "RestoredExecutable", "exec_key_str",
    "run_suite", "run_suite_file", "stream_reference", "harmonic_mean",
    "pearson_r", "SuiteStats",
    "trace_gs", "TraceReport", "TracedAccess",
    "appdb", "bandwidth", "compat",
]
