"""Multi-pattern suite runner — the paper's JSON-input mode (§3.3, §3.5).

Runs many patterns, then reports the aggregate stats the paper reports:
per-pattern bandwidths, suite min/max, harmonic mean, and — opt-in via
``stream_r=True`` — Pearson's R against a STREAM-like reference (paper
Eq. 1 / Table 4): the suite runs alongside ``stream_reference()`` and
``SuiteStats.stream_r`` correlates each pattern's measured-over-STREAM
fraction with its modeled-over-STREAM fraction.

Execution goes through the suite planner by default (``batch=True``):
patterns are grouped into shape buckets and each bucket runs as one
vmapped launch through a process-wide executable cache, so an N-pattern
suite compiles #buckets executables instead of N and repeated suite runs
compile nothing.  See the DESIGN NOTE in plan.py for the full plan ->
compile -> execute design and the padding/scratch-row semantics.
``batch=False`` restores the original one-GSEngine-per-pattern path.
``mesh=`` places every bucket launch on a 2-D (pattern-batch x lane)
device mesh (plan.Placement, DESIGN.md §11) for multi-device suite
runs; it accepts an int N (batch-only), a ``(b, l)`` tuple, a raw Mesh
(batch-only over ``mesh_axis``), a ``Placement``, or the strings
``"auto"`` (per-bucket cost-model placement, DESIGN.md §15/§16) and
``"auto-suite"`` (one cost-model shape for the whole suite).
``mode=`` selects scatter write semantics ("store" last-write-wins —
the paper's default — or "add" accumulation) on every path.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .engine import SCATTER_MODES, GSEngine, RunResult
from .pattern import Pattern, load_suite, make_pattern
from .plan import ExecutorCache, SuitePlan, as_placement, run_plan


# metric aliases -> the RunResult.row() column they select
_METRIC_COLUMNS = {
    "measured": "measured_cpu_gbs",
    "measured_cpu_gbs": "measured_cpu_gbs",
    "modeled": "modeled_v5e_gbs",
    "modeled_v5e_gbs": "modeled_v5e_gbs",
}


def _metric_column(metric: str) -> str:
    col = _METRIC_COLUMNS.get(metric)
    if col is None:
        raise ValueError(f"unknown metric {metric!r}; "
                         f"expected one of {sorted(_METRIC_COLUMNS)}")
    return col


@dataclasses.dataclass
class SuiteStats:
    results: list[RunResult]
    min_gbs: float
    max_gbs: float
    hmean_gbs: float
    plan: SuitePlan | None = None        # set when the batched path ran
    stream_gbs: float | None = None      # measured STREAM-like GB/s
                                         # (run_suite(stream_r=True))
    stream_r: float | None = None        # paper Eq. 1 Pearson's R of the
                                         # STREAM-normalized bandwidths

    def table(self, metric: str = "measured_cpu_gbs") -> list[dict]:
        """Per-pattern rows with ``gbs`` set to the requested metric.

        ``metric`` picks which bandwidth column ("measured"/"modeled", or
        the full row() column names) populates the uniform ``gbs`` field;
        unknown metrics raise ValueError.
        """
        col = _metric_column(metric)
        rows = []
        for r in self.results:
            row = r.row()
            row["gbs"] = row[col]
            rows.append(row)
        return rows

    def to_json(self, metric: str = "measured") -> dict:
        """JSON-safe dict: aggregates + the per-pattern ``table(metric)``.

        Non-finite aggregates (e.g. a NaN ``stream_r`` on a degenerate
        suite) serialize as null so the document stays strict JSON — the
        serving daemon's response embeds this verbatim.
        """
        def _f(x):
            return x if x is not None and math.isfinite(x) else None
        table = [{k: (_f(v) if isinstance(v, float) else v)
                  for k, v in row.items()}
                 for row in self.table(metric)]
        return {
            "metric": _metric_column(metric),
            "n_patterns": len(self.results),
            "min_gbs": _f(self.min_gbs),
            "max_gbs": _f(self.max_gbs),
            "hmean_gbs": _f(self.hmean_gbs),
            "stream_gbs": _f(self.stream_gbs),
            "stream_r": _f(self.stream_r),
            "n_buckets": self.plan.n_buckets if self.plan else None,
            "table": table,
        }


def harmonic_mean(xs) -> float:
    xs = [x for x in xs if x > 0]
    if not xs:
        return 0.0
    return len(xs) / sum(1.0 / x for x in xs)


def pearson_r(xs, ys) -> float:
    """Paper Eq. (1): R = cov(X, STREAM) / (std(X)·std(STREAM))."""
    x, y = np.asarray(xs, float), np.asarray(ys, float)
    if x.size < 2 or x.std() == 0 or y.std() == 0:
        return float("nan")
    return float(np.corrcoef(x, y)[0, 1])


def aggregate_stats(results: list[RunResult], *, metric: str = "measured",
                    plan: SuitePlan | None = None,
                    stream_ref: RunResult | None = None) -> SuiteStats:
    """Fold per-pattern RunResults into the paper's §3.5 aggregates.

    The single aggregation point shared by ``run_suite`` and the serving
    scheduler (serve/daemon.py builds SuiteStats from demuxed scheduler
    results) — min/max/harmonic-mean over the requested metric column,
    plus, when a STREAM reference run is supplied, paper Eq. 1: Pearson's
    R between each pattern's measured/STREAM fraction and its
    modeled/STREAM fraction.  R is scale-invariant, so dividing each
    series by its platform's STREAM bandwidth cannot change it; it is
    computed on the raw columns and the reference run kept only for the
    paper-style ``stream_gbs`` anchor the fractions are read against.
    """
    if not results:
        raise ValueError("aggregate_stats needs at least one result")
    col = _metric_column(metric)
    key = (lambda r: r.measured_gbs) if col == "measured_cpu_gbs" \
        else (lambda r: r.modeled_gbs)
    vals = [key(r) for r in results]
    stream_gbs = r_val = None
    if stream_ref is not None:
        stream_gbs = stream_ref.measured_gbs
        r_val = pearson_r([r.measured_gbs for r in results],
                          [r.modeled_gbs for r in results])
    return SuiteStats(
        results=list(results),
        min_gbs=min(vals), max_gbs=max(vals),
        hmean_gbs=harmonic_mean(vals),
        plan=plan,
        stream_gbs=stream_gbs, stream_r=r_val,
    )


def run_suite(patterns: list[Pattern], *, backend: str = "xla",
              dtype=None, row_width: int = 1, runs: int = 10,
              metric: str = "measured", mode: str = "store",
              batch: bool = True, seed: int = 0,
              cache: ExecutorCache | None = None,
              mesh=None, mesh_axis: str = "data",
              stream_r: bool = False, stream_n: int = 2 ** 22,
              stream_ref: RunResult | None = None,
              digest: bool = False) -> SuiteStats:
    """Run a pattern suite and aggregate the paper's §3.5 statistics.

    ``mode`` applies to every scatter in the suite on both execution
    paths (the planner and ``batch=False``'s per-pattern engines).
    ``stream_r`` additionally times a STREAM-like reference
    (``stream_reference(n=stream_n)``) and reports paper Eq. 1: Pearson's
    R between each pattern's measured/STREAM fraction and its
    modeled/STREAM fraction (``SuiteStats.stream_r``; NaN for suites with
    fewer than two patterns or zero variance).  Passing a precomputed
    ``stream_ref`` RunResult skips the reference run — the serving daemon
    memoizes one per (backend, stream_n, runs) so warm requests stay
    execute-only.  ``digest`` attaches a
    sha256 of each pattern's computed output (planner path only) — the
    serving layer's bit-identity proof for repeated requests.
    """
    import jax.numpy as jnp
    if not patterns:
        raise ValueError("run_suite needs at least one pattern")
    col = _metric_column(metric)            # reject typos up front
    if mode not in SCATTER_MODES:           # mirror the metric validation
        raise ValueError(f"unknown mode {mode!r}; "
                         f"expected one of {SCATTER_MODES}")
    # mesh="auto" / "auto-suite": deferred to run_plan, which resolves
    # them through the §15 cost model (per-bucket / one-shape-per-suite
    # respectively).  The selections name plain (batch, lane) shapes, so
    # the ExecKeys (and digests) are exactly what the same explicit
    # meshes would produce.  Every other accepted mesh= form (int,
    # (b, l) tuple, Mesh, Placement) is normalized up front so
    # shape/device-count errors surface here, with this function's
    # signature in the traceback, not mid-plan.
    if isinstance(mesh, str):
        if mesh not in ("auto", "auto-suite"):
            raise ValueError(f"unknown mesh string {mesh!r}; "
                             f"expected 'auto' or 'auto-suite'")
    elif isinstance(mesh, list):
        # explicit per-bucket placements (what "auto" resolves to)
        mesh = [as_placement(m, mesh_axis) for m in mesh]
    else:
        mesh = as_placement(mesh, mesh_axis)
    if mesh is not None and not batch:
        raise ValueError("mesh execution requires the batched planner "
                         "(batch=True)")
    if digest and not batch:
        raise ValueError("digest requires the batched planner "
                         "(batch=True)")
    dtype = dtype or jnp.float32
    plan = None
    if batch:
        plan = SuitePlan.build(patterns)
        results = run_plan(plan, backend=backend, dtype=dtype,
                           row_width=row_width, runs=runs, mode=mode,
                           seed=seed, cache=cache,
                           mesh=mesh, mesh_axis=mesh_axis, digest=digest)
    else:
        results = []
        for p in patterns:
            eng = GSEngine(p, backend=backend, dtype=dtype,
                           row_width=row_width, mode=mode, seed=seed)
            results.append(eng.run(runs=runs))
    ref = None
    if stream_r:
        ref = stream_ref if stream_ref is not None else \
            stream_reference(n=stream_n, runs=runs, backend=backend)
    return aggregate_stats(results, metric=metric, plan=plan,
                           stream_ref=ref)


def run_suite_file(path: str, **kw) -> SuiteStats:
    return run_suite(load_suite(path), **kw)


def stream_reference(*, n: int = 2 ** 22, runs: int = 10,
                     backend: str = "xla") -> RunResult:
    """STREAM-copy analogue (paper §3.4): UNIFORM:8:1 with delta 8."""
    p = make_pattern("UNIFORM:8:1", kind="gather", delta=8, count=n // 8,
                     name="STREAM-like")
    return GSEngine(p, backend=backend).run(runs=runs)
