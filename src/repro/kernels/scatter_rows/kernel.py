"""MXU one-hot scatter-add — the TPU-native Spatter scatter kernel.

CPU/GPU scatter relies on hardware write combining / atomics; the TPU has
neither at kernel level.  The TPU-native reformulation (DESIGN.md §2) turns
scatter-add into dense compute: for each chunk of ``block_n`` (index, row)
pairs, build a (block_v, block_n) one-hot membership matrix for the output
tile and contract it with the chunk's rows on the MXU:

    out[vb] += onehot(idx_chunk in vb) @ vals_chunk

The output tile revisits are *consecutive* (chunk is the innermost grid
dim), so the accumulator stays resident in VMEM across the whole sweep —
the analogue of keeping the scatter target cache-resident in the paper's
CPU backend.  Duplicate indices are handled by construction (they just add).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scatter_add_kernel(block_v: int, block_n: int,
                        idx_ref, vals_blk, out_blk):
    vb = pl.program_id(0)
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        out_blk[...] = jnp.zeros_like(out_blk)

    chunk = idx_ref[pl.ds(c * block_n, block_n)]          # (block_n,)
    local = chunk - vb * block_v                           # relative to tile
    rows = jax.lax.broadcasted_iota(jnp.int32, (block_v, block_n), 0)
    onehot = (rows == local[None, :]).astype(vals_blk.dtype)
    out_blk[...] += jax.lax.dot(
        onehot, vals_blk[...], precision=jax.lax.Precision.DEFAULT,
        preferred_element_type=out_blk.dtype)


def scatter_add_rows_kernel(idx: jax.Array, vals: jax.Array, v_padded: int, *,
                            block_v: int, block_n: int,
                            interpret: bool) -> jax.Array:
    """sum-scatter ``vals`` (N, D) into a zeroed (v_padded, D) table.

    Caller guarantees: N % block_n == 0, v_padded % block_v == 0, and padded
    entries of ``idx`` point outside [0, v_padded) so they are dropped.
    """
    n, d = vals.shape
    grid = (v_padded // block_v, n // block_n)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda vb, c, idx_ref: (c, 0)),
        ],
        out_specs=pl.BlockSpec((block_v, d), lambda vb, c, idx_ref: (vb, 0)),
    )
    return pl.pallas_call(
        functools.partial(_scatter_add_kernel, block_v, block_n),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((v_padded, d), vals.dtype),
        interpret=interpret,
    )(idx, vals)
