"""Multi-device suite execution: the 2-D placement layer's mesh-shape
sweep (core/plan.py Placement, DESIGN.md §11).

Runs the two placement-sensitive suites — ``suites/apps.json`` (Table 5
appdb proxies, many near-singleton buckets) and ``suites/widelane.json``
(few patterns, huge counts) — inside a subprocess that forces ``N_DEV``
fake host devices (XLA_FLAGS must be set before jax initializes, so this
cannot run in the parent process).  Each suite runs single-device and at
every mesh shape in ``SHAPES`` (``8x1``, ``4x2``, ``2x4``, ``1x8``);
per shape we record aggregate harmonic-mean GB/s, wall clock, exact
compile count, and the plan's pad waste at that ``(batch, lane)`` grid —
the number the 2-D layer exists to shrink (a ``8x1`` launch of a
2-member bucket wastes 6/8 of the mesh on scratch patterns; ``4x2``
moves half of that parallelism onto the lane axis).

The per-shape records merge into ``BENCH_suite.json`` (key
``mesh_sweep``) so the shape trajectory rides the canonical perf record.

On a CPU host the fake devices share the same cores, so wall-clock
parity (not speedup) is the expected result — the bench verifies the
placement layer's overhead structure and padding accounting; the
per-device split is the number that scales on real multi-chip hardware.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from .harness import emit

N_DEV = 8
SHAPES = ((8, 1), (4, 2), (2, 4), (1, 8))
SUITES = ("apps", "widelane")
OUT_PATH = "BENCH_suite.json"

_CHILD = textwrap.dedent("""\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(n_dev)d"
    import dataclasses, json, sys, time
    sys.path.insert(0, %(src)r)
    import jax
    from repro.core import ExecutorCache, SuitePlan, load_suite, run_suite

    runs = %(runs)d
    cap = %(cap)d
    shapes = %(shapes)r
    out = {}
    for name in %(suites)r:
        pats = load_suite(%(root)r + "/suites/" + name + ".json")
        if cap:
            pats = [dataclasses.replace(p, count=min(p.count, cap))
                    for p in pats]
        plan = SuitePlan.build(pats)

        cache = ExecutorCache()
        t0 = time.perf_counter()
        single = run_suite(pats, backend="xla", runs=runs, cache=cache)
        rec = {"n_patterns": len(pats), "n_buckets": plan.n_buckets,
               "single": {"hmean_gbs": single.hmean_gbs,
                          "wall_s": time.perf_counter() - t0,
                          "compiles": cache.stats().misses,
                          "pad_waste": plan.pad_waste()},
               "shapes": {}}
        for b, l in shapes:
            cache = ExecutorCache()
            t0 = time.perf_counter()
            stats = run_suite(pats, backend="xla", runs=runs, cache=cache,
                              mesh=(b, l))
            rec["shapes"]["%%dx%%d" %% (b, l)] = {
                "hmean_gbs": stats.hmean_gbs,
                "wall_s": time.perf_counter() - t0,
                "compiles": cache.stats().misses,
                "pad_waste": plan.pad_waste(b, l),
            }
        out[name] = rec
    print(json.dumps(out))
    """)


def run(runs: int = 3, *, out_path: str | None = OUT_PATH) -> dict:
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    cap = 2048 if runs <= 3 else 0          # quick mode: count cap
    code = _CHILD % {
        "n_dev": N_DEV, "src": os.path.join(root, "src"), "root": root,
        "runs": runs, "cap": cap,
        "shapes": tuple(SHAPES), "suites": tuple(SUITES),
    }
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=540)
    if r.returncode != 0:
        raise RuntimeError(f"sharded-suite child failed: {r.stderr[-2000:]}")
    sweep = json.loads(r.stdout.strip().splitlines()[-1])

    for name, rec in sweep.items():
        emit(f"sharded_suite/{name}_single",
             rec["single"]["wall_s"] * 1e6,
             f"{rec['single']['hmean_gbs']:.2f}GB/s;"
             f"waste={rec['single']['pad_waste']:.0%}")
        for shape, row in rec["shapes"].items():
            emit(f"sharded_suite/{name}_{shape}",
                 row["wall_s"] * 1e6,
                 f"{row['hmean_gbs']:.2f}GB/s;"
                 f"waste={row['pad_waste']:.0%};"
                 f"{row['compiles']}compiles")

    # merge the sweep into the canonical trajectory record (bench_suite
    # owns the rest of the file; a missing file still gets the sweep).
    # ``out_path=None`` skips the write — run.py passes it on full CSV
    # sweeps so the committed baseline is never silently clobbered (the
    # same guard bench_suite honors).  Resolved against the repo root
    # like the suite inputs, so an explicit write from another cwd still
    # updates the canonical file; count_cap rides in the record because
    # capped counts change widelane's whole geometry (pad_waste included)
    # and the numbers are only comparable within a matching cap.
    if out_path:
        if not os.path.isabs(out_path):
            out_path = os.path.join(root, out_path)
        doc = {}
        if os.path.exists(out_path):
            with open(out_path) as f:
                doc = json.load(f)
        doc["mesh_sweep"] = {"n_dev": N_DEV, "runs": runs,
                             "count_cap": cap, "suites": sweep}
        with open(out_path, "w") as f:
            json.dump(doc, f, indent=2)
        emit("sharded_suite/json", 0.0, out_path)
    return sweep


if __name__ == "__main__":
    run()
