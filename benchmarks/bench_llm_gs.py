"""Beyond-paper: the framework's own G/S hot paths through the same lens.

Measures the three LLM indexed-access families (DESIGN.md §3) with the
paper's methodology — bandwidth of useful bytes, min-of-K:

  * embedding lookup (vocab-table row gather)  - xla vs pallas-interpret
  * MoE dispatch/combine (sort-based scatter/gather)
  * paged KV decode gather (Pallas flash-decode, interpret)

And the jaxpr-trace report (paper §2 Table 1 analogue) for a smoke model.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models.zoo import Model
from .harness import emit, time_fn

RNG = np.random.default_rng(0)


def bench_embedding(runs: int = 5):
    v, d, n = 8192, 256, 4096
    table = jnp.asarray(RNG.standard_normal((v, d)), jnp.float32)
    idx = jnp.asarray(RNG.integers(0, v, n), jnp.int32)
    from repro.core import backends as B
    for backend in ("xla", "onehot"):
        fn = jax.jit(lambda t, i, b=backend: B.gather(t, i, backend=b))
        t = time_fn(fn, table, idx, runs=runs)
        gbs = n * d * 4 / t / 1e9
        emit(f"llm_gs/embedding/{backend}", t * 1e6, f"{gbs:.2f}GB/s")
    # pallas interpret: correctness-mode timing (not perf-representative)
    from repro.kernels.gather_rows import ops as gops
    t = time_fn(lambda: gops.gather_rows(table, idx), runs=2)
    emit("llm_gs/embedding/pallas_interpret", t * 1e6,
         "correctness-mode (TPU perf via roofline model)")


def bench_moe_dispatch(runs: int = 3):
    cfg = dataclasses.replace(get_smoke_config("deepseek-v2-236b"),
                              dtype="float32")
    from repro.models.moe import moe_apply, moe_defs
    from repro.models.common import init_tree
    p = init_tree(jax.random.PRNGKey(0), moe_defs(cfg), jnp.float32)
    x = jnp.asarray(RNG.standard_normal((8, 64, cfg.d_model)), jnp.float32)
    fn = jax.jit(lambda p, x: moe_apply(cfg, p, x)[0])
    t = time_fn(fn, p, x, runs=runs)
    tokens = 8 * 64
    emit("llm_gs/moe_dispatch", t * 1e6,
         f"{tokens / t:.0f} tok/s E={cfg.n_experts} k={cfg.top_k}")


def bench_paged_decode(runs: int = 3):
    from repro.kernels.paged_decode import ops as pops
    b, kvh, g, dh, pages, page, pps = 4, 2, 4, 64, 64, 16, 16
    q = jnp.asarray(RNG.standard_normal((b, kvh, g, dh)), jnp.float32)
    kp = jnp.asarray(RNG.standard_normal((kvh, pages, page, dh)), jnp.float32)
    vp = jnp.asarray(RNG.standard_normal((kvh, pages, page, dh)), jnp.float32)
    pt = jnp.asarray(RNG.integers(0, pages, (b, pps)), jnp.int32)
    ln = jnp.full((b,), page * pps, jnp.int32)
    t = time_fn(lambda: pops.paged_decode_attention(q, kp, vp, pt, ln),
                runs=2)
    kv_bytes = b * kvh * pps * page * dh * 2 * 4
    emit("llm_gs/paged_decode_interpret", t * 1e6,
         f"gathers {kv_bytes/1e6:.1f}MB KV per step (interpret mode)")


def bench_trace_report():
    from repro.core import trace_gs
    from repro.models import transformer as T
    cfg = dataclasses.replace(get_smoke_config("deepseek-v2-236b"),
                              dtype="float32")
    model = Model(cfg)
    params = model.abstract_params(jnp.float32)
    rep = trace_gs(lambda p, t: T.forward(cfg, p, t)[0], params,
                   jax.ShapeDtypeStruct((2, 32), jnp.int32))
    emit("llm_gs/trace/deepseek_smoke", 0.0,
         f"gathers={len(rep.gathers())} scatters={len(rep.scatters())} "
         f"gs_fraction={rep.gs_fraction:.2f} (Table 1 analogue)")


def run(runs: int = 3):
    bench_embedding(runs)
    bench_moe_dispatch(runs)
    bench_paged_decode(runs)
    bench_trace_report()


if __name__ == "__main__":
    run()
