"""Spatter pattern language.

A memory access pattern (paper §3.1, §3.3) is the triple

    (index buffer, delta, count)

meaning: for i in 0..count-1, perform one gather/scatter at base address
``delta * i`` using the offsets in the index buffer.  On TPU the *element*
is a table row (lane-width multiple), not an 8-byte double — see DESIGN.md §2.

Built-in generators follow the released Spatter semantics:

    UNIFORM:N:S            -> [0, S, 2S, ..., (N-1)S]
    MS1:N:BREAKS:GAPS      -> stride-1 run with jumps of GAP at each break
    LAPLACIAN:D:L:SIZE     -> D-dim stencil, branch length L, grid side SIZE
    BROADCAST:N:R          -> [0,0,..(R times)..,1,1,..] length N
    STREAM:N               -> alias UNIFORM:N:1 (paper §3.4 STREAM-like)
    CUSTOM:i0,i1,...       -> verbatim buffer

(The paper's printed ``UNIFORM:8:4 -> [0,4,8,12]`` truncates; the Spatter
code generates length-N buffers.  We follow the code — DESIGN.md §9.)
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Iterable, Sequence

import numpy as np

_KINDS = ("gather", "scatter")


@dataclasses.dataclass(frozen=True)
class Pattern:
    """A fully-specified Spatter pattern (paper §3.3)."""

    name: str
    kind: str                      # "gather" | "scatter"
    index: tuple[int, ...]         # the index buffer (offsets for one G/S)
    delta: int                     # base-address advance between G/S ops
    count: int                     # number of gathers/scatters to perform
    source: str = "custom"         # generator string or app name

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"kind must be one of {_KINDS}, got {self.kind!r}")
        if len(self.index) == 0:
            raise ValueError("index buffer must be non-empty")
        if any(i < 0 for i in self.index):
            raise ValueError("index buffer entries must be >= 0")
        if self.delta < 0:
            raise ValueError("delta must be >= 0")
        if self.count < 1:
            raise ValueError("count must be >= 1")

    # -- derived geometry ---------------------------------------------------
    @property
    def index_len(self) -> int:
        return len(self.index)

    @property
    def span(self) -> int:
        """Extent touched by a single G/S op (max offset + 1)."""
        return max(self.index) + 1

    def footprint(self) -> int:
        """Number of addressable elements the whole pattern touches.

        This is how Spatter sizes its sparse buffer: the last base address is
        ``delta * (count - 1)`` and the largest offset from it is ``span - 1``.
        """
        return self.delta * (self.count - 1) + self.span

    def useful_elements(self) -> int:
        """Elements actually moved (paper §3.5 bandwidth numerator)."""
        return self.index_len * self.count

    def unique_elements(self) -> int:
        """Distinct addresses touched — measures reuse (< useful => reuse)."""
        idx = np.asarray(self.index, dtype=np.int64)
        deltas = np.arange(self.count, dtype=np.int64) * self.delta
        all_addr = (deltas[:, None] + idx[None, :]).ravel()
        return int(np.unique(all_addr).size)

    def reuse_factor(self) -> float:
        """useful / unique; 1.0 means no temporal reuse."""
        return self.useful_elements() / max(1, self.unique_elements())

    # -- materialization ----------------------------------------------------
    def absolute_indices(self) -> np.ndarray:
        """(count, index_len) int32 array of absolute element indices."""
        idx = np.asarray(self.index, dtype=np.int64)
        deltas = np.arange(self.count, dtype=np.int64) * self.delta
        out = deltas[:, None] + idx[None, :]
        if out.max(initial=0) >= np.iinfo(np.int32).max:
            raise ValueError("pattern footprint exceeds int32 index range")
        return out.astype(np.int32)

    def index_array(self) -> np.ndarray:
        return np.asarray(self.index, dtype=np.int32)

    # -- classification (paper Table 1 / Table 5 "Type" column) -------------
    def classify(self) -> str:
        idx = np.asarray(self.index, dtype=np.int64)
        if idx.size == 1:
            return "Stride-1"
        d = np.diff(idx)
        if np.all(d == d[0]) and d[0] > 0:
            return f"Stride-{int(d[0])}"
        if np.all(d >= 0) and np.max(idx) + 1 < idx.size:
            return "Broadcast"
        # broadcast: runs of repeated values
        if np.unique(idx).size < idx.size and np.all(np.diff(np.unique(idx)) == 1):
            return "Broadcast"
        ones = np.count_nonzero(d == 1)
        if ones >= 0.5 * d.size:
            return "Mostly Stride-1"
        return "Complex"

    # -- (de)serialization ---------------------------------------------------
    def to_json(self) -> dict:
        return {
            "name": self.name, "kernel": self.kind,
            "pattern": list(self.index), "delta": self.delta,
            "count": self.count, "source": self.source,
        }

    @staticmethod
    def from_json(d: dict) -> "Pattern":
        index = d["pattern"]
        if isinstance(index, str):
            index = generate_index(index)
        return Pattern(
            name=d.get("name", "unnamed"),
            kind=d.get("kernel", "gather").lower(),
            index=tuple(int(i) for i in index),
            delta=int(d.get("delta", 1)),
            count=int(d.get("count", 1)),
            source=d.get("source", "json"),
        )


# ---------------------------------------------------------------------------
# Generators (paper §3.3.1-3.3.4)
# ---------------------------------------------------------------------------

def uniform(n: int, stride: int) -> tuple[int, ...]:
    """UNIFORM:N:STRIDE (§3.3.1): length-N buffer with a fixed stride."""
    if n < 1 or stride < 0:
        raise ValueError(f"bad UNIFORM args n={n} stride={stride}")
    return tuple(i * stride for i in range(n))


def ms1(n: int, breaks: int | Sequence[int], gaps: int | Sequence[int]) -> tuple[int, ...]:
    """MS1:N:BREAKS:GAPS (§3.3.2): mostly-stride-1 with jumps.

    ``breaks`` are positions (1-indexed into the buffer) where instead of +1
    the running index jumps by the corresponding ``gap``.  Paper example:
    MS1:8:4:20 -> [0,1,2,3,23,24,25,26]   (at position 4, jump by +20).
    """
    if isinstance(breaks, int):
        breaks = [breaks]
    if isinstance(gaps, int):
        gaps = [gaps] * len(breaks)
    if len(gaps) != len(breaks):
        raise ValueError("MS1 needs one gap per break")
    bset = {int(b): int(g) for b, g in zip(breaks, gaps)}
    out, cur = [0], 0
    for pos in range(1, n):
        cur += bset.get(pos, 1)
        out.append(cur)
    return tuple(out)


def laplacian(dim: int, length: int, size: int) -> tuple[int, ...]:
    """LAPLACIAN:D:L:SIZE (§3.3.3): D-dim stencil, branch length L, grid side SIZE.

    Offsets are {±k·SIZE^d : d<D, 1<=k<=L} ∪ {0}, shifted to zero base.
    LAPLACIAN:2:2:100 -> [0,100,198,199,200,201,202,300,400].
    """
    if dim < 1 or length < 1 or size < 1:
        raise ValueError(f"bad LAPLACIAN args {dim}:{length}:{size}")
    offs = {0}
    for d in range(dim):
        s = size ** d
        for k in range(1, length + 1):
            offs.add(k * s)
            offs.add(-k * s)
    base = -min(offs)
    return tuple(sorted(o + base for o in offs))


def broadcast(n: int, repeat: int) -> tuple[int, ...]:
    """BROADCAST:N:R — PENNANT-G4 style [0,0,0,0,1,1,1,1,...] (Table 5)."""
    if n < 1 or repeat < 1:
        raise ValueError(f"bad BROADCAST args n={n} repeat={repeat}")
    return tuple(i // repeat for i in range(n))


_GEN_RE = re.compile(r"^([A-Z0-9_]+)(:.*)?$")


def generate_index(spec: str | Sequence[int]) -> tuple[int, ...]:
    """Parse a pattern-buffer spec string (paper §3.3) into an index buffer."""
    if not isinstance(spec, str):
        return tuple(int(i) for i in spec)
    spec = spec.strip()
    m = _GEN_RE.match(spec)
    if not m:
        # bare comma-separated custom buffer:  "0,4,8,12"
        return tuple(int(t) for t in spec.split(","))
    head, rest = m.group(1), (m.group(2) or "")
    args = [a for a in rest.split(":") if a != ""]
    if head == "UNIFORM":
        n, s = int(args[0]), int(args[1])
        return uniform(n, s)
    if head == "MS1":
        n = int(args[0])
        brk = [int(x) for x in args[1].split(",")]
        gap = [int(x) for x in args[2].split(",")]
        return ms1(n, brk, gap if len(gap) > 1 else gap[0])
    if head == "LAPLACIAN":
        return laplacian(int(args[0]), int(args[1]), int(args[2]))
    if head == "BROADCAST":
        return broadcast(int(args[0]), int(args[1]))
    if head == "STREAM":
        return uniform(int(args[0]), 1)
    if head == "CUSTOM":
        return tuple(int(t) for t in ":".join(args).split(","))
    # fall back: maybe a custom buffer that starts with a digit
    try:
        return tuple(int(t) for t in spec.split(","))
    except ValueError as e:
        raise ValueError(f"unrecognized pattern spec {spec!r}") from e


def make_pattern(spec: str | Sequence[int], *, kind: str = "gather",
                 delta: int = 1, count: int = 1, name: str | None = None,
                 source: str | None = None) -> Pattern:
    """One-stop constructor mirroring the CLI (§3.4):

        make_pattern("UNIFORM:8:1", kind="gather", delta=8, count=2**24)
    """
    index = generate_index(spec)
    return Pattern(
        name=name or (spec if isinstance(spec, str) else "custom"),
        kind=kind, index=index, delta=delta, count=count,
        source=source or (spec if isinstance(spec, str) else "custom"),
    )


# ---------------------------------------------------------------------------
# JSON suite files (paper §3.3 "JSON Specification")
# ---------------------------------------------------------------------------

def load_suite(path_or_text: str) -> list[Pattern]:
    """Load a JSON suite: a list of {name, kernel, pattern, delta, count}."""
    text = path_or_text
    if not path_or_text.lstrip().startswith(("[", "{")):
        with open(path_or_text) as f:
            text = f.read()
    data = json.loads(text)
    if isinstance(data, dict):
        data = data.get("patterns", [data])
    return [Pattern.from_json(d) for d in data]


def dump_suite(patterns: Iterable[Pattern]) -> str:
    return json.dumps([p.to_json() for p in patterns], indent=2)
