"""gemma2-27b [dense] — 46L d4608 32H GQA kv=16 d_ff=36864 vocab=256000.

Local(4096)+global alternating attention, attn logit softcap 50, final
logit softcap 30, GeGLU. [arXiv:2408.00118; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16,
    d_ff=36864, vocab=256000, head_dim=128,
    attn_kind="local_global", window=4096,
    attn_softcap=50.0, logit_softcap=30.0,
    mlp_kind="geglu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    arch_id="gemma2-27b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab=512, head_dim=16,
    attn_kind="local_global", window=32,
    attn_softcap=50.0, logit_softcap=30.0,
    mlp_kind="geglu", tie_embeddings=True, attn_chunk=16,
)
