"""Per-arch smoke tests: reduced same-family configs, one train/forward
step on CPU, asserting output shapes and no NaNs (assignment requirement).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config, SHAPES, \
    shape_skips
from repro.models.zoo import Model, count_params, matmul_params, model_flops

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _cfg(arch):
    return dataclasses.replace(get_smoke_config(arch), dtype="float32",
                               remat="none")


def _batch(cfg):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(1, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(1, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            0.01 * rng.standard_normal((B, S // cfg.frame_ratio,
                                        cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        batch["img_embeds"] = jnp.asarray(
            0.01 * rng.standard_normal((B, cfg.n_img_tokens, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_loss_finite(arch):
    cfg = _cfg(arch)
    m = Model(cfg)
    params = m.init(KEY)
    loss = m.loss(params, _batch(cfg))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    # tied-embedding archs without a logit softcap (recurrentgemma) start
    # near ~24 at this width; all others start near ln(vocab)
    assert 1.0 < float(loss) < 30.0, f"{arch} loss implausible: {loss}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nans(arch):
    from repro.optim import AdamWConfig, init_opt_state
    from repro.runtime.train import make_train_step
    cfg = _cfg(arch)
    m = Model(cfg)
    params = m.init(KEY)
    opt = init_opt_state(params)
    step = make_train_step(m, AdamWConfig(lr=1e-3))
    p2, o2, metrics = jax.jit(step)(params, opt, _batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    for leaf in jax.tree.leaves(p2):
        assert bool(jnp.all(jnp.isfinite(leaf))), f"{arch}: NaN params"
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved, f"{arch}: update was a no-op"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = _cfg(arch)
    m = Model(cfg)
    params = m.init(KEY)
    max_len = 16
    nf = S // cfg.frame_ratio if cfg.family == "audio" else 0
    cache = m.init_cache(B, max_len, n_frames=nf)
    if cfg.family == "audio":
        _, cache = m.prefill(params, {"frames": _batch(cfg)["frames"],
                                      "max_len": max_len})
    tok = jnp.ones((B, 1), jnp.int32)
    logits, cache2 = m.decode_step(params, cache, tok, jnp.int32(0))
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: NaN logits"
    # second step with updated cache
    logits2, _ = m.decode_step(params, cache2, tok, jnp.int32(1))
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("arch", ["llama3-8b", "gemma2-27b",
                                  "deepseek-v2-236b", "chatglm3-6b",
                                  "starcoder2-15b", "internvl2-26b",
                                  "kimi-k2-1t-a32b"])
def test_prefill_matches_decode(arch):
    """Prefill caches + decode must agree with a from-scratch forward."""
    cfg = _cfg(arch)
    m = Model(cfg)
    params = m.init(KEY)
    toks = jnp.asarray(np.random.default_rng(1).integers(1, cfg.vocab,
                                                         (B, 8)), jnp.int32)
    # reference: full forward logits at last position
    from repro.models import transformer as T
    hidden, _ = T.forward(cfg, params, toks)
    ref_last = T.unembed_logits(cfg, params["embed"], hidden[:, -1:])[:, 0]
    last, _ = m.prefill(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(last), np.asarray(ref_last),
                               rtol=1e-4, atol=1e-4)


def test_full_config_params():
    """Published-config parameter counts are in the right ballpark."""
    expect = {
        "llama3-8b": (7.5e9, 9e9),
        "gemma2-27b": (25e9, 30e9),
        "starcoder2-15b": (14e9, 17e9),
        "deepseek-v2-236b": (2.1e11, 2.6e11),
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        "falcon-mamba-7b": (6e9, 8.5e9),
        "whisper-base": (6e7, 1.2e8),
    }
    for arch, (lo, hi) in expect.items():
        n = count_params(get_config(arch))
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B params out of range"


def test_moe_active_params():
    cfg = get_config("kimi-k2-1t-a32b")
    total = count_params(cfg)
    active = count_params(cfg, active_only=True)
    assert active < total / 5          # 1T total / 32B active regime
    assert 2e10 < active < 6e10


def test_model_flops_shapes():
    cfg = get_config("llama3-8b")
    train = model_flops(cfg, SHAPES["train_4k"])
    dec = model_flops(cfg, SHAPES["decode_32k"])
    assert train > 1e16
    assert dec < train / 1e3           # decode is per-token


def test_shape_skips():
    assert shape_skips(get_config("llama3-8b"), SHAPES["long_500k"])
    assert shape_skips(get_config("gemma2-27b"), SHAPES["long_500k"])
    assert not shape_skips(get_config("falcon-mamba-7b"), SHAPES["long_500k"])
    assert not shape_skips(get_config("recurrentgemma-9b"),
                           SHAPES["long_500k"])
    assert not shape_skips(get_config("llama3-8b"), SHAPES["decode_32k"])


def test_moe_ep_equals_baseline_subprocess():
    """shard_map EP MoE == GSPMD baseline (fwd + grads) on 8 fake devices."""
    import subprocess, sys, textwrap, os
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, %r)
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models.moe import moe_defs, moe_apply_gspmd, moe_apply_ep
        from repro.models.common import init_tree
        from repro.runtime.sharding import use_mesh
        cfg = dataclasses.replace(get_smoke_config("deepseek-v2-236b"),
                                  dtype="float32", n_experts=8, top_k=2,
                                  capacity_factor=8.0)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        p = init_tree(jax.random.PRNGKey(0), moe_defs(cfg), jnp.float32)
        x = jnp.asarray(np.random.default_rng(0).standard_normal(
            (4, 16, cfg.d_model)), jnp.float32)
        with use_mesh(mesh):
            y1, _ = jax.jit(lambda p, x: moe_apply_ep(cfg, p, x))(p, x)
            gx1 = jax.jit(jax.grad(
                lambda x: moe_apply_ep(cfg, p, x)[0].sum()))(x)
            gp1 = jax.jit(jax.grad(
                lambda p: moe_apply_ep(cfg, p, x)[0].sum()))(p)
        y0, _ = jax.jit(lambda p, x: moe_apply_gspmd(cfg, p, x))(p, x)
        gx0 = jax.jit(jax.grad(
            lambda x: moe_apply_gspmd(cfg, p, x)[0].sum()))(x)
        gp0 = jax.jit(jax.grad(
            lambda p: moe_apply_gspmd(cfg, p, x)[0].sum()))(p)
        assert np.allclose(y0, y1, atol=2e-5)
        assert np.allclose(gx0, gx1, atol=3e-4)
        worst = max(jax.tree.leaves(jax.tree.map(
            lambda a, b: float(jnp.abs(a - b).max()), gp0, gp1)))
        assert worst < 3e-4, worst
        print("OK")
    """) % os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                        "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=420)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
