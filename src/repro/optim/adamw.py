"""AdamW from scratch, with ZeRO-1-style optimizer-state sharding.

Moments are fp32 regardless of param dtype.  ``opt_state_axes`` derives the
moment sharding from the param logical axes and *additionally* shards the
largest replicated-and-divisible dimension over the data axes ("opt_extra"
rule) — the pjit-native form of ZeRO-1: params stay replicated across data,
moments are fully sharded, and XLA inserts the gather of updates.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float | Callable = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init_opt_state(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _lr_at(cfg: AdamWConfig, step):
    return cfg.lr(step) if callable(cfg.lr) else cfg.lr


def adamw_update(cfg: AdamWConfig, params, grads, state):
    step = state["step"] + 1
    lr = _lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}


def opt_state_axes(param_axes, rules: dict | None = None) -> dict:
    """Moment logical axes = param axes with ZeRO-1 extra data sharding.

    The first dim whose logical axis resolves to *replicated* under the
    rules gets the "opt_state" rule (→ (pod, data, model) minus already-
    used axes, divisibility-checked at spec resolution time by
    runtime/sharding.py).  Params stay replicated across data; moments are
    fully sharded; XLA inserts the update gather — ZeRO-1.
    """
    from repro.runtime.sharding import DEFAULT_RULES
    rules = rules or DEFAULT_RULES

    def momentize(axes):
        axes = tuple(axes)
        out = []
        promoted = False
        for a in axes:
            if not promoted and (a is None or rules.get(a) is None):
                out.append("opt_state")
                promoted = True
            else:
                out.append(a)
        return tuple(out)

    is_axes = lambda v: isinstance(v, tuple) and all(
        isinstance(e, (str, type(None))) for e in v)
    m_axes = jax.tree.map(momentize, param_axes, is_leaf=is_axes)
    return {"m": m_axes, "v": m_axes, "step": ()}
