"""ModelConfig: one dataclass covering every assigned architecture family.

Families: dense | moe | ssm | hybrid | audio (enc-dec) | vlm.
All published numbers live in the per-arch modules; reduced smoke variants
are derived automatically (same family/topology, tiny dims).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

ARCH_IDS = [
    "chatglm3-6b", "llama3-8b", "gemma2-27b", "starcoder2-15b",
    "deepseek-v2-236b", "kimi-k2-1t-a32b", "whisper-base",
    "falcon-mamba-7b", "internvl2-26b", "recurrentgemma-9b",
]


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                          # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                    # 0 -> d_model // n_heads

    # attention flavor
    attn_kind: str = "full"              # full | mla | local_global | none
    rope: str = "full"                   # full | partial | 2d | none
    rope_theta: float = 10000.0
    window: int = 0                      # local attention window
    attn_softcap: float = 0.0            # gemma2: 50.0
    logit_softcap: float = 0.0           # gemma2: 30.0
    mlp_kind: str = "swiglu"             # swiglu | geglu | gelu
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_dense_layers: int = 0              # leading dense layers (deepseek/kimi)
    d_ff_dense: int = 0                  # their FFN width
    capacity_factor: float = 1.25
    router_scale: float = 1.0
    moe_impl: str = "gspmd_sort"         # gspmd_sort | ep_shardmap (§Perf)

    # MLA (deepseek-v2)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # SSM (mamba1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0                 # 0 -> ceil(d_model/16)

    # RG-LRU hybrid (recurrentgemma)
    lru_width: int = 0
    block_pattern: tuple[str, ...] = ()  # e.g. ("rec","rec","attn")

    # enc-dec (whisper)
    n_enc_layers: int = 0
    frame_ratio: int = 4                 # stub frontend: frames = seq/ratio

    # vlm (internvl)
    n_img_tokens: int = 0

    # numerics / runtime
    dtype: str = "bfloat16"
    remat: str = "block"                 # none | block | full
    scan_layers: bool = True
    attn_chunk: int = 512                # query-chunked attention block

    # -- derived -------------------------------------------------------------
    @property
    def dh(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.n_heads))

    @property
    def is_subquadratic(self) -> bool:
        """True if the arch handles 500k context (assignment's long_500k gate)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Total parameters (analytic, for roofline MODEL_FLOPS)."""
        from repro.models.zoo import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.zoo import count_params
        return count_params(self, active_only=True)


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(
        f"repro.configs.{arch_id.replace('-', '_').replace('.', '_')}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(
        f"repro.configs.{arch_id.replace('-', '_').replace('.', '_')}")
    return mod.SMOKE


def shape_skips(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """Return a skip reason if this (arch, shape) cell is skipped, else None.

    Per the assignment: long_500k only for sub-quadratic archs; no
    encoder-only archs are assigned, so decode shapes run everywhere.
    """
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return ("full-attention arch (global attention layers present): "
                "524k context requires sub-quadratic attention — skipped "
                "per assignment; see DESIGN.md §6")
    return None
