"""kernels/autotune — the deterministic per-geometry tile search (§16).

Three contracts, counter-proven:

  * Determinism: the choice is a pure function of the TileKey — same
    answer after a memo reset, and the same answer in a fresh process
    (no timing, no RNG, no dict-order dependence).
  * Purity of the kernels w.r.t. the tile: EVERY candidate tiling is
    bit-identical on ragged geometries (N < block, D not a block_d
    multiple, OOB padding lanes) — the tile choice may change speed,
    never bits, which is why it must not enter ExecKey.
  * Persistence: DiskTier entries carry the tiles their executable
    baked in; a restored entry re-seeds the memo so a warm restart
    never searches (``searched == 0``) and ``disk_hits`` stays exact.
"""
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DiskTier, ExecutorCache, SuitePlan, make_pattern
from repro.core.plan import run_plan
from repro.kernels import autotune
from repro.kernels.gather_rows import ops as gops
from repro.kernels.scatter_rows import ops as sops

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(autouse=True)
def _clean_memo():
    autotune.reset()
    yield
    autotune.reset()


def _keys():
    """A small grid of representative geometries, every op covered."""
    out = []
    for op, rows in (("gather_vmem", 256), ("gather_dma", 4096),
                     ("scatter", 96)):
        for batch, lanes, width in ((1, 64, 1), (4, 1000, 8), (2, 7, 520)):
            out.append(autotune.TileKey(op=op, batch=batch, lanes=lanes,
                                        rows=rows, width=width,
                                        dtype="float32",
                                        platform="interpret"))
    return out


# ---------------------------------------------------------------------------
# determinism


def test_choice_survives_memo_reset():
    for key in _keys():
        first = autotune.choose(key)
        autotune.reset()
        assert autotune.choose(key) == first


def test_memo_hit_does_not_research():
    key = _keys()[0]
    autotune.choose(key)
    autotune.choose(key)
    s = autotune.stats()
    assert s["searched"] == 1 and s["hits"] == 1


def test_choices_are_powers_of_two():
    for key in _keys():
        c = autotune.choose(key)
        for b in (c.block_n, c.block_v, c.block_i, c.block_d):
            assert b == 0 or (b & (b - 1)) == 0, (key, c)


_CHILD = """
import json, sys
sys.path.insert(0, %(src)r)
from repro.kernels import autotune
keys = [autotune.TileKey(**k) for k in json.loads(sys.argv[1])]
print(json.dumps(autotune.to_wire({k: autotune.choose(k) for k in keys})))
"""


def test_cross_process_determinism():
    # two fresh interpreters, no shared memo: identical wire dicts,
    # identical to the in-process answer
    payload = json.dumps([vars(k) for k in _keys()])
    outs = []
    for _ in range(2):
        r = subprocess.run([sys.executable, "-c", _CHILD % {"src": SRC},
                            payload], capture_output=True, text=True,
                           timeout=120)
        assert r.returncode == 0, r.stderr
        outs.append(json.loads(r.stdout))
    assert outs[0] == outs[1]
    here = autotune.to_wire({k: autotune.choose(k) for k in _keys()})
    assert here == outs[0]


def test_legacy_mirrors_kernel_defaults():
    # the disabled() path serves what the kernels shipped with — pinned
    # against the _DEFAULT_BLOCK_* constants so neither drifts alone
    assert autotune.LEGACY["gather_vmem"].block_n == gops._DEFAULT_BLOCK_N
    assert autotune.LEGACY["gather_dma"].block_i == gops._DEFAULT_BLOCK_I
    assert autotune.LEGACY["gather_dma"].block_d == gops._pick_block_d(4096)
    assert autotune.LEGACY["scatter"].block_v == sops._DEFAULT_BLOCK_V
    assert autotune.LEGACY["scatter"].block_n == sops._DEFAULT_BLOCK_N


def test_disabled_serves_legacy_without_memo():
    key = _keys()[0]
    with autotune.disabled():
        assert autotune.choose(key) == autotune.LEGACY[key.op]
    assert autotune.stats() == {"searched": 0, "hits": 0, "seeded": 0}
    assert autotune.lookup(key) is None


# ---------------------------------------------------------------------------
# wire format


def test_wire_round_trip_and_seed_priority():
    entries = {k: autotune.choose(k) for k in _keys()}
    wire = autotune.to_wire(entries)
    json.dumps(wire)                       # must be JSON-clean as-is
    autotune.reset()
    assert autotune.seed_wire(wire) == len(entries)
    for k, v in entries.items():
        assert autotune.lookup(k) == v
    # existing memo entries win over a later (conflicting) seed
    key = _keys()[0]
    fake = dict(wire)
    fake[next(iter(fake))] = [1, 0, 0, 0]
    assert autotune.seed_wire(fake) == 0
    assert autotune.lookup(key) == entries[key]


def test_seed_wire_skips_malformed_entries():
    assert autotune.seed_wire(None) == 0
    assert autotune.seed_wire({"not:a:key": [64, 0, 0, 0],
                               "gather_vmem:1:64:256:1:float32:interpret":
                                   ["x", 0, 0, 0]}) == 0
    good = {"gather_vmem:1:64:256:1:float32:interpret": [64, 0, 0, 0]}
    assert autotune.seed_wire(good) == 1


# ---------------------------------------------------------------------------
# bit-identity: every candidate tiling computes the same bits


def _ragged_gather(rng, n=13, v=19, d=7, batch=2):
    table = rng.normal(size=(batch, v, d)).astype(np.float32)
    idx = rng.integers(0, v, size=(batch, n)).astype(np.int32)
    return table, idx


def _gather_candidates(n):
    return autotune._pow2s(8, max(8, min(autotune._MAX_BLOCK,
                                         autotune._next_pow2(n))))


@pytest.mark.parametrize("block_n", _gather_candidates(13))
def test_gather_vmem_bit_identical_across_tiles(block_n):
    # N=13 < most blocks, D=7 ragged: padding lanes index row 0 and are
    # sliced off — the tile must never leak into the bits
    table, idx = _ragged_gather(np.random.default_rng(0))
    ref = np.take_along_axis(table, idx[..., None], axis=1)
    out = gops.gather_rows_batched(jnp.asarray(table), jnp.asarray(idx),
                                   mode="vmem", block_n=block_n)
    assert (np.asarray(out) == ref).all()


@pytest.mark.parametrize("block_i", (8, 16, 64))
@pytest.mark.parametrize("block_d", (2, 8, 512))
def test_gather_dma_bit_identical_across_tiles(block_i, block_d):
    # dma path: D=6 is not a multiple of any block_d candidate, so the
    # kernel pads the row dim too
    table, idx = _ragged_gather(np.random.default_rng(1), n=21, v=33, d=6)
    ref = np.take_along_axis(table, idx[..., None], axis=1)
    out = gops.gather_rows_batched(jnp.asarray(table), jnp.asarray(idx),
                                   mode="dma", block_i=block_i,
                                   block_d=block_d)
    assert (np.asarray(out) == ref).all()


def _scatter_candidates(v, n):
    pairs = []
    for bv in autotune._pow2s(8, max(8, autotune._next_pow2(v))):
        for bn in autotune._pow2s(8, max(8, autotune._next_pow2(n))):
            pairs.append((bv, bn))
    return pairs


@pytest.mark.parametrize("block_v,block_n", _scatter_candidates(19, 13))
def test_scatter_store_bit_identical_across_tiles(block_v, block_n):
    # unique in-range indices (the store-mode contract), one deliberately
    # OOB lane, plus the OOB padding lanes every non-divisible block adds
    rng = np.random.default_rng(2)
    batch, n, v, d = 2, 13, 19, 7
    dst = rng.normal(size=(batch, v, d)).astype(np.float32)
    idx = np.stack([rng.permutation(v)[:n] for _ in range(batch)]
                   ).astype(np.int32)
    idx[0, 3] = v + 5                       # dropped, not wrapped
    vals = rng.normal(size=(batch, n, d)).astype(np.float32)
    ref = dst.copy()
    for b in range(batch):
        for j in range(n):
            if 0 <= idx[b, j] < v:
                ref[b, idx[b, j]] = vals[b, j]
    out = sops.scatter_store_rows_batched(
        jnp.asarray(dst), jnp.asarray(idx), jnp.asarray(vals),
        block_v=block_v, block_n=block_n)
    assert (np.asarray(out) == ref).all()


@pytest.mark.parametrize("block_v,block_n", ((8, 8), (64, 16), (128, 128)))
def test_scatter_add_bit_identical_across_tiles(block_v, block_n):
    rng = np.random.default_rng(3)
    batch, n, v, d = 2, 27, 19, 5
    idx = rng.integers(0, v, size=(batch, n)).astype(np.int32)
    vals = rng.integers(-100, 100, size=(batch, n, d)).astype(np.float32)
    ref = np.zeros((batch, v, d), np.float32)
    for b in range(batch):
        np.add.at(ref[b], idx[b], vals[b])
    out = sops.scatter_add_rows_batched(jnp.asarray(idx), jnp.asarray(vals),
                                        v, block_v=block_v, block_n=block_n)
    assert (np.asarray(out) == ref).all()


def test_gather_property_bit_identical():
    # hypothesis sweep over ragged geometries x candidate tiles; skipped
    # (not xfailed) where hypothesis isn't installed — the parametrized
    # tests above keep the deterministic floor
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(deadline=None, max_examples=25)
    @hyp.given(n=st.integers(1, 40), v=st.integers(1, 50),
               d=st.integers(1, 16), bi=st.integers(0, 9),
               seed=st.integers(0, 2 ** 16))
    def run(n, v, d, bi, seed):
        rng = np.random.default_rng(seed)
        table, idx = _ragged_gather(rng, n=n, v=v, d=d)
        cands = _gather_candidates(n)
        block_n = cands[bi % len(cands)]
        ref = np.take_along_axis(table, idx[..., None], axis=1)
        out = gops.gather_rows_batched(jnp.asarray(table), jnp.asarray(idx),
                                       mode="vmem", block_n=block_n)
        assert (np.asarray(out) == ref).all()

    run()


# ---------------------------------------------------------------------------
# persistence through DiskTier


PLAN = SuitePlan.build([
    make_pattern("UNIFORM:8:1", kind="gather", delta=8, count=16),
    make_pattern("UNIFORM:8:2", kind="scatter", delta=2, count=16),
])


def _digests(cache):
    return [r.out_digest for r in run_plan(PLAN, runs=1, backend="pallas",
                                           cache=cache, digest=True)]


def test_disk_restore_skips_search_and_disk_hits_exact(tmp_path):
    root = str(tmp_path)
    cold = ExecutorCache(disk=DiskTier(root))
    ref = _digests(cold)
    assert cold.disk.stats()["stores"] == PLAN.n_buckets
    assert autotune.stats()["searched"] > 0          # the cold run searched

    # "restart": fresh memo, fresh cache over the same directory
    autotune.reset()
    warm = ExecutorCache()
    assert warm.attach_disk(DiskTier(root), preload=True) == PLAN.n_buckets
    s = autotune.stats()
    assert s["seeded"] > 0                           # headers re-seeded it
    assert _digests(warm) == ref                     # bit-identical
    assert autotune.stats()["searched"] == 0         # never searched again
    assert warm.stats().misses == 0
    assert warm.stats().disk_hits == PLAN.n_buckets  # exact, per bucket


def test_disk_header_carries_tiles_wire(tmp_path):
    root = str(tmp_path)
    with autotune.recording() as rec:
        cache = ExecutorCache(disk=DiskTier(root))
        _digests(cache)
    assert rec                                       # pallas traces chose
    wire = autotune.to_wire(rec)
    # a fresh process seeded ONLY from disk resolves every recorded key
    autotune.reset()
    warm = ExecutorCache()
    warm.attach_disk(DiskTier(root), preload=True)
    for ks, v in wire.items():
        key = autotune._key_from_wire(ks)
        assert autotune.lookup(key) == autotune.TileChoice.from_wire(v)
