"""serve/scheduler — the concurrent, coalescing work-unit executor
(DESIGN.md §13).

The ISSUE 7 acceptance contract, telemetry-proven:

* N concurrent identical requests cause exactly ONE compile and fewer
  launches than requests (bucket-affinity coalescing);
* digests under concurrency are bit-identical to the serial ``run_plan``
  path, shared-suite and mixed-suite alike;
* a full queue rejects at ``submit`` — before any JAX work runs — and a
  request is queued whole or not at all;
* ``stop(drain=True)`` completes queued + in-flight work before the
  workers exit; ``stop(drain=False)`` fails queued tickets instead.

Determinism recipe: ``pause()`` stages every request in the queue, then
``resume()`` releases the workers — the first worker to wake sweeps the
whole same-key queue into one launch, so "coalesced" stops being a race
and becomes an assertion.
"""
import time

import pytest

from repro.core import ExecutorCache, SuitePlan, make_pattern
from repro.core.plan import make_work, run_plan
from repro.serve.scheduler import (QUARANTINE_AFTER, DeadlineExceeded,
                                   FamilyQuarantined, QueueFull,
                                   RequestCancelled, Scheduler,
                                   SchedulerStopped)

# one bucket: the sharpest coalescing target (N requests -> 1 launch)
SINGLE = SuitePlan.build(
    [make_pattern("UNIFORM:8:2", kind="gather", delta=2, count=32)])

# three buckets across kinds/shapes, same shape as test_serve's SUITE
MIXED = SuitePlan.build([
    make_pattern("UNIFORM:8:1", kind="gather", delta=8, count=16),
    make_pattern("UNIFORM:8:4", kind="gather", delta=4, count=64),
    make_pattern("UNIFORM:8:2", kind="scatter", delta=2, count=16),
])


def _digests(results):
    return [r.out_digest for r in results]


def _ticket_digests(ticket, n):
    assert sorted(ticket.results) == list(range(n))
    return [ticket.results[i].out_digest for i in range(n)]


def _serial_reference(plan, runs):
    return _digests(run_plan(plan, runs=runs, cache=ExecutorCache(),
                             digest=True))


# ---------------------------------------------------------------------------
# coalescing: exactly one compile, fewer launches than requests
# ---------------------------------------------------------------------------

def test_identical_concurrent_requests_one_compile_fewer_launches():
    cache = ExecutorCache()
    sched = Scheduler(cache, workers=2)
    n = 8
    try:
        sched.pause()
        tickets = [sched.submit(make_work(SINGLE, runs=2, digest=True))
                   for _ in range(n)]
        assert sched.snapshot()["queue_depth"] == n
        sched.resume()
        for t in tickets:
            t.wait(timeout=300)
    finally:
        sched.stop()

    # staged queue -> ONE coalesced launch serves all n requests
    snap = sched.snapshot()
    assert snap["total_launches"] == 1
    assert snap["coalesced_launches"] == 1
    assert snap["total_launches"] < n
    assert snap["submitted"] == n and snap["completed"] == n

    # exactly one compile, attributed to exactly one ticket; everyone
    # else rode the launch warm — and the sum matches the cache's own
    # exact compile count
    assert sum(t.misses for t in tickets) == 1
    assert cache.stats().misses == 1
    assert sum(1 for t in tickets if t.misses == 1) == 1
    assert all(t.launches == 1 for t in tickets)
    assert all(t.coalesced_launches == 1 for t in tickets)
    assert all(t.queued_ms >= 0.0 for t in tickets)

    # every request's digests are bit-identical to the serial path
    ref = _serial_reference(SINGLE, runs=2)
    assert all(d is not None for d in ref)
    for t in tickets:
        assert _ticket_digests(t, len(ref)) == ref


def test_mixed_suite_concurrency_matches_serial_digests():
    cache = ExecutorCache()
    sched = Scheduler(cache, workers=2)
    try:
        sched.pause()
        # interleave two different suites so the queue mixes families
        mixed = [sched.submit(make_work(MIXED, runs=1, digest=True))
                 for _ in range(3)]
        single = [sched.submit(make_work(SINGLE, runs=1, digest=True))
                  for _ in range(3)]
        sched.resume()
        for t in mixed + single:
            t.wait(timeout=300)
    finally:
        sched.stop()

    ref_mixed = _serial_reference(MIXED, runs=1)
    ref_single = _serial_reference(SINGLE, runs=1)
    for t in mixed:
        assert _ticket_digests(t, len(ref_mixed)) == ref_mixed
    for t in single:
        assert _ticket_digests(t, len(ref_single)) == ref_single

    # exactness survives bracket proliferation: a coalesced launch may
    # land in a larger pow-2 bracket (extra compile per family), but the
    # summed per-ticket misses still equal the cache's compile count
    assert (sum(t.misses for t in mixed + single)
            == cache.stats().misses)
    assert sched.snapshot()["total_launches"] < 6 * 2  # fewer than items


def test_coalesce_member_cap_splits_launches():
    cache = ExecutorCache()
    # cap so small that two single-bucket requests cannot share a launch
    sched = Scheduler(cache, workers=1, max_coalesce_members=1)
    try:
        sched.pause()
        tickets = [sched.submit(make_work(SINGLE, runs=1, digest=True))
                   for _ in range(3)]
        sched.resume()
        for t in tickets:
            t.wait(timeout=300)
    finally:
        sched.stop()
    snap = sched.snapshot()
    assert snap["total_launches"] == 3           # no coalescing possible
    assert snap["coalesced_launches"] == 0
    assert all(t.coalesced_launches == 0 for t in tickets)
    # still exactly one compile total: the cache serves warm repeats
    assert cache.stats().misses == 1
    assert sum(t.misses for t in tickets) == 1


# ---------------------------------------------------------------------------
# backpressure: reject at submit, before any JAX work
# ---------------------------------------------------------------------------

def test_queue_full_rejects_before_any_launch():
    cache = ExecutorCache()
    sched = Scheduler(cache, workers=1, max_queue=2)
    try:
        sched.pause()
        t1 = sched.submit(make_work(SINGLE, runs=1))
        t2 = sched.submit(make_work(SINGLE, runs=1))
        with pytest.raises(QueueFull) as ei:
            sched.submit(make_work(SINGLE, runs=1))
        assert ei.value.depth == 2 and ei.value.limit == 2
        # the rejection happened BEFORE the run: nothing compiled,
        # nothing launched
        assert cache.stats().misses == 0
        assert sched.snapshot()["total_launches"] == 0
        sched.resume()
        t1.wait(timeout=300)
        t2.wait(timeout=300)
    finally:
        sched.stop()
    assert sched.snapshot()["completed"] == 2


def test_submit_is_all_or_nothing():
    sched = Scheduler(ExecutorCache(), workers=1, max_queue=4)
    try:
        sched.pause()
        sched.submit(make_work(MIXED, runs=1))       # 3 of 4 slots
        with pytest.raises(QueueFull):
            sched.submit(make_work(MIXED, runs=1))   # 3 more won't fit
        # the failed submit left NO partial items behind
        assert sched.snapshot()["queue_depth"] == 3
        assert sched.snapshot()["submitted"] == 1
        sched.resume()
    finally:
        sched.stop()


# ---------------------------------------------------------------------------
# shutdown: drain vs fail-fast
# ---------------------------------------------------------------------------

def test_stop_drains_queued_work():
    cache = ExecutorCache()
    sched = Scheduler(cache, workers=2)
    sched.pause()
    tickets = [sched.submit(make_work(MIXED, runs=1, digest=True))
               for _ in range(3)]
    # stop() un-pauses, lets the workers drain the queue, and only then
    # joins them — every ticket must resolve with full results
    sched.stop(drain=True)
    ref = _serial_reference(MIXED, runs=1)
    for t in tickets:
        assert t.done.is_set()
        t.wait(timeout=0.1)                      # no error to re-raise
        assert _ticket_digests(t, len(ref)) == ref
    snap = sched.snapshot()
    assert snap["queue_depth"] == 0
    assert snap["completed"] == 3 and snap["failed"] == 0
    assert snap["stopping"] is True
    with pytest.raises(SchedulerStopped):
        sched.submit(make_work(SINGLE, runs=1))


def test_stop_without_drain_fails_queued_tickets():
    sched = Scheduler(ExecutorCache(), workers=1)
    sched.pause()
    tickets = [sched.submit(make_work(SINGLE, runs=1)) for _ in range(2)]
    sched.stop(drain=False)
    for t in tickets:
        assert t.done.is_set()
        with pytest.raises(SchedulerStopped):
            t.wait(timeout=0.1)
    assert sched.snapshot()["failed"] == 2


# ---------------------------------------------------------------------------
# failure isolation: one bad request cannot poison its neighbors
# ---------------------------------------------------------------------------

def test_launch_failure_fails_only_its_ticket():
    cache = ExecutorCache()
    sched = Scheduler(cache, workers=1)

    # a ticket that fails mid-suite (here: injected, as if an earlier
    # bucket launch raised) must have its still-queued items retired
    # dead — no launch, no results — while neighbors run untouched
    sched.pause()
    good = sched.submit(make_work(SINGLE, runs=1, digest=True))
    victim = sched.submit(make_work(MIXED, runs=1, digest=True))
    with sched._cv:
        victim.error = RuntimeError("injected: earlier bucket failed")
        victim.done.set()
    sched.resume()
    good.wait(timeout=300)
    sched.stop()
    # the good ticket completed untouched; the victim's dead items were
    # retired without running (3 items retired, only SINGLE launched +
    # however the sweep batched — crucially, results stay empty)
    assert good.error is None and len(good.results) == 1
    assert victim.results == {}
    assert sched.snapshot()["queue_depth"] == 0


# ---------------------------------------------------------------------------
# deadlines, cancellation, quarantine (ISSUE 8 fault tolerance)
# ---------------------------------------------------------------------------

def test_deadline_expired_in_queue_never_launches():
    cache = ExecutorCache()
    sched = Scheduler(cache, workers=1)
    try:
        # pause -> the item sits queued past its deadline -> resume: the
        # worker must retire it dead, not launch it
        sched.pause()
        doomed = sched.submit(make_work(SINGLE, runs=1), deadline_s=0.05)
        fine = sched.submit(make_work(SINGLE, runs=1, digest=True))
        time.sleep(0.2)
        sched.resume()
        with pytest.raises(DeadlineExceeded):
            doomed.wait(timeout=300)
        fine.wait(timeout=300)
    finally:
        sched.stop()
    snap = sched.snapshot()
    # the expired request launched NOTHING and compiled NOTHING of its
    # own: the only launch/compile belongs to the healthy neighbor
    assert snap["deadline_expired"] == 1
    assert snap["failed"] == 1 and snap["completed"] == 1
    assert doomed.results == {} and doomed.launches == 0
    assert snap["total_launches"] == fine.launches == 1
    assert cache.stats().misses == 1 and fine.misses == 1


def test_unexpired_deadline_is_harmless():
    sched = Scheduler(ExecutorCache(), workers=1)
    try:
        t = sched.submit(make_work(SINGLE, runs=1, digest=True),
                         deadline_s=300.0)
        t.wait(timeout=300)
    finally:
        sched.stop()
    assert t.error is None and len(t.results) == 1
    assert sched.snapshot()["deadline_expired"] == 0


def test_cancel_removes_queued_items_before_launch():
    cache = ExecutorCache()
    sched = Scheduler(cache, workers=1)
    try:
        sched.pause()
        victim = sched.submit(make_work(MIXED, runs=1))
        survivor = sched.submit(make_work(SINGLE, runs=1, digest=True))
        removed = sched.cancel(victim)
        assert removed == 3                      # all queued items pulled
        assert sched.snapshot()["queue_depth"] == 1
        sched.resume()
        with pytest.raises(RequestCancelled):
            victim.wait(timeout=300)
        survivor.wait(timeout=300)
    finally:
        sched.stop()
    snap = sched.snapshot()
    assert snap["cancelled"] == 1 and snap["failed"] == 1
    # cancelled items never launched: only the survivor's launch ran
    assert victim.results == {} and victim.launches == 0
    assert snap["total_launches"] == 1
    assert cache.stats().misses == 1
    # cancelling a completed ticket is a no-op
    assert sched.cancel(survivor) == 0
    assert sched.snapshot()["cancelled"] == 1


def test_quarantine_after_consecutive_launch_failures():
    from repro.serve.faults import FaultInjector
    n_fail = QUARANTINE_AFTER
    faults = FaultInjector.from_spec(f"launch:fail:{n_fail}")
    sched = Scheduler(ExecutorCache(), workers=1, max_coalesce_members=1,
                      faults=faults)
    try:
        # each submit is its own launch (coalescing capped off), so the
        # streak builds one failure at a time
        for _ in range(n_fail):
            t = sched.submit(make_work(SINGLE, runs=1))
            with pytest.raises(Exception):
                t.wait(timeout=300)
        assert sched.snapshot()["quarantined_families"] == 1
        # the family now fails FAST: no launch, injector exhausted
        t = sched.submit(make_work(SINGLE, runs=1))
        with pytest.raises(FamilyQuarantined):
            t.wait(timeout=300)
        assert sched.snapshot()["total_launches"] == n_fail
        # operator reset: the family launches (and succeeds) again
        assert sched.clear_quarantine() == 1
        t = sched.submit(make_work(SINGLE, runs=1, digest=True))
        t.wait(timeout=300)
        assert len(t.results) == 1
    finally:
        sched.stop()
    assert sched.snapshot()["quarantined_families"] == 0
