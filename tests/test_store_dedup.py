"""Duplicate-index store scatter: all four backends bit-identical on
patterns that write the same row twice, on every execution path
(per-pattern GSEngine, batched bucket, sharded bucket).

The sequential-scalar backend is the semantic oracle: a fori_loop of
writes IS last-write-wins by construction, with no mask involved.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ExecutorCache, GSEngine, Pattern, SuitePlan,
                        execute_bucket, make_pattern)
from repro.core import backends as B
from repro.core.engine import make_host_buffers

# delta < span: neighbouring gathers/scatters overlap -> duplicate writes.
# BROADCAST repeats indices inside one op; delta 0 stacks every op on the
# same base (the LULESH-S3 regime).
DUP_PATTERNS = [
    make_pattern("UNIFORM:8:2", kind="scatter", delta=2, count=16,
                 name="overlap"),
    make_pattern("BROADCAST:8:4", kind="scatter", delta=1, count=12,
                 name="bcast"),
    Pattern("delta0", "scatter", (0, 3, 3, 7), delta=0, count=8),
    Pattern("same-row", "scatter", (5,), delta=0, count=32),
]


def _lww_ref(p: Pattern) -> np.ndarray:
    """Sequential last-write-wins oracle on the engine's own buffers."""
    _, abs_idx, vals, _ = make_host_buffers(p, 1)
    ref = np.zeros((p.footprint(), 1), np.float32)
    for i, j in enumerate(abs_idx):
        ref[j] = vals[i]
    return ref


@pytest.mark.parametrize("p", DUP_PATTERNS, ids=lambda p: p.name)
@pytest.mark.parametrize("backend", B.BACKENDS)
def test_per_pattern_store_bit_identical(p, backend):
    fn, args = GSEngine(p, backend=backend).build()
    np.testing.assert_array_equal(np.asarray(fn(*args)), _lww_ref(p))


@pytest.mark.parametrize("backend", B.BACKENDS)
def test_batched_bucket_store_bit_identical(backend):
    plan = SuitePlan.build(DUP_PATTERNS)
    for bucket in plan.buckets:
        outs = execute_bucket(plan, bucket, backend=backend, mode="store",
                              cache=ExecutorCache())
        for out, pos in zip(outs, bucket.members):
            np.testing.assert_array_equal(
                out, _lww_ref(plan.patterns[pos]),
                err_msg=f"{backend}/{plan.patterns[pos].name}")


@pytest.mark.parametrize("backend", B.BACKENDS)
def test_sharded_bucket_store_bit_identical(backend):
    mesh = jax.make_mesh((1,), ("data",))
    plan = SuitePlan.build(DUP_PATTERNS)
    for bucket in plan.buckets:
        outs = execute_bucket(plan, bucket, backend=backend, mode="store",
                              cache=ExecutorCache(), mesh=mesh)
        for out, pos in zip(outs, bucket.members):
            np.testing.assert_array_equal(
                out, _lww_ref(plan.patterns[pos]),
                err_msg=f"{backend}/{plan.patterns[pos].name}")


def test_all_backends_agree_with_each_other_batched():
    """Cross-check the batched path across backends directly (not just
    against the oracle) so a shared-oracle bug can't mask a divergence."""
    plan = SuitePlan.build(DUP_PATTERNS)
    for bucket in plan.buckets:
        ref = execute_bucket(plan, bucket, backend="scalar", mode="store",
                             cache=ExecutorCache())
        for backend in ("xla", "onehot", "pallas"):
            outs = execute_bucket(plan, bucket, backend=backend,
                                  mode="store", cache=ExecutorCache())
            for o, r_ in zip(outs, ref):
                np.testing.assert_array_equal(o, r_, err_msg=backend)
