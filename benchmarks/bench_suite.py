"""Canonical suite benchmark -> BENCH_suite.json (perf trajectory).

Runs a JSON suite (default ``suites/demo.json``) through the planner on
each backend and writes one machine-readable record per (pattern, backend):
measured/modeled GB/s, attributed wall time, plus per-backend compile
counts (ExecutorCache.misses — exact) and the pallas one-launch-per-bucket
census (pallas_call primitives in each store/gather bucket executable's
jaxpr).  Two §16 sections ride along: ``autotune`` (the pallas sweep
under the legacy fixed tiles vs the deterministic tile search, plus the
per-geometry tiles chosen) and ``pallas_lane`` (lane-sharded pallas on 8
fake devices in a child process, bit-identity checked against the
single-device planner).  The file is read-merge-written — other benches
own their own sections.  CI uploads it as an artifact so the perf
trajectory is tracked across PRs; compare against the committed baseline
with::

    PYTHONPATH=src python -m benchmarks.run --quick --only suite

``--quick`` scales pattern counts down (recorded in ``meta.count_cap``) so
the pallas interpret-mode grids stay small on CPU; absolute numbers are
only comparable within a matching ``meta`` block.
"""
from __future__ import annotations

import dataclasses
import json
import os
import platform
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp

from repro.core import ExecutorCache, SuitePlan, load_suite, run_suite
from repro.core.plan import _assemble_bucket, _build_executable
from repro.core.tracing import count_primitives
from repro.kernels import autotune

from .harness import emit

DEFAULT_SUITE = "suites/demo.json"
DEFAULT_OUT = "BENCH_suite.json"
BACKENDS = ("xla", "onehot", "scalar", "pallas")

# §16 lane-sharded pallas sweep: its own process so the forced device
# count never leaks into this one (same discipline as the sharded bench)
LANE_SHAPES = ((1, 8), (4, 2))
_LANE_CHILD = textwrap.dedent("""\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json, sys, time
    sys.path.insert(0, %(src)r)
    from repro.core import ExecutorCache, load_suite, run_suite

    pats = load_suite(%(suite)r)
    cap = %(cap)d
    if cap:
        pats = [dataclasses.replace(p, count=min(p.count, cap))
                for p in pats]
    out = {}
    ref = run_suite(pats, backend="pallas", runs=%(runs)d,
                    cache=ExecutorCache(), digest=True)
    d_ref = [r.out_digest for r in ref.results]
    out["single"] = {"hmean_gbs": ref.hmean_gbs}
    for b, l in %(shapes)r:
        cache = ExecutorCache()
        t0 = time.perf_counter()
        stats = run_suite(pats, backend="pallas", runs=%(runs)d,
                          cache=cache, mesh=(b, l), digest=True)
        out["%%dx%%d" %% (b, l)] = {
            "hmean_gbs": stats.hmean_gbs,
            "wall_s": time.perf_counter() - t0,
            "compiles": cache.stats().misses,
            "digests_match_single":
                [r.out_digest for r in stats.results] == d_ref,
        }
    print(json.dumps(out))
    """)


def _pallas_lane_sweep(suite: str, runs: int, cap: int) -> dict:
    """Lane-sharded pallas on 8 fake devices: hmean per shape + the
    bit-identity check against the single-device planner."""
    src = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                       "..", "src"))
    code = _LANE_CHILD % {"src": src, "suite": os.path.abspath(suite),
                          "cap": cap, "runs": runs,
                          "shapes": tuple(LANE_SHAPES)}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=540)
    if r.returncode != 0:
        raise RuntimeError(f"pallas-lane child failed: {r.stderr[-2000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def _pallas_launch_census(plan: SuitePlan) -> list[dict]:
    """pallas_call count per bucket executable (acceptance: store == 1)."""
    rows = []
    for bucket in plan.buckets:
        spec = bucket.spec
        mode = "store" if spec.kind == "scatter" else ""
        args, _ = _assemble_bucket(plan, bucket, jnp.float32, 1, 0)
        fn = _build_executable("pallas", spec.kind, mode or "store")
        counts = count_primitives(jax.make_jaxpr(fn)(*args))
        rows.append({
            "kind": spec.kind, "idx_len": spec.idx_len,
            "footprint": spec.footprint, "batch": args[1].shape[0],
            "pallas_calls": counts.get("pallas_call", 0),
            "sort_prims": counts.get("sort", 0),
        })
    return rows


def run(runs: int = 3, *, suite: str = DEFAULT_SUITE,
        out_path: str | None = DEFAULT_OUT, count_cap: int | None = None,
        backends=BACKENDS) -> dict:
    quick = runs <= 3
    if count_cap is None:
        count_cap = 512 if quick else 0          # 0 = uncapped
    patterns = load_suite(suite)
    if count_cap:
        patterns = [dataclasses.replace(p, count=min(p.count, count_cap))
                    for p in patterns]
    plan = SuitePlan.build(patterns)

    results = []
    per_backend = {}
    tiles: dict = {}
    for backend in backends:
        cache = ExecutorCache()
        t0 = time.perf_counter()
        with autotune.recording() as rec:
            stats = run_suite(patterns, backend=backend, runs=runs,
                              cache=cache)
        wall = time.perf_counter() - t0
        tiles.update(rec)
        per_backend[backend] = {
            "compiles": cache.misses,
            "n_buckets": stats.plan.n_buckets,
            "wall_s": wall,
            "hmean_measured_gbs": stats.hmean_gbs,
        }
        for r in stats.results:
            results.append({
                "pattern": r.pattern.name,
                "kind": r.pattern.kind,
                "type": r.pattern.classify(),
                "backend": backend,
                "measured_gbs": r.measured_gbs,
                "modeled_gbs": r.modeled_gbs,
                "time_s": r.time_s,
            })
        emit(f"suite/{backend}", wall * 1e6,
             f"{cache.misses}compiles;hmean={stats.hmean_gbs:.3f}gbs")

    # the before leg of the §16 autotuner: the same pallas sweep under
    # the legacy fixed tiles (what every PR before the search shipped)
    before = None
    if "pallas" in backends:
        with autotune.disabled():
            t0 = time.perf_counter()
            legacy = run_suite(patterns, backend="pallas", runs=runs,
                               cache=ExecutorCache())
            legacy_wall = time.perf_counter() - t0
        before = {"hmean_measured_gbs": legacy.hmean_gbs,
                  "wall_s": legacy_wall}
        tuned = per_backend["pallas"]["hmean_measured_gbs"]
        speedup = (tuned / legacy.hmean_gbs) if legacy.hmean_gbs else -1.0
        emit("suite/pallas_legacy_tiles", legacy_wall * 1e6,
             f"hmean={legacy.hmean_gbs:.3e}gbs;tuned_speedup={speedup:.2f}x")

    lane = None
    if "pallas" in backends:
        lane = _pallas_lane_sweep(suite, max(1, min(runs, 2)),
                                  min(count_cap or 128, 128))
        for shape, row in lane.items():
            if shape == "single":
                continue
            emit(f"suite/pallas_lane_{shape}", row["wall_s"] * 1e6,
                 f"{row['hmean_gbs']:.3e}gbs;"
                 f"ident={row['digests_match_single']}")

    doc = {
        "meta": {
            "suite": suite,
            "runs": runs,
            "count_cap": count_cap,
            "n_patterns": len(patterns),
            "n_buckets": plan.n_buckets,
            "jax": jax.__version__,
            "device": jax.devices()[0].platform,
            "host": platform.machine(),
        },
        "backends": per_backend,
        "pallas_bucket_launches": _pallas_launch_census(plan),
        "results": results,
        # §16: what the deterministic tile search bought on this host —
        # legacy-tile leg vs the autotuned pallas sweep above — plus the
        # per-geometry tiles it chose (the wire form DiskTier persists)
        "autotune": {
            "before_legacy_tiles": before,
            "after_hmean_measured_gbs":
                per_backend.get("pallas", {}).get("hmean_measured_gbs"),
            "tiles": autotune.to_wire(tiles),
        },
        # §16: lane-sharded pallas (8 fake devices, own process) — every
        # shape must stay bit-identical to the single-device planner
        "pallas_lane": lane,
    }
    if out_path:                       # None = CSV only, no trajectory write
        # read-merge-write: other benches (mesh_sweep, serve_concurrency,
        # ...) own their sections of the trajectory file
        prev = {}
        if os.path.exists(out_path):
            with open(out_path) as f:
                prev = json.load(f)
        prev.update(doc)
        with open(out_path, "w") as f:
            json.dump(prev, f, indent=2)
        emit("suite/json", 0.0, out_path)
    return doc


if __name__ == "__main__":
    run()
