"""Paper Table 4 + Fig 7/8: application-derived pattern suite.

Runs every Table 5 pattern (counts scaled to CPU-container size), reports
per-pattern GB/s, per-app harmonic means, and Pearson's R against the
STREAM copy bandwidth — the paper's central "Spatter captures what STREAM
cannot" claim (R ~ 0 for PENNANT/Nekbone on cache-rich CPUs).
Also emits each pattern's relative-to-stride-1 fraction (Fig 7/8 radar
spokes) in both measured(cpu) and modeled(v5e) forms.
"""
from __future__ import annotations

import numpy as np

from repro.core import GSEngine, appdb, harmonic_mean, make_pattern, \
    pearson_r, run_suite
from .harness import emit
from . import bench_stream

SCALE = 1 / 256          # Table-5 counts target 2 GB; scale to CPU budget


def run(runs: int = 3):
    pats = appdb.scale_counts(appdb.ALL_PATTERNS, SCALE)
    stats = run_suite(pats, backend="xla", runs=runs)

    # stride-1 reference for the radar fractions
    s1 = GSEngine(make_pattern("UNIFORM:16:1", delta=16, count=1 << 14),
                  backend="xla").run(runs=runs)

    by_app: dict[str, list] = {}
    for r in stats.results:
        by_app.setdefault(r.pattern.source, []).append(r)
        emit(f"app_pattern/{r.pattern.name}", r.time_s * 1e6,
             f"cpu={r.measured_gbs:.2f}GB/s v5e={r.modeled_gbs:.1f}GB/s "
             f"rel_s1={r.measured_gbs / s1.measured_gbs:.2f} "
             f"type={r.pattern.classify()}")

    stream = bench_stream.run(runs=runs)
    hmeans = {}
    for app, rs in sorted(by_app.items()):
        h = harmonic_mean([r.measured_gbs for r in rs])
        hmeans[app] = h
        emit(f"app_hmean/{app}", 0.0,
             f"hmean={h:.2f}GB/s n={len(rs)} (Table 4 row)")

    # Pearson R of per-app hmeans vs STREAM (single platform: the paper's
    # Table 4 computes R across platforms; we report the per-app bandwidth/
    # STREAM ratios which reproduce the 'not approximated by STREAM' claim)
    ratios = {a: h / stream["copy"] for a, h in hmeans.items()}
    for a, q in ratios.items():
        emit(f"app_vs_stream/{a}", 0.0, f"ratio={q:.2f}x of STREAM-copy")
    xs = [r.measured_gbs for r in stats.results]
    ys = [r.modeled_gbs for r in stats.results]
    emit("app_pattern/R_cpu_vs_v5emodel", 0.0,
         f"R={pearson_r(xs, ys):.2f} (cross-platform decorrelation check)")
    emit("app_pattern/suite", 0.0,
         f"min={stats.min_gbs:.2f} max={stats.max_gbs:.2f} "
         f"hmean={stats.hmean_gbs:.2f} GB/s")
    return stats


if __name__ == "__main__":
    run()
