"""Model zoo substrate: pure-JAX, pjit-ready, scan-over-layers."""
