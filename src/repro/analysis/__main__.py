"""spatterlint / spattercost matrix runner — ``python -m repro.analysis``
(CI's lint and cost jobs; DESIGN.md §12, §15).

Default (lint) mode audits every (suite x placement x backend) cell
statically plus the serving-layer ast lint, writes one merged JSON
report, and exits non-zero on any violation::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m repro.analysis \\
        --suite suites/demo.json --suite suites/apps.json \\
        --suite suites/widelane.json \\
        --mesh 1x1 --mesh 8x1 --mesh 4x2 --mesh 1x8 \\
        --out LINT_report.json

``--cost`` switches to the traffic matrix (repro.analysis.cost): every
cell's executables are byte-accounted and reconciled against their
lowered StableHLO, with GB/s predicted off the BENCH-calibrated
roofline; ``--mesh auto`` is a legal cell (the min-predicted-cost
shape).  ``--write-baseline FILE`` additionally freezes each unit's
predicted I/O bytes as the cost-regression gate's committed baseline::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m repro.analysis --cost \\
        --suite suites/demo.json --mesh 1x1 --mesh 8x1 \\
        --out COST_report.json [--write-baseline COST_baseline.json]

Placement cells that need more devices than are visible are a hard
error (exit 2), not a skip: CI asserting "matrix clean" must never
silently audit less than the matrix.
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="spatterlint/spattercost: static audit of planner "
                    "executables over a suite x placement matrix")
    ap.add_argument("--suite", action="append", default=[],
                    metavar="FILE", help="suites/*.json file (repeatable)")
    ap.add_argument("--mesh", action="append", default=[],
                    metavar="N|BxL|auto",
                    help="placement cell, e.g. 1x1, 8x1, 4x2, 1x8, auto "
                         "(per-bucket cost-model), or auto-suite (one "
                         "suite-wide shape); repeatable; default: "
                         "single-device only")
    ap.add_argument("--backend", action="append", default=[],
                    choices=["xla", "onehot", "scalar", "pallas"],
                    help="backend(s) to audit (default: xla + pallas)")
    ap.add_argument("--mode", default="store", choices=["store", "add"])
    ap.add_argument("--out", default=None, metavar="FILE",
                    help="write the merged JSON report here")
    ap.add_argument("--no-serve-lint", action="store_true",
                    help="skip the repro/serve ast concurrency lint")
    ap.add_argument("--cost", action="store_true",
                    help="run the spattercost traffic matrix instead of "
                         "spatterlint (DESIGN.md §15)")
    ap.add_argument("--write-baseline", default=None, metavar="FILE",
                    help="--cost: freeze each unit's predicted I/O bytes "
                         "to FILE — the cost-regression rule's committed "
                         "baseline")
    args = ap.parse_args(argv)
    if args.write_baseline and not args.cost:
        ap.error("--write-baseline requires --cost")
    if args.cost and args.no_serve_lint:
        ap.error("--no-serve-lint does not apply to --cost (the traffic "
                 "matrix has no serve lint)")
    if args.cost and not args.suite:
        ap.error("--cost needs at least one --suite FILE")
    if not args.suite and args.no_serve_lint:
        ap.error("nothing to lint: pass --suite and/or drop "
                 "--no-serve-lint")

    from repro.serve.schema import parse_mesh

    backends = tuple(args.backend) or ("xla", "pallas")
    meshes = [parse_mesh(m) for m in args.mesh] or [0]

    if args.cost:
        from repro.analysis.cost import (CostReport, cost_suite_file,
                                         write_baseline)
        report = CostReport()
        try:
            for suite in args.suite:
                for mesh in meshes:
                    report = report.merge(cost_suite_file(
                        suite, mesh=mesh or None, backends=backends,
                        mode=args.mode))
        except ValueError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        if args.write_baseline:
            units = {}
            for u in report.units:
                # the matrix may cost one ExecKey from several cells
                # (shared buckets); the predicted bytes are a pure
                # function of the key, so collisions agree — keep max
                # defensively
                units[u.exec_key] = max(
                    u.io_bytes, units.get(u.exec_key, 0))
            write_baseline(units, args.write_baseline,
                           meta={"suites": args.suite,
                                 "meshes": args.mesh or ["single"],
                                 "backends": list(backends)})
            print(f"baseline: {args.write_baseline} "
                  f"({len(units)} unit(s))")
    else:
        from repro.analysis.lint import lint_serve, lint_suite_file
        from repro.analysis.report import LintReport
        report = LintReport()
        if not args.no_serve_lint:
            report = report.merge(lint_serve())
        try:
            for suite in args.suite:
                for mesh in meshes:
                    report = report.merge(lint_suite_file(
                        suite, mesh=mesh, backends=backends,
                        mode=args.mode))
        except ValueError as e:
            # an unbuildable cell (mesh > visible devices, bad suite)
            # must fail the job loudly — a skipped cell is not a clean
            # cell
            print(f"error: {e}", file=sys.stderr)
            return 2

    if args.out:
        report.dump(args.out)
    print(report.summary())
    if args.out:
        print(f"report: {args.out}")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
