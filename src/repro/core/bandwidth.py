"""Bandwidth accounting — the paper's §3.5 formula plus a TPU tile model.

Paper formula (useful-bytes rate; cache reuse allowed):

    BW = sizeof(elem) * len(index) * count / time

On this CPU-only container we report two numbers for every run and label
them explicitly (DESIGN.md §9):

  * ``measured(cpu)``  — the paper formula over measured XLA-CPU wall time.
  * ``modeled(v5e)``   — the paper formula over *modeled* TPU time from the
    tile-traffic model below.  The TPU moves HBM<->VMEM in (8,128) tiles, so
    a 1D element buffer is fetched in runs of ``tile_bytes`` contiguous
    bytes; "tile efficiency" (useful/fetched) plays the cache-line-
    utilization role of paper Fig 3, and a VMEM-capacity LRU plays the role
    of the L2/L3 cache that lets app patterns beat STREAM (paper Table 4).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

from .pattern import Pattern

# --- TPU v5e hardware constants (also used by launch/roofline.py) ----------
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
VMEM_BYTES = 64 * 1024 * 1024     # usable VMEM working set (model parameter)
VMEM_BW = 11e12                   # VMEM streaming bandwidth (model parameter)
TILE_BYTES = 8 * 128 * 4          # one (8,128) f32 tile = 4 KiB


def useful_bytes(p: Pattern, elem_bytes: int) -> int:
    """Paper §3.5 numerator: data actually requested."""
    return p.index_len * p.count * elem_bytes


def paper_bandwidth(p: Pattern, time_s: float, elem_bytes: int) -> float:
    """The paper's bandwidth formula, in bytes/s."""
    return useful_bytes(p, elem_bytes) / time_s


@dataclasses.dataclass(frozen=True)
class TileModelResult:
    useful_bytes: int
    fetched_bytes: int            # HBM traffic after VMEM-LRU filtering
    tile_efficiency: float        # useful / fetched (<= 1 unless reuse)
    hbm_time_s: float
    vmem_time_s: float
    modeled_time_s: float         # max of the two (simple roofline)
    modeled_gbs: float            # paper-formula bandwidth over modeled time


def tpu_tile_model(p: Pattern, elem_bytes: int, *, sim_ops: int = 256,
                   tile_bytes: int = TILE_BYTES,
                   vmem_bytes: int = VMEM_BYTES) -> TileModelResult:
    """Count HBM tile traffic for a pattern under a VMEM-capacity LRU.

    Simulates ``min(count, sim_ops)`` consecutive G/S ops exactly and
    extrapolates linearly (patterns are periodic in the base address, so the
    steady-state per-op traffic converges within a few ops).
    """
    elems_per_tile = max(1, tile_bytes // elem_bytes)
    n_sim = min(p.count, sim_ops)
    idx = np.asarray(p.index, dtype=np.int64)

    cache: OrderedDict[int, None] = OrderedDict()
    capacity = max(1, vmem_bytes // tile_bytes)
    fetched_tiles = 0
    # warm-up ops are simulated too; steady state dominates for big counts
    for i in range(n_sim):
        tiles = np.unique((p.delta * i + idx) // elems_per_tile)
        for t in tiles.tolist():
            if t in cache:
                cache.move_to_end(t)
            else:
                fetched_tiles += 1
                cache[t] = None
                if len(cache) > capacity:
                    cache.popitem(last=False)

    per_op = fetched_tiles / n_sim
    total_fetched = int(per_op * p.count) * tile_bytes
    useful = useful_bytes(p, elem_bytes)
    hbm_t = total_fetched / HBM_BW
    vmem_t = useful / VMEM_BW
    modeled_t = max(hbm_t, vmem_t, 1e-30)
    return TileModelResult(
        useful_bytes=useful,
        fetched_bytes=total_fetched,
        tile_efficiency=useful / max(1, total_fetched),
        hbm_time_s=hbm_t,
        vmem_time_s=vmem_t,
        modeled_time_s=modeled_t,
        modeled_gbs=useful / modeled_t / 1e9,
    )


def pipeline_model(p: Pattern, elem_bytes: int, *, buffers: int = 2,
                   dma_latency_s: float = 2e-6) -> dict:
    """Paper Fig 4 analogue: Pallas pipeline multi-buffering on/off.

    With ``buffers>=2`` DMA issue overlaps compute/copy (prefetch ON); with
    ``buffers==1`` every block waits out the full DMA latency (prefetch
    OFF).  Returns modeled times for both the bandwidth and latency terms.
    """
    tm = tpu_tile_model(p, elem_bytes)
    n_blocks = p.count                      # one G/S op per grid step
    bw_time = tm.hbm_time_s
    lat_time = n_blocks * dma_latency_s
    if buffers >= 2:
        total = max(bw_time, tm.vmem_time_s) + dma_latency_s  # overlapped
    else:
        total = bw_time + lat_time + tm.vmem_time_s           # serialized
    return {
        "buffers": buffers,
        "modeled_time_s": total,
        "modeled_gbs": tm.useful_bytes / total / 1e9,
        "bw_time_s": bw_time,
        "latency_time_s": lat_time if buffers < 2 else dma_latency_s,
    }
