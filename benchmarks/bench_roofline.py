"""Roofline-terms bench: reads the dry-run cell JSONs (deliverable g),
plus the spattercost predicted-vs-measured record (DESIGN.md §15).

Emits one CSV row per (arch x shape) cell on the single-pod mesh with the
three roofline terms and the dominant bottleneck — the `derived` column is
the §Roofline table in benchmark form.  Requires the dry-run sweep to have
run (experiments/dryrun/*.json); emits a pointer row if absent.

The second half evaluates the static traffic model against what this
host actually measured: for every (suite x placement) cell — demo, apps
and widelane at single/8x1/4x2/2x4/1x8 — it computes the model's
predicted GB/s (calibrated roofline x useful/device traffic fraction,
``analysis.cost.shape_cost``) next to the recorded measurement from
``BENCH_suite.json`` (the ``backends`` record for demo, the
``mesh_sweep`` cells for apps/widelane; null where nothing was
recorded), plus paper Eq. 1's Pearson R over the measured pairs.  Counts
are capped exactly as the recorded runs were (``meta.count_cap`` /
``mesh_sweep.count_cap``) so predicted and measured describe the same
launch geometry.  The record merges into ``BENCH_suite.json`` under
``cost_model`` (same clobber guard as the other mergers: ``out_path=None``
skips the write).
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os

from .harness import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")
OUT_PATH = "BENCH_suite.json"
SHAPES = ((8, 1), (4, 2), (2, 4), (1, 8))
SUITES = ("demo", "apps", "widelane")


def _cost_model(doc: dict, root: str) -> dict:
    from repro.analysis import cost as C
    from repro.core import SuitePlan, load_suite
    from repro.core.suite import pearson_r

    bw = doc.get("backends", {}).get("xla", {}).get("hmean_measured_gbs",
                                                    0.0)
    sweep = doc.get("mesh_sweep", {})
    sweep_suites = sweep.get("suites", {})
    rec = {"bw_gbs_xla": bw, "suites": {}}
    pred, meas = [], []
    for name in SUITES:
        pats = load_suite(os.path.join(root, "suites", name + ".json"))
        # predicted and measured must describe the SAME launch geometry:
        # re-apply the count cap the recorded run used (capping changes
        # bucket idx_len, so pad waste and the whole byte split move)
        cap = sweep.get("count_cap", 0) if name in sweep_suites \
            else doc.get("meta", {}).get("count_cap", 0)
        if cap:
            pats = [dataclasses.replace(p, count=min(p.count, cap))
                    for p in pats]
        plan = SuitePlan.build(pats)
        cells = {}
        for shape in (None,) + tuple(SHAPES):
            key = "single" if shape is None else f"{shape[0]}x{shape[1]}"
            sc = C.shape_cost(plan, shape or (1, 1))
            predicted = bw * sc["useful_bytes"] / sc["device_bytes"] \
                if bw else None
            if name in sweep_suites:
                srec = sweep_suites[name]
                cell = srec.get("single", {}) if key == "single" \
                    else srec.get("shapes", {}).get(key, {})
                measured = cell.get("hmean_gbs")
            else:
                measured = doc.get("backends", {}).get(
                    "xla", {}).get("hmean_measured_gbs") \
                    if key == "single" else None
            cells[key] = {"predicted_gbs": predicted,
                          "measured_gbs": measured,
                          "overhead": sc["overhead"]}
            if predicted is not None and measured is not None:
                pred.append(predicted)
                meas.append(measured)
        cells["auto"] = "single" if C.select_shape(
            plan, n_devices=sweep.get("n_dev", 8)) == (1, 1) \
            else "%dx%d" % C.select_shape(plan,
                                          n_devices=sweep.get("n_dev", 8))
        rec["suites"][name] = cells
    r = pearson_r(pred, meas)
    rec["pearson_r"] = r if r == r else None       # NaN -> null
    rec["n_cells_measured"] = len(pred)
    return rec


def run(runs: int = 0, *, out_path: str | None = OUT_PATH):
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*__16x16.json")))
    if not files:
        emit("roofline/missing", 0.0,
             "run: PYTHONPATH=src python -m repro.launch.dryrun --all")
    for fn in files:
        with open(fn) as f:
            j = json.load(f)
        r = j["roofline"]
        emit(f"roofline/{r['arch']}/{r['shape']}",
             max(r["t_compute_s"], r["t_memory_s"],
                 r["t_collective_s"]) * 1e6,
             f"comp={r['t_compute_s']:.2f}s mem={r['t_memory_s']:.2f}s "
             f"coll={r['t_collective_s']:.2f}s dom={r['dominant']} "
             f"frac={100*r['roofline_fraction']:.1f}% "
             f"useful={r['useful_flops_ratio']:.2f}")

    # predicted-vs-measured for the static traffic model (DESIGN.md §15)
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    bench_path = out_path if out_path and os.path.isabs(out_path) \
        else os.path.join(root, out_path or OUT_PATH)
    doc = {}
    if os.path.exists(bench_path):
        with open(bench_path) as f:
            doc = json.load(f)
    rec = _cost_model(doc, root)
    for name, cells in rec["suites"].items():
        for key, cell in cells.items():
            if not isinstance(cell, dict):
                continue
            p, m = cell["predicted_gbs"], cell["measured_gbs"]
            emit(f"cost_model/{name}_{key}", 0.0,
                 (f"pred={p:.4f}GB/s;" if p is not None else "pred=n/a;")
                 + (f"meas={m:.4f}GB/s;" if m is not None else "meas=n/a;")
                 + f"overhead={cell['overhead']:.2f}x")
        emit(f"cost_model/{name}_auto", 0.0, f"auto={cells['auto']}")
    emit("cost_model/pearson_r", 0.0,
         f"R={rec['pearson_r']};n={rec['n_cells_measured']}")
    if out_path:
        doc["cost_model"] = rec
        with open(bench_path, "w") as f:
            json.dump(doc, f, indent=2)
        emit("cost_model/json", 0.0, bench_path)
    return rec


if __name__ == "__main__":
    run()
