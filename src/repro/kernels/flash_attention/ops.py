"""Public wrapper: fused attention with recompute-based backward.

Forward runs the Pallas kernel; backward recomputes through the reference
(jax.checkpoint-style custom_vjp would add a bwd kernel — the fwd kernel is
what removes the score HBM round-trips that dominate the measured memory
term; see EXPERIMENTS.md §Perf iteration 3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel
from .ref import flash_attention_ref


def _should_interpret(interpret: bool | None) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _flash(q, k, v, scale, causal, window, softcap, block_q, block_k,
           interpret):
    return kernel.flash_attention_fwd(
        q, k, v, scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=interpret)


def _fwd(q, k, v, scale, causal, window, softcap, block_q, block_k,
         interpret):
    out = _flash(q, k, v, scale, causal, window, softcap, block_q, block_k,
                 interpret)
    return out, (q, k, v)


def _bwd(scale, causal, window, softcap, block_q, block_k, interpret,
         res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: flash_attention_ref(
            q, k, v, scale=scale, causal=causal, window=window,
            softcap=softcap), q, k, v)
    return vjp(g)


_flash.defvjp(_fwd, _bwd)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    scale: float | None = None, causal: bool = True,
                    window: int = 0, softcap: float = 0.0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """Fused attention. q (B,KVH,G,S,dh); k/v (B,KVH,T,dh)."""
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    s, t = q.shape[3], k.shape[2]
    bq = min(block_q, s)
    while s % bq:
        bq //= 2
    bk = min(block_k, t)
    while t % bk:
        bk //= 2
    return _flash(q, k, v, float(scale), causal, window, float(softcap),
                  max(1, bq), max(1, bk), _should_interpret(interpret))
