"""Multi-pattern suite runner — the paper's JSON-input mode (§3.3, §3.5).

Runs many patterns, then reports the aggregate stats the paper reports:
per-pattern bandwidths, suite min/max, harmonic mean, and Pearson's R
against a STREAM-like reference (paper Eq. 1 / Table 4).

Execution goes through the suite planner by default (``batch=True``):
patterns are grouped into shape buckets and each bucket runs as one
vmapped launch through a process-wide executable cache, so an N-pattern
suite compiles #buckets executables instead of N and repeated suite runs
compile nothing.  See the DESIGN NOTE in plan.py for the full plan ->
compile -> execute design and the padding/scratch-row semantics.
``batch=False`` restores the original one-GSEngine-per-pattern path.
``mesh=``/``mesh_axis=`` split every bucket launch's pattern-batch dim
over a mesh axis (plan.ShardedExecutor) for multi-device suite runs.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .engine import GSEngine, RunResult
from .pattern import Pattern, load_suite, make_pattern
from .plan import ExecutorCache, SuitePlan, run_plan


# metric aliases -> the RunResult.row() column they select
_METRIC_COLUMNS = {
    "measured": "measured_cpu_gbs",
    "measured_cpu_gbs": "measured_cpu_gbs",
    "modeled": "modeled_v5e_gbs",
    "modeled_v5e_gbs": "modeled_v5e_gbs",
}


def _metric_column(metric: str) -> str:
    col = _METRIC_COLUMNS.get(metric)
    if col is None:
        raise ValueError(f"unknown metric {metric!r}; "
                         f"expected one of {sorted(_METRIC_COLUMNS)}")
    return col


@dataclasses.dataclass
class SuiteStats:
    results: list[RunResult]
    min_gbs: float
    max_gbs: float
    hmean_gbs: float
    plan: SuitePlan | None = None        # set when the batched path ran

    def table(self, metric: str = "measured_cpu_gbs") -> list[dict]:
        """Per-pattern rows with ``gbs`` set to the requested metric.

        ``metric`` picks which bandwidth column ("measured"/"modeled", or
        the full row() column names) populates the uniform ``gbs`` field;
        unknown metrics raise ValueError.
        """
        col = _metric_column(metric)
        rows = []
        for r in self.results:
            row = r.row()
            row["gbs"] = row[col]
            rows.append(row)
        return rows


def harmonic_mean(xs) -> float:
    xs = [x for x in xs if x > 0]
    if not xs:
        return 0.0
    return len(xs) / sum(1.0 / x for x in xs)


def pearson_r(xs, ys) -> float:
    """Paper Eq. (1): R = cov(X, STREAM) / (std(X)·std(STREAM))."""
    x, y = np.asarray(xs, float), np.asarray(ys, float)
    if x.size < 2 or x.std() == 0 or y.std() == 0:
        return float("nan")
    return float(np.corrcoef(x, y)[0, 1])


def run_suite(patterns: list[Pattern], *, backend: str = "xla",
              dtype=None, row_width: int = 1, runs: int = 10,
              metric: str = "measured", batch: bool = True,
              cache: ExecutorCache | None = None,
              mesh=None, mesh_axis: str = "data") -> SuiteStats:
    import jax.numpy as jnp
    if not patterns:
        raise ValueError("run_suite needs at least one pattern")
    col = _metric_column(metric)            # reject typos up front
    if mesh is not None and not batch:
        raise ValueError("mesh execution requires the batched planner "
                         "(batch=True)")
    dtype = dtype or jnp.float32
    plan = None
    if batch:
        plan = SuitePlan.build(patterns)
        results = run_plan(plan, backend=backend, dtype=dtype,
                           row_width=row_width, runs=runs, cache=cache,
                           mesh=mesh, mesh_axis=mesh_axis)
    else:
        results = []
        for p in patterns:
            eng = GSEngine(p, backend=backend, dtype=dtype,
                           row_width=row_width)
            results.append(eng.run(runs=runs))
    key = (lambda r: r.measured_gbs) if col == "measured_cpu_gbs" \
        else (lambda r: r.modeled_gbs)
    vals = [key(r) for r in results]
    return SuiteStats(
        results=results,
        min_gbs=min(vals), max_gbs=max(vals),
        hmean_gbs=harmonic_mean(vals),
        plan=plan,
    )


def run_suite_file(path: str, **kw) -> SuiteStats:
    return run_suite(load_suite(path), **kw)


def stream_reference(*, n: int = 2 ** 22, runs: int = 10,
                     backend: str = "xla") -> RunResult:
    """STREAM-copy analogue (paper §3.4): UNIFORM:8:1 with delta 8."""
    p = make_pattern("UNIFORM:8:1", kind="gather", delta=8, count=n // 8,
                     name="STREAM-like")
    return GSEngine(p, backend=backend).run(runs=runs)
