"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Every parameter / activation dimension carries a *logical* axis name
("batch", "vocab", "heads", ...).  Rules map logical names to mesh axes;
``logical_to_spec`` resolves them against concrete shapes, silently dropping
a mesh axis when it does not divide the dimension (e.g. kv_heads=2 cannot
shard over model=16 — it stays replicated, which is exactly what a GQA
tensor-parallel layout does).

A thread-local context carries (mesh, rules) so model code can annotate
activations without threading the mesh through every call:

    with use_mesh(mesh, rules):
        x = constrain(x, ("batch", "seq", "embed"))
"""
from __future__ import annotations

import contextlib
import threading
from typing import Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes). None = replicate.
DEFAULT_RULES: dict[str, object] = {
    "batch": ("pod", "data"),
    "seq": None,               # flipped to "model" for sequence parallelism
    "seq_resid": "model",      # residual stream between blocks (Megatron-SP):
                               # shrinks the per-layer saved activations by
                               # the model-axis factor (17 GB -> 1.07 GB on
                               # llama3-8b train_4k; see EXPERIMENTS.md §Perf)
    "embed": None,
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "qk_rank": None,           # MLA low-rank dims
    "kv_rank": None,
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,
    "capacity": None,
    "layers": None,            # scan-stacked leading dim
    "state": None,             # SSM state dim
    "conv": None,
    "rnn_width": "model",      # RG-LRU width / mamba d_inner
    "frames": None,
    "opt_state": ("pod", "data", "model"),  # ZeRO-1 flat shard axis
}

_ctx = threading.local()


def _mesh_axes(mesh: Mesh) -> set[str]:
    return set(mesh.axis_names)


def resolve_axis(logical: str | None, dim: int, mesh: Mesh,
                 rules: dict) -> object:
    """Resolve one logical axis to mesh axes that actually divide ``dim``."""
    if logical is None:
        return None
    target = rules.get(logical, None)
    if target is None:
        return None
    axes = (target,) if isinstance(target, str) else tuple(target)
    axes = [a for a in axes if a in _mesh_axes(mesh)]
    # keep the longest prefix of axes whose product divides dim
    kept = []
    prod = 1
    for a in axes:
        if dim % (prod * mesh.shape[a]) == 0:
            kept.append(a)
            prod *= mesh.shape[a]
        else:
            break
    if not kept:
        return None
    return kept[0] if len(kept) == 1 else tuple(kept)


def logical_to_spec(axes: Sequence[str | None], shape: Sequence[int],
                    mesh: Mesh, rules: dict | None = None) -> P:
    rules = rules or DEFAULT_RULES
    if len(axes) != len(shape):
        raise ValueError(f"axes {axes} vs shape {shape}")
    used: set[str] = set()
    out = []
    for name, dim in zip(axes, shape):
        r = resolve_axis(name, dim, mesh, rules)
        # a mesh axis may appear at most once in a spec
        if isinstance(r, tuple):
            r = tuple(a for a in r if a not in used) or None
            if isinstance(r, tuple) and len(r) == 1:
                r = r[0]
        if isinstance(r, str) and r in used:
            r = None
        if r is not None:
            used.update(r if isinstance(r, tuple) else (r,))
        out.append(r)
    return P(*out)


def named_shardings(mesh: Mesh, *specs: P) -> tuple[NamedSharding, ...]:
    """PartitionSpecs -> NamedShardings on ``mesh``, one per spec.

    The single constructor the placement layer (``plan.Placement``) uses
    to place gather/scatter operands, so placement policy lives in one
    spot.
    """
    return tuple(NamedSharding(mesh, s) for s in specs)


# -- gather/scatter placement rules (plan.Placement; DESIGN.md §11) ---------

def _gs_spec(*axes: str | None) -> P:
    """PartitionSpec from per-dim mesh axes, trailing Nones stripped (so a
    degenerate axis yields exactly the spec the 1-D code paths used)."""
    entries = list(axes)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def gs_specs(kind: str, *, batched: bool, batch_axis: str | None = None,
             lane_axis: str | None = None) -> tuple[tuple[P, ...], P]:
    """(in_specs, out_spec) for a gather/scatter executable on a 2-D
    ``(batch, lane)`` placement — the single axis-semantics rule table
    behind ``plan.Placement`` (and therefore behind both
    ``GSEngine.sharded`` and the suite planner's sharded bucket launches).
    Either axis may be ``None`` (degenerate), which recovers the 1-D
    specs exactly.

    Batched operands (one bucket launch, ``B`` patterns): dim 0 is the
    pattern-batch dim and shards over ``batch_axis``; the flattened lane
    dim (dim 1 of idx/vals/keep, dim 1 of the gather output) shards over
    ``lane_axis``.  Tables stay *replicated along the lane axis* — every
    lane shard may read (gather) or write (scatter) any row of its
    pattern's table, so the gather src is ``P(batch)`` and the scatter
    dst/out are ``P(batch)``: within one pattern, cross-lane-shard row
    traffic is the partitioner's job, while a pattern still never
    straddles batch shards.

    Unbatched operands (one pattern, ``GSEngine.sharded``): the lane dim
    is dim 0 of idx/vals/out; the table is fully replicated and a
    scatter's result replicated (any shard, any row) — the paper's
    OpenMP-thread split.  ``batch_axis`` is meaningless here (there is no
    batch dim) and must be ``None``.

    Scatter executables take four operands (dst, idx, vals, keep): the
    host-precomputed last-write-wins keep mask rides with the indices,
    which is also why lane-sharded store scatter stays correct — the mask
    is computed globally over the whole padded lane buffer before the
    split, so across all lane shards at most one write per row survives
    (DESIGN.md §11).
    """
    if kind not in ("gather", "scatter"):
        raise ValueError(f"kind must be gather|scatter, got {kind!r}")
    b, l = batch_axis, lane_axis
    if batched:
        if kind == "gather":
            # src (B,F,R), idx (B,N) -> out (B,N,R)
            return (_gs_spec(b), _gs_spec(b, l)), _gs_spec(b, l)
        # dst (B,F,R), idx (B,N), vals (B,N,R), keep (B,N) -> out (B,F,R)
        return ((_gs_spec(b), _gs_spec(b, l), _gs_spec(b, l),
                 _gs_spec(b, l)), _gs_spec(b))
    if b is not None:
        raise ValueError("unbatched executables have no pattern-batch dim "
                         f"to shard (batch_axis={b!r})")
    if kind == "gather":
        # src (F,R) replicated, idx (N,) -> out (N,R)
        return (_gs_spec(None), _gs_spec(l)), _gs_spec(l)
    # dst (F,R) replicated, idx/vals/keep lane-sharded -> out replicated
    return ((_gs_spec(None), _gs_spec(l), _gs_spec(l), _gs_spec(l)),
            _gs_spec(None))


# -- context ----------------------------------------------------------------

@contextlib.contextmanager
def use_mesh(mesh: Mesh | None, rules: dict | None = None):
    prev = getattr(_ctx, "state", None)
    _ctx.state = (mesh, rules or DEFAULT_RULES)
    try:
        yield
    finally:
        _ctx.state = prev


def current_mesh() -> tuple[Mesh | None, dict]:
    st = getattr(_ctx, "state", None)
    if st is None:
        return None, DEFAULT_RULES
    return st


def constrain(x: jax.Array, axes: Sequence[str | None]) -> jax.Array:
    """Annotate an activation with its logical sharding (no-op off-mesh)."""
    mesh, rules = current_mesh()
    if mesh is None or len(mesh.devices.flat) == 1:
        return x
    spec = logical_to_spec(axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_if_sharded(x: jax.Array, axes: Sequence[str | None],
                         key_dim: int) -> jax.Array:
    """Constrain only if the resolved spec actually shards ``key_dim``.

    Replacing a GSPMD-chosen sharding with an explicit *replicated* spec is
    a pessimization (measured: llama3 kvh=8 on model=16 — §Perf); only pin
    when the rule resolves to a real axis for the key dimension.
    """
    mesh, rules = current_mesh()
    if mesh is None or len(mesh.devices.flat) == 1:
        return x
    spec = logical_to_spec(axes, x.shape, mesh, rules)
    if spec[key_dim] is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def spec_tree(axes_tree, shape_tree, mesh: Mesh, rules: dict | None = None):
    """Map logical_to_spec over parallel pytrees of axes and shapes."""
    return jax.tree.map(
        lambda axes, shp: logical_to_spec(axes, shp, mesh, rules),
        axes_tree, shape_tree,
        is_leaf=lambda v: isinstance(v, tuple) and all(
            isinstance(e, (str, type(None))) for e in v),
    )
