"""Train / serve step builders: pjit-ready, sharding-annotated, donatable.

``make_train_step`` returns (step_fn, shardings) where step_fn is
(params, opt_state, batch) -> (params', opt_state', metrics).  All sharding
comes from logical axes resolved against the active mesh, so the same code
lowers on 1 CPU device, a 16x16 pod, or the 2x16x16 multi-pod mesh.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.zoo import Model
from repro.optim import (AdamWConfig, adamw_update, clip_by_global_norm,
                         init_opt_state, opt_state_axes)
from .sharding import DEFAULT_RULES, logical_to_spec, use_mesh

_AXES_LEAF = lambda v: isinstance(v, tuple) and all(
    isinstance(e, (str, type(None))) for e in v)


def shardings_from_axes(axes_tree, abstract_tree, mesh: Mesh,
                        rules: dict | None = None):
    return jax.tree.map(
        lambda ax, av: NamedSharding(
            mesh, logical_to_spec(ax, av.shape, mesh, rules)),
        axes_tree, abstract_tree, is_leaf=_AXES_LEAF)


def zero1_shardings(axes_tree, abstract_tree, mesh: Mesh,
                    rules: dict | None = None):
    """Optimizer-moment shardings: param spec + every remaining mesh axis.

    Shape-aware ZeRO-1: after resolving the param's own spec, walk the
    remaining mesh axes (largest first) and attach each to the first
    still-replicated dim it divides.  A 1T-param MoE's (60, 384, 7168, 2048)
    expert moments go from /16 (param spec) to /256 (fully sharded).
    """
    def one(ax, av):
        spec = list(logical_to_spec(ax, av.shape, mesh, rules))
        used = set()
        for s in spec:
            used.update(s if isinstance(s, tuple) else (s,) if s else ())
        free = [a for a in mesh.axis_names if a not in used]
        free.sort(key=lambda a: -mesh.shape[a])
        for a in free:
            for i, s in enumerate(spec):
                if s is None and av.shape[i] % mesh.shape[a] == 0 and \
                        av.shape[i] >= mesh.shape[a]:
                    spec[i] = a
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, axes_tree, abstract_tree, is_leaf=_AXES_LEAF)


def batch_specs(batch_tree, mesh: Mesh, rules: dict | None = None):
    """Inputs: shard leading (batch) dim over (pod, data); scalars replicated."""
    def spec(av):
        if not hasattr(av, "shape") or len(av.shape) == 0:
            return NamedSharding(mesh, P())
        axes = ("batch",) + (None,) * (len(av.shape) - 1)
        return NamedSharding(mesh, logical_to_spec(axes, av.shape, mesh,
                                                   rules))
    return jax.tree.map(spec, batch_tree)


def make_train_step(model: Model, opt_cfg: AdamWConfig, *,
                    microbatches: int = 1, aux_weight: float = 0.01):
    """Returns train_step(params, opt_state, batch) -> (p', s', metrics)."""
    cfg = model.cfg

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            # gradient accumulation: scan over microbatch slices, one psum'd
            # backward at the end of each slice, grads accumulated in fp32.
            def slice_mb(i, t):
                mb = t.shape[0] // microbatches
                return jax.lax.dynamic_slice_in_dim(t, i * mb, mb, axis=0)

            def mb_body(acc, i):
                mb = jax.tree.map(lambda t: slice_mb(i, t), batch)
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc_g, acc_l = acc
                acc_g = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc_g, g)
                return (acc_g, acc_l + l), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g32, loss_sum), _ = jax.lax.scan(
                mb_body, (zero_g, jnp.zeros((), jnp.float32)),
                jnp.arange(microbatches))
            loss = loss_sum / microbatches
            grads = jax.tree.map(
                lambda g, p: (g / microbatches).astype(p.dtype), g32, params)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        params, opt_state = adamw_update(opt_cfg, params, grads, opt_state)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": opt_state["step"]}
        return params, opt_state, metrics

    return train_step


def make_serve_step(model: Model):
    """serve_step(params, cache, tokens, pos) -> (logits, cache')."""
    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)
    return serve_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


# ---------------------------------------------------------------------------
# Fully-assembled jitted steps with shardings (used by train.py and dryrun)
# ---------------------------------------------------------------------------

def assemble_train(model: Model, mesh: Mesh, opt_cfg: AdamWConfig, *,
                   abstract_batch: Any, rules: dict | None = None,
                   microbatches: int = 1, donate: bool = True):
    """Returns (jitted_fn, abstract_args, shardings) for train_step."""
    aparams = model.abstract_params()
    aopt = jax.eval_shape(init_opt_state, aparams)
    p_axes = model.param_axes()
    p_sh = shardings_from_axes(p_axes, aparams, mesh, rules)
    moment_sh = zero1_shardings(p_axes, aparams, mesh, rules)
    o_sh = {
        "m": moment_sh,
        "v": moment_sh,
        "step": NamedSharding(mesh, P()),
    }
    b_sh = batch_specs(abstract_batch, mesh, rules)
    step = make_train_step(model, opt_cfg, microbatches=microbatches)

    def wrapped(params, opt_state, batch):
        with use_mesh(mesh, rules):
            return step(params, opt_state, batch)

    metrics_sh = {"loss": NamedSharding(mesh, P()),
                  "grad_norm": NamedSharding(mesh, P()),
                  "step": NamedSharding(mesh, P())}
    jit_kw = dict(
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, metrics_sh),
    )
    if donate:
        jit_kw["donate_argnums"] = (0, 1)
    return jax.jit(wrapped, **jit_kw), (aparams, aopt), (p_sh, o_sh)


def assemble_serve(model: Model, mesh: Mesh, *, rules: dict | None = None,
                   donate: bool = True):
    """Returns jitted serve_step with cache donation + shardings builder."""
    aparams = model.abstract_params()
    p_sh = shardings_from_axes(model.param_axes(), aparams, mesh, rules)
    step = make_serve_step(model)

    def wrapped(params, cache, tokens, pos):
        with use_mesh(mesh, rules):
            return step(params, cache, tokens, pos)

    def cache_shardings(acache):
        c_axes = model.cache_axes()
        return jax.tree.map(
            lambda ax, av: NamedSharding(
                mesh, logical_to_spec(ax, av.shape, mesh, rules)),
            c_axes, acache, is_leaf=_AXES_LEAF)

    return wrapped, p_sh, cache_shardings
