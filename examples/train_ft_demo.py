"""Fault-tolerance demo: train, checkpoint, crash, resume — end to end.

    PYTHONPATH=src python examples/train_ft_demo.py

Trains a tiny llama3-family LM on the synthetic pipeline, simulates a node
failure mid-run (a raised exception), and shows the supervisor restoring
from the latest async checkpoint and continuing to a lower loss.
"""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data import TokenPipeline
from repro.models.zoo import Model
from repro.optim import AdamWConfig, init_opt_state, warmup_cosine
from repro.runtime.supervisor import SupervisorConfig, TrainSupervisor
from repro.runtime.train import make_train_step

STEPS = 60
cfg = dataclasses.replace(get_smoke_config("llama3-8b"), dtype="float32",
                          remat="none")
model = Model(cfg)
pipe = TokenPipeline(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=0)
opt_cfg = AdamWConfig(lr=warmup_cosine(3e-3, warmup=5, total=STEPS),
                      weight_decay=0.0)
core = jax.jit(make_train_step(model, opt_cfg))
crash = {"armed": True}


def build(ckpt):
    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    start = ckpt.latest_step() or 0
    if start:
        like = {"params": params, "opt": opt}
        restored = ckpt.restore(start, like)
        params, opt = restored["params"], restored["opt"]
        print(f"--- restored from checkpoint step {start} ---")

    def step_fn(state, i):
        if i == 25 and crash["armed"]:
            crash["armed"] = False
            raise RuntimeError("simulated node failure at step 25")
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(i).items()}
        p, o, m = core(state["params"], state["opt"], batch)
        return {"params": p, "opt": o}, m

    return {"params": params, "opt": opt}, step_fn, start


with tempfile.TemporaryDirectory() as d:
    sup = TrainSupervisor(SupervisorConfig(ckpt_dir=d, ckpt_every=10,
                                           max_restarts=2))
    sup.run(build, STEPS)
    losses = [s.loss for s in sup.stats]
    print(f"\nfirst-5 loss {np.mean(losses[:5]):.3f} -> "
          f"last-5 loss {np.mean(losses[-5:]):.3f} "
          f"(crash + restore happened mid-run; stragglers logged: "
          f"{len(sup.straggler_events)})")
    sup.ckpt.close()
assert np.mean(losses[-5:]) < np.mean(losses[:5]), "did not learn"
print("FT demo OK")
