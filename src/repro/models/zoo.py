"""Model zoo: one uniform interface over all 10 assigned architectures.

    model = Model(get_config("llama3-8b"))
    params = model.init(key)                       # real arrays
    aparams = model.abstract_params()              # ShapeDtypeStructs (dry-run)
    loss = model.loss(params, batch)
    logits, cache = model.decode_step(params, cache, tokens, pos)
    specs = model.input_specs(SHAPES["train_4k"])  # ShapeDtypeStruct stand-ins
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from . import encdec, transformer
from .common import abstract_tree, axes_tree, init_tree, is_def, tree_params


def _dtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        if cfg.family == "audio":
            self.defs = encdec.encdec_defs(cfg)
        else:
            self.defs = transformer.stack_stage_defs(cfg)

    # -- params ---------------------------------------------------------------
    def init(self, key: jax.Array, dtype=None):
        return init_tree(key, self.defs, dtype or _dtype(self.cfg))

    def abstract_params(self, dtype=None):
        return abstract_tree(self.defs, dtype or _dtype(self.cfg))

    def param_axes(self):
        return axes_tree(self.defs)

    # -- steps ------------------------------------------------------------------
    def loss(self, params, batch: dict) -> jax.Array:
        if self.cfg.family == "audio":
            return encdec.encdec_loss(self.cfg, params, batch)
        return transformer.lm_loss(self.cfg, params, batch)

    def forward(self, params, tokens, **kw):
        return transformer.forward(self.cfg, params, tokens, **kw)

    def prefill(self, params, batch: dict):
        """Prefill: returns (last-position logits or None, caches)."""
        cfg = self.cfg
        if cfg.family == "audio":
            b = batch["frames"].shape[0]
            cache = encdec.encdec_init_cache(
                cfg, b, batch["max_len"], _dtype(cfg),
                batch["frames"].shape[1])
            cache = encdec.encdec_prefill_cross(cfg, params, batch["frames"],
                                                cache)
            return None, cache
        hidden, _, caches = transformer.forward(
            cfg, params, batch["tokens"],
            img_embeds=batch.get("img_embeds"), collect_cache=True)
        last = transformer.unembed_logits(cfg, params["embed"],
                                          hidden[:, -1:])[:, 0]
        return last, caches

    def init_cache(self, batch: int, max_len: int, dtype=None,
                   n_frames: int = 0):
        cfg = self.cfg
        dtype = dtype or _dtype(cfg)
        if cfg.family == "audio":
            return encdec.encdec_init_cache(cfg, batch, max_len, dtype,
                                            n_frames)
        return transformer.init_cache(cfg, batch, max_len, dtype)

    def cache_axes(self):
        if self.cfg.family == "audio":
            return encdec.encdec_cache_axes()
        return transformer.cache_axes(self.cfg)

    def decode_step(self, params, cache, tokens, pos):
        if self.cfg.family == "audio":
            return encdec.encdec_decode_step(self.cfg, params, cache, tokens,
                                             pos)
        return transformer.decode_step(self.cfg, params, cache, tokens, pos)

    # -- shape stand-ins (dry run; no allocation) -------------------------------
    def input_specs(self, shape: ShapeConfig) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of this shape."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        dt = _dtype(cfg)
        tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if shape.kind == "train":
            specs = {"tokens": tok, "labels": jax.ShapeDtypeStruct(
                (b, s), jnp.int32)}
            if cfg.family == "audio":
                f = s // cfg.frame_ratio
                specs["frames"] = jax.ShapeDtypeStruct((b, f, cfg.d_model), dt)
            if cfg.family == "vlm":
                specs["img_embeds"] = jax.ShapeDtypeStruct(
                    (b, cfg.n_img_tokens, cfg.d_model), dt)
            return specs
        if shape.kind == "prefill":
            specs = {"tokens": tok}
            if cfg.family == "audio":
                f = s // cfg.frame_ratio
                specs = {"frames": jax.ShapeDtypeStruct((b, f, cfg.d_model),
                                                        dt),
                         "max_len": s}
            if cfg.family == "vlm":
                specs["img_embeds"] = jax.ShapeDtypeStruct(
                    (b, cfg.n_img_tokens, cfg.d_model), dt)
            return specs
        # decode: one new token against a seq_len KV cache
        max_len = s + (cfg.n_img_tokens if cfg.family == "vlm" else 0)
        n_frames = s // cfg.frame_ratio if cfg.family == "audio" else 0
        cache = jax.eval_shape(
            lambda: self.init_cache(b, max_len, n_frames=n_frames))
        return {
            "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
            "cache": cache,
        }


# ---------------------------------------------------------------------------
# Analytic parameter counts (roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------

def _count(defs, path=()) -> tuple[int, int, int]:
    """returns (total, routed_expert, embed_table) param counts."""
    if is_def(defs):
        n = int(np.prod(defs.shape))
        routed = n if any(p == "experts" for p in path) else 0
        table = n if path and path[-1] == "table" else 0
        return n, routed, table
    total = routed = table = 0
    if isinstance(defs, dict):
        items = defs.items()
    elif isinstance(defs, (list, tuple)):
        items = enumerate(defs)
    else:
        return 0, 0, 0
    for k, v in items:
        t, r, e = _count(v, path + (str(k),))
        total, routed, table = total + t, routed + r, table + e
    return total, routed, table


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    m = Model(cfg)
    total, routed, _ = _count(m.defs)
    if active_only and cfg.n_experts:
        active_routed = routed * cfg.top_k / cfg.n_experts
        return int(total - routed + active_routed)
    return total


def matmul_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Params participating in matmuls (excludes the embed *lookup* table,
    which moves bytes, not FLOPs — unless tied, where it is also the
    unembedding projection)."""
    m = Model(cfg)
    total, routed, table = _count(m.defs)
    n = total if cfg.tie_embeddings else total - table
    if active_only and cfg.n_experts:
        n = n - routed + int(routed * cfg.top_k / cfg.n_experts)
    return n


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6·N·D (train) or 2·N·D (fwd-only), N active for MoE."""
    n = matmul_params(cfg, active_only=True)
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    d = shape.global_batch * 1          # decode: one token per sequence
    return 2.0 * n * d
