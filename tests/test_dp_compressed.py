"""int8-compressed cross-pod DP vs exact DP (subprocess, 4 fake devices)."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

CODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys; sys.path.insert(0, %r)
    import dataclasses, jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_smoke_config
    from repro.data import TokenPipeline
    from repro.models.zoo import Model
    from repro.optim import AdamWConfig, init_opt_state
    from repro.runtime.dp_compressed import (make_compressed_dp_step,
                                             init_residuals)

    cfg = dataclasses.replace(get_smoke_config("llama3-8b"),
                              dtype="float32", remat="none")
    model = Model(cfg)
    mesh = jax.make_mesh((4,), ("data",))
    opt_cfg = AdamWConfig(lr=1e-3, weight_decay=0.0)
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=16, global_batch=8,
                         seed=0)

    params0 = model.init(jax.random.PRNGKey(0))
    exact = jax.jit(make_compressed_dp_step(model, opt_cfg, mesh,
                                            compress=False))
    comp = jax.jit(make_compressed_dp_step(model, opt_cfg, mesh,
                                           compress=True))

    pe = pc = params0
    oe = init_opt_state(params0)
    oc = init_opt_state(params0)
    res = init_residuals(params0)
    losses_e, losses_c = [], []
    for i in range(24):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(i).items()}
        pe, oe, _, me = exact(pe, oe, init_residuals(params0), batch)
        pc, oc, res, mc = comp(pc, oc, res, batch)
        losses_e.append(float(me["loss"]))
        losses_c.append(float(mc["loss"]))

    # losses track closely; params drift stays bounded (error feedback)
    diffs = [abs(a - b) for a, b in zip(losses_e, losses_c)]
    assert max(diffs) < 0.05, diffs
    drift = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(pe), jax.tree.leaves(pc)))
    assert drift < 0.05, drift
    # both learn (windowed means: single-step compares are noise-prone)
    assert sum(losses_c[-4:]) / 4 < sum(losses_c[:4]) / 4 - 0.3
    print("OK", max(diffs), drift)
""") % REPO


def test_compressed_dp_matches_exact():
    r = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                       text=True, timeout=420)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
