"""Table 5 — the paper's application-derived G/S pattern database.

Every pattern from Appendix A, verbatim: PENNANT (hydro), LULESH (shock
hydrodynamics), Nekbone (spectral elements), AMG (algebraic multigrid).
Counts are not given in Table 5; the paper's experimental setup (§4) sizes
app-pattern runs to read/write >= 2 GB, so ``count`` below is chosen per
pattern to move ~2**25 useful elements (~256 MB of doubles) by default and is
scalable via ``scale_counts``.
"""
from __future__ import annotations

from .pattern import Pattern

_TARGET_ELEMENTS = 2 ** 25  # useful elements per pattern at scale=1.0


def _p(name: str, kind: str, index: list[int], delta: int) -> Pattern:
    count = max(1, _TARGET_ELEMENTS // len(index))
    return Pattern(name=name, kind=kind, index=tuple(index), delta=delta,
                   count=count, source=name.split("-")[0])


# --- Gather patterns (Table 5, upper block) --------------------------------
PENNANT_GATHERS = [
    _p("PENNANT-G0", "gather", [2, 484, 482, 0, 4, 486, 484, 2, 6, 488, 486, 4, 8, 490, 488, 6], 2),
    _p("PENNANT-G1", "gather", [0, 2, 484, 482, 2, 4, 486, 484, 4, 6, 488, 486, 6, 8, 490, 488], 2),
    _p("PENNANT-G2", "gather", [0, 4, 8, 12, 16, 20, 24, 28, 32, 36, 40, 44, 48, 52, 56, 60], 2),
    _p("PENNANT-G3", "gather", [4, 8, 12, 0, 20, 24, 28, 16, 36, 40, 44, 32, 52, 56, 60, 48], 2),
    _p("PENNANT-G4", "gather", [0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3], 4),
    _p("PENNANT-G5", "gather", [4, 8, 12, 0, 20, 24, 28, 16, 36, 40, 44, 32, 52, 56, 60, 48], 4),
    _p("PENNANT-G6", "gather", [482, 0, 2, 484, 484, 2, 4, 486, 486, 4, 6, 488, 488, 6, 8, 490], 480),
    _p("PENNANT-G7", "gather", [482, 0, 2, 484, 484, 2, 4, 486, 486, 4, 6, 488, 488, 6, 8, 490], 482),
    _p("PENNANT-G8", "gather", [2, 0, 0, 0, 2, 0, 0, 0, 2, 0, 0, 2, 0, 0, 0], 129608),
    _p("PENNANT-G9", "gather", [0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3], 388852),
    _p("PENNANT-G10", "gather", [0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3], 388848),
    _p("PENNANT-G11", "gather", [0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3], 388848),
    _p("PENNANT-G12", "gather", [6, 0, 2, 4, 14, 8, 10, 12, 22, 16, 18, 20, 30, 24, 26, 28], 518408),
    _p("PENNANT-G13", "gather", [6, 0, 2, 4, 14, 8, 10, 12, 22, 16, 18, 20, 30, 24, 26, 28], 518408),
    _p("PENNANT-G14", "gather", [6, 0, 2, 4, 14, 8, 10, 12, 22, 16, 18, 20, 30, 24, 26, 28], 1036816),
    _p("PENNANT-G15", "gather", [0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3], 1882384),
]

LULESH_GATHERS = [
    _p("LULESH-G0", "gather", list(range(16)), 1),
    _p("LULESH-G1", "gather", list(range(16)), 8),
    _p("LULESH-G2", "gather", [8 * i for i in range(16)], 1),
    _p("LULESH-G3", "gather", [24 * i for i in range(16)], 8),
    _p("LULESH-G4", "gather", [24 * i for i in range(16)], 4),
    _p("LULESH-G5", "gather", [24 * i for i in range(16)], 1),
    _p("LULESH-G6", "gather", [24 * i for i in range(16)], 8),
    _p("LULESH-G7", "gather", list(range(16)), 41),
]

NEKBONE_GATHERS = [
    _p("NEKBONE-G0", "gather", [6 * i for i in range(16)], 3),
    _p("NEKBONE-G1", "gather", [6 * i for i in range(16)], 8),
    _p("NEKBONE-G2", "gather", [6 * i for i in range(16)], 8),
]

AMG_GATHERS = [
    _p("AMG-G0", "gather",
       [1333, 0, 1, 36, 37, 72, 73, 1296, 1297, 1332, 1368, 1369, 2592, 2593, 2628, 2629], 1),
    _p("AMG-G1", "gather",
       [1333, 0, 1, 2, 36, 37, 38, 72, 73, 74, 1296, 1297, 1298, 1332, 1334, 1368], 1),
]

# --- Scatter patterns (Table 5, lower block) -------------------------------
PENNANT_SCATTERS = [
    _p("PENNANT-S0", "scatter", [0, 4, 8, 12, 16, 20, 24, 28, 32, 36, 40, 44, 48, 52, 56, 60], 1),
]

LULESH_SCATTERS = [
    _p("LULESH-S0", "scatter", [8 * i for i in range(16)], 1),
    _p("LULESH-S1", "scatter", [24 * i for i in range(16)], 8),
    _p("LULESH-S2", "scatter", [24 * i for i in range(16)], 1),
    # LULESH-S3: the delta-0 broadcast scatter discussed at length in §5.4
    # (cache-invalidation pathology; only TX2 handles it well).  Table 5 omits
    # its row but §5.4.1/§5.4.2 define it: a scatter with delta 0.
    _p("LULESH-S3", "scatter", list(range(16)), 0),
]

ALL_GATHERS = PENNANT_GATHERS + LULESH_GATHERS + NEKBONE_GATHERS + AMG_GATHERS
ALL_SCATTERS = PENNANT_SCATTERS + LULESH_SCATTERS
ALL_PATTERNS = ALL_GATHERS + ALL_SCATTERS

BY_APP: dict[str, list[Pattern]] = {}
for _pat in ALL_PATTERNS:
    BY_APP.setdefault(_pat.source, []).append(_pat)


def get(name: str) -> Pattern:
    for p in ALL_PATTERNS:
        if p.name == name:
            return p
    raise KeyError(name)


def scale_counts(patterns: list[Pattern], scale: float,
                 max_footprint: int = 1 << 27) -> list[Pattern]:
    """Scale every pattern's count (e.g. tiny counts for CPU-container runs).

    Counts are additionally capped so the sparse buffer stays below
    ``max_footprint`` elements (PENNANT's delta-1.8M patterns would exceed
    int32 indexing at full count on a scaled-down host).
    """
    out = []
    for p in patterns:
        count = max(1, int(p.count * scale))
        if p.delta > 0:
            count = min(count, max(1, (max_footprint - p.span) // p.delta))
        out.append(Pattern(name=p.name, kind=p.kind, index=p.index,
                           delta=p.delta, count=count, source=p.source))
    return out
