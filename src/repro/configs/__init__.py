"""Architecture configs. One module per assigned architecture.

``get_config(arch_id)`` returns the full published config;
``get_smoke_config(arch_id)`` returns a reduced same-family config for
CPU smoke tests (small layers/width/experts/vocab).
"""
from .base import ModelConfig, SHAPES, ShapeConfig, get_config, \
    get_smoke_config, ARCH_IDS, shape_skips

__all__ = ["ModelConfig", "SHAPES", "ShapeConfig", "get_config",
           "get_smoke_config", "ARCH_IDS", "shape_skips"]
